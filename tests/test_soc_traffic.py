"""Continuous-traffic serving (repro.soc.traffic + vecenv.ServeEnv).

Pins the subsystem's load-bearing contracts:

  * arrival tables are pre-sampled from the spec's OWN key (the
    ``SelectNoise``/``StepFault`` pattern): monotone clocks, tenant mix,
    chunk continuation, and an offered-load sweep that never retraces;
  * ``traffic=None`` is the episodic path, bitwise, fused and unfused;
  * admission is bounded: queue depth never exceeds ``queue_cap``,
    shed + served == offered, retries stay within the backoff budget;
  * deadline shedding is deterministic under a fixed key and responds
    monotonically to the deadline budget;
  * traffic composes with the PR-7 fault subsystem, and the Pallas
    serving kernel (interpret mode) is bitwise-equal to the reference
    scan with and without a storm;
  * multi-chunk serving is crash-resumable bitwise
    (``serve_checkpointed``, the ``train_batched_checkpointed`` kill
    tests re-aimed at an open stream).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode
from repro.soc import faults, traffic, vecenv
from repro.soc.apps import make_phase
from repro.soc.config import SOC1
from repro.soc.des import Application, SoCSimulator

TILE_SEED = 7
N_REQ = 64
QUEUE_CAP = 4


def _chain_app(soc, seed, n_threads=1):
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=n_threads,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L"))
    ]
    return Application(name=f"{soc.name}-serve{seed}", phases=phases)


def _tree_bitwise(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


@pytest.fixture(scope="module")
def setting():
    soc = SOC1
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    compiled = vecenv.compile_app(_chain_app(soc, 0), soc, seed=TILE_SEED)
    serve_env = vecenv.ServeEnv(env, queue_cap=QUEUE_CAP, n_requests=N_REQ)
    cfg = qlearn.QConfig()
    return sim, env, serve_env, compiled, cfg


@pytest.fixture(scope="module")
def calib(setting):
    """Mean service time from a near-idle probe; load rates derive from
    it so the overload tests saturate on any timing model."""
    _, env, serve_env, compiled, cfg = setting
    spec = env.lower(compiled, "fixed",
                     fixed_modes=CoherenceMode.NON_COH_DMA)
    _, _, res = serve_env.serve(compiled, spec, _tspec(rate=1e-9),
                                cfg=cfg)
    ex = np.asarray(res.executed)
    mean_exec = float(np.asarray(res.exec_time)[ex].mean())
    return mean_exec, env.soc.n_accs / mean_exec   # (mean_exec, cap_rate)


def _tspec(rate=2e-6, deadline=5e5, backoff=5e4, seed=11, **kw):
    return traffic.poisson(rate, deadline=deadline, backoff=backoff,
                           seed=seed, **kw)


# ------------------------------------------------------------ arrival tables
def test_arrivals_monotone_and_tenant_mix():
    spec = traffic.bursty(1e-5, mix=(0.8, 0.2), deadline=(1e5, 0.0),
                          priority=(1.0, 0.25), seed=3)
    arr = traffic.sample_arrivals(spec, 512, 30)
    t = np.asarray(arr.t_arr)
    assert np.all(np.diff(t) >= 0) and t[0] > 0
    ten = np.asarray(arr.tenant)
    frac = (ten == 0).mean()
    assert 0.6 < frac < 0.95            # ~0.8 mix, finite-sample slack
    # tenant 1 has no deadline: the sentinel, not t_arr + 0
    dl = np.asarray(arr.deadline)
    assert np.all(dl[ten == 1] > 1e29)
    assert np.all(dl[ten == 0] == t[ten == 0] + np.float32(1e5))
    assert np.all((np.asarray(arr.row) >= 0) & (np.asarray(arr.row) < 30))


def test_arrivals_chunk_key_continues_clock():
    spec = _tspec()
    a0 = traffic.sample_arrivals(spec, 32, 9)
    a1 = traffic.sample_arrivals(traffic.chunk_key(spec, 1), 32, 9,
                                 t0=a0.t_arr[-1])
    assert float(a1.t_arr[0]) >= float(a0.t_arr[-1])
    # distinct chunk keys: the second chunk is not a replay of the first
    assert not np.array_equal(np.asarray(a0.row), np.asarray(a1.row))


def test_rate_sweep_does_not_retrace(setting):
    _, env, _, compiled, cfg = setting
    spec = env.lower(compiled, "fixed",
                     fixed_modes=CoherenceMode.NON_COH_DMA)
    # fresh ServeEnv: the jit cache starts empty, so the count below is
    # exactly this sweep's
    serve_env = vecenv.ServeEnv(env, queue_cap=QUEUE_CAP, n_requests=N_REQ)
    fn, _ = serve_env._serve_fn(N_REQ)
    for rate, dl in [(1e-6, 5e5), (4e-6, 2e5), (8e-6, 1e5)]:
        serve_env.serve(compiled, spec, _tspec(rate=rate, deadline=dl),
                        cfg=cfg)
    assert fn._cache_size() == 1


# ------------------------------------------------------- episodic identity
@pytest.mark.parametrize("fused", [True, False])
def test_traffic_none_is_episodic_bitwise(setting, fused):
    sim, _, _, compiled, cfg = setting
    env = vecenv.VecEnv.from_simulator(sim, fused_step=fused)
    serve_env = vecenv.ServeEnv(env, queue_cap=QUEUE_CAP, n_requests=N_REQ)
    spec = env.lower(compiled, "q")
    key = jax.random.PRNGKey(5)
    out_a = serve_env.serve(compiled, spec, None, cfg=cfg, key=key)
    out_b = env.episode_spec(compiled, spec, cfg=cfg, key=key)
    _tree_bitwise(out_a, out_b)


# ------------------------------------------------------- admission bounds
def test_queue_bounds_and_conservation(setting, calib):
    _, env, serve_env, compiled, cfg = setting
    mean_exec, cap_rate = calib
    spec = env.lower(compiled, "fixed",
                     fixed_modes=CoherenceMode.NON_COH_DMA)
    # hot load: 5x capacity with a tight deadline so queues saturate and
    # shedding engages
    _, _, res = serve_env.serve(
        compiled, spec,
        _tspec(rate=5.0 * cap_rate, deadline=2.0 * mean_exec,
               backoff=0.1 * mean_exec), cfg=cfg)
    ex = np.asarray(res.executed)
    assert int(ex.sum()) + int((~ex).sum()) == N_REQ
    assert 0 < int(ex.sum()) < N_REQ      # some served, some shed
    depth = np.asarray(res.depth)
    assert np.all(depth <= QUEUE_CAP)     # ring never overflows
    retries = np.asarray(res.retries)
    assert np.all(retries[ex] <= 3)       # admitted within the budget
    assert np.all(retries[~ex] == 4)      # shed marker
    assert np.all(np.asarray(res.mode)[~ex] == -1)
    assert np.all(np.asarray(res.latency)[~ex] == 0)
    fin = np.asarray(res.finish)[ex]
    start = np.asarray(res.start)[ex]
    assert np.all(fin > start)


def test_deadline_shedding_deterministic_and_monotone(setting, calib):
    _, env, serve_env, compiled, cfg = setting
    mean_exec, cap_rate = calib
    spec = env.lower(compiled, "fixed",
                     fixed_modes=CoherenceMode.NON_COH_DMA)
    key = jax.random.PRNGKey(2)
    run = lambda dl: serve_env.serve(
        compiled, spec, _tspec(rate=3.0 * cap_rate, deadline=dl),
        cfg=cfg, key=key)
    out_a, out_b = run(2.0 * mean_exec), run(2.0 * mean_exec)
    _tree_bitwise(out_a, out_b)           # fixed key -> bitwise replay
    shed_tight = int((~np.asarray(run(0.5 * mean_exec)[2].executed)).sum())
    shed_loose = int((~np.asarray(run(1e3 * mean_exec)[2].executed)).sum())
    assert shed_tight > shed_loose


# ------------------------------------------------------ faults composition
def test_traffic_composes_with_fault_storm(setting):
    _, env, serve_env, compiled, cfg = setting
    spec = env.lower(compiled, "q")
    fs = faults.storm(N_REQ, 0.7, jax.random.PRNGKey(42))
    carry, qs, res = serve_env.serve(compiled, spec, _tspec(), cfg=cfg,
                                     faults=fs)
    ex = np.asarray(res.executed)
    assert 0 < int(ex.sum()) <= N_REQ
    assert np.isfinite(np.asarray(res.reward)[ex]).all()
    # the storm must actually change the outcome vs a healthy stream
    _, _, healthy = serve_env.serve(compiled, spec, _tspec(), cfg=cfg)
    assert not np.array_equal(np.asarray(res.exec_time),
                              np.asarray(healthy.exec_time))


@pytest.mark.parametrize("faulted", [False, True])
def test_serve_kernel_matches_ref(setting, faulted):
    """Pallas serving kernel (interpret) bitwise vs the reference scan,
    healthy and mid-storm."""
    from repro.kernels.soc_step import ops as soc_step_ops

    _, env, serve_env, compiled, cfg = setting
    spec = env.lower(compiled, "q")
    fs = (faults.storm(N_REQ, 0.7, jax.random.PRNGKey(42))
          if faulted else None)
    base = vecenv.build_serve_fn(N_REQ, QUEUE_CAP, fused=True)
    args = (env.params, compiled.schedule, spec, cfg,
            rewards.PAPER_DEFAULT_WEIGHTS, _tspec(),
            None, jax.random.PRNGKey(0), jnp.zeros((), jnp.float32), fs)

    orig = soc_step_ops.fused_serve_episode
    calls = {}

    def spy(*a, **kw):
        calls["ref"] = orig(*a, **{**kw, "kernel": False})
        calls["ker"] = orig(*a, **{**kw, "kernel": True, "interpret": True})
        return calls["ker"]

    soc_step_ops.fused_serve_episode = spy
    try:
        base(*args)
    finally:
        soc_step_ops.fused_serve_episode = orig
    _tree_bitwise(calls["ref"], calls["ker"])


# ------------------------------------------------------------ checkpointing
class _Killer:
    """Simulated crash: dies (before writing) after N successful saves."""

    def __init__(self, inner: CheckpointManager, die_after: int):
        self._inner, self._left = inner, die_after

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def save(self, step, tree):
        if self._left <= 0:
            raise KeyboardInterrupt("simulated crash")
        self._left -= 1
        self._inner.save(step, tree)
        self._inner.wait()


def _monolithic_stream(serve_env, compiled, spec, cfg, tspec, key,
                       n_chunks):
    """The uninterrupted reference: chain chunks by hand."""
    carry, qs, t0 = None, spec.qstate, jnp.zeros((), jnp.float32)
    outs = []
    for i in range(n_chunks):
        carry, qs, res = serve_env.serve(
            compiled, spec._replace(qstate=qs),
            traffic.chunk_key(tspec, i), cfg=cfg,
            key=jax.random.fold_in(key, i), carry=carry, t0=t0)
        outs.append(res)
        t0 = res.t_arr[-1]
    flat = jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *outs)
    return carry, qs, flat


def test_serve_checkpointed_matches_monolithic(setting, tmp_path):
    _, env, serve_env, compiled, cfg = setting
    spec = env.lower(compiled, "q")
    tspec, key = _tspec(), jax.random.PRNGKey(8)
    ref = _monolithic_stream(serve_env, compiled, spec, cfg, tspec, key, 3)
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    got = serve_env.serve_checkpointed(compiled, spec, tspec, mgr,
                                       n_chunks=3, cfg=cfg, key=key)
    _tree_bitwise(ref, got)
    assert mgr.latest_step() == 3


@pytest.mark.parametrize("die_after", [1, 2])
def test_serve_kill_and_resume_bitwise(setting, tmp_path, die_after):
    """Mid-stream crash + restart restores the carry, clock and Q-state
    bitwise — the serving analogue of the training kill tests."""
    _, env, serve_env, compiled, cfg = setting
    spec = env.lower(compiled, "q")
    tspec, key = _tspec(), jax.random.PRNGKey(8)
    ref = _monolithic_stream(serve_env, compiled, spec, cfg, tspec, key, 3)
    ckdir = str(tmp_path / f"kill{die_after}")
    with pytest.raises(KeyboardInterrupt):
        serve_env.serve_checkpointed(
            compiled, spec, tspec, _Killer(CheckpointManager(ckdir),
                                           die_after),
            n_chunks=3, cfg=cfg, key=key)
    mgr2 = CheckpointManager(ckdir)
    assert mgr2.latest_step() == die_after
    got = serve_env.serve_checkpointed(compiled, spec, tspec, mgr2,
                                       n_chunks=3, cfg=cfg, key=key)
    _tree_bitwise(ref, got)


# ----------------------------------------------------------- DES fidelity
def test_des_serving_mirror_agrees(setting):
    """Vectorized serving vs SoCSimulator.serve on the SAME arrival
    table: identical admission decisions, latencies to float tolerance."""
    from repro.core.policies import FixedHomogeneous

    sim, env, serve_env, compiled, cfg = setting
    mode = CoherenceMode.NON_COH_DMA
    spec = env.lower(compiled, "fixed", fixed_modes=mode)
    tspec = _tspec(rate=2e-5, deadline=3e5)
    _, _, res = serve_env.serve(compiled, spec, tspec, cfg=cfg)
    arr = traffic.sample_arrivals(tspec, N_REQ,
                                  compiled.schedule.acc_id.shape[0])
    des = sim.serve(compiled.schedule, FixedHomogeneous(mode), arr,
                    queue_cap=QUEUE_CAP, backoff=float(tspec.backoff))
    v_ex = np.asarray(res.executed)
    d_ex = np.array([r["executed"] for r in des])
    np.testing.assert_array_equal(v_ex, d_ex)
    v_lat = np.asarray(res.latency)[v_ex]
    d_lat = np.array([r["latency"] for r in des])[v_ex]
    np.testing.assert_allclose(v_lat, d_lat, rtol=1e-4)


# -------------------------------------------------------------- stacked
def test_stacked_serve_shapes_and_bounds():
    from benchmarks.fig9_socs import SOC_FLAVORS
    from repro.soc.config import SOCS
    from repro.soc.stacked import StackedVecEnv

    sims = [SoCSimulator(SOCS[n], seed=1, flavor=f)
            for n, f in SOC_FLAVORS[:2]]
    env = StackedVecEnv.from_simulators(sims)
    apps = [_chain_app(s.soc, i, n_threads=1 + i)
            for i, s in enumerate(sims)]
    from repro.core.policies import FixedHomogeneous

    stacked = env.compile(apps, seed=0)
    specs = env.lower(stacked, [FixedHomogeneous(CoherenceMode.NON_COH_DMA),
                                FixedHomogeneous(CoherenceMode.FULLY_COH)])
    _, _, res = env.serve(stacked, specs, _tspec(rate=1e-5),
                          queue_cap=QUEUE_CAP, n_requests=32)
    assert res.executed.shape == (2, 2, 32)
    ex = np.asarray(res.executed)
    assert np.all(np.asarray(res.depth) <= QUEUE_CAP)
    # padding rows (valid=False tails) are never invoked: every served
    # request's state index is a real row's
    assert np.all(np.asarray(res.state_idx)[ex] >= 0)
    assert np.isfinite(np.asarray(res.latency)).all()
