"""Deeper model-semantics properties: sliding windows, softcap, MoE
padding, M-RoPE, musicgen codebooks, remat equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: install the [test] extra
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.models import init_params, loss_fn
from repro.models.transformer import forward, lm_logits
from repro.models import mlp as mlp_mod


def _tokens(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.n_codebooks:
        return jnp.asarray(rng.integers(0, cfg.vocab, (B, cfg.n_codebooks, S)),
                           jnp.int32)
    return jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)


def test_sliding_window_locality():
    """Tokens beyond every layer's reach must not affect late logits.

    A local-attention-only stack with window w and L layers has receptive
    field L*w; perturbing a token further back than that must leave the
    last-position logits unchanged."""
    cfg = smoke_config("gemma2-9b").replace(
        global_every=-1, sliding_window=4, n_layers=2)  # all-local, reach 8
    params = init_params(cfg, jax.random.PRNGKey(0))
    S = 32
    toks = _tokens(cfg, 1, S)
    h, _ = forward(cfg, params, {"tokens": toks})
    base = lm_logits(cfg, params, h)[:, -1]

    # perturb position S-1-16 (beyond reach 8 from the last token)
    toks2 = toks.at[0, S - 1 - 16].set((toks[0, S - 1 - 16] + 1) % cfg.vocab)
    h2, _ = forward(cfg, params, {"tokens": toks2})
    pert = lm_logits(cfg, params, h2)[:, -1]
    np.testing.assert_allclose(np.asarray(base), np.asarray(pert),
                               rtol=1e-5, atol=1e-5)

    # sanity: perturbing within the window DOES change the logits
    toks3 = toks.at[0, S - 2].set((toks[0, S - 2] + 1) % cfg.vocab)
    h3, _ = forward(cfg, params, {"tokens": toks3})
    assert float(jnp.max(jnp.abs(
        lm_logits(cfg, params, h3)[:, -1] - base))) > 1e-6


def test_global_layers_see_everything():
    """With alternating local/global (gemma2 pattern), distant tokens DO
    reach the last position through the global layers."""
    cfg = smoke_config("gemma2-9b")     # global_every=2, window=8
    params = init_params(cfg, jax.random.PRNGKey(0))
    S = 32
    toks = _tokens(cfg, 1, S)
    h, _ = forward(cfg, params, {"tokens": toks})
    base = lm_logits(cfg, params, h)[:, -1]
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    h2, _ = forward(cfg, params, {"tokens": toks2})
    assert float(jnp.max(jnp.abs(
        lm_logits(cfg, params, h2)[:, -1] - base))) > 1e-7


def test_attn_softcap_bounds_logits():
    from repro.models.common import softcap
    x = jnp.linspace(-1e4, 1e4, 101)
    y = softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


def test_moe_padded_experts_never_selected():
    cfg = smoke_config("granite-moe-3b-a800m").replace(
        n_experts=3, expert_pad_to=8, top_k=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"])
    moe_p = p["l0_attn_global"]["moe"]
    assert moe_p.router.shape == (cfg.d_model, 8)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    out, aux = mlp_mod.moe(cfg, moe_p, x)
    # run router manually: chosen experts must be < n_experts
    logits = x.reshape(-1, cfg.d_model) @ moe_p.router
    logits = jnp.where(jnp.arange(8)[None] >= 3, -1e30, logits)
    _, ids = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    assert int(jnp.max(ids)) < 3


def test_moe_drop_frac_reported():
    cfg = smoke_config("granite-moe-3b-a800m").replace(capacity_factor=0.25)
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"])
    x = jnp.ones((2, 32, cfg.d_model), jnp.float32)   # all tokens identical
    _, aux = mlp_mod.moe(cfg, p["l0_attn_global"]["moe"], x)
    # identical tokens all route to the same experts -> heavy drops
    assert float(aux["drop_frac"]) > 0.2


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50))
def test_remat_equivalence(seed):
    """Property: remat policies change memory, never math."""
    base = smoke_config("qwen3-8b")
    toks = _tokens(base, 2, 16, seed)
    params = init_params(base, jax.random.PRNGKey(0))
    outs = []
    for remat in ("none", "dots", "full"):
        cfg = base.replace(remat=remat)
        loss, _ = loss_fn(cfg, params, {"tokens": toks, "labels": toks})
        outs.append(float(loss))
    assert abs(outs[0] - outs[1]) < 1e-5
    assert abs(outs[0] - outs[2]) < 1e-5


def test_mrope_sections_rotate_independently():
    from repro.models.common import apply_mrope, apply_rope
    B, S, H, hd = 1, 8, 2, 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    pos = jnp.tile(jnp.arange(S)[None, :], (B, 1))
    # all three streams equal -> must match plain rope
    p3 = jnp.stack([pos, pos, pos])
    out = apply_mrope(x, p3, (4, 2, 2))
    ref = apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # differing h/w streams must diverge from plain rope
    p3b = jnp.stack([pos, pos * 2, pos * 3])
    out2 = apply_mrope(x, p3b, (4, 2, 2))
    assert float(jnp.max(jnp.abs(out2 - ref))) > 1e-4


def test_musicgen_codebooks_independent_heads():
    cfg = smoke_config("musicgen-large")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = _tokens(cfg, 1, 8)
    h, _ = forward(cfg, params, {"tokens": toks})
    logits = lm_logits(cfg, params, h)
    assert logits.shape == (1, cfg.n_codebooks, 8, cfg.vocab)
    # heads differ (independent per-codebook projections)
    assert float(jnp.max(jnp.abs(logits[:, 0] - logits[:, 1]))) > 1e-6


def test_scan_vs_unrolled_equivalence():
    cfg = smoke_config("recurrentgemma-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = _tokens(cfg, 2, 12)
    h1, _ = forward(cfg, params, {"tokens": toks})
    h2, _ = forward(cfg.replace(scan_layers=False), params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-5, atol=2e-5)
