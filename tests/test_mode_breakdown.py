"""Unit coverage for orchestrator.mode_breakdown (Fig. 7 bucketing).

Hand-built RunResults pin the S/M/L/XL size-class edges (<=L2, <=LLC slice,
<=aggregate LLC, beyond) and the per-bucket normalization.
"""
import numpy as np

from repro.core.modes import CoherenceMode, N_MODES
from repro.core.orchestrator import mode_breakdown
from repro.soc.config import SOC_MOTIV_ISO
from repro.soc.des import InvocationRecord, PhaseResult, RunResult

SOC = SOC_MOTIV_ISO   # l2=32KB, llc_slice=512KB, 2 tiles -> llc_total=1MB


def _rec(footprint, mode):
    return InvocationRecord(
        acc_id=0, acc_name="fft", footprint=float(footprint), mode=int(mode),
        state_idx=0, start=0.0, end=1.0, exec_time=1.0,
        offchip_true=0.0, offchip_attr=0.0, reward=0.0)


def _run(records):
    return RunResult(
        policy="test",
        phases=[PhaseResult(name="p0", wall_time=1.0, offchip_accesses=0.0,
                            invocations=list(records))],
        decide_overhead_s=0.0)


def test_size_class_edges():
    """Footprints exactly at a capacity boundary land in the lower class."""
    res = _run([
        _rec(SOC.l2_bytes, CoherenceMode.FULLY_COH),           # S (== L2)
        _rec(SOC.l2_bytes + 1, CoherenceMode.COH_DMA),         # M
        _rec(SOC.llc_slice_bytes, CoherenceMode.COH_DMA),      # M (== slice)
        _rec(SOC.llc_total_bytes, CoherenceMode.LLC_COH_DMA),  # L (== LLC)
        _rec(SOC.llc_total_bytes + 1, CoherenceMode.NON_COH_DMA),  # XL
    ])
    bd = mode_breakdown(res, SOC)
    assert bd["S"][CoherenceMode.FULLY_COH] == 1.0
    assert bd["M"][CoherenceMode.COH_DMA] == 1.0
    assert bd["L"][CoherenceMode.LLC_COH_DMA] == 1.0
    assert bd["XL"][CoherenceMode.NON_COH_DMA] == 1.0


def test_fractions_normalized_per_bucket():
    res = _run(
        [_rec(1024, CoherenceMode.FULLY_COH)] * 3
        + [_rec(1024, CoherenceMode.COH_DMA)]
        + [_rec(16 << 20, CoherenceMode.NON_COH_DMA)] * 2
    )
    bd = mode_breakdown(res, SOC)
    np.testing.assert_allclose(bd["S"][CoherenceMode.FULLY_COH], 0.75)
    np.testing.assert_allclose(bd["S"][CoherenceMode.COH_DMA], 0.25)
    np.testing.assert_allclose(bd["XL"][CoherenceMode.NON_COH_DMA], 1.0)
    # totals mix both buckets: 3/6, 1/6, 2/6
    np.testing.assert_allclose(
        bd["total"],
        np.array([2, 0, 1, 3]) / 6.0)
    for k in ("total", "S", "XL"):
        np.testing.assert_allclose(bd[k].sum(), 1.0)


def test_empty_buckets_stay_zero():
    res = _run([_rec(1024, CoherenceMode.FULLY_COH)])
    bd = mode_breakdown(res, SOC)
    assert bd["M"].sum() == 0.0
    assert bd["L"].sum() == 0.0
    assert bd["XL"].sum() == 0.0
    assert bd["total"].shape == (N_MODES,)
