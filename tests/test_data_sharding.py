"""Data pipeline determinism + sharding-rule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: install the [test] extra
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import PrefetchIterator
from repro.data.synthetic import (DataConfig, apply_delay_pattern,
                                  batch_iterator, host_batch)
from repro.distributed import sharding as shd


# ------------------------------------------------------------------ data --
def test_host_batch_deterministic_and_restartable():
    cfg = smoke_config("qwen3-8b")
    dc = DataConfig(seq_len=32, global_batch=8, seed=3)
    a = host_batch(cfg, dc, step=17)
    b = host_batch(cfg, dc, step=17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = host_batch(cfg, dc, step=18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_batch_shards_disjoint_across_hosts():
    cfg = smoke_config("qwen3-8b")
    dc = DataConfig(seq_len=16, global_batch=8, seed=0)
    h0 = host_batch(cfg, dc, step=0, host=0, n_hosts=2)
    h1 = host_batch(cfg, dc, step=0, host=1, n_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = smoke_config("yi-34b")
    dc = DataConfig(seq_len=16, global_batch=2, seed=1)
    b = host_batch(cfg, dc, step=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_within_vocab_all_archs():
    for arch in ("granite-moe-3b-a800m", "musicgen-large", "qwen2-vl-2b"):
        cfg = get_arch(arch)
        b = host_batch(cfg, DataConfig(8, 2, seed=0), 0)
        assert b["tokens"].max() < cfg.vocab
        assert b["tokens"].min() >= 0


def test_delay_pattern():
    t = np.arange(2 * 3 * 5).reshape(2, 3, 5)
    out = apply_delay_pattern(t, pad_id=-7)
    np.testing.assert_array_equal(out[:, 0], t[:, 0])       # k=0 unshifted
    assert np.all(out[:, 1, 0] == -7)                       # k=1 shifted by 1
    np.testing.assert_array_equal(out[:, 1, 1:], t[:, 1, :4])
    assert np.all(out[:, 2, :2] == -7)


def test_prefetch_iterator_preserves_order():
    cfg = smoke_config("qwen3-8b")
    it = PrefetchIterator(
        batch_iterator(cfg, DataConfig(8, 2, seed=0)), depth=2)
    ref = batch_iterator(cfg, DataConfig(8, 2, seed=0))
    for _ in range(5):
        a, b = next(it), next(ref)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), b["tokens"])


# -------------------------------------------------------------- sharding --
def _mesh44():
    devs = np.asarray(jax.devices()[:1])
    # 1-device mesh shaped (1, 1) — rule logic is shape-driven, not
    # device-count-driven, so this exercises the spec construction.
    return Mesh(devs.reshape(1, 1), ("data", "model"))


def test_param_rules_embed_vocab_on_model():
    mesh = _mesh44()
    specs = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["transformer"])
        .transformer.init_params(smoke_config("qwen3-8b"),
                                 jax.random.PRNGKey(0)))
    sh = shd.param_shardings(mesh, specs)
    assert sh["embed"].spec == P("model", None)
    assert sh["lm_head"].spec == P(None, "model")


def test_fit_spec_drops_indivisible():
    devs = np.asarray(jax.devices()[:1] * 1)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # dim 12 over axis of size 1 divides; over a fake axis it's the
    # activation constraint that handles padding — here just shape logic.
    out = shd._fit_spec(P("data", "model"), (4, 8), mesh)
    assert out == P("data", "model")


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 2, 4, 8, 16, 128]),
       h=st.sampled_from([2, 8, 12, 24, 56]))
def test_activation_spec_utilization_rule(b, h):
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    spec = shd.activation_spec(mesh, (b, 16, h, 64), batch_dim=0, head_dim=2)
    # with mesh axes of size 1, everything is utilization-1 and shardable
    assert spec[0] == "data"
    assert spec[2] == "model"
