"""Optimizer + gradient-compression tests (unit + property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: install the [test] extra
from hypothesis import given, settings, strategies as st

from repro.optim import adafactor, adamw, compress, schedule


def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.5]), "b": jnp.asarray(4.0)}


def _quad_loss(p):
    return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])


def test_adamw_converges_quadratic():
    params = _quad_params()
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw.init(params, cfg)
    for _ in range(300):
        grads = jax.grad(_quad_loss)(params)
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(_quad_loss(params)) < 1e-3


def test_adafactor_converges_quadratic():
    params = {"w": jnp.ones((4, 4)) * 3.0}
    cfg = adafactor.AdafactorConfig(lr=0.3, min_dim_size_to_factor=2)
    state = adafactor.init(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = adafactor.update(grads, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_adafactor_memory_is_factored():
    """The 480B-enabler: second moments of a (n, m) matrix cost n + m."""
    params = {"w": jnp.zeros((512, 256))}
    state = adafactor.init(params)
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.v))
    assert n <= 512 + 256 + 1, n


def test_adamw_clip_norm():
    grads = {"w": jnp.full((10,), 1e6)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(norm) > 1e6
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-4


def test_schedule_warmup_cosine():
    s = schedule.warmup_cosine(jnp.asarray(0), warmup_steps=10,
                               total_steps=100)
    assert float(s) == 0.0
    s_w = schedule.warmup_cosine(jnp.asarray(10), warmup_steps=10,
                                 total_steps=100)
    assert abs(float(s_w) - 1.0) < 1e-6
    s_end = schedule.warmup_cosine(jnp.asarray(100), warmup_steps=10,
                                   total_steps=100, min_ratio=0.1)
    assert abs(float(s_end) - 0.1) < 1e-6


# -------------------------------------------------------- compression -----
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
def test_int8_roundtrip_error_bound(seed, scale):
    """Property: |x - deq(q(x))| <= scale_block (half-ulp of 127 levels)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(777,)) * scale, jnp.float32)
    q, s = compress.quantize_int8(x)
    deq = compress.dequantize_int8(q, s, x.shape)
    blocks = np.pad(np.asarray(x), (0, (-x.size) % compress.BLOCK)).reshape(
        -1, compress.BLOCK)
    bound = np.abs(blocks).max(axis=1) / 127.0 * 0.5 + 1e-9
    err = np.abs(np.asarray(deq) - np.asarray(x))
    err_blocks = np.pad(err, (0, (-x.size) % compress.BLOCK)).reshape(
        -1, compress.BLOCK)
    assert np.all(err_blocks.max(axis=1) <= bound * 1.01)


def test_error_feedback_unbiased_over_time():
    """EF property: the *running sum* of compressed grads tracks the running
    sum of true grads (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(300,)), jnp.float32) * 0.01
              for _ in range(50)]
    ef = compress.init_ef({"g": g_true[0]})
    sum_c = jnp.zeros(300)
    sum_t = jnp.zeros(300)
    for g in g_true:
        cg, ef = compress.compress_grads({"g": g}, ef)
        sum_c += cg["g"]
        sum_t += g
    resid = float(jnp.max(jnp.abs(sum_c - sum_t)))
    # Residual equals the last EF state — bounded by one quantization step.
    assert resid <= float(jnp.max(jnp.abs(ef.residual["g"]))) + 1e-6


def test_compressed_training_still_converges():
    params = _quad_params()
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0)
    state = adamw.init(params, cfg)
    ef = compress.init_ef(params)
    for _ in range(400):
        grads = jax.grad(_quad_loss)(params)
        grads, ef = compress.compress_grads(grads, ef)
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(_quad_loss(params)) < 1e-2
