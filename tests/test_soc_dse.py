"""Generative design space (soc.dse) + the k-way bucketing it rides on.

Three contracts:

  * the budgeted sampler emits validated, budget-fitting, deterministic
    design points (and SoCConfig's own validator catches buggy ones);
  * k-way ``length_buckets`` partitions exactly, never wastes more
    padded volume than fewer buckets, and keeps the old 2-bucket
    behaviour;
  * per-lane metrics reassembled from bucketed sublane runs are
    BITWISE-equal to the single-call stacked run on the same lanes —
    padding rows/tiles/phases are inert down to the last ulp, which is
    what lets the sweep report per-SoC numbers independent of bucket
    layout.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.modes import CoherenceMode
from repro.core.policies import FixedHomogeneous, ManualPolicy
from repro.soc import dse, stacked as stk
from repro.soc.config import (DEFAULT_BUDGET, SOCS, SoCBudget, SoCConfig,
                              budget_report, soc_area, soc_offchip_bw)
from repro.soc.des import Application
from repro.soc.apps import make_phase


# ----------------------------------------------------------- config validator
def test_all_handwritten_socs_validate_and_fit_budget():
    for name, soc in SOCS.items():
        rep = budget_report(soc)   # __post_init__ already ran at import
        assert rep["within_budget"], (name, rep)
        assert soc_area(soc) > 0 and soc_offchip_bw(soc) > 0


@pytest.mark.parametrize("patch, match", [
    (dict(accelerators=("fft",)), "accelerator names"),
    (dict(no_private_cache=(7,)), "no_private_cache"),
    (dict(no_private_cache=(-1,)), "no_private_cache"),
    (dict(noc_rows=1, noc_cols=3), "tiles"),
    (dict(llc_slice_bytes=0), "llc_slice_bytes"),
    (dict(l2_bytes=-4), "l2_bytes"),
    (dict(n_accs=0, accelerators=()), "n_accs"),
])
def test_soc_config_rejects_broken_invariants(patch, match):
    base = dict(name="bad", n_accs=2, noc_rows=3, noc_cols=3, n_cpus=1,
                n_mem_tiles=1, llc_slice_bytes=1024, l2_bytes=512,
                accelerators=("fft", "gemm"))
    with pytest.raises(ValueError, match=match):
        SoCConfig(**{**base, **patch})


def test_soc_config_error_names_the_config_and_all_problems():
    with pytest.raises(ValueError) as ei:
        SoCConfig(name="frankensoc", n_accs=3, noc_rows=1, noc_cols=1,
                  n_cpus=1, n_mem_tiles=1, llc_slice_bytes=0, l2_bytes=8,
                  accelerators=("fft",))
    msg = str(ei.value)
    assert "frankensoc" in msg and "llc_slice_bytes" in msg
    assert "accelerator names" in msg and "tiles" in msg


# ------------------------------------------------------------------- sampler
def test_sampler_is_deterministic_and_count_independent():
    a = dse.sample_socs(3, 10)
    b = dse.sample_socs(3, 4)
    assert [s.config for s in b] == [s.config for s in a[:4]]
    assert [s.seed for s in b] == [s.seed for s in a[:4]]
    assert dse.sample_socs(4, 1)[0].config != a[0].config or (
        dse.sample_socs(4, 1)[0].seed != a[0].seed)


def test_sampled_socs_fit_budget_and_validate():
    budget = DEFAULT_BUDGET
    for s in dse.sample_socs(1, 24):
        rep = budget_report(s.config, budget)
        assert rep["within_budget"], (s.config.name, rep)
        assert len(s.config.accelerators) == s.config.n_accs
        assert all(0 <= i < s.config.n_accs
                   for i in s.config.no_private_cache)
        for axis in dse.FEATURE_AXES:
            assert np.isfinite(s.axes[axis]), axis


def test_sampler_repairs_into_a_tight_budget():
    tight = SoCBudget(max_area=14.0, max_offchip_bw=4.0)
    for s in dse.sample_socs(2, 8, budget=tight):
        rep = budget_report(s.config, tight)
        assert rep["within_budget"], (s.config.name, rep)
        assert s.config.n_mem_tiles == 1   # 4 bytes/cycle cap == 1 channel


def test_config_seeds_are_distinct():
    seeds = [s.seed for s in dse.sample_socs(0, 32)]
    assert len(set(seeds)) == len(seeds)


# --------------------------------------------------- k-way bucketing properties
def _padded_volume(lens, groups):
    return sum(len(g) * max(lens[i] for i in g) for g in groups)


def test_buckets_partition_and_volume_monotone_in_max_buckets():
    rng = np.random.default_rng(0)
    for _ in range(40):
        k = int(rng.integers(1, 24))
        lens = rng.integers(1, 400, size=k).tolist()
        prev_vol = None
        for mb in range(1, 7):
            groups = stk.length_buckets(lens, max_buckets=mb, min_gain=0.0)
            flat = sorted(i for g in groups for i in g)
            assert flat == list(range(k)), (lens, mb, groups)
            assert len(groups) <= max(1, mb)
            vol = _padded_volume(lens, groups)
            if prev_vol is not None:
                assert vol <= prev_vol, (lens, mb)
            prev_vol = vol
        # every bucket tight: its max is a real member length
        for g in groups:
            assert max(lens[i] for i in g) in [lens[i] for i in g]


def test_two_bucket_results_unchanged_and_min_gain_stop_rule():
    # the old single-cut behaviour, pinned
    assert stk.length_buckets([100, 101, 102]) == [[0, 1, 2]]
    assert stk.length_buckets([10, 11, 40]) == [[0, 1], [2]]
    # k-way splits where the old code raised
    assert stk.length_buckets([10, 10, 40, 40, 100], max_buckets=3,
                              min_gain=0.0) == [[0, 1], [2, 3], [4]]
    # min_gain gates EACH extra cut: the second cut's small gain is refused
    lens = [10, 10, 100, 100, 104]
    g2 = stk.length_buckets(lens, max_buckets=4, min_gain=0.05)
    assert g2 == [[0, 1], [2, 3, 4]]
    assert stk.length_buckets(lens, max_buckets=4, min_gain=0.0) \
        == [[0, 1], [2, 3], [4]]
    # uniform lengths never split, whatever the budget
    assert stk.length_buckets([7] * 5, max_buckets=5, min_gain=0.0) \
        == [[0, 1, 2, 3, 4]]


def test_compile_lanes_rejects_seed_length_mismatch():
    socs = [SOCS["SoC1"], SOCS["SoC2"]]
    apps = [_chain_app(soc, seed=i) for i, soc in enumerate(socs)]
    with pytest.raises(ValueError, match="2 per-lane seeds vs 3 apps"):
        stk.compile_apps_stacked(apps + [apps[0]], socs + [socs[0]],
                                 seed=[1, 2])
    with pytest.raises(ValueError, match="3 per-lane seeds vs 2 apps"):
        stk.compile_apps_stacked(apps, socs, seed=[1, 2, 3])
    # matching sequence still works and equals per-lane scalar compiles
    sa = stk.compile_apps_stacked(apps, socs, seed=[5, 6])
    assert sa.n_lanes == 2


def test_reassemble_lanes_rejects_non_partition():
    with pytest.raises(ValueError, match="partition"):
        stk.reassemble_lanes([[0, 1], [1, 2]],
                             [np.zeros(2), np.zeros(2)])


# ------------------------------------------- bitwise bucketed-vs-single contract
def _chain_app(soc, seed, n_phases=3):
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=1 + (i % 2),
                   size_classes=[c], chain_len=3, loops=2 + i)
        for i, c in enumerate(("S", "M", "L", "XL")[:n_phases])
    ]
    return Application(name=f"{soc.name}-dse-chain", phases=phases)


@pytest.fixture(scope="module")
def fig9_like():
    """Four heterogeneous SoCs with deliberately divergent schedule
    lengths (the Fig. 9 regime that makes bucketing pay off)."""
    socs = [SOCS["SoC1"], SOCS["SoC2"], SOCS["SoC5"], SOCS["SoC6"]]
    apps = [_chain_app(soc, seed=20 + i, n_phases=2 + i % 3)
            for i, soc in enumerate(socs)]
    env = stk.StackedVecEnv(socs, seed=0)
    return socs, apps, env


def test_bucketed_metrics_bitwise_equal_single_call(fig9_like):
    """Per-lane normalized metrics from bucketed sublane runs, reassembled
    to lane order, are bitwise-equal to one stacked call over all lanes
    for every deterministic family (the fixed suite + manual Algorithm 1
    — the families fig9 pins).  Keyed families are excluded by
    construction: jax's threefry pairs counter halves by total draw
    length, so pre-sampled select noise legitimately differs when a
    bucket pads to a shorter scan."""
    import jax
    from repro.soc import vecenv as vec

    socs, apps, env = fig9_like
    seeds = [100 + i for i in range(len(socs))]
    suite = [FixedHomogeneous(m) for m in CoherenceMode] + [ManualPolicy()]
    lane_seeds = np.asarray(seeds, np.int64)

    def norms(sub_env, sa, lanes):
        specs = sub_env.lower(sa, suite)
        keys = dse._eval_keys(lane_seeds[lanes], len(suite))
        res = sub_env.episodes(sa, specs, keys=keys)
        base = jax.tree_util.tree_map(lambda x: x[:, 0], res)
        nt, nm = jax.vmap(jax.vmap(vec.normalized_metrics,
                                   in_axes=(0, None, None)),
                          in_axes=(0, 0, 0))(res, base, sa.phase_mask)
        return np.asarray(nt), np.asarray(nm)

    single = env.compile(apps, seed=seeds)
    nt_one, nm_one = norms(env, single, list(range(len(socs))))

    buckets = stk.compile_apps_bucketed(apps, socs, seed=seeds,
                                        max_buckets=3, min_gain=0.0)
    groups = [g for g, _ in buckets]
    assert len(groups) > 1, "fixture must actually split"
    parts_t, parts_m = [], []
    for g, sa in buckets:
        nt, nm = norms(env.sublanes(g), sa, list(g))
        parts_t.append(nt)
        parts_m.append(nm)
    nt_re = stk.reassemble_lanes(groups, parts_t)
    nm_re = stk.reassemble_lanes(groups, parts_m)
    np.testing.assert_array_equal(nt_re, nt_one)
    np.testing.assert_array_equal(nm_re, nm_one)


def test_sweep_one_call_pair_per_bucket_and_reassembly():
    """A small end-to-end sweep: exactly one train + one eval call per
    bucket, margins finite, NON_COH row normalizes to exactly 1."""
    samples = dse.sample_socs(11, 6)
    out = dse.run_sweep(samples, iters=2, n_phases=2, max_buckets=3,
                        min_gain=0.0)
    calls = out["calls"]
    assert calls["train"] == calls["n_buckets"] <= 3
    assert calls["eval"] == calls["n_buckets"]
    assert sorted(i for g in out["groups"] for i in g) == list(range(6))
    nt, nm = out["norm_time"], out["norm_mem"]
    assert nt.shape == (6, len(dse.EVAL_FAMILIES))
    np.testing.assert_array_equal(nt[:, 0], np.ones(6))  # NON_COH row
    np.testing.assert_array_equal(nm[:, 0], np.ones(6))
    for v in out["margins"].values():
        assert np.isfinite(v).all()
    assert out["waste"]["padded_volume_bucketed"] \
        <= out["waste"]["padded_volume_single_call"]
    rank = out["axis_ranking"]["speedup_vs_noncoh"]
    assert len(rank["ranked_coefficients"]) == len(dse.FEATURE_AXES)


def test_sweep_results_independent_of_bucket_count():
    """Deterministic-family per-SoC numbers must not depend on how the
    sweep was bucketed (per-config seeds drive keys and striping, and
    padding rows are inert).  Keyed families (random, cohmeleon) redraw
    their pre-sampled noise when the padded scan length changes — those
    columns are only required to stay finite and in range."""
    samples = dse.sample_socs(12, 5)
    one = dse.run_sweep(samples, iters=2, n_phases=2, max_buckets=1)
    many = dse.run_sweep(samples, iters=2, n_phases=2, max_buckets=3,
                         min_gain=0.0)
    assert len(many["groups"]) > 1
    det = [i for i, f in enumerate(dse.EVAL_FAMILIES)
           if f.startswith("fixed") or f == "manual"]
    np.testing.assert_array_equal(one["norm_time"][:, det],
                                  many["norm_time"][:, det])
    np.testing.assert_array_equal(one["norm_mem"][:, det],
                                  many["norm_mem"][:, det])
    for out in (one, many):
        assert np.isfinite(out["norm_time"]).all()
        assert (out["norm_time"] > 0).all()


def test_sweep_sharded_flag_single_device_is_bitwise():
    """``run_sweep(sharded=True)`` routes each bucket's training through
    ``shard.sharded_train_batched_stacked``; on a single device the
    wrapper falls back to the plain vmap call, so the whole sweep output
    must be bitwise-identical to ``sharded=False``."""
    samples = dse.sample_socs(13, 4)
    plain = dse.run_sweep(samples, iters=2, n_phases=2, max_buckets=2,
                          min_gain=0.0)
    shard = dse.run_sweep(samples, iters=2, n_phases=2, max_buckets=2,
                          min_gain=0.0, sharded=True)
    np.testing.assert_array_equal(plain["norm_time"], shard["norm_time"])
    np.testing.assert_array_equal(plain["norm_mem"], shard["norm_mem"])
    assert plain["groups"] == shard["groups"]


def test_rank_axes_recovers_a_planted_signal():
    samples = dse.sample_socs(0, 48)
    y = np.asarray([0.5 * s.axes["no_l2_frac"] - 0.05 for s in samples])
    out = dse.rank_axes(samples, {"planted": y})
    top = out["planted"]["ranked_coefficients"][0]
    assert top[0] == "no_l2_frac" and top[1] > 0
    assert out["planted"]["r2"] > 0.99


def test_budget_dataclass_roundtrip():
    b = dataclasses.replace(DEFAULT_BUDGET, max_area=10.0)
    assert b.max_area == 10.0 and DEFAULT_BUDGET.max_area != 10.0
