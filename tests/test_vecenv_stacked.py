"""Stacked multi-SoC axis (soc.stacked) vs per-lane VecEnv and the DES.

Lanes of a stacked call are padded to common (steps, threads, tiles,
phases) shapes; these tests pin that padding is inert: every lane
reproduces exactly what its own environment — and, on single-thread
applications, the DES — produces, and batched training gates padding rows
out of the Q-table/decay bookkeeping.  This is the equivalence contract
behind routing fig5/fig7/fig9 through the vecenv backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode
from repro.core.orchestrator import (compare_policies,
                                     profile_fixed_heterogeneous,
                                     train_cohmeleon_batched)
from repro.core.policies import FixedHomogeneous, ManualPolicy
from repro.soc import stacked as stk, vecenv
from repro.soc.apps import make_application, make_fig5_phases, make_phase
from repro.soc.config import SOC1, SOC2, SOC_MOTIV_ISO, SOC_MOTIV_PAR
from repro.soc.des import Application, SoCSimulator

TILE_SEED = 7
# Deliberately heterogeneous lanes: different n_accs (12/7/9), mem tiles
# (2/4/2), phase counts and schedule lengths — every padding axis is real.
SOCS3 = [SOC_MOTIV_ISO, SOC1, SOC2]


def _chain_app(soc, seed, n_phases=3):
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=1,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L", "XL")[:n_phases])
    ]
    return Application(name=f"{soc.name}-chain", phases=phases)


@pytest.fixture(scope="module")
def lanes():
    sims = [SoCSimulator(soc) for soc in SOCS3]
    env = stk.StackedVecEnv.from_simulators(sims)
    # Different phase counts per lane exercise the phase_mask padding.
    apps = [_chain_app(soc, seed=3 + i, n_phases=3 + (i % 2))
            for i, soc in enumerate(SOCS3)]
    return sims, env, apps, env.compile(apps, seed=TILE_SEED)


def test_padding_shapes(lanes):
    _, env, apps, sa = lanes
    assert sa.n_lanes == 3
    assert sa.schedule.acc_id.shape[0] == 3
    assert sa.n_tiles == max(soc.n_mem_tiles for soc in SOCS3)
    assert sa.n_threads == 1
    for k, c in enumerate(sa.compiled):
        assert sa.n_steps[k] == c.n_steps
        assert np.asarray(sa.phase_mask)[k].sum() == c.n_phases
        # padding rows are invalid and sit at the tail
        valid = np.asarray(sa.schedule.valid)[k]
        assert valid[:c.n_steps].all() and not valid[c.n_steps:].any()


def test_stacked_fixed_modes_match_des_per_lane(lanes):
    sims, env, apps, sa = lanes
    suite = [FixedHomogeneous(m) for m in CoherenceMode]
    res = env.episodes(sa, env.lower(sa, suite))
    for k, (sim, app) in enumerate(zip(sims, apps)):
        pt, po = env.lane_phase_metrics(sa, res, k)
        for mi, mode in enumerate(CoherenceMode):
            des = sim.run(app, FixedHomogeneous(mode), seed=TILE_SEED,
                          train=False)
            dt = np.array([p.wall_time for p in des.phases])
            do = np.array([p.offchip_accesses for p in des.phases])
            np.testing.assert_allclose(pt[mi], dt, rtol=1e-4,
                                       err_msg=f"lane{k} {mode}")
            np.testing.assert_allclose(po[mi], do, rtol=1e-4, atol=1e-3)


def _manual_only(env, sa):
    res = env.episodes(sa, env.lower(sa, [ManualPolicy()]))
    return jax.tree_util.tree_map(lambda x: x[:, 0], res)


def test_stacked_manual_matches_des_per_lane(lanes):
    sims, env, apps, sa = lanes
    res = _manual_only(env, sa)
    for k, (sim, app) in enumerate(zip(sims, apps)):
        des = sim.run(app, ManualPolicy(), seed=TILE_SEED, train=False)
        dt = np.array([p.wall_time for p in des.phases])
        pt, _ = env.lane_phase_metrics(sa, res, k)
        np.testing.assert_allclose(pt, dt, rtol=1e-4, err_msg=f"lane{k}")


def test_stacked_lane_equals_unstacked_env(lanes):
    """A stacked lane reproduces its own (unpadded) VecEnv bit-for-bit on
    deterministic policies — padding slots/tiles/rows are inert."""
    sims, env, apps, sa = lanes
    res = _manual_only(env, sa)
    for k, sim in enumerate(sims):
        solo = env.envs[k]
        compiled = vecenv.compile_app(apps[k], sim.soc, seed=TILE_SEED)
        _, r = solo.episode(compiled, policy="manual")
        pt, po = env.lane_phase_metrics(sa, res, k)
        np.testing.assert_allclose(pt, np.asarray(r.phase_time), rtol=1e-6)
        np.testing.assert_allclose(po, np.asarray(r.phase_offchip),
                                   rtol=1e-6, atol=1e-6)
        n = sa.n_steps[k]
        np.testing.assert_array_equal(
            np.asarray(res.mode)[k][:n], np.asarray(r.mode))


def test_mixed_spec_batch_equals_per_family_calls(lanes):
    """THE redesign contract: a heterogeneous (fixed + manual + learned)
    spec batch in one ``episodes`` call is bitwise-identical, column by
    column, to running each family as its own homogeneous batch — the
    lax.select on ``learned`` leaks nothing across the policy axis."""
    sims, env, apps, sa = lanes
    from repro.core.policies import QPolicy, RandomPolicy

    agent = QPolicy(qlearn.QConfig(), seed=5)
    agent.qs = qlearn.update(agent.qs, qlearn.QConfig(), 7, 2, 0.9)
    suite = [FixedHomogeneous(CoherenceMode.COH_DMA), ManualPolicy(),
             RandomPolicy(), agent]
    keys = env._default_keys(env.n_lanes, len(suite))
    mixed = env.episodes(sa, env.lower(sa, suite), keys=keys)
    for i, pol in enumerate(suite):
        solo = env.episodes(sa, env.lower(sa, [pol]),
                            keys=keys[:, i:i + 1])
        for leaf_m, leaf_s in zip(mixed, solo):
            a = np.asarray(leaf_m)[:, i]
            b = np.asarray(leaf_s)[:, 0]
            if np.issubdtype(a.dtype, np.integer):
                np.testing.assert_array_equal(a, b, err_msg=pol.name)
            else:
                np.testing.assert_allclose(a, b, rtol=1e-6, atol=0,
                                           err_msg=pol.name)


def test_length_buckets_and_sublanes(lanes):
    """Bucketed compilation splits divergent-length lanes into tighter
    stacked calls whose per-lane results match the single padded call."""
    sims, env, apps, sa = lanes
    lens = sa.n_steps
    groups = stk.length_buckets(lens, min_gain=0.0)
    assert sorted(i for g in groups for i in g) == list(range(len(lens)))
    # near-uniform lengths stay one call under the default gain threshold
    assert stk.length_buckets([100, 101, 102]) == [[0, 1, 2]]
    assert stk.length_buckets([10, 11, 40]) == [[0, 1], [2]]

    buckets = stk.compile_apps_bucketed(apps, env.socs, seed=TILE_SEED,
                                        min_gain=0.0)
    full = _manual_only(env, sa)
    for g, sub_stacked in buckets:
        sub_env = env.sublanes(g)
        waste_sub = stk.padded_waste(sub_stacked)
        assert waste_sub <= stk.padded_waste(sa) + 1e-9
        res = _manual_only(sub_env, sub_stacked)
        for j, lane in enumerate(g):
            pt, po = sub_env.lane_phase_metrics(sub_stacked, res, j)
            ptf, pof = env.lane_phase_metrics(sa, full, lane)
            np.testing.assert_allclose(pt, ptf[..., :pt.shape[-1]],
                                       rtol=1e-6)


def test_stacked_training_gates_padding(lanes):
    """(K lanes x B agents) training in one call: per-lane step counters
    count only real invocations, per-lane decay horizons apply, and
    evaluation histories are finite and lane-distinct."""
    sims, env, apps, _ = lanes
    iters, B = 2, 2
    train_apps = [make_application(soc, seed=0, n_phases=2)
                  for soc in SOCS3]
    stacked_iters = [env.compile(train_apps, seed=it) for it in range(iters)]
    eval_st = env.compile(
        [make_application(soc, seed=1000, n_phases=2) for soc in SOCS3],
        seed=77)
    cfg = qlearn.QConfig(decay_steps=jnp.asarray(
        [s * iters for s in stacked_iters[0].n_steps], jnp.int32))
    wb = rewards.stack_weights([rewards.PAPER_DEFAULT_WEIGHTS] * B)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3 * B)).reshape(3, B, 2)
    qs, hist = env.train_batched(stacked_iters, cfg, wb, keys,
                                 eval_stacked=eval_st)
    assert qs.qtable.shape == (3, B, 243, 4)
    expect = np.array([[s * iters] * B for s in stacked_iters[0].n_steps])
    np.testing.assert_array_equal(np.asarray(qs.step), expect)
    ht = np.asarray(hist[0])
    assert ht.shape == (3, B, iters) and np.isfinite(ht).all()
    nt, nm = env.evaluate_batched(eval_st, qs, cfg)
    assert nt.shape == (3, B)
    assert np.all(np.isfinite(np.asarray(nt))) and np.all(np.asarray(nt) > 0)


def test_fig_protocol_backends_agree_single_thread():
    """The fig5/fig7 routing (batched vecenv training + vecenv
    compare_policies) agrees with the DES on single-thread apps for every
    deterministic policy in the suite."""
    sim = SoCSimulator(SOC_MOTIV_PAR)
    app = _chain_app(SOC_MOTIV_PAR, seed=11, n_phases=3)
    suite = [FixedHomogeneous(m) for m in CoherenceMode] + [ManualPolicy()]
    cd = compare_policies(sim, app, suite, seed=TILE_SEED, backend="des")
    cv = compare_policies(sim, app, suite, seed=TILE_SEED, backend="vecenv")
    for name in cd.policies:
        td, md = cd.geomean(name)
        tv, mv = cv.geomean(name)
        assert abs(tv - td) <= 1e-3 * max(td, 1e-9), name
        assert abs(mv - md) <= 1e-3 * max(md, 1e-9) + 1e-6, name
    # the trained-policy protocol produces a usable frozen QPolicy
    policy = train_cohmeleon_batched(sim, iterations=2, seed=0,
                                     n_phases=2).qpolicy(0)
    cq = compare_policies(sim, app, [policy], seed=TILE_SEED,
                          backend="vecenv")
    t, m = cq.geomean("cohmeleon")
    assert np.isfinite(t) and t > 0 and np.isfinite(m)


def test_profile_fixed_heterogeneous_backends_agree():
    """Design-time profiling sweeps single-invocation apps — the exactness
    regime — so the vecenv backend must pick identical assignments."""
    sim = SoCSimulator(SOC1)
    des = profile_fixed_heterogeneous(sim, backend="des")
    fast = profile_fixed_heterogeneous(sim, backend="vecenv")
    assert des.assignment == fast.assignment
