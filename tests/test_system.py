"""End-to-end behaviour tests for the paper's system.

The headline integration checks: Cohmeleon learns online, matches the
manually-tuned expert policy, beats fixed (design-time) policies on the
multi-objective frontier, and the beyond-paper autotuner transfers the same
machinery to train-step memory modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.modes import CoherenceMode
from repro.core.orchestrator import (compare_policies, mode_breakdown,
                                     standard_policy_suite, train_cohmeleon)
from repro.core.policies import FixedHomogeneous, ManualPolicy, RandomPolicy
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


@pytest.fixture(scope="module")
def trained():
    sim = SoCSimulator(SOC_MOTIV_PAR)
    policy, _ = train_cohmeleon(sim, iterations=6, seed=0, n_phases=6)
    test_app = make_application(sim.soc, seed=4242, n_phases=6)
    suite = [FixedHomogeneous(m) for m in CoherenceMode]
    suite += [RandomPolicy(), ManualPolicy(), policy]
    cmp = compare_policies(sim, test_app, suite, seed=5)
    return sim, policy, cmp


def test_cohmeleon_learns_beats_random(trained):
    _, _, cmp = trained
    ct, cm = cmp.geomean("cohmeleon")
    rt, rm = cmp.geomean("random")
    assert ct < rt
    assert cm < rm * 1.05


def test_cohmeleon_matches_manual_time(trained):
    """Paper: 'can match runtime solutions manually tuned for the target
    architecture' — within 10% of Algorithm 1's execution time."""
    _, _, cmp = trained
    ct, _ = cmp.geomean("cohmeleon")
    mt, _ = cmp.geomean("manual")
    assert ct <= mt * 1.10


def test_cohmeleon_beats_mean_fixed_policy(trained):
    """Paper headline direction: faster AND fewer off-chip accesses than
    the average fixed (design-time) policy."""
    _, _, cmp = trained
    fixed_t = [cmp.geomean(n)[0] for n in cmp.policies
               if n.startswith("fixed")]
    fixed_m = [cmp.geomean(n)[1] for n in cmp.policies
               if n.startswith("fixed")]
    ct, cm = cmp.geomean("cohmeleon")
    assert ct < np.mean(fixed_t)
    assert cm < np.mean(fixed_m)


def test_learned_policy_is_size_aware(trained):
    """Fig. 7 structure: the learned policy leans on DMA-without-caching
    more at XL than at S, and keeps small workloads mostly cached.

    The assertion is seeded (module fixture trains with fixed seeds) and
    tolerance-based: the paper reports ~0.6-0.9 non-coh share at XL, but
    the exact share of a 6-iteration training run swings with the sampled
    application instance, so instead of a hard absolute threshold we pin
    the *structure* — a clear S -> XL margin — plus a loose floor well
    below the observed seeded value (0.25 at seed 0)."""
    sim, policy, cmp = trained
    bd = mode_breakdown(cmp.raw["cohmeleon"], sim.soc)
    non_coh = CoherenceMode.NON_COH_DMA
    margin = 0.10
    assert bd["XL"][non_coh] >= bd["S"][non_coh] + margin, (
        bd["S"][non_coh], bd["XL"][non_coh])
    assert bd["XL"][non_coh] >= 0.15, bd["XL"][non_coh]
    assert bd["S"][non_coh] < 0.5    # small workloads mostly cached


def test_q_table_visits_cover_states(trained):
    _, policy, _ = trained
    visited = int(jnp.sum(policy.qs.visits.sum(axis=1) > 0))
    assert visited >= 10   # hundreds of invocations across diverse phases


def test_autotuner_converges_and_is_cheap():
    """Beyond-paper: the Q-machinery over train-step memory modes must
    (a) CONVERGE — decisions concentrate on one mode (which mode wins
    depends on ambient machine load: time-dominant reward picks
    remat_none on a quiet box, the memory proxy favors remat_full under
    contention — both are correct per the multi-objective reward), and
    (b) keep the paper's negligible-overhead property on the decide path.
    The quiet-box remat_none convergence is asserted by
    examples/autotune_train.py."""
    from repro.configs import smoke_config
    from repro.configs.shapes import ShapeSpec
    from repro.core.autotune import MemoryModeOrchestrator
    from repro.data.synthetic import DataConfig, host_batch
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config("qwen3-8b")
    spec = ShapeSpec("t", "train", 64, 8)
    orch = MemoryModeOrchestrator(cfg, spec, make_host_mesh(), seed=0,
                                  total_steps=40)
    state = steps_lib.make_train_state(cfg, jax.random.PRNGKey(0))
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in
                 host_batch(cfg, DataConfig(64, 8, seed=step), step).items()}
        state, _ = orch.step(state, batch)
    counts = orch.decision_counts()
    top = max(counts.values())
    assert top >= 0.5 * sum(counts.values()), counts   # converged
    assert orch.decide_overhead_s() < 0.1              # negligible
