"""int8 KV-cache quantization (the §Perf Cell-C decode lever)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import decode_step, init_params, prefill
from repro.models.attention import cache_read, cache_write, quantize_kv
from repro.models.transformer import forward, init_cache, lm_logits


def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)) * 3.0, jnp.float32)
    q, s = quantize_kv(x)
    deq = q.astype(jnp.float32) * s
    err = np.abs(np.asarray(deq - x))
    bound = np.asarray(s) * 0.5 + 1e-7
    assert np.all(err <= bound * 1.01)


def test_cache_write_read_int8_entry():
    entry = (jnp.zeros((1, 4, 2, 8), jnp.int8),
             jnp.ones((1, 4, 2, 1), jnp.float32))
    val = jnp.ones((1, 1, 2, 8), jnp.bfloat16) * 0.5
    entry = cache_write(entry, val, 2)
    out = cache_read(entry, jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out[:, 2], np.float32), 0.5,
                               rtol=1e-2)
    assert np.all(np.asarray(out[:, 0], np.float32) == 0.0)


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma2-9b"])
def test_int8_cache_decode_close_to_fp(arch):
    cfg = smoke_config(arch).replace(kv_cache_dtype="int8")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    h, _ = forward(cfg, params, {"tokens": toks})
    full_logits = lm_logits(cfg, params, h)
    pre = S - 4
    cache, _ = prefill(cfg, params, {"tokens": toks[:, :pre]}, max_len=S)
    for t in range(pre, S):
        cache, dlog = decode_step(cfg, params, cache,
                                  {"tokens": toks[:, t:t + 1]}, jnp.int32(t))
        err = float(jnp.max(jnp.abs(full_logits[:, t, :] - dlog[:, 0, :])))
        assert err < 0.15, (arch, t, err)


def test_int8_cache_halves_bytes():
    cfg = smoke_config("qwen3-8b")
    c_fp = init_cache(cfg, 2, 64)
    c_q = init_cache(cfg.replace(kv_cache_dtype="int8"), 2, 64)
    fp_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(c_fp))
    q_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(c_q))
    # int8 payload (1B vs 4B fp32 compute dtype in smoke configs) + scales
    assert q_bytes < 0.5 * fp_bytes
