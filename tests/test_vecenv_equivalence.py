"""Scale path (soc.vecenv) vs fidelity path (soc.des) equivalence.

On single-thread applications the lockstep concurrency model of the vecenv
degenerates to the DES's event order exactly — same tile striping rng, same
sensed states, same timing-model inputs — so per-phase wall time and
off-chip accesses must match to float tolerance across every policy the two
paths share.  Multi-thread applications exercise the documented lockstep
approximation, pinned with looser bounds.
"""
import numpy as np
import jax
import pytest

from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode
from repro.core.orchestrator import compare_policies, train_cohmeleon_batched
from repro.core.policies import FixedHomogeneous, ManualPolicy, RandomPolicy
from repro.soc import vecenv
from repro.soc.apps import make_phase
from repro.soc.config import SOC1, SOC_MOTIV_ISO, SOC_MOTIV_PAR
from repro.soc.des import Application, SoCSimulator

TILE_SEED = 7


def _chain_app(soc, seed, n_threads=1):
    """Small app: every phase is ``n_threads`` serial accelerator chains."""
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=n_threads,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L"))
    ]
    return Application(name=f"{soc.name}-chain{n_threads}", phases=phases)


@pytest.fixture(scope="module", params=["SoC-motiv-iso", "SoC1"])
def pair(request):
    """(simulator, env, single-thread app, compiled app) on two SoCs —
    one with the named ESP accelerators, one with sampled traffic-gens."""
    soc = {"SoC-motiv-iso": SOC_MOTIV_ISO, "SoC1": SOC1}[request.param]
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    app = _chain_app(soc, seed=3)
    return sim, env, app, vecenv.compile_app(app, soc, seed=TILE_SEED)


def _des_phase_metrics(res):
    return (np.array([p.wall_time for p in res.phases]),
            np.array([p.offchip_accesses for p in res.phases]))


def test_fixed_modes_match_des_per_phase(pair):
    sim, env, app, compiled = pair
    for mode in CoherenceMode:
        des = sim.run(app, FixedHomogeneous(mode), seed=TILE_SEED,
                      train=False)
        _, res = env.episode(compiled, policy="fixed", fixed_modes=int(mode))
        dt, do = _des_phase_metrics(des)
        np.testing.assert_allclose(np.asarray(res.phase_time), dt,
                                   rtol=1e-4, err_msg=str(mode))
        np.testing.assert_allclose(np.asarray(res.phase_offchip), do,
                                   rtol=1e-4, atol=1e-3, err_msg=str(mode))


def test_manual_policy_matches_des(pair):
    sim, env, app, compiled = pair
    des = sim.run(app, ManualPolicy(), seed=TILE_SEED, train=False)
    _, res = env.episode(compiled, policy="manual")
    des_modes = [r.mode for p in des.phases for r in p.invocations]
    assert des_modes == [int(m) for m in np.asarray(res.mode)]
    dt, do = _des_phase_metrics(des)
    np.testing.assert_allclose(np.asarray(res.phase_time), dt, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(res.phase_offchip), do,
                               rtol=1e-4, atol=1e-3)


def test_sensed_states_match_des(pair):
    """The Table-3 state stream feeding the Q-table is identical, so a
    policy trained on one path reads the same states on the other."""
    sim, env, app, compiled = pair
    des = sim.run(app, FixedHomogeneous(CoherenceMode.COH_DMA),
                  seed=TILE_SEED, train=False)
    _, res = env.episode(compiled, policy="fixed",
                         fixed_modes=int(CoherenceMode.COH_DMA))
    des_states = [r.state_idx for p in des.phases for r in p.invocations]
    assert des_states == [int(s) for s in np.asarray(res.state_idx)]


def test_compare_policies_backends_agree(pair):
    sim, _, app, _ = pair
    suite = [FixedHomogeneous(m) for m in CoherenceMode] + [ManualPolicy()]
    cd = compare_policies(sim, app, suite, seed=TILE_SEED, backend="des")
    cv = compare_policies(sim, app, suite, seed=TILE_SEED, backend="vecenv")
    for name in cd.policies:
        td, md = cd.geomean(name)
        tv, mv = cv.geomean(name)
        assert abs(tv - td) <= 1e-3 * max(td, 1e-9), name
        assert abs(mv - md) <= 1e-3 * max(md, 1e-9) + 1e-6, name


def test_multithread_noncoh_offchip_exact():
    """NON_COH traffic bypasses every shared cache, so off-chip counts are
    contention-independent and must match the DES even under the lockstep
    approximation; wall clock stays within a loose envelope."""
    soc = SOC_MOTIV_PAR
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    app = _chain_app(soc, seed=5, n_threads=2)
    compiled = vecenv.compile_app(app, soc, seed=TILE_SEED)
    des = sim.run(app, FixedHomogeneous(CoherenceMode.NON_COH_DMA),
                  seed=TILE_SEED, train=False)
    _, res = env.episode(compiled, policy="fixed",
                         fixed_modes=int(CoherenceMode.NON_COH_DMA))
    dt, do = _des_phase_metrics(des)
    np.testing.assert_allclose(np.asarray(res.phase_offchip), do, rtol=1e-4)
    ratio = np.asarray(res.phase_time) / np.maximum(dt, 1e-30)
    assert np.all(ratio > 0.5) and np.all(ratio < 1.5), ratio


def test_batched_training_vmaps_agents():
    """One jitted call trains a (weights x seeds) grid of agents; every
    agent explores, learns a table, and evaluates against the NON_COH
    baseline without leaving jit."""
    res = train_cohmeleon_batched(
        SOC_MOTIV_PAR, iterations=2, seed=0, n_phases=2, n_seeds=2,
        weights=[(0.675, 0.075, 0.25), (1.0, 0.0, 0.0), (0.0, 0.0, 1.0)])
    assert res.n_agents == 6
    assert res.qstates.qtable.shape == (6, 243, 4)
    visits = np.asarray(res.qstates.visits)
    assert all(int((visits[i].sum(-1) > 0).sum()) >= 3 for i in range(6))
    nt, nm = res.evaluate()
    assert nt.shape == (6,) and np.all(np.isfinite(nt)) and np.all(nt > 0)
    assert nm.shape == (6,) and np.all(np.isfinite(nm)) and np.all(nm > 0)
    assert res.per_weight(nt).shape == (3,)
    # agents trained with different weights end with different tables
    qt = np.asarray(res.qstates.qtable)
    assert not np.allclose(qt[0], qt[4])


def test_random_policy_lowering_is_uniform():
    """RandomPolicy lowers to a frozen untrained table: randomized-argmax
    tie-breaking makes it uniform over available modes (the paper's
    'iteration 0 == Random' property)."""
    soc = SOC_MOTIV_ISO
    sim = SoCSimulator(soc)
    app = _chain_app(soc, seed=9)
    cmp = compare_policies(sim, app, [RandomPolicy()], seed=1,
                           backend="vecenv")
    modes = [r.mode for p in cmp.raw["random"].phases
             for r in p.invocations]
    assert len(set(modes)) >= 2   # actually mixes modes
