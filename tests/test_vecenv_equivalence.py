"""Scale path (soc.vecenv) vs fidelity path (soc.des) equivalence.

On single-thread applications the lockstep concurrency model of the vecenv
degenerates to the DES's event order exactly — same tile striping rng, same
sensed states, same timing-model inputs — so per-phase wall time and
off-chip accesses must match to float tolerance across every policy the two
paths share.  Multi-thread applications exercise the documented lockstep
approximation, pinned with looser bounds.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode
from repro.core.orchestrator import compare_policies, train_cohmeleon_batched
from repro.core.policies import FixedHomogeneous, ManualPolicy, RandomPolicy
from repro.soc import vecenv
from repro.soc.apps import make_phase
from repro.soc.config import SOC1, SOC_MOTIV_ISO, SOC_MOTIV_PAR
from repro.soc.des import Application, SoCSimulator

TILE_SEED = 7


def _chain_app(soc, seed, n_threads=1):
    """Small app: every phase is ``n_threads`` serial accelerator chains."""
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=n_threads,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L"))
    ]
    return Application(name=f"{soc.name}-chain{n_threads}", phases=phases)


@pytest.fixture(scope="module", params=["SoC-motiv-iso", "SoC1"])
def pair(request):
    """(simulator, env, single-thread app, compiled app) on two SoCs —
    one with the named ESP accelerators, one with sampled traffic-gens."""
    soc = {"SoC-motiv-iso": SOC_MOTIV_ISO, "SoC1": SOC1}[request.param]
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    app = _chain_app(soc, seed=3)
    return sim, env, app, vecenv.compile_app(app, soc, seed=TILE_SEED)


def _des_phase_metrics(res):
    return (np.array([p.wall_time for p in res.phases]),
            np.array([p.offchip_accesses for p in res.phases]))


def test_fixed_modes_match_des_per_phase(pair):
    sim, env, app, compiled = pair
    for mode in CoherenceMode:
        des = sim.run(app, FixedHomogeneous(mode), seed=TILE_SEED,
                      train=False)
        _, res = env.episode(compiled, policy="fixed", fixed_modes=int(mode))
        dt, do = _des_phase_metrics(des)
        np.testing.assert_allclose(np.asarray(res.phase_time), dt,
                                   rtol=1e-4, err_msg=str(mode))
        np.testing.assert_allclose(np.asarray(res.phase_offchip), do,
                                   rtol=1e-4, atol=1e-3, err_msg=str(mode))


def test_manual_policy_matches_des(pair):
    sim, env, app, compiled = pair
    des = sim.run(app, ManualPolicy(), seed=TILE_SEED, train=False)
    _, res = env.episode(compiled, policy="manual")
    des_modes = [r.mode for p in des.phases for r in p.invocations]
    assert des_modes == [int(m) for m in np.asarray(res.mode)]
    dt, do = _des_phase_metrics(des)
    np.testing.assert_allclose(np.asarray(res.phase_time), dt, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(res.phase_offchip), do,
                               rtol=1e-4, atol=1e-3)


def test_sensed_states_match_des(pair):
    """The Table-3 state stream feeding the Q-table is identical, so a
    policy trained on one path reads the same states on the other."""
    sim, env, app, compiled = pair
    des = sim.run(app, FixedHomogeneous(CoherenceMode.COH_DMA),
                  seed=TILE_SEED, train=False)
    _, res = env.episode(compiled, policy="fixed",
                         fixed_modes=int(CoherenceMode.COH_DMA))
    des_states = [r.state_idx for p in des.phases for r in p.invocations]
    assert des_states == [int(s) for s in np.asarray(res.state_idx)]


def test_compare_policies_backends_agree(pair):
    sim, _, app, _ = pair
    suite = [FixedHomogeneous(m) for m in CoherenceMode] + [ManualPolicy()]
    cd = compare_policies(sim, app, suite, seed=TILE_SEED, backend="des")
    cv = compare_policies(sim, app, suite, seed=TILE_SEED, backend="vecenv")
    for name in cd.policies:
        td, md = cd.geomean(name)
        tv, mv = cv.geomean(name)
        assert abs(tv - td) <= 1e-3 * max(td, 1e-9), name
        assert abs(mv - md) <= 1e-3 * max(md, 1e-9) + 1e-6, name


def test_multithread_noncoh_offchip_exact():
    """NON_COH traffic bypasses every shared cache, so off-chip counts are
    contention-independent and must match the DES even under the lockstep
    approximation; wall clock stays within a loose envelope."""
    soc = SOC_MOTIV_PAR
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    app = _chain_app(soc, seed=5, n_threads=2)
    compiled = vecenv.compile_app(app, soc, seed=TILE_SEED)
    des = sim.run(app, FixedHomogeneous(CoherenceMode.NON_COH_DMA),
                  seed=TILE_SEED, train=False)
    _, res = env.episode(compiled, policy="fixed",
                         fixed_modes=int(CoherenceMode.NON_COH_DMA))
    dt, do = _des_phase_metrics(des)
    np.testing.assert_allclose(np.asarray(res.phase_offchip), do, rtol=1e-4)
    ratio = np.asarray(res.phase_time) / np.maximum(dt, 1e-30)
    assert np.all(ratio > 0.5) and np.all(ratio < 1.5), ratio


def test_invocation_perf_cached_matches_full_signature():
    """The fast-path timing signature (precomputed other-slot demand) is
    the self-contained one exactly, for random concurrent sets."""
    from repro.soc import memsys

    soc = SOC_MOTIV_PAR
    env = vecenv.VecEnv(soc)
    s, T, n_tiles = env.static, 6, soc.n_mem_tiles
    rng = np.random.default_rng(42)
    for trial in range(10):
        mode = int(rng.integers(0, 4))
        acc = int(rng.integers(0, soc.n_accs))
        fp = float(np.exp(rng.uniform(np.log(2**11), np.log(2**24))))
        my_tiles = jnp.asarray(rng.random(n_tiles) < 0.6)
        o_modes = jnp.asarray(
            np.where(rng.random(T) < 0.5, rng.integers(0, 4, T), -1),
            jnp.int32)
        o_accs = rng.integers(0, soc.n_accs, T)
        o_profiles = jnp.asarray(np.asarray(env.pmat)[o_accs])
        o_fps = jnp.asarray(
            np.exp(rng.uniform(np.log(2**11), np.log(2**24), T)),
            jnp.float32)
        o_tiles = jnp.asarray(rng.random((T, n_tiles)) < 0.5)
        warm = float(rng.random())
        m_full, aux_full = memsys.invocation_perf(
            mode, env.pmat[acc], fp, my_tiles, o_modes, o_profiles,
            o_fps, o_tiles, warm, s)
        od, ol = jax.vmap(
            lambda mm, pp, ff: memsys.dma_demand(mm, pp, ff, s))(
                o_modes, o_profiles, o_fps)
        m_fast, aux_fast = memsys.invocation_perf_cached(
            mode, env.pmat[acc], fp, my_tiles, o_modes, od, ol,
            o_fps, o_tiles, warm, s)
        for a, b in zip(m_full, m_fast):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"trial {trial}")
        np.testing.assert_array_equal(
            np.asarray(aux_fast["demand_dram"]),
            np.asarray(memsys.dma_demand(mode, env.pmat[acc], fp, s)[0]))


def test_carry_cached_demand_matches_fresh_after_writes():
    """Property test of the cache-invalidation contract: after an arbitrary
    sequence of slot writes, every slot's carried (dram, llc) demand equals
    a fresh ``dma_demand`` of that slot's current (mode, profile,
    footprint) — the exactness the scan carry relies on (a slot's demand
    changes only when that slot is written)."""
    from repro.soc import memsys

    soc = SOC_MOTIV_PAR
    env = vecenv.VecEnv(soc)
    s, T = env.static, 8
    fresh = jax.jit(jax.vmap(
        lambda m, p, f: memsys.dma_demand(m, p, f, s)))
    rng = np.random.default_rng(7)
    modes = np.full(T, -1, np.int64)
    accs = np.zeros(T, np.int64)
    fps = np.ones(T)
    cache = np.zeros((T, 2))
    pmat = np.asarray(env.pmat)
    for step in range(60):
        t = int(rng.integers(0, T))          # the slot this step writes
        modes[t] = int(rng.integers(0, 4))
        accs[t] = int(rng.integers(0, soc.n_accs))
        fps[t] = float(np.exp(rng.uniform(np.log(2**11), np.log(2**24))))
        d, l = memsys.dma_demand(int(modes[t]), env.pmat[int(accs[t])],
                                 fps[t], s)
        cache[t] = float(d), float(l)        # invalidate only this slot
        if step % 10 == 9:
            fd, fl = fresh(jnp.asarray(modes, jnp.int32),
                           jnp.asarray(pmat[accs]),
                           jnp.asarray(fps, jnp.float32))
            written = modes >= 0
            np.testing.assert_allclose(cache[written, 0],
                                       np.asarray(fd)[written], rtol=1e-6)
            np.testing.assert_allclose(cache[written, 1],
                                       np.asarray(fl)[written], rtol=1e-6)


@pytest.mark.parametrize("policy", ["q", "fixed", "manual"])
def test_demand_cache_episode_equivalence(policy):
    """Cached-demand episodes equal recompute-every-step episodes exactly,
    through the full scan step (multi-thread app, so slot writes and the
    concurrency masks are exercised), for every policy kind — including
    the training path's mode/state/reward traces."""
    soc = SOC_MOTIV_PAR
    app = _chain_app(soc, seed=6, n_threads=3)
    compiled = vecenv.compile_app(app, soc, seed=TILE_SEED)
    results = {}
    for cache in (False, True):
        env = vecenv.VecEnv(soc, seed=0, demand_cache=cache)
        qs, res = env.episode(compiled, policy=policy,
                              key=jax.random.PRNGKey(3))
        results[cache] = (qs, res)
    qs_a, res_a = results[False]
    qs_b, res_b = results[True]
    np.testing.assert_array_equal(np.asarray(res_a.mode),
                                  np.asarray(res_b.mode))
    np.testing.assert_array_equal(np.asarray(res_a.state_idx),
                                  np.asarray(res_b.state_idx))
    np.testing.assert_allclose(np.asarray(res_a.exec_time),
                               np.asarray(res_b.exec_time), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res_a.phase_time),
                               np.asarray(res_b.phase_time), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res_a.reward),
                               np.asarray(res_b.reward), rtol=1e-5,
                               atol=1e-6)
    if policy == "q":
        np.testing.assert_allclose(np.asarray(qs_a.qtable),
                                   np.asarray(qs_b.qtable), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(qs_a.visits),
                                      np.asarray(qs_b.visits))


def _tree_bitwise(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("policy", ["q", "fixed", "manual"])
def test_fused_step_episode_bitwise(policy):
    """The fused soc_step episode lowering (the default) equals the
    unfused reference step bit for bit — traces, phase metrics and (for
    the q family) the trained Q-state with replayed visit counters —
    for every policy family on a multi-thread app."""
    soc = SOC_MOTIV_PAR
    app = _chain_app(soc, seed=6, n_threads=3)
    compiled = vecenv.compile_app(app, soc, seed=TILE_SEED)
    out = {}
    for fused in (False, True):
        env = vecenv.VecEnv(soc, seed=0, fused_step=fused)
        out[fused] = env.episode(compiled, policy=policy,
                                 key=jax.random.PRNGKey(3))
    _tree_bitwise(out[False], out[True])


def test_fused_step_train_batched_bitwise():
    """Multi-iteration batched training under the fused step reproduces
    the unfused path exactly (qtable, visits, step, frozen)."""
    soc = SOC_MOTIV_PAR
    app = _chain_app(soc, seed=6, n_threads=2)
    compiled = vecenv.compile_app(app, soc, seed=TILE_SEED)
    iters, B = 2, 3
    cfg = qlearn.QConfig(decay_steps=compiled.n_steps * iters)
    wb = rewards.stack_weights([rewards.PAPER_DEFAULT_WEIGHTS] * B)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(B))
    out = {}
    for fused in (False, True):
        env = vecenv.VecEnv(soc, seed=0, fused_step=fused)
        qs, _ = env.train_batched([compiled] * iters, cfg, wb, keys)
        out[fused] = qs
    _tree_bitwise(out[False], out[True])


def test_batched_training_vmaps_agents():
    """One jitted call trains a (weights x seeds) grid of agents; every
    agent explores, learns a table, and evaluates against the NON_COH
    baseline without leaving jit."""
    res = train_cohmeleon_batched(
        SOC_MOTIV_PAR, iterations=2, seed=0, n_phases=2, n_seeds=2,
        weights=[(0.675, 0.075, 0.25), (1.0, 0.0, 0.0), (0.0, 0.0, 1.0)])
    assert res.n_agents == 6
    assert res.qstates.qtable.shape == (6, 243, 4)
    visits = np.asarray(res.qstates.visits)
    assert all(int((visits[i].sum(-1) > 0).sum()) >= 3 for i in range(6))
    nt, nm = res.evaluate()
    assert nt.shape == (6,) and np.all(np.isfinite(nt)) and np.all(nt > 0)
    assert nm.shape == (6,) and np.all(np.isfinite(nm)) and np.all(nm > 0)
    assert res.per_weight(nt).shape == (3,)
    # agents trained with different weights end with different tables
    qt = np.asarray(res.qstates.qtable)
    assert not np.allclose(qt[0], qt[4])


def test_random_policy_lowering_is_uniform():
    """RandomPolicy lowers to a frozen untrained table: randomized-argmax
    tie-breaking makes it uniform over available modes (the paper's
    'iteration 0 == Random' property)."""
    soc = SOC_MOTIV_ISO
    sim = SoCSimulator(soc)
    app = _chain_app(soc, seed=9)
    cmp = compare_policies(sim, app, [RandomPolicy()], seed=1,
                           backend="vecenv")
    modes = [r.mode for p in cmp.raw["random"].phases
             for r in p.invocations]
    assert len(set(modes)) >= 2   # actually mixes modes
