"""Roofline extraction unit tests (the §Perf score depends on these)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.configs.shapes import SHAPES
from repro.launch import roofline


def test_collective_bytes_parses_kinds():
    hlo = """
  %ag = f32[1024,256]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = bf16[512]{0} all-reduce(%x), to_apply=%sum
  %rs = f32[128,64]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = u8[4096]{0} all-to-all(%z)
  %cp = f32[16,16]{1,0} collective-permute(%w)
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-gather"] == 1024 * 256 * 4
    assert out["all-reduce"] == 512 * 2
    assert out["reduce-scatter"] == 128 * 64 * 4
    assert out["all-to-all"] == 4096
    assert out["collective-permute"] == 16 * 16 * 4


def test_collective_bytes_skips_done_counts_start():
    hlo = """
  %ar0 = (f32[256]{0}, f32[256]{0}) all-reduce-start(%x), to_apply=%s
  %ar1 = f32[256]{0} all-reduce-done(%ar0)
"""
    out = roofline.collective_bytes(hlo)
    # -start counted once (operand+result tuple), -done skipped
    assert out["all-reduce"] == 2 * 256 * 4
    assert len(out) == 1


def test_collective_bytes_ignores_noncollectives():
    hlo = "%m = f32[64,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}"
    assert roofline.collective_bytes(hlo) == {}


def test_roofline_terms_math():
    t = roofline.RooflineTerms(
        arch="x", shape="train_4k", mesh="m", chips=256,
        hlo_flops=256 * roofline.PEAK_FLOPS,       # exactly 1 s of compute
        hlo_bytes=256 * roofline.HBM_BW * 2.0,     # 2 s of memory
        coll_bytes=roofline.ICI_BW * 0.5,          # 0.5 s of collectives
        coll_breakdown={}, model_flops=256 * roofline.PEAK_FLOPS * 0.8,
        bytes_per_device=1e9)
    assert abs(t.t_comp - 1.0) < 1e-9
    assert abs(t.t_mem - 2.0) < 1e-9
    assert abs(t.t_coll - 0.5) < 1e-9
    assert t.dominant == "memory"
    assert abs(t.roofline_fraction - 0.5) < 1e-9
    assert abs(t.useful_ratio - 0.8) < 1e-9


def test_model_flops_counts_active_only_for_moe():
    cfg = get_arch("arctic-480b")
    spec = SHAPES["train_4k"]
    f = roofline.model_flops_for(cfg, spec)
    dense_equiv = 6.0 * cfg.param_count() * spec.global_batch * spec.seq_len
    # top-2 of 128 experts: active flops are a small fraction of total
    assert f < 0.2 * dense_equiv


def test_model_flops_decode_is_per_token():
    cfg = get_arch("qwen3-8b")
    f_dec = roofline.model_flops_for(cfg, SHAPES["decode_32k"])
    f_pre = roofline.model_flops_for(cfg, SHAPES["prefill_32k"])
    # decode: 128 tokens vs prefill: 32*32768 tokens
    assert f_dec < f_pre / 1000
