"""Crash-resumable batched training (VecEnv.train_batched_checkpointed).

The contract under test: the checkpointed trainer is a pure re-chunking
of ``train_batched``'s sequential scan — any interleaving of checkpoint
saves, crashes and restarts yields final Q-tables and evaluation
histories **bitwise-equal** to one uninterrupted run with the same
arguments.
"""
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import qlearn, rewards
from repro.soc import faults, vecenv
from repro.soc.apps import make_phase
from repro.soc.config import SOC1
from repro.soc.des import Application, SoCSimulator

TILE_SEED = 7
B = 2         # agents
ITERS = 5     # training iterations


def _chain_app(soc, seed, n_threads=1):
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=n_threads,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L"))
    ]
    return Application(name=f"{soc.name}-ckpt{seed}", phases=phases)


@pytest.fixture(scope="module")
def setting():
    soc = SOC1
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    apps = [vecenv.compile_app(_chain_app(soc, s), soc, seed=TILE_SEED + s)
            for s in range(ITERS)]
    wb = rewards.stack_weights([rewards.RewardWeights()] * B)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(B))
    cfg = qlearn.QConfig(collapse_frac=0.5)   # watchdog on: full carry
    fs = faults.storm(apps[0].n_steps, 0.5, jax.random.PRNGKey(9))
    return env, apps, cfg, wb, keys, fs


def _tree_bitwise(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


@pytest.mark.parametrize("ckpt_every", [1, 2])
def test_chunked_equals_monolithic(setting, tmp_path, ckpt_every):
    env, apps, cfg, wb, keys, fs = setting
    ref_qs, ref_hist = env.train_batched(apps, cfg, wb, keys,
                                         eval_app=apps[0], faults=fs)
    mgr = CheckpointManager(str(tmp_path / f"ck{ckpt_every}"), keep=2)
    qs, hist = env.train_batched_checkpointed(
        apps, cfg, wb, keys, mgr, ckpt_every=ckpt_every,
        eval_app=apps[0], faults=fs)
    _tree_bitwise(ref_qs, qs)
    _tree_bitwise(ref_hist, hist)
    # every chunk left a checkpoint; retention kept the newest two
    assert mgr.latest_step() == ITERS
    assert len(mgr.all_steps()) <= 2


def test_chunked_no_eval_no_faults(setting, tmp_path):
    env, apps, cfg, wb, keys, _ = setting
    ref_qs, _ = env.train_batched(apps, cfg, wb, keys)
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    qs, _ = env.train_batched_checkpointed(apps, cfg, wb, keys, mgr,
                                           ckpt_every=2)
    _tree_bitwise(ref_qs, qs)


class _Killer:
    """CheckpointManager proxy that simulates a crash: after ``die_after``
    successful saves, the next save raises (before writing anything) —
    the training loop dies exactly as a SIGKILL'd host would, leaving the
    directory in its last-consistent state."""

    def __init__(self, inner: CheckpointManager, die_after: int):
        self._inner = inner
        self._left = die_after

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def save(self, step, tree):
        if self._left <= 0:
            raise KeyboardInterrupt("simulated crash")
        self._left -= 1
        self._inner.save(step, tree)
        self._inner.wait()   # deterministic on-disk state at the crash


@pytest.mark.parametrize("die_after", [1, 2])
def test_kill_and_resume_bitwise(setting, tmp_path, die_after):
    env, apps, cfg, wb, keys, fs = setting
    ref_qs, ref_hist = env.train_batched(apps, cfg, wb, keys,
                                         eval_app=apps[0], faults=fs)
    ckdir = str(tmp_path / f"kill{die_after}")
    with pytest.raises(KeyboardInterrupt):
        env.train_batched_checkpointed(
            apps, cfg, wb, keys,
            _Killer(CheckpointManager(ckdir), die_after),
            ckpt_every=1, eval_app=apps[0], faults=fs)
    # restart: a fresh process constructs a fresh manager over the same
    # directory and the run picks up where the last complete save left it
    mgr2 = CheckpointManager(ckdir)
    assert mgr2.latest_step() == die_after
    qs, hist = env.train_batched_checkpointed(
        apps, cfg, wb, keys, mgr2, ckpt_every=1,
        eval_app=apps[0], faults=fs)
    _tree_bitwise(ref_qs, qs)
    _tree_bitwise(ref_hist, hist)


def test_resume_past_damaged_newest(setting, tmp_path):
    """A crash *during* the newest save (torn checkpoint) must fall back to
    the previous complete one and still finish bitwise-equal."""
    env, apps, cfg, wb, keys, fs = setting
    ref_qs, ref_hist = env.train_batched(apps, cfg, wb, keys,
                                         eval_app=apps[0], faults=fs)
    ckdir = str(tmp_path / "torn")
    with pytest.raises(KeyboardInterrupt):
        env.train_batched_checkpointed(
            apps, cfg, wb, keys, _Killer(CheckpointManager(ckdir), 3),
            ckpt_every=1, eval_app=apps[0], faults=fs)
    # tear the newest checkpoint: manifest written but a leaf vanished
    newest = os.path.join(ckdir, "step_00000003")
    leaves = [f for f in os.listdir(newest) if f.endswith(".npy")]
    os.remove(os.path.join(newest, leaves[0]))
    qs, hist = env.train_batched_checkpointed(
        apps, cfg, wb, keys, CheckpointManager(ckdir), ckpt_every=1,
        eval_app=apps[0], faults=fs)
    _tree_bitwise(ref_qs, qs)
    _tree_bitwise(ref_hist, hist)


def test_fresh_directory_trains_from_scratch(setting, tmp_path):
    env, apps, cfg, wb, keys, _ = setting
    mgr = CheckpointManager(str(tmp_path / "fresh"))
    assert mgr.latest_step() is None
    qs, _ = env.train_batched_checkpointed(apps, cfg, wb, keys, mgr,
                                           ckpt_every=ITERS)
    ref_qs, _ = env.train_batched(apps, cfg, wb, keys)
    _tree_bitwise(ref_qs, qs)
