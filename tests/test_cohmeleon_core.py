"""Unit + property tests for the Cohmeleon core (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: install the [test] extra
from hypothesis import given, settings, strategies as st

from repro.core import qlearn, rewards, state as cstate
from repro.core.modes import CoherenceMode, N_MODES, flush_kind
from repro.core.monitors import attribute_ddr
from repro.core.policies import (DecisionContext, ManualPolicy, QPolicy,
                                 RandomPolicy, EXTRA_SMALL_THRESHOLD)
from repro.soc.config import SOC0


# ----------------------------------------------------------------- state --
def test_state_space_size():
    assert cstate.N_STATES == 243          # 3^5, paper §4.2
    assert cstate.N_STATES * N_MODES == 972  # Q-table entries


@settings(max_examples=30, deadline=None)
@given(attrs=st.lists(st.integers(0, 2), min_size=5, max_size=5))
def test_state_encode_decode_roundtrip(attrs):
    idx = int(cstate.encode_attrs(jnp.asarray(attrs)))
    assert 0 <= idx < cstate.N_STATES
    assert list(cstate.decode_state(idx)) == attrs


def test_observe_buckets_footprint():
    geom = SOC0.geometry
    common = dict(
        active_modes=jnp.asarray([-1]), active_footprints=jnp.zeros(1),
        needed_tiles=jnp.zeros((1, 4), bool),
        target_tiles=jnp.asarray([True, False, False, False]), geom=geom)
    s_small = int(cstate.observe(target_footprint=1024.0, **common))
    s_large = int(cstate.observe(target_footprint=1e9, **common))
    assert cstate.decode_state(s_small)[4] == 0     # <= L2
    assert cstate.decode_state(s_large)[4] == 2     # > LLC slice


# ---------------------------------------------------------------- reward --
def test_reward_components_match_paper_forms():
    rs = rewards.init_reward_state(2)
    m1 = rewards.Measurement(exec_time=jnp.float32(100.0),
                             comm_cycles=jnp.float32(50.0),
                             total_cycles=jnp.float32(100.0),
                             offchip_accesses=jnp.float32(10.0),
                             footprint=jnp.float32(1000.0))
    r1, rs, (re1, rc1, rm1) = rewards.evaluate(rs, 0, m1)
    # First invocation: every component is at its own historical best.
    assert abs(float(re1) - 1.0) < 1e-6
    assert abs(float(rc1) - 1.0) < 1e-6
    assert abs(float(rm1) - 1.0) < 1e-6

    # Second invocation twice as slow -> R_exec = min/current = 0.5.
    m2 = m1._replace(exec_time=jnp.float32(200.0))
    _, rs, (re2, _, _) = rewards.evaluate(rs, 0, m2)
    assert abs(float(re2) - 0.5) < 1e-6


def test_reward_mem_maps_extremes_to_unit_interval():
    rs = rewards.init_reward_state(1)
    base = rewards.Measurement(jnp.float32(1.0), jnp.float32(1.0),
                               jnp.float32(2.0), jnp.float32(100.0),
                               jnp.float32(100.0))
    _, rs, _ = rewards.evaluate(rs, 0, base)
    _, rs, (_, _, rm_best) = rewards.evaluate(
        rs, 0, base._replace(offchip_accesses=jnp.float32(0.0)))
    assert abs(float(rm_best) - 1.0) < 1e-6   # new min -> 1
    _, rs, (_, _, rm_worst) = rewards.evaluate(
        rs, 0, base._replace(offchip_accesses=jnp.float32(100.0)))
    assert abs(float(rm_worst)) < 1e-6        # at max -> 0


@settings(max_examples=20, deadline=None)
@given(x=st.floats(0, 1), y=st.floats(0, 1), seed=st.integers(0, 99))
def test_reward_bounded(x, y, seed):
    """Property: with weights summing to 1, reward in [0, ~1+eps]."""
    z = max(0.0, 1.0 - x - y)
    s = x + y + z or 1.0
    w = rewards.RewardWeights(x / s, y / s, z / s)
    rng = np.random.default_rng(seed)
    rs = rewards.init_reward_state(1)
    for _ in range(5):
        m = rewards.Measurement(
            jnp.float32(rng.uniform(1, 100)), jnp.float32(rng.uniform(1, 50)),
            jnp.float32(100.0), jnp.float32(rng.uniform(0, 10)),
            jnp.float32(1000.0))
        r, rs, _ = rewards.evaluate(rs, 0, m, w)
        assert 0.0 <= float(r) <= 1.0 + 1e-5


# --------------------------------------------------------------- qlearn ---
def test_q_update_rule_is_papers():
    """Q <- (1-a) Q + a R with a = alpha0 at step 0 (Q starts at q_init —
    optimistic init, a documented beyond-paper deviation)."""
    cfg = qlearn.QConfig(decay_steps=100)
    qs = qlearn.init_qstate(cfg)
    qs = qlearn.update(qs, cfg, 5, 2, 0.3)
    expected = (1 - cfg.alpha0) * cfg.q_init + cfg.alpha0 * 0.3
    assert abs(float(qs.qtable[5, 2]) - expected) < 1e-6
    assert int(qs.visits[5, 2]) == 1
    # paper-exact variant: zero-initialized table
    cfg0 = qlearn.QConfig(decay_steps=100, q_init=0.0)
    qs0 = qlearn.update(qlearn.init_qstate(cfg0), cfg0, 5, 2, 1.0)
    assert abs(float(qs0.qtable[5, 2]) - cfg0.alpha0 * 1.0) < 1e-6


def test_epsilon_alpha_linear_decay_to_zero():
    cfg = qlearn.QConfig(decay_steps=10)
    eps0, a0 = qlearn.schedule(cfg, jnp.asarray(0))
    eps5, a5 = qlearn.schedule(cfg, jnp.asarray(5))
    eps10, a10 = qlearn.schedule(cfg, jnp.asarray(20))
    assert abs(float(eps0) - 0.5) < 1e-6 and abs(float(a0) - 0.25) < 1e-6
    assert abs(float(eps5) - 0.25) < 1e-6
    assert float(eps10) == 0.0 and float(a10) == 0.0


def test_greedy_after_freeze_and_action_mask():
    cfg = qlearn.QConfig()
    qs = qlearn.init_qstate(cfg)
    qs = qs._replace(qtable=qs.qtable.at[0, 1].set(5.0).at[0, 3].set(9.0))
    qs = qlearn.freeze(qs)
    key = jax.random.PRNGKey(0)
    a = int(qlearn.select(qs, cfg, 0, key))
    assert a == 3
    mask = jnp.asarray([True, True, True, False])   # SoC3-style no-FULLY_COH
    a2 = int(qlearn.select(qs, cfg, 0, key, action_mask=mask))
    assert a2 == 1


def test_frozen_qtable_stops_learning():
    cfg = qlearn.QConfig()
    qs = qlearn.freeze(qlearn.init_qstate(cfg))
    qs2 = qlearn.update(qs, cfg, 0, 0, 100.0)
    assert float(qs2.qtable[0, 0]) == cfg.q_init   # unchanged
    assert int(qs2.step) == 0


# --------------------------------------------------------------- manual ---
def _ctx(footprint, active_modes=(), active_fp=0.0):
    return DecisionContext(
        acc_id=0, acc_name="fft", footprint=footprint, state_idx=0,
        active_modes=list(active_modes), active_footprint=active_fp,
        available=[True] * 4, soc=SOC0, rng=np.random.default_rng(0))


def test_manual_algorithm1_branches():
    pol = ManualPolicy()
    # extra-small -> FULLY_COH
    assert pol.decide(_ctx(2048)) == CoherenceMode.FULLY_COH
    # <= L2 with more coh-dma active than fully-coh -> FULLY_COH
    assert pol.decide(_ctx(32 * 1024, [CoherenceMode.COH_DMA])) \
        == CoherenceMode.FULLY_COH
    # <= L2 otherwise -> COH_DMA
    assert pol.decide(_ctx(32 * 1024)) == CoherenceMode.COH_DMA
    # footprint + active > LLC -> NON_COH
    assert pol.decide(_ctx(1 << 20, active_fp=SOC0.llc_total_bytes)) \
        == CoherenceMode.NON_COH_DMA
    # else with >= 2 non-coh active -> LLC_COH
    assert pol.decide(_ctx(
        512 * 1024, [CoherenceMode.NON_COH_DMA] * 2)) \
        == CoherenceMode.LLC_COH_DMA
    # else -> COH_DMA
    assert pol.decide(_ctx(512 * 1024)) == CoherenceMode.COH_DMA


# -------------------------------------------------------------- monitors --
def test_ddr_attribution_proportional():
    """The paper's ddr(k,m) equation: shares proportional to footprint."""
    ddr_total = jnp.asarray([100.0, 50.0])
    fp = jnp.asarray([[10.0, 0.0], [30.0, 50.0]])   # 2 accs x 2 tiles
    shares = attribute_ddr(ddr_total, fp)
    np.testing.assert_allclose(np.asarray(shares[0]), [25.0, 0.0])
    np.testing.assert_allclose(np.asarray(shares[1]), [75.0, 50.0])
    # conservation
    np.testing.assert_allclose(np.asarray(shares.sum(0)),
                               np.asarray(ddr_total))


def test_flush_kinds():
    assert flush_kind(CoherenceMode.NON_COH_DMA) == "full"
    assert flush_kind(CoherenceMode.LLC_COH_DMA) == "private"
    assert flush_kind(CoherenceMode.COH_DMA) == "none"
    assert flush_kind(CoherenceMode.FULLY_COH) == "none"
