"""PolicySpec lowering: one episode API for every policy family.

The redesign contract, pinned on Fig. 2-style isolated apps and Fig. 9
SoCs: every DES-side ``Policy`` lowers (``Policy.lower``) into a
:class:`repro.soc.vecenv.PolicySpec` whose unified episode reproduces
what the old per-kind episodes produced — which is exactly what the DES
produces on single-thread applications (the per-kind episodes' own
equivalence contract).  On top of that, the spec semantics are pinned
bitwise: the mode table is dead weight for learned specs, the Q-state is
dead weight for non-learned specs, and a heterogeneous spec batch equals
the same specs run one at a time.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qlearn
from repro.core.modes import CoherenceMode
from repro.core.policies import (FixedHeterogeneous, FixedHomogeneous,
                                 ManualPolicy, Policy, QPolicy, RandomPolicy)
from repro.soc import vecenv
from repro.soc.apps import make_phase
from repro.soc.config import SOCS, SOC_MOTIV_ISO
from repro.soc.des import (Application, Invocation, Phase, SoCSimulator,
                           Thread)

TILE_SEED = 11
FIG9_SOC = SOCS["SoC1"]


def _chain_app(soc, seed, n_phases=3):
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=1,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L")[:n_phases])
    ]
    return Application(name=f"{soc.name}-chain", phases=phases)


def _fig2_app(footprint=256 << 10):
    """One accelerator alone, one invocation — the Fig. 2 cell."""
    return Application(name="isolated", phases=[
        Phase(name="only",
              threads=[Thread(chain=[Invocation(0, float(footprint))])])])


@pytest.fixture(scope="module", params=["SoC-motiv-iso", "SoC1"])
def lowered(request):
    soc = {"SoC-motiv-iso": SOC_MOTIV_ISO, "SoC1": FIG9_SOC}[request.param]
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    app = _chain_app(soc, seed=3)
    return sim, env, app, vecenv.compile_app(app, soc, seed=TILE_SEED)


def _des_metrics(res):
    return (np.array([p.wall_time for p in res.phases]),
            np.array([p.offchip_accesses for p in res.phases]),
            [r.mode for p in res.phases for r in p.invocations])


def _assert_matches_des(sim, env, app, compiled, pol: Policy):
    des = sim.run(app, pol, seed=TILE_SEED, train=False)
    spec = pol.lower(env, compiled)
    _, res = env.episode_spec(compiled, spec)
    dt, do, dmodes = _des_metrics(des)
    assert dmodes == [int(m) for m in np.asarray(res.mode)], pol.name
    np.testing.assert_allclose(np.asarray(res.phase_time), dt, rtol=1e-4,
                               err_msg=pol.name)
    np.testing.assert_allclose(np.asarray(res.phase_offchip), do,
                               rtol=1e-4, atol=1e-3, err_msg=pol.name)


def test_fixed_lowering_matches_des(lowered):
    sim, env, app, compiled = lowered
    for mode in CoherenceMode:
        _assert_matches_des(sim, env, app, compiled, FixedHomogeneous(mode))


def test_manual_lowering_matches_des(lowered):
    sim, env, app, compiled = lowered
    _assert_matches_des(sim, env, app, compiled, ManualPolicy())


def test_fixed_heterogeneous_lowering_matches_des(lowered):
    sim, env, app, compiled = lowered
    modes = list(CoherenceMode)
    assignment = {p.name: modes[i % len(modes)]
                  for i, p in enumerate(sim.profiles)}
    _assert_matches_des(sim, env, app, compiled,
                        FixedHeterogeneous(assignment))


def test_fixed_lowering_matches_des_on_fig2_cell(lowered):
    """The Fig. 2 protocol (isolated accelerator, one invocation)."""
    sim, env, _, _ = lowered
    app = _fig2_app()
    compiled = vecenv.compile_app(app, sim.soc, seed=TILE_SEED)
    for mode in CoherenceMode:
        _assert_matches_des(sim, env, app, compiled, FixedHomogeneous(mode))


def test_q_lowering_equals_learned_episode_bitwise(lowered):
    """QPolicy.lower drops the trained table into the unified episode
    unchanged: same key -> bitwise-identical traces as the plain learned
    episode (the old 'q' kind's exact noise/selection protocol)."""
    _, env, _, compiled = lowered
    cfg = qlearn.QConfig(decay_steps=compiled.n_steps)
    qs, _ = env.episode(compiled, policy="q", cfg=cfg,
                        key=jax.random.PRNGKey(2))     # train one episode
    pol = QPolicy(cfg)
    pol.qs = qs
    key = jax.random.PRNGKey(9)
    _, via_lower = env.episode_spec(compiled, pol.lower(env, compiled),
                                    cfg=cfg, key=key)
    _, via_kind = env.episode(compiled, policy="q",
                              qstate=qlearn.freeze(qs), cfg=cfg, key=key)
    for a, b in zip(via_lower, via_kind):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_learned_spec_mode_table_is_dead_weight(lowered):
    """``learned=True`` must make the precomputed mode table unreachable:
    garbage modes produce bitwise-identical episodes."""
    _, env, _, compiled = lowered
    spec = env.lower(compiled, "q", qstate=qlearn.frozen_qstate())
    garbage = spec._replace(modes=jnp.full_like(spec.modes, 3))
    key = jax.random.PRNGKey(4)
    _, a = env.episode_spec(compiled, spec, key=key)
    _, b = env.episode_spec(compiled, garbage, key=key)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_nonlearned_spec_qstate_is_dead_weight(lowered):
    """``learned=False`` must make the Q branch inert: swapping the
    placeholder for a trained frozen table changes nothing, and the
    returned state is value-identical to the input (no-op update)."""
    _, env, _, compiled = lowered
    spec = ManualPolicy().lower(env, compiled)
    trained = qlearn.freeze(qlearn.update(
        qlearn.init_qstate(), qlearn.QConfig(), 7, 1, 0.25))
    swapped = spec._replace(qstate=trained)
    qs_a, a = env.episode_spec(compiled, spec, key=jax.random.PRNGKey(0))
    qs_b, b = env.episode_spec(compiled, swapped,
                               key=jax.random.PRNGKey(8))
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_array_equal(np.asarray(qs_b.qtable),
                                  np.asarray(trained.qtable))
    np.testing.assert_array_equal(np.asarray(qs_b.visits),
                                  np.asarray(trained.visits))
    assert int(qs_b.step) == int(trained.step)


def test_random_lowering_mixes_modes(lowered):
    _, env, _, compiled = lowered
    spec = RandomPolicy().lower(env, compiled)
    _, res = env.episode_spec(compiled, spec, key=jax.random.PRNGKey(1))
    assert len(set(int(m) for m in np.asarray(res.mode))) >= 2


def test_mixed_spec_batch_equals_individual_episodes(lowered):
    """VecEnv.episodes over stacked heterogeneous specs == each spec run
    alone (same keys) — the single-SoC mixed-policy sweep is sound."""
    sim, env, _, compiled = lowered
    pols = [FixedHomogeneous(CoherenceMode.LLC_COH_DMA), ManualPolicy(),
            RandomPolicy()]
    specs = vecenv.stack_specs([p.lower(env, compiled) for p in pols])
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(len(pols)) + 40)
    batch = env.episodes(compiled, specs, keys=keys)
    for i, pol in enumerate(pols):
        _, solo = env.episode_spec(compiled, pol.lower(env, compiled),
                                   key=keys[i])
        for lb, ls in zip(batch, solo):
            a, b = np.asarray(lb)[i], np.asarray(ls)
            if np.issubdtype(a.dtype, np.integer):
                np.testing.assert_array_equal(a, b, err_msg=pol.name)
            else:
                np.testing.assert_allclose(a, b, rtol=1e-6, atol=0,
                                           err_msg=pol.name)


def test_placeholder_mlp_attach_is_bitwise_noop(lowered):
    """Attaching the inert placeholder network to a table spec
    (``attach_placeholder_mlp``) must change nothing: episode results are
    bitwise-identical to the bare spec and the placeholder pack comes
    back untouched — the qfun analogue of the dead-weight pins above."""
    from repro.soc import nn as socnn

    _, env, _, compiled = lowered
    key = jax.random.PRNGKey(4)
    for pol in (QPolicy(qlearn.QConfig()), ManualPolicy()):
        spec = pol.lower(env, compiled)
        qs0, res0 = env.episode_spec(compiled, spec, key=key)
        (qs1, mlp1), res1 = env.episode_spec(
            compiled, vecenv.attach_placeholder_mlp(spec), key=key)
        for a, b in zip(jax.tree_util.tree_leaves((qs0, res0)),
                        jax.tree_util.tree_leaves((qs1, res1))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=pol.name)
        ph = socnn.frozen_mlp_qstate()
        np.testing.assert_array_equal(np.asarray(mlp1.wpack),
                                      np.asarray(ph.wpack))
        assert int(mlp1.step) == 0


@pytest.mark.parametrize("fused", [False, True])
def test_distilled_mlp_selects_identical_modes(lowered, fused):
    """``mlp_from_qtable`` (one-hot embedding, weights = the table) fed to
    the qfun spec selects exactly the modes of the frozen table spec it
    was distilled from, under both episode lowerings — the spec-lowering
    equivalence contract for the function-approximation family."""
    from repro.soc import nn as socnn

    sim, _, app, _ = lowered
    env = vecenv.VecEnv(sim.soc, seed=0, fused_step=fused)
    compiled = vecenv.compile_app(app, sim.soc, seed=TILE_SEED)
    cfg = qlearn.QConfig(decay_steps=compiled.n_steps)
    qs, _ = env.episode(compiled, policy="q", cfg=cfg,
                        key=jax.random.PRNGKey(2))
    qs = qlearn.freeze(qs)
    pol = QPolicy(cfg)
    pol.qs = qs
    key = jax.random.PRNGKey(9)
    _, res_t = env.episode_spec(compiled, pol.lower(env, compiled),
                                cfg=cfg, key=key)
    mspec = vecenv.mlp_policy_spec(
        socnn.freeze(socnn.mlp_from_qtable(qs.qtable)), compiled.schedule)
    (_, _), res_m = env.episode_spec(compiled, mspec, cfg=cfg, key=key)
    np.testing.assert_array_equal(np.asarray(res_t.mode),
                                  np.asarray(res_m.mode))
    np.testing.assert_array_equal(np.asarray(res_t.state_idx),
                                  np.asarray(res_m.state_idx))


def test_base_policy_has_no_lowering():
    class Weird(Policy):
        name = "weird"

    with pytest.raises(NotImplementedError, match="backend='des'"):
        Weird().lower(None, None)
