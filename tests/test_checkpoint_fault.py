"""Checkpoint/restart, retention, elastic re-mesh, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.fault import (ElasticRunner, HeartbeatMonitor,
                                     StragglerDetector)


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                   "c": jnp.asarray(7, jnp.int32)},
    }


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "step1")
    ckpt.save(path, t)
    restored = ckpt.restore(path, jax.eval_shape(lambda: t))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)),
        t, restored)
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_ckpt_shape_mismatch_rejected(tmp_path):
    t = _tree()
    path = str(tmp_path / "step1")
    ckpt.save(path, t)
    bad = dict(t, a=jnp.zeros((2, 2)))
    with pytest.raises(ValueError):
        ckpt.restore(path, jax.eval_shape(lambda: bad))


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for step in (1, 2, 3, 4):
        mgr.save(step, {"x": jnp.asarray(step)})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    restored = mgr.restore({"x": jnp.asarray(0)})
    assert int(restored["x"]) == 4


def test_manager_async_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    mgr.save(7, {"x": jnp.ones((1000, 100))})
    mgr.wait()
    assert mgr.latest_step() == 7


def test_restart_resumes_from_latest_complete(tmp_path):
    """A partially-written checkpoint must be invisible after restart."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    mgr.save(5, {"x": jnp.asarray(5.0)})
    # simulate a crash mid-write: stray tmp dir without manifest
    os.makedirs(tmp_path / "step_00000009")
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert mgr2.latest_step() == 5


def test_restore_falls_back_past_deleted_step(tmp_path):
    """latest_step() races retention pruning: the newest step a restarted
    job discovered can be rmtree'd by a concurrent writer before its
    leaves are read.  restore(step=None) must fall back to the next
    restorable checkpoint instead of dying on FileNotFoundError."""
    import shutil

    mgr = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    for step in (1, 2, 3):
        mgr.save(step, {"x": jnp.asarray(float(step))})
    # simulate the race: step 3 vanishes after discovery, before read
    shutil.rmtree(tmp_path / "step_00000003")
    restored = mgr.restore({"x": jnp.asarray(0.0)})
    assert float(restored["x"]) == 2.0


def test_restore_falls_back_past_corrupt_manifest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    for step in (1, 2):
        mgr.save(step, {"x": jnp.asarray(float(step))})
    with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
        f.write("{ not json")
    restored = mgr.restore({"x": jnp.asarray(0.0)})
    assert float(restored["x"]) == 1.0


def test_restore_falls_back_past_missing_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    for step in (1, 2):
        mgr.save(step, {"x": jnp.asarray(float(step))})
    newest = tmp_path / "step_00000002"
    for name in os.listdir(newest):
        if name.endswith(".npy"):
            os.remove(newest / name)
    restored = mgr.restore({"x": jnp.asarray(0.0)})
    assert float(restored["x"]) == 1.0


def test_restore_all_damaged_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    mgr.save(1, {"x": jnp.asarray(1.0)})
    with open(tmp_path / "step_00000001" / "manifest.json", "w") as f:
        f.write("garbage")
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jnp.asarray(0.0)})


def test_restore_explicit_step_never_falls_back(tmp_path):
    """A pinned step is a hard reference: damage is the caller's error to
    see, not something to paper over with an older checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    for step in (1, 2):
        mgr.save(step, {"x": jnp.asarray(float(step))})
    with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
        f.write("garbage")
    with pytest.raises(Exception):
        mgr.restore({"x": jnp.asarray(0.0)}, step=2)
    # the implicit path still finds step 1
    assert float(mgr.restore({"x": jnp.asarray(0.0)})["x"]) == 1.0


def test_orphaned_tmp_dirs_swept_on_construction(tmp_path):
    """A writer SIGKILL'd inside ckpt.save leaves a .ckpt-tmp-* dir whose
    atomic rename never ran; a restarted manager must clean it up."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    mgr.save(1, {"x": jnp.asarray(1.0)})
    orphan = tmp_path / ".ckpt-tmp-dead1234"
    os.makedirs(orphan)
    with open(orphan / "leaf_0.npy", "w") as f:
        f.write("partial")
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert not orphan.exists()
    assert mgr2.latest_step() == 1


def test_all_steps_tolerates_missing_directory(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "sub"), keep=3)
    import shutil

    shutil.rmtree(tmp_path / "sub")
    assert mgr.all_steps() == []
    assert mgr.latest_step() is None


def test_elastic_restore_across_meshes(tmp_path):
    """Save under one sharding, restore under another (elastic re-mesh)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    devs = jax.devices()
    mesh1 = Mesh(np.asarray(devs[:1]).reshape(1), ("data",))
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    t = jax.device_put(t, NamedSharding(mesh1, P("data")))
    path = str(tmp_path / "s")
    ckpt.save(path, t)
    # "new cluster": different mesh (same devices here, CPU container)
    mesh2 = Mesh(np.asarray(devs[:1]).reshape(1), ("model",))
    shardings = {"w": NamedSharding(mesh2, P(None, "model"))}
    restored = ckpt.restore(path, jax.eval_shape(lambda: t), shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(16, dtype=np.float32).reshape(4, 4))


def test_heartbeat_failure_detection():
    mon = HeartbeatMonitor(n_workers=3, timeout=10.0)
    mon.beat(0, now=100.0)
    mon.beat(1, now=105.0)
    # worker 2 never beats; worker 0 went silent
    assert set(mon.failed_workers(now=111.0)) == {0, 2}


def test_straggler_detection():
    det = StragglerDetector(threshold=1.5, window=10)
    for _ in range(10):
        for w in range(4):
            det.record(w, 1.0 if w != 2 else 2.5)
    assert det.stragglers() == [2]


def test_elastic_runner_recovers_from_injected_failure(tmp_path):
    """Full loop: train, checkpoint, inject node loss, re-mesh, resume."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)

    def build(devices):
        def step_fn(state):
            return {"x": state["x"] + 1.0}
        shardings = None
        return step_fn, shardings

    runner = ElasticRunner(build, mgr, ckpt_every=5)
    state = {"x": jnp.asarray(0.0)}
    final, step = runner.run(state, n_steps=20, devices=jax.devices(),
                             inject_failure_at=12,
                             surviving_devices=jax.devices())
    assert runner.recoveries == 1
    assert step == 20
    # after recovery we resumed from step 10's checkpoint and re-ran
    assert float(final["x"]) == 20.0
