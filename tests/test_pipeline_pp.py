"""Pipeline-parallelism correctness on multiple host devices.

Spawned with XLA_FLAGS=--xla_force_host_platform_device_count=8 via a
subprocess so the main pytest process keeps its single-device view.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply, make_pipe_mesh

n_stages, m, mb, d = 4, 8, 2, 16
rng = np.random.default_rng(0)
stage_w = jnp.asarray(rng.normal(size=(n_stages, d, d)) * 0.3, jnp.float32)
mbs = jnp.asarray(rng.normal(size=(m, mb, d)), jnp.float32)

def stage_fn(w, x):
    return jnp.tanh(x @ w)

mesh = make_pipe_mesh(n_stages)
out = pipeline_apply(stage_fn, stage_w, mbs, mesh)

# sequential reference: microbatch through all stages in order
ref = mbs
for s in range(n_stages):
    ref = jnp.tanh(ref @ stage_w[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE_OK" in proc.stdout, proc.stderr[-2000:]
