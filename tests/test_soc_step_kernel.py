"""soc_step fused-episode kernel vs the pure-jnp reference scan.

Separate from tests/test_kernels.py so it runs without the optional
``hypothesis`` dependency — the soc_step oracle checks are part of the
tier-1 suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.soc_step import ops as soc_step_ops
from repro.kernels.soc_step.ref import StepInputs


def _soc_step_case(learned: bool):
    """(args, n_steps) for fused_episode on a small real schedule."""
    from repro.core import qlearn, rewards
    from repro.soc import vecenv
    from repro.soc.apps import make_phase
    from repro.soc.config import SOC_MOTIV_PAR
    from repro.soc.des import Application

    soc = SOC_MOTIV_PAR
    env = vecenv.VecEnv(soc, seed=1)
    rng = np.random.default_rng(3)
    phases = [make_phase(rng, soc, name=f"p{i}", n_threads=2,
                         size_classes=[c], chain_len=2, loops=1)
              for i, c in enumerate(("S", "M"))]
    app = Application(name="soc-step-kernel-test", phases=phases)
    compiled = vecenv.compile_app(app, soc, seed=7)
    sched = compiled.schedule
    n_steps = sched.acc_id.shape[0]

    cfg = qlearn.QConfig(decay_steps=n_steps)
    qs0 = qlearn.init_qstate(cfg)
    noise = qlearn.sample_select_noise(jax.random.PRNGKey(0), (n_steps,),
                                       env.masks.shape[-1])
    inc = (sched.valid & ~qs0.frozen).astype(jnp.int32)
    eps_t, alpha_t = qlearn.decay_arrays(cfg, qs0.step, qs0.frozen, inc)
    xs = StepInputs(
        acc_id=sched.acc_id, footprint=sched.footprint, tiles=sched.tiles,
        thread=sched.thread, fresh=sched.fresh, others=sched.others,
        valid=sched.valid,
        pre_mode=(sched.acc_id % env.masks.shape[-1]).astype(jnp.int32),
        profile=env.pmat[sched.acc_id], avail=env.masks[sched.acc_id],
        eps=eps_t, alpha=alpha_t, u_explore=noise.u_explore,
        g_pick=noise.g_pick, g_tie=noise.g_tie)
    extrema0 = rewards.init_reward_state(env.pmat.shape[0]).extrema
    args = (env.static, jnp.asarray(learned, bool),
            rewards.PAPER_DEFAULT_WEIGHTS, qs0.qtable, extrema0, xs)
    return args, n_steps


@pytest.mark.parametrize("ddr,gated,learned", [
    (False, False, True),
    (True, True, True),      # the stacked / fidelity-reward configuration
    (False, False, False),   # spec-mode (fixed/manual) policies
])
def test_soc_step_kernel_matches_ref(ddr, gated, learned):
    """The Pallas episode kernel (interpret mode on CPU) reproduces the
    pure-XLA reference scan over a real compiled schedule."""
    args, _ = _soc_step_case(learned)
    qt_ref, ys_ref = soc_step_ops.fused_episode(
        *args, ddr_attribution=ddr, gated=gated, kernel=False)
    qt_ker, ys_ker = soc_step_ops.fused_episode(
        *args, ddr_attribution=ddr, gated=gated, kernel=True,
        interpret=True)
    np.testing.assert_allclose(np.asarray(qt_ker), np.asarray(qt_ref),
                               rtol=2e-5, atol=2e-5)
    names = ("mode", "state_idx", "action", "exec_time", "offchip",
             "reward")
    for name, a, b in zip(names, ys_ker, ys_ref):
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(a.dtype, np.integer):
            np.testing.assert_array_equal(a, b, err_msg=name)
        else:
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5,
                                       err_msg=name)


@pytest.mark.parametrize("ddr,gated", [(False, False), (True, True)])
def test_soc_step_kernel_matches_ref_mlp(ddr, gated):
    """The nn-policy (qfun) branch through the Pallas kernel: the Q-table
    and every decision trace (mode, state, action) match the reference
    scan bitwise; float traces and the TD-updated weight pack agree to
    ~1 ULP (the interpret grid loop and lax.scan contract FMAs
    differently on CPU — the tabular cases above stay fully bitwise)."""
    from repro.soc import nn as socnn

    args, _ = _soc_step_case(True)
    mlp = socnn.init_mlp_qstate(jax.random.PRNGKey(5))
    kw = dict(ddr_attribution=ddr, gated=gated,
              qfun=jnp.ones((), bool), mlp=mlp)
    qt_ref, wp_ref, ys_ref = soc_step_ops.fused_episode(
        *args, kernel=False, **kw)
    qt_ker, wp_ker, ys_ker = soc_step_ops.fused_episode(
        *args, kernel=True, interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(qt_ker), np.asarray(qt_ref))
    np.testing.assert_allclose(np.asarray(wp_ker), np.asarray(wp_ref),
                               rtol=0, atol=1e-6)
    assert bool(jnp.any(wp_ker != mlp.wpack))   # the kernel actually trained
    names = ("mode", "state_idx", "action", "exec_time", "offchip",
             "reward")
    for name, a, b in zip(names, ys_ker, ys_ref):
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(a.dtype, np.integer):
            np.testing.assert_array_equal(a, b, err_msg=name)
        else:
            np.testing.assert_allclose(a, b, rtol=2e-6, atol=1e-6,
                                       err_msg=name)


def test_soc_step_kernel_placeholder_mlp_is_dead_weight():
    """A table spec with an inert placeholder network attached runs the
    kernel's nn program (qfun=False) but must be bitwise-indistinguishable
    from the tabular kernel, with the weight pack returned untouched."""
    from repro.soc import nn as socnn

    args, _ = _soc_step_case(True)
    ph = socnn.frozen_mlp_qstate()
    qt_a, ys_a = soc_step_ops.fused_episode(*args, kernel=True,
                                            interpret=True)
    qt_b, wp_b, ys_b = soc_step_ops.fused_episode(
        *args, kernel=True, interpret=True,
        qfun=jnp.zeros((), bool), mlp=ph)
    np.testing.assert_array_equal(np.asarray(qt_a), np.asarray(qt_b))
    np.testing.assert_array_equal(np.asarray(wp_b), np.asarray(ph.wpack))
    for a, b in zip(ys_a, ys_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_soc_step_cpu_auto_dispatch_is_ref():
    """kernel=None on a CPU backend lowers to the XLA reference scan —
    bitwise, not just close (the --fidelity contract)."""
    args, _ = _soc_step_case(True)
    auto = soc_step_ops.fused_episode(*args, ddr_attribution=True,
                                      gated=True)
    ref = soc_step_ops.fused_episode(*args, ddr_attribution=True,
                                     gated=True, kernel=False)
    for a, b in zip(jax.tree_util.tree_leaves(auto),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
