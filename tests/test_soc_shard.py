"""repro.soc.shard: the shard_map scale-out must not change results.

On a 1-device host the default path falls back to the plain vmap call —
bitwise-identical by construction, pinned here — while
``force_shard_map=True`` exercises the real shard_map wrapper on a
single-device lane mesh: integer state (visits, step counters, modes)
stays bitwise and float leaves agree to roundoff (the wrapper re-jits
the program, so XLA may refuse reductions in a different order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qlearn, rewards
from repro.soc import shard, vecenv
from repro.soc.apps import make_phase
from repro.soc.config import SOC_MOTIV_ISO, SOC_MOTIV_PAR
from repro.soc.des import Application, SoCSimulator
from repro.soc.stacked import StackedVecEnv


def _chain_app(soc, seed, n_threads=2):
    rng = np.random.default_rng(seed)
    phases = [make_phase(rng, soc, name=f"p{i}", n_threads=n_threads,
                         size_classes=[c], chain_len=2, loops=2)
              for i, c in enumerate(("S", "M"))]
    return Application(name=f"{soc.name}-shard-test", phases=phases)


def _tree_bitwise(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _tree_close(a, b, rtol=1e-5, atol=1e-6):
    """Integer leaves bitwise, float leaves to roundoff."""
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if np.issubdtype(x.dtype, np.floating):
            np.testing.assert_allclose(x, y, rtol=rtol, atol=atol)
        else:
            np.testing.assert_array_equal(x, y)


def test_lane_mesh_covers_all_devices():
    mesh = shard.lane_mesh()
    assert mesh.axis_names == ("lanes",)
    assert int(mesh.devices.size) == jax.device_count()


# ----------------------------------------------------------- VecEnv (B) ----
@pytest.fixture(scope="module")
def vec_setup():
    soc = SOC_MOTIV_PAR
    env = vecenv.VecEnv(soc, seed=0)
    app = _chain_app(soc, seed=4)
    compiled = vecenv.compile_app(app, soc, seed=7)
    iters, B = 2, 4
    cfg = qlearn.QConfig(decay_steps=compiled.n_steps * iters)
    wb = rewards.stack_weights([rewards.PAPER_DEFAULT_WEIGHTS] * B)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(B))
    return env, [compiled] * iters, cfg, wb, keys


def test_train_batched_default_fallback_bitwise(vec_setup):
    env, apps, cfg, wb, keys = vec_setup
    direct = env.train_batched(apps, cfg, wb, keys)
    via = shard.sharded_train_batched(env, apps, cfg, wb, keys)
    _tree_bitwise(direct, via)


def test_train_batched_forced_shard_map(vec_setup):
    env, apps, cfg, wb, keys = vec_setup
    qs, _ = env.train_batched(apps, cfg, wb, keys)
    qs_s, _ = shard.sharded_train_batched(env, apps, cfg, wb, keys,
                                          force_shard_map=True)
    _tree_close(qs, qs_s)
    # integer Q-state leaves must stay exactly equal even under shard_map
    np.testing.assert_array_equal(np.asarray(qs.visits),
                                  np.asarray(qs_s.visits))
    np.testing.assert_array_equal(np.asarray(qs.step),
                                  np.asarray(qs_s.step))


# --------------------------------------------------- StackedVecEnv (K, B) ----
@pytest.fixture(scope="module")
def stacked_setup():
    sims = [SoCSimulator(SOC_MOTIV_ISO, seed=1),
            SoCSimulator(SOC_MOTIV_PAR, seed=1)]
    env = StackedVecEnv.from_simulators(sims)
    apps = [_chain_app(sim.soc, seed=5) for sim in sims]
    iters, B = 2, 4
    stacked_iters = [env.compile(apps, seed=it) for it in range(iters)]
    cfg = qlearn.QConfig(decay_steps=jnp.asarray(
        [s * iters for s in stacked_iters[0].n_steps], jnp.int32))
    wb = rewards.stack_weights([rewards.PAPER_DEFAULT_WEIGHTS] * B)
    keys = env._default_keys(env.n_lanes, B)
    return env, stacked_iters, cfg, wb, keys


def test_stacked_train_batched_fallback_bitwise(stacked_setup):
    env, its, cfg, wb, keys = stacked_setup
    direct = env.train_batched(its, cfg, wb, keys)
    via = shard.sharded_train_batched_stacked(env, its, cfg, wb, keys)
    _tree_bitwise(direct, via)


def test_stacked_train_batched_forced_shard_map(stacked_setup):
    env, its, cfg, wb, keys = stacked_setup
    qs, _ = env.train_batched(its, cfg, wb, keys)
    qs_s, _ = shard.sharded_train_batched_stacked(env, its, cfg, wb, keys,
                                                  force_shard_map=True)
    _tree_close(qs, qs_s)


def test_episodes_fallback_bitwise_and_forced_close(stacked_setup):
    env, its, cfg, wb, keys = stacked_setup
    stacked = its[0]
    qs, _ = env.train_batched(its, cfg, wb, keys)
    specs = env.lower_qstates(stacked, qs, freeze=True)
    ekeys = env._default_keys(*specs.learned.shape)
    direct = env.episodes(stacked, specs, cfg, ekeys)
    via = shard.sharded_episodes(env, stacked, specs, cfg, ekeys)
    _tree_bitwise(direct, via)
    forced = shard.sharded_episodes(env, stacked, specs, cfg, ekeys,
                                    force_shard_map=True)
    np.testing.assert_array_equal(np.asarray(direct.mode),
                                  np.asarray(forced.mode))
    np.testing.assert_array_equal(np.asarray(direct.state_idx),
                                  np.asarray(forced.state_idx))
    _tree_close(direct, forced)
