"""Neural policy subsystem (soc.nn): the function-approximation Q agent.

Contracts, from unit to end-to-end:

  * the packed-weight forward/backward pair is shape-correct, the one-hot
    distillation of a Q-table reproduces gathered table rows exactly, and
    the semi-gradient TD update moves Q(s, a) toward R while frozen /
    ungated / non-finite updates are bitwise no-ops;
  * an MLP PolicySpec runs bitwise-equivalently through the fused and
    unfused episode lowerings on the integer traces (modes, states,
    actions, step counters), with float traces and the TD-updated weight
    pack agreeing to ~1 ULP (XLA contracts FMAs differently across the
    two scan bodies on CPU);
  * non-finite weights degrade every step to NON_COH through the
    existing non-finite-row fallback (the PR-7 fault contract);
  * the DES host mirror (MLPQPolicy.decide) selects the same modes as
    the lowered spec on single-thread apps — the fidelity cross-check
    the tabular families already pin;
  * serving carries and trains the weights in ServeCarry.wpack;
  * the portfolio trainer learns across (SoC x app) pairs and is
    crash-resumable: interrupted + resumed == uninterrupted, bitwise.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import qlearn
from repro.checkpoint.manager import CheckpointManager
from repro.soc import nn as socnn, vecenv as vec
from repro.soc.apps import make_phase
from repro.soc.config import SOCS, SOC_MOTIV_ISO, SOC_MOTIV_PAR
from repro.soc.des import Application, SoCSimulator

TILE_SEED = 11


def _chain_app(soc, seed, n_threads=1, n_phases=3):
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=n_threads,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L")[:n_phases])
    ]
    return Application(name=f"{soc.name}-nnchain", phases=phases)


# ------------------------------------------------------------------- units
def test_pack_shape_and_forward_shape():
    cfg = socnn.MLPConfig()
    dims = socnn.mlp_dims(cfg)
    assert dims == (socnn.N_SENSE_FEATURES, 16, 16, 4)
    rows, cols = socnn.pack_shape(dims)
    assert (rows, cols) == (sum(d + 1 for d in dims[:-1]), 16)
    mlp = socnn.init_mlp_qstate(jax.random.PRNGKey(0), cfg)
    assert mlp.wpack.shape == (rows, cols)
    x = jnp.linspace(0.0, 1.0, dims[0])
    row = socnn.forward_packed(mlp.wpack, x, dims)
    assert row.shape == (4,) and bool(jnp.all(jnp.isfinite(row)))


def test_fresh_network_is_all_tie_at_optimistic_init():
    """Output layer starts at W=0, b=q_init, so every Q-row is the tabular
    optimistic all-tie — untrained MLP == Random policy under the
    randomized-argmax selection (the paper's iteration-0 property)."""
    for ctor in (lambda: socnn.init_mlp_qstate(jax.random.PRNGKey(3)),
                 socnn.frozen_mlp_qstate):
        mlp = ctor()
        dims = socnn.mlp_dims(mlp.cfg)
        for t in np.linspace(0.0, 1.0, 5):
            x = jnp.full((dims[0],), jnp.float32(t))
            row = socnn.forward_packed(mlp.wpack, x, dims)
            np.testing.assert_array_equal(np.asarray(row), np.ones(4))
    # the placeholder is deterministic — two builds are bitwise-identical
    a, b = socnn.frozen_mlp_qstate(), socnn.frozen_mlp_qstate()
    np.testing.assert_array_equal(np.asarray(a.wpack), np.asarray(b.wpack))
    assert bool(a.frozen) and float(a.lr) == 0.0


def test_onehot_distillation_reproduces_table_rows_exactly():
    rng = np.random.default_rng(0)
    qtable = jnp.asarray(rng.normal(size=(243, 4)), jnp.float32)
    mlp = socnn.mlp_from_qtable(qtable)
    dims = socnn.mlp_dims(mlp.cfg)
    for s in (0, 7, 100, 242):
        x = (socnn._iota1d(243) == s).astype(jnp.float32)
        row = socnn.forward_packed(mlp.wpack, x, dims)
        np.testing.assert_array_equal(np.asarray(row),
                                      np.asarray(qtable[s]))


def test_td_update_moves_q_toward_reward_and_gates_are_noops():
    cfg = socnn.MLPConfig()
    dims = socnn.mlp_dims(cfg)
    mlp = socnn.init_mlp_qstate(jax.random.PRNGKey(1), cfg)
    x = jnp.linspace(0.1, 0.9, dims[0])
    action, reward = jnp.asarray(2, jnp.int32), jnp.float32(0.25)

    def q_a(wp):
        return float(socnn.forward_packed(wp, x, dims)[2])

    d0 = abs(q_a(mlp.wpack) - 0.25)
    wp = mlp.wpack
    for _ in range(20):
        wp = socnn.td_update_packed(wp, x, action, reward,
                                    jnp.float32(0.05), dims,
                                    jnp.asarray(True))
    assert abs(q_a(wp) - 0.25) < 0.2 * d0
    # gate off / zero step size / non-finite reward: bitwise no-ops
    for kw in ((jnp.float32(0.05), jnp.asarray(False)),
               (jnp.float32(0.0), jnp.asarray(True)),):
        out = socnn.td_update_packed(mlp.wpack, x, action, reward,
                                     kw[0], dims, kw[1])
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(mlp.wpack))
    out = socnn.td_update_packed(mlp.wpack, x, action, jnp.float32(np.nan),
                                 jnp.float32(0.05), dims, jnp.asarray(True))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(mlp.wpack))


def test_mlp_config_is_static_treedef():
    """MLPConfig rides the treedef: vmap/tree_map skip it and stacking
    states with mismatched configs fails at the structure level."""
    a = socnn.init_mlp_qstate(jax.random.PRNGKey(0))
    b = socnn.init_mlp_qstate(jax.random.PRNGKey(1))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), a, b)
    assert stacked.cfg is a.cfg
    c = socnn.mlp_from_qtable(jnp.zeros((243, 4)))
    with pytest.raises(ValueError):
        jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), a, c)


# ------------------------------------------------- episode-level contracts
@pytest.fixture(scope="module")
def nn_env():
    soc = SOC_MOTIV_PAR
    app = _chain_app(soc, seed=6, n_threads=2)
    compiled = vec.compile_app(app, soc, seed=TILE_SEED)
    mlp = socnn.init_mlp_qstate(jax.random.PRNGKey(7))
    return soc, app, compiled, mlp


def test_mlp_episode_fused_unfused_equivalence(nn_env):
    """The two episode lowerings take identical decisions everywhere
    (modes, states, actions, step counters — exact), and their float
    traces / trained packs agree to ~1 ULP: the extra network ops change
    how XLA contracts FMAs in the surrounding timing model, so full
    bitwise equality holds only for the table families (pinned in
    test_vecenv_equivalence)."""
    soc, _, compiled, mlp = nn_env
    cfg = qlearn.QConfig(decay_steps=compiled.n_steps)
    out = {}
    for fused in (False, True):
        env = vec.VecEnv(soc, seed=0, fused_step=fused)
        spec = vec.mlp_policy_spec(mlp, compiled.schedule)
        out[fused] = env.episode_spec(compiled, spec, cfg=cfg,
                                      key=jax.random.PRNGKey(3))
    (qs_a, mlp_a), res_a = out[False]
    (qs_b, mlp_b), res_b = out[True]
    np.testing.assert_array_equal(np.asarray(res_a.mode),
                                  np.asarray(res_b.mode))
    np.testing.assert_array_equal(np.asarray(res_a.state_idx),
                                  np.asarray(res_b.state_idx))
    assert int(mlp_a.step) == int(mlp_b.step) > 0
    # the (placeholder) table is untouched on both paths — bitwise
    np.testing.assert_array_equal(np.asarray(qs_a.qtable),
                                  np.asarray(qs_b.qtable))
    np.testing.assert_allclose(np.asarray(mlp_a.wpack),
                               np.asarray(mlp_b.wpack), rtol=0, atol=1e-6)
    for fld in ("exec_time", "offchip", "reward", "phase_time",
                "phase_offchip"):
        a, b = getattr(res_a, fld), getattr(res_b, fld)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=1e-6, err_msg=fld)
    assert bool(jnp.any(mlp_b.wpack != mlp.wpack))  # it actually learned


def test_non_finite_weights_degrade_to_non_coh(nn_env):
    soc, _, compiled, mlp = nn_env
    bad = socnn.freeze(mlp._replace(
        wpack=mlp.wpack.at[0, 0].set(jnp.nan)))
    for fused in (False, True):
        env = vec.VecEnv(soc, seed=0, fused_step=fused)
        spec = vec.mlp_policy_spec(bad, compiled.schedule)
        (_, _), res = env.episode_spec(compiled, spec,
                                       key=jax.random.PRNGKey(0))
        assert np.all(np.asarray(res.mode) == 0), fused


@pytest.mark.parametrize("socname", ["SoC-motiv-iso", "SoC1"])
def test_mlp_des_fidelity_single_thread(socname):
    """MLPQPolicy.decide (host features + greedy argmax) picks the same
    modes as the lowered qfun spec on single-thread apps, where the
    concurrent-set features are trivially equal — the same DES-vs-vecenv
    fidelity contract the tabular families pin.  The network is briefly
    trained first: a fresh one is an exact all-tie everywhere, where
    selection is *defined* to tie-break randomly."""
    soc = {"SoC-motiv-iso": SOC_MOTIV_ISO, "SoC1": SOCS["SoC1"]}[socname]
    sim = SoCSimulator(soc)
    env = vec.VecEnv.from_simulator(sim)
    app = _chain_app(soc, seed=3)
    compiled = vec.compile_app(app, soc, seed=TILE_SEED)
    cfg = qlearn.QConfig(decay_steps=compiled.n_steps * 2)
    mlp = socnn.init_mlp_qstate(jax.random.PRNGKey(7))
    for it in range(2):
        spec = vec.mlp_policy_spec(mlp, compiled.schedule)
        (_, mlp), _ = env.episode_spec(compiled, spec, cfg=cfg,
                                       key=jax.random.PRNGKey(it))
    mlp = socnn.freeze(mlp)
    pol = socnn.MLPQPolicy(mlp)
    des = sim.run(app, pol, seed=TILE_SEED, train=False)
    _, res = env.episode_spec(compiled, pol.lower(env, compiled))
    des_modes = [r.mode for p in des.phases for r in p.invocations]
    assert des_modes == [int(m) for m in np.asarray(res.mode)]
    dt = np.array([p.wall_time for p in des.phases])
    np.testing.assert_allclose(np.asarray(res.phase_time), dt, rtol=1e-4)


def test_serve_carries_and_trains_the_weights(nn_env):
    from repro.soc import traffic as traffic_mod

    soc, _, compiled, mlp = nn_env
    env = vec.VecEnv(soc, seed=0)
    senv = vec.ServeEnv(env, n_requests=64)
    tspec = traffic_mod.poisson(0.001, key=jax.random.PRNGKey(3))
    spec = vec.mlp_policy_spec(mlp, compiled.schedule)
    carry, _, sres = senv.serve(compiled, spec, tspec,
                                cfg=qlearn.QConfig(decay_steps=64),
                                key=jax.random.PRNGKey(1))
    assert int(sres.served) > 0
    assert bool(jnp.all(jnp.isfinite(carry.wpack)))
    assert bool(jnp.any(carry.wpack != mlp.wpack))
    # frozen network: served stream leaves the weights bitwise untouched
    fr = vec.mlp_policy_spec(socnn.freeze(mlp), compiled.schedule)
    carry_f, _, _ = senv.serve(compiled, fr, tspec,
                               key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(carry_f.wpack),
                                  np.asarray(mlp.wpack))


def test_stacked_lower_mlps_runs_k_by_b_grid():
    socs = [SOCS["SoC6"], SOCS["SoC2"]]
    from repro.soc import stacked as stk
    apps = [_chain_app(s, seed=i, n_phases=2) for i, s in enumerate(socs)]
    env = stk.StackedVecEnv(socs, seed=0)
    st = env.compile(apps)
    per_kb = [[socnn.init_mlp_qstate(jax.random.PRNGKey(k * 3 + b))
               for b in range(2)] for k in range(2)]
    mlps = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *row)
          for row in per_kb])
    specs = env.lower_mlps(st, mlps)
    assert specs.mlp.wpack.shape[:2] == (2, 2)
    assert bool(jnp.all(specs.qfun)) and bool(jnp.all(specs.mlp.frozen))
    res = env.episodes(st, specs, qlearn.QConfig())
    assert np.isfinite(np.asarray(res.phase_time)).all()


# ------------------------------------------------------ portfolio training
def _portfolio_items(n=2):
    items = []
    for i, name in enumerate(("SoC6", "SoC2")[:n]):
        soc = SOCS[name]
        env = vec.VecEnv(soc, seed=0)
        comps = [vec.compile_app(_chain_app(soc, seed=10 + i, n_phases=2),
                                 soc, seed=TILE_SEED)]
        items.append((env, comps))
    return items


def test_train_portfolio_learns_a_shared_network():
    items = _portfolio_items()
    cfg = qlearn.QConfig(decay_steps=2048)
    mlp, hist = socnn.train_portfolio(items, cfg, iterations=3, batch=2,
                                      key=jax.random.PRNGKey(1))
    assert hist.shape == (3,) and np.isfinite(np.asarray(hist)).all()
    assert int(mlp.step) > 0
    assert bool(jnp.all(jnp.isfinite(mlp.wpack)))
    # the shared pack moved off the all-tie init
    fresh = socnn.init_mlp_qstate(jax.random.PRNGKey(99))
    dims = socnn.mlp_dims(mlp.cfg)
    x = jnp.linspace(0.1, 0.9, dims[0])
    row = socnn.forward_packed(mlp.wpack, x, dims)
    assert len(np.unique(np.asarray(row))) > 1
    del fresh


class _Killer:
    """Simulated crash: dies (before writing) after N successful saves."""

    def __init__(self, inner: CheckpointManager, die_after: int):
        self._inner, self._left = inner, die_after

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def save(self, step, tree):
        if self._left <= 0:
            raise KeyboardInterrupt("simulated crash")
        self._left -= 1
        return self._inner.save(step, tree)


def test_train_portfolio_checkpoint_resume_is_bitwise(tmp_path):
    """Crash after iteration 1's snapshot, resume from the manager: final
    weights, step counter and history equal the uninterrupted run (the
    per-iteration keys are fold_in-derived, never carried)."""
    cfg = qlearn.QConfig(decay_steps=2048)
    key = jax.random.PRNGKey(5)
    full, hist_full = socnn.train_portfolio(
        _portfolio_items(), cfg, iterations=3, batch=2, key=key)

    ckdir = str(tmp_path / "ck")
    with pytest.raises(KeyboardInterrupt):
        socnn.train_portfolio(
            _portfolio_items(), cfg, iterations=3, batch=2, key=key,
            manager=_Killer(CheckpointManager(ckdir, async_write=False), 1))
    mgr2 = CheckpointManager(ckdir, async_write=False)
    assert mgr2.latest_step() == 1
    resumed, hist_res = socnn.train_portfolio(
        _portfolio_items(), cfg, iterations=3, batch=2, key=key,
        manager=mgr2)
    np.testing.assert_array_equal(np.asarray(resumed.wpack),
                                  np.asarray(full.wpack))
    assert int(resumed.step) == int(full.step)
    np.testing.assert_array_equal(np.asarray(hist_res),
                                  np.asarray(hist_full))
