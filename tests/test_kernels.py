"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp ref.py oracles (interpret=True executes the kernel bodies on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: install the [test] extra
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import wkv_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- flash ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, H, Hkv, Sq, Skv, hd)
    (1, 4, 4, 128, 128, 64),     # MHA
    (2, 8, 2, 128, 128, 64),     # GQA 4:1
    (1, 4, 1, 256, 256, 128),    # MQA
    (1, 2, 2, 128, 384, 64),     # cross-length (prefill-with-prefix)
])
@pytest.mark.parametrize("feat", [
    dict(causal=True),
    dict(causal=True, window=64),
    dict(causal=True, softcap=50.0),
    dict(causal=False),
])
def test_flash_attention(shape, dtype, feat):
    b, h, hkv, sq, skv, hd = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, hkv, hd)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_kv=64, **feat)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), **feat)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(jnp.swapaxes(ref, 1, 2), np.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bkv=st.sampled_from([32, 64, 128]),
    window=st.sampled_from([0, 32, 100]),
)
def test_flash_attention_block_invariance(bq, bkv, window):
    """Property: output must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, window=window,
                        block_q=bq, block_kv=bkv)
    b = flash_attention(q, k, v, causal=True, window=window,
                        block_q=128, block_kv=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- rwkv6 ----
@pytest.mark.parametrize("shape", [
    (1, 2, 32, 16), (2, 4, 64, 32), (1, 1, 128, 64),
])
def test_rwkv6_scan(shape):
    b, h, t, k = shape
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.normal(size=shape), jnp.float32)
    kk = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(rng.normal(size=shape), jnp.float32)
    logw = jnp.maximum(
        jnp.asarray(-np.exp(rng.normal(size=shape) * 0.5), jnp.float32), -4.0)
    u = jnp.asarray(rng.normal(size=(h, k)), jnp.float32)
    y, s = rwkv6_scan(r, kk, v, logw, u)
    yr, sr = wkv_ref(r, kk, v, logw, u, jnp.zeros((b, h, k, k)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), decay=st.floats(0.1, 3.5))
def test_rwkv6_state_composition(seed, decay):
    """Property: scanning T tokens == scanning two halves with carried
    state (the invariant multi-chunk serving relies on)."""
    rng = np.random.default_rng(seed)
    b, h, t, k = 1, 2, 64, 16
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, k)), jnp.float32)
    r, kk, v = mk(), mk(), mk()
    logw = jnp.maximum(jnp.asarray(
        -decay * np.abs(rng.normal(size=(b, h, t, k))), jnp.float32), -4.0)
    u = jnp.asarray(rng.normal(size=(h, k)), jnp.float32)
    y_full, s_full = wkv_ref(r, kk, v, logw, u, jnp.zeros((b, h, k, k)))
    half = t // 2
    y1, s1 = wkv_ref(r[:, :, :half], kk[:, :, :half], v[:, :, :half],
                     logw[:, :, :half], u, jnp.zeros((b, h, k, k)))
    y2, s2 = wkv_ref(r[:, :, half:], kk[:, :, half:], v[:, :, half:],
                     logw[:, :, half:], u, s1)
    np.testing.assert_allclose(np.asarray(y_full[:, :, half:]),
                               np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- rglru ----
@pytest.mark.parametrize("shape,chunk", [
    ((2, 128, 32), 32), ((1, 256, 64), 128), ((3, 64, 16), 64),
])
def test_rglru_scan(shape, chunk):
    b, t, w = shape
    rng = np.random.default_rng(0)
    log_a = jnp.asarray(-np.exp(rng.normal(size=shape)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=shape), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, w)), jnp.float32)
    y, hf = rglru_scan(log_a, bb, h0, chunk=chunk)
    b_ref = bb.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * h0)
    yr, hr = rglru_ref(log_a, b_ref, jnp.zeros((b, w)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ gmm ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (4, 64, 128, 96), (8, 32, 64, 64), (2, 128, 256, 128),
])
def test_moe_gmm(shape, dtype):
    e, c, d, f = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(e, c, d)), dtype)
    w = jnp.asarray(rng.normal(size=(e, d, f)), dtype)
    sizes = jnp.asarray(rng.integers(0, c + 1, (e,)), jnp.int32)
    out = moe_gmm(x, w, sizes, block_c=32, block_f=32, block_d=64)
    ref = gmm_ref(x, w, sizes)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-1 if dtype == jnp.bfloat16 else 1e-3)


@settings(max_examples=8, deadline=None)
@given(sizes=st.lists(st.integers(0, 64), min_size=4, max_size=4))
def test_moe_gmm_ragged_rows_zeroed(sizes):
    """Property: rows beyond group_size are exactly zero (skip safety)."""
    rng = np.random.default_rng(0)
    e, c, d, f = 4, 64, 64, 64
    x = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    out = np.asarray(moe_gmm(x, w, gs, block_c=32, block_f=32, block_d=64))
    for ei in range(e):
        assert np.all(out[ei, sizes[ei]:, :] == 0.0)
