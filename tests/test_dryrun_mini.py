"""Mini dry-run: the production lowering path on an 8-device host mesh.

The full 512-device dry-run runs via launch/dryrun.py (results in
reports/dryrun); this test exercises the same code path — shardings, jit
lower + compile, roofline extraction — at a size that fits the test suite,
via a subprocess so the main process keeps its 1-device view.
"""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import Mesh
import numpy as np
from repro.configs import smoke_config
from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import activation_mesh
from repro.launch import steps, roofline
from repro.launch.roofline import cost_dict

mesh = jax.make_mesh((4, 2), ("data", "model"))
spec = ShapeSpec("mini", "train", seq_len=32, global_batch=8)

for arch in ("qwen3-8b", "granite-moe-3b-a800m", "rwkv6-3b",
             "recurrentgemma-9b", "gemma2-9b"):
    cfg = smoke_config(arch)
    with mesh, activation_mesh(mesh):
        state_sh, batch_sh = steps.train_shardings(cfg, mesh, spec)
        step = steps.make_train_step(cfg)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
        lowered = jitted.lower(steps.train_state_specs(cfg),
                               steps.input_specs(cfg, spec))
        compiled = lowered.compile()
    cost = cost_dict(compiled)
    assert cost.get("flops", 0) > 0, arch
    coll = roofline.collective_bytes(compiled.as_text())
    # sharded training must communicate *something*
    assert sum(coll.values()) > 0, arch
    # and the step must actually run on the 8 fake devices
    state = jax.device_put(steps.make_train_state(cfg, jax.random.PRNGKey(0)),
                           state_sh)
    toks = jnp.zeros((8, 32), jnp.int32) if not cfg.n_codebooks else \
        jnp.zeros((8, cfg.n_codebooks, 32), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros((8, cfg.vision_tokens,
                                            cfg.vision_dim))
        batch["mrope_positions"] = jnp.zeros((3, 8, 32), jnp.int32)
    batch = jax.device_put(batch, batch_sh)
    new_state, metrics = compiled(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    print(f"MINI_OK {arch} loss={float(metrics['loss']):.3f} "
          f"coll_bytes={sum(coll.values())}")
print("ALL_MINI_OK")
"""


def test_mini_dryrun_and_execute():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ALL_MINI_OK" in proc.stdout, (proc.stdout[-1500:],
                                          proc.stderr[-3000:])
