"""Fault-injection subsystem (soc.faults) contracts.

Three pillars, matching the module's design rules:

  * **Zero-spec identity** — an all-neutral :func:`soc.faults.no_faults`
    spec is bitwise-identical to ``faults=None`` on every backend path
    (unfused scan, fused episode, batched training): the fault rows
    reduce to IEEE no-ops and the spec's own key never touches the
    episode's main PRNG stream.
  * **Cross-lowering agreement** — a *nonzero* spec produces
    bitwise-equal episodes across the fused kernel lowering, the
    ``episode_ref`` scan, and the unfused step, and matches the DES on
    single-thread applications (deterministic outage windows + degenerate
    drop probabilities, so the stochastic component is pinned too).
  * **Degradation safety** — non-finite Q-rows fall back to non-coherent
    mode, non-finite rewards never blend into the table, the reward
    watchdog re-opens exploration on collapse, and ``debug_finite``
    tripwires fire on injected NaNs.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode
from repro.core.policies import FixedHomogeneous
from repro.soc import faults, vecenv
from repro.soc.apps import make_phase
from repro.soc.config import SOC1
from repro.soc.des import Application, SoCSimulator

TILE_SEED = 7


@pytest.fixture(autouse=True)
def _drain_effect_tokens():
    """debug_finite tests leave a failed jax.debug.callback token pending;
    drain it so it doesn't surface as an ignored atexit exception."""
    yield
    try:
        jax.effects_barrier()
    except Exception:
        # a raising token aborts block_until_ready before its clear();
        # drop it explicitly or the atexit hook trips over it again
        from jax._src import dispatch as _dispatch
        _dispatch.runtime_tokens.clear()


def _chain_app(soc, seed, n_threads=1):
    rng = np.random.default_rng(seed)
    phases = [
        make_phase(rng, soc, name=f"p{i}", n_threads=n_threads,
                   size_classes=[c], chain_len=3, loops=2)
        for i, c in enumerate(("S", "M", "L"))
    ]
    return Application(name=f"{soc.name}-faults{n_threads}", phases=phases)


@pytest.fixture(scope="module")
def setting():
    soc = SOC1
    sim = SoCSimulator(soc)
    app = _chain_app(soc, seed=3)
    compiled = vecenv.compile_app(app, soc, seed=TILE_SEED)
    return sim, app, compiled


def _storm(compiled, intensity=0.7):
    return faults.storm(compiled.n_steps, intensity, jax.random.PRNGKey(42))


def _tree_bitwise(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


# --------------------------------------------------------- zero-spec identity
@pytest.mark.parametrize("fused", [False, True])
def test_zero_spec_bitwise_identical_episode(setting, fused):
    sim, app, compiled = setting
    env = vecenv.VecEnv.from_simulator(sim, fused_step=fused)
    cfg = qlearn.QConfig()
    key = jax.random.PRNGKey(1)
    qs0, r0 = env.episode(compiled, policy="q", cfg=cfg, key=key)
    qs1, r1 = env.episode(compiled, policy="q", cfg=cfg, key=key,
                          faults=faults.no_faults())
    _tree_bitwise(qs0, qs1)
    _tree_bitwise(r0, r1)


def test_zero_spec_bitwise_identical_train_batched(setting):
    sim, app, compiled = setting
    env = vecenv.VecEnv.from_simulator(sim)
    soc = sim.soc
    apps = [vecenv.compile_app(_chain_app(soc, 3), soc, seed=s)
            for s in range(3)]
    wb = rewards.stack_weights([rewards.RewardWeights()] * 2)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(2))
    cfg = qlearn.QConfig()
    out0 = env.train_batched(apps, cfg, wb, keys, eval_app=apps[0])
    out1 = env.train_batched(apps, cfg, wb, keys, eval_app=apps[0],
                             faults=faults.no_faults())
    _tree_bitwise(out0, out1)


# ----------------------------------------------------- cross-lowering parity
def test_storm_perturbs_and_fused_unfused_bitwise(setting):
    sim, app, compiled = setting
    fs = _storm(compiled)
    cfg = qlearn.QConfig()
    key = jax.random.PRNGKey(1)
    outs = []
    for fused in (False, True):
        env = vecenv.VecEnv.from_simulator(sim, fused_step=fused)
        qs_h, r_h = env.episode(compiled, policy="q", cfg=cfg, key=key)
        qs_f, r_f = env.episode(compiled, policy="q", cfg=cfg, key=key,
                                faults=fs)
        # the storm must actually bite
        assert not np.array_equal(np.asarray(r_f.exec_time),
                                  np.asarray(r_h.exec_time))
        outs.append((qs_f, r_f))
    _tree_bitwise(outs[0][0], outs[1][0])
    _tree_bitwise(outs[0][1], outs[1][1])


def test_kernel_vs_ref_bitwise_under_faults(setting):
    """The Pallas kernel body (interpreted on CPU) and episode_ref agree
    bitwise on the packed faulted episode."""
    from repro.kernels.soc_step import ops as soc_step_ops
    from repro.kernels.soc_step.ref import StepInputs, episode_ref

    sim, app, compiled = setting
    env = vecenv.VecEnv.from_simulator(sim, fused_step=True)
    sched = compiled.schedule
    cfg = qlearn.QConfig()
    qs0 = qlearn.init_qstate(cfg)
    fs = _storm(compiled)
    fr = faults.sample_fault_arrays(fs, sched.acc_id)
    n_steps = sched.acc_id.shape[0]
    noise = qlearn.sample_select_noise(
        jax.random.PRNGKey(1), (n_steps,), env.masks.shape[-1])
    inc = jnp.ones((n_steps,), jnp.int32)
    eps_t, alpha_t = qlearn.decay_arrays(cfg, qs0.step, qs0.frozen, inc)
    xs = StepInputs(
        acc_id=sched.acc_id, footprint=sched.footprint, tiles=sched.tiles,
        thread=sched.thread, fresh=sched.fresh, others=sched.others,
        valid=sched.valid, pre_mode=jnp.zeros_like(sched.acc_id),
        profile=env.pmat[sched.acc_id], avail=env.masks[sched.acc_id],
        eps=eps_t, alpha=alpha_t, u_explore=noise.u_explore,
        g_pick=noise.g_pick, g_tie=noise.g_tie,
        f_exec=fr.exec_scale, f_ddr=fr.ddr_scale, f_llc=fr.llc_extra,
        f_retry=fr.retry_cycles)
    learned = jnp.ones((), bool)
    w = rewards.PAPER_DEFAULT_WEIGHTS
    ex0 = rewards.init_reward_state(env.pmat.shape[0]).extrema
    q_ref, ys_ref = episode_ref(env.static, learned, w, qs0.qtable, ex0, xs)
    q_ker, ys_ker = soc_step_ops.fused_episode(
        env.static, learned, w, qs0.qtable, ex0, xs,
        kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_ker))
    _tree_bitwise(ys_ref, ys_ker)


def test_des_crosscheck_deterministic_window(setting):
    """Single-thread app under a deterministic fault storm: DES and vecenv
    agree per phase.  drop_prob is pinned to 1.0 so the retry component is
    deterministic (every attempt in the window fails, costing the full
    bounded backoff)."""
    sim, app, compiled = setting
    env = vecenv.VecEnv.from_simulator(sim)
    fs = _storm(compiled, 0.6)._replace(
        drop_prob=jnp.asarray(1.0, jnp.float32))
    for mode in (CoherenceMode.NON_COH_DMA, CoherenceMode.FULLY_COH):
        des = sim.run(app, FixedHomogeneous(mode), seed=TILE_SEED,
                      train=False, faults=fs)
        _, res = env.episode(compiled, policy="fixed",
                             fixed_modes=int(mode), faults=fs)
        dt = np.array([p.wall_time for p in des.phases])
        do = np.array([p.offchip_accesses for p in des.phases])
        np.testing.assert_allclose(np.asarray(res.phase_time), dt,
                                   rtol=1e-4, err_msg=str(mode))
        np.testing.assert_allclose(np.asarray(res.phase_offchip), do,
                                   rtol=1e-4, atol=1e-3, err_msg=str(mode))
        # the storm slows the app down vs healthy
        des_h = sim.run(app, FixedHomogeneous(mode), seed=TILE_SEED,
                        train=False)
        assert des.total_time > des_h.total_time


def test_fault_row_semantics():
    """Window tests, victim selection and retry/backoff arithmetic."""
    fs = faults.no_faults()._replace(
        slow_start=jnp.asarray(2, jnp.int32),
        slow_end=jnp.asarray(5, jnp.int32),
        slow_acc=jnp.asarray(1, jnp.int32),
        slow_factor=jnp.asarray(3.0, jnp.float32),
        drop_start=jnp.asarray(0, jnp.int32),
        drop_end=jnp.asarray(10, jnp.int32),
        drop_prob=jnp.asarray(1.0, jnp.float32),
        backoff=jnp.asarray(100.0, jnp.float32))
    u = jnp.zeros((faults.FAULT_MAX_RETRIES,), jnp.float32)
    # inside the window, matching victim
    row = faults.fault_row(fs, jnp.int32(3), jnp.int32(1), u)
    assert float(row.exec_scale) == 3.0
    # outside window / wrong victim -> neutral
    assert float(faults.fault_row(fs, jnp.int32(5), jnp.int32(1),
                                  u).exec_scale) == 1.0
    assert float(faults.fault_row(fs, jnp.int32(3), jnp.int32(0),
                                  u).exec_scale) == 1.0
    # drop_prob=1: all FAULT_MAX_RETRIES attempts fail ->
    # backoff * (2^R - 1) cycles
    expect = 100.0 * (2.0 ** faults.FAULT_MAX_RETRIES - 1.0)
    assert float(row.retry_cycles) == expect
    # drop_prob=0 -> exactly +0.0
    row0 = faults.fault_row(fs._replace(
        drop_prob=jnp.asarray(0.0, jnp.float32)), jnp.int32(3),
        jnp.int32(1), u)
    assert float(row0.retry_cycles) == 0.0


# --------------------------------------------------------- degradation safety
def test_selector_falls_back_on_nonfinite_row():
    cfg = qlearn.QConfig(epsilon0=0.0)  # pure greedy
    qs = qlearn.init_qstate(cfg)
    # make FULLY_COH the greedy winner at state 5, then poison the row
    qs = qs._replace(qtable=qs.qtable.at[5, int(CoherenceMode.FULLY_COH)]
                     .set(10.0))
    noise = qlearn.sample_select_noise(jax.random.PRNGKey(0), (), 4)
    avail = jnp.ones((4,), bool)
    healthy = qlearn.select_presampled(qs, cfg, jnp.int32(5), noise, avail)
    assert int(healthy) == int(CoherenceMode.FULLY_COH)
    bad = qs._replace(qtable=qs.qtable.at[5, 2].set(jnp.nan))
    assert int(qlearn.select_presampled(bad, cfg, jnp.int32(5), noise,
                                        avail)) == qlearn._FALLBACK
    assert int(qlearn.select(bad, cfg, jnp.int32(5), jax.random.PRNGKey(0),
                             avail)) == qlearn._FALLBACK
    assert int(qlearn.row_select_presampled(
        bad.qtable[5], jnp.float32(0.0), noise, avail)) == qlearn._FALLBACK


def test_row_update_drops_nonfinite_reward():
    row = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    for bad in (jnp.nan, jnp.inf, -jnp.inf):
        out = qlearn.row_update(row, jnp.float32(0.5), jnp.int32(1),
                                jnp.float32(bad))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(row))
    out = qlearn.row_update(row, jnp.float32(0.5), jnp.int32(1),
                            jnp.float32(10.0))
    assert float(out[1]) == 6.0


def test_reward_extrema_ignore_nonfinite_measurement():
    rs = rewards.init_reward_state(2)
    m = rewards.Measurement(
        exec_time=jnp.float32(jnp.nan), comm_cycles=jnp.float32(1.0),
        total_cycles=jnp.float32(2.0), offchip_accesses=jnp.float32(3.0),
        footprint=jnp.float32(4096.0))
    _, rs2, _ = rewards.evaluate(rs, jnp.int32(0), m)
    assert np.all(np.isfinite(np.asarray(rs2.extrema))
                  | (np.asarray(rs.extrema) == np.asarray(rs2.extrema)))
    # exec_min column untouched by the NaN
    assert float(rs2.extrema[0, 0]) == float(rs.extrema[0, 0])


def test_reward_watchdog():
    cfg = qlearn.QConfig(collapse_frac=0.5, reopen_frac=0.5)
    qs = qlearn.init_qstate(cfg)._replace(
        step=jnp.asarray(cfg.decay_steps, jnp.int32))
    # collapse: episode reward far below best -> step rewinds (epsilon
    # re-opens) and best resets to the collapsed value
    new_qs, best = qlearn.reward_watchdog(cfg, qs, jnp.float32(0.1),
                                          jnp.float32(1.0))
    assert int(new_qs.step) < int(qs.step)
    assert float(best) == pytest.approx(0.1)
    # healthy episode: no-op, best ratchets up
    ok_qs, best2 = qlearn.reward_watchdog(cfg, qs, jnp.float32(2.0),
                                          jnp.float32(1.0))
    assert int(ok_qs.step) == int(qs.step)
    assert float(best2) == pytest.approx(2.0)
    # disabled (collapse_frac=0, the default): bitwise no-op on step
    off_qs, _ = qlearn.reward_watchdog(qlearn.QConfig(), qs,
                                       jnp.float32(0.0), jnp.float32(1.0))
    assert int(off_qs.step) == int(qs.step)
    # frozen agents never collapse
    fr_qs, _ = qlearn.reward_watchdog(cfg, qlearn.freeze(qs),
                                      jnp.float32(0.1), jnp.float32(1.0))
    assert int(fr_qs.step) == int(qs.step)


def test_debug_finite_fires_on_injected_nan():
    cfg = qlearn.QConfig()
    qs = qlearn.init_qstate(cfg)
    qlearn.clear_finite_violations()
    with pytest.raises(Exception):
        jax.block_until_ready(qlearn.update(
            qs, cfg, jnp.int32(0), jnp.int32(0), jnp.float32(jnp.nan),
            debug_finite=True).qtable)
    v = qlearn.finite_violations()
    assert v and v[0].startswith("qlearn.update")
    assert "reward" in v[0]
    qlearn.clear_finite_violations()
    # healthy update with the flag on: silent
    jax.block_until_ready(qlearn.update(
        qs, cfg, jnp.int32(0), jnp.int32(0), jnp.float32(1.0),
        debug_finite=True).qtable)
    assert not qlearn.finite_violations()


def test_debug_finite_env_flag(setting):
    """A VecEnv built with debug_finite=True trips on an episode whose
    schedule carries a NaN footprint (and stays silent on a healthy one)."""
    sim, app, compiled = setting
    env = vecenv.VecEnv.from_simulator(sim, debug_finite=True)
    cfg = qlearn.QConfig()
    qlearn.clear_finite_violations()
    _, res = env.episode(compiled, policy="q", cfg=cfg)
    jax.block_until_ready(res.reward)
    assert not qlearn.finite_violations()
    bad_sched = compiled.schedule._replace(
        footprint=compiled.schedule.footprint.at[2].set(jnp.nan))
    bad = vecenv.CompiledApp(
        name=compiled.name, schedule=bad_sched, n_phases=compiled.n_phases,
        n_threads=compiled.n_threads, n_steps=compiled.n_steps,
        phase_names=compiled.phase_names)
    try:
        _, res = env.episode(bad, policy="q", cfg=cfg)
        jax.block_until_ready(res.reward)
    except Exception:
        pass
    assert any(v.startswith("vecenv.episode")
               for v in qlearn.finite_violations())
    qlearn.clear_finite_violations()


def test_nonfinite_footprint_forces_noncoh_fallback(setting):
    """A NaN footprint mid-episode degrades that invocation to NON_COH_DMA
    (both lowerings) instead of poisoning downstream state."""
    sim, app, compiled = setting
    bad_sched = compiled.schedule._replace(
        footprint=compiled.schedule.footprint.at[2].set(jnp.nan))
    bad = vecenv.CompiledApp(
        name=compiled.name, schedule=bad_sched, n_phases=compiled.n_phases,
        n_threads=compiled.n_threads, n_steps=compiled.n_steps,
        phase_names=compiled.phase_names)
    for fused in (False, True):
        env = vecenv.VecEnv.from_simulator(sim, fused_step=fused)
        _, ok = env.episode(compiled, policy="fixed",
                            fixed_modes=CoherenceMode.FULLY_COH)
        _, res = env.episode(bad, policy="fixed",
                             fixed_modes=CoherenceMode.FULLY_COH)
        modes, healthy = np.asarray(res.mode), np.asarray(ok.mode)
        assert modes[2] == int(CoherenceMode.NON_COH_DMA)
        # only the poisoned invocation degrades; the rest match the
        # healthy run (availability masking included)
        keep = np.arange(modes.shape[0]) != 2
        np.testing.assert_array_equal(modes[keep], healthy[keep])


# ------------------------------------------------------------------ plumbing
def test_storm_zero_intensity_is_neutral(setting):
    sim, app, compiled = setting
    env = vecenv.VecEnv.from_simulator(sim)
    fs = faults.storm(compiled.n_steps, 0.0, jax.random.PRNGKey(42))
    key = jax.random.PRNGKey(1)
    qs0, r0 = env.episode(compiled, policy="q", key=key)
    qs1, r1 = env.episode(compiled, policy="q", key=key, faults=fs)
    _tree_bitwise(qs0, qs1)
    _tree_bitwise(r0, r1)


def test_spec_sweep_no_retrace(setting):
    """Changing fault intensities reuses the jitted episode (FaultSpec
    leaves are traced scalars, not static)."""
    sim, app, compiled = setting
    env = vecenv.VecEnv.from_simulator(sim)
    env.episode(compiled, policy="q",
                faults=_storm(compiled, 0.25))  # compile once
    jit_key = ("jit", compiled.n_phases, compiled.n_threads)
    fn = env._episode_cache[jit_key]
    before = fn._cache_size()
    for i in (0.5, 0.75, 1.0):
        env.episode(compiled, policy="q", faults=_storm(compiled, i))
    assert fn._cache_size() == before
