"""SoC timing-model fidelity tests: the paper's §3 qualitative findings."""
import numpy as np
import pytest

from repro.core.modes import CoherenceMode
from repro.core.orchestrator import run_isolated
from repro.soc.apps import make_application
from repro.soc.config import (SOC_MOTIV_ISO, SOC_MOTIV_PAR, SOCS,
                              WORKLOAD_LARGE, WORKLOAD_MEDIUM,
                              WORKLOAD_SMALL)
from repro.soc.des import (Application, Invocation, Phase, SoCSimulator,
                           Thread)
from repro.core.policies import FixedHomogeneous


@pytest.fixture(scope="module")
def sim():
    return SoCSimulator(SOC_MOTIV_ISO)


def _iso(sim, acc, mode, fp):
    return run_isolated(sim, acc, mode, fp)


def _acc_id(sim, name):
    return [p.name for p in sim.profiles].index(name)


def test_small_warm_workloads_cached_modes_zero_offchip(sim):
    """Paper Fig. 2: small/medium warm data -> no red bar for cached modes."""
    for name in ("autoencoder", "mlp", "fft"):
        acc = _acc_id(sim, name)
        for mode in (CoherenceMode.LLC_COH_DMA, CoherenceMode.COH_DMA,
                     CoherenceMode.FULLY_COH):
            res = _iso(sim, acc, mode, WORKLOAD_SMALL)
            assert res.total_offchip == 0.0, (name, mode)
        non_coh = _iso(sim, acc, CoherenceMode.NON_COH_DMA, WORKLOAD_SMALL)
        assert non_coh.total_offchip > 0.0


def test_small_fully_coh_beats_non_coh(sim):
    """Paper Fig. 2 Small: flush + cold DRAM reads make NON_COH slowest."""
    for name in ("autoencoder", "spmv", "fft", "sort"):
        acc = _acc_id(sim, name)
        t_nc = _iso(sim, acc, CoherenceMode.NON_COH_DMA,
                    WORKLOAD_SMALL).total_time
        t_fc = _iso(sim, acc, CoherenceMode.FULLY_COH,
                    WORKLOAD_SMALL).total_time
        assert t_fc < t_nc, name


def test_large_streaming_non_coh_wins(sim):
    """Paper Fig. 2 Large: burst DMA beats thrashing caches (autoencoder
    'at least 3x faster' case; we assert > 1.5x)."""
    for name in ("autoencoder", "sort"):
        acc = _acc_id(sim, name)
        t_nc = _iso(sim, acc, CoherenceMode.NON_COH_DMA,
                    WORKLOAD_LARGE).total_time
        for mode in (CoherenceMode.LLC_COH_DMA, CoherenceMode.FULLY_COH):
            t = _iso(sim, acc, mode, WORKLOAD_LARGE).total_time
            assert t > 1.5 * t_nc, (name, mode)


def test_large_cached_can_have_more_offchip(sim):
    """Paper: 'FFT Large: non-coherent has fewer off-chip accesses' —
    thrashing evictions inflate cached-mode traffic."""
    acc = _acc_id(sim, "fft")
    m_nc = _iso(sim, acc, CoherenceMode.NON_COH_DMA,
                WORKLOAD_LARGE).total_offchip
    m_llc = _iso(sim, acc, CoherenceMode.LLC_COH_DMA,
                 WORKLOAD_LARGE).total_offchip
    assert m_llc > m_nc


def test_irregular_accelerator_prefers_caches(sim):
    """Paper Fig. 9 'irregular': word-granularity DMA is latency-bound."""
    acc = _acc_id(sim, "spmv")
    for fp in (WORKLOAD_SMALL, WORKLOAD_MEDIUM, WORKLOAD_LARGE):
        t_nc = _iso(sim, acc, CoherenceMode.NON_COH_DMA, fp).total_time
        t_cd = _iso(sim, acc, CoherenceMode.COH_DMA, fp).total_time
        assert t_cd < t_nc, fp


def test_gemm_compute_bound_mode_insensitive(sim):
    """Paper: GEMM is compute-bound — 'never has the non-coherent mode as
    the best option' because exec times tie (<10% spread) while cached
    modes save off-chip traffic at cacheable sizes."""
    acc = _acc_id(sim, "gemm")
    for fp in (WORKLOAD_SMALL, WORKLOAD_MEDIUM, WORKLOAD_LARGE):
        times = {m: _iso(sim, acc, m, fp).total_time for m in CoherenceMode}
        spread = max(times.values()) / min(times.values())
        assert spread < 1.10, (fp, times)
    for fp in (WORKLOAD_SMALL, WORKLOAD_MEDIUM):
        m_nc = _iso(sim, acc, CoherenceMode.NON_COH_DMA, fp).total_offchip
        m_fc = _iso(sim, acc, CoherenceMode.FULLY_COH, fp).total_offchip
        assert m_fc < m_nc, fp


def _parallel_app(n):
    threads = [Thread(chain=[Invocation(acc_id=i,
                                        footprint=WORKLOAD_MEDIUM)], loops=6)
               for i in range(n)]
    return Application(name=f"par{n}",
                       phases=[Phase(name="p", threads=threads)])


def test_concurrency_degradation_ordering():
    """Paper Fig. 3 at 12 accelerators: NON_COH degrades least (~2.4x),
    COH_DMA collapses worst (~8x)."""
    sim = SoCSimulator(SOC_MOTIV_PAR)
    slowdown = {}
    for mode in CoherenceMode:
        iso = sim.run(_parallel_app(1), FixedHomogeneous(mode), train=False)
        par = sim.run(_parallel_app(12), FixedHomogeneous(mode), train=False)
        t_iso = np.mean([r.exec_time for r in iso.phases[0].invocations])
        t_par = np.mean([r.exec_time for r in par.phases[0].invocations])
        slowdown[mode] = t_par / t_iso
    assert slowdown[CoherenceMode.NON_COH_DMA] < 3.0
    assert slowdown[CoherenceMode.NON_COH_DMA] > 1.5
    assert slowdown[CoherenceMode.COH_DMA] == max(slowdown.values())
    assert slowdown[CoherenceMode.COH_DMA] > 4.0
    for m in (CoherenceMode.LLC_COH_DMA, CoherenceMode.FULLY_COH):
        assert slowdown[m] >= slowdown[CoherenceMode.NON_COH_DMA] * 0.95


def test_non_coh_offchip_constant_under_concurrency():
    """Paper Fig. 3: NON_COH off-chip accesses stay ~constant per acc."""
    sim = SoCSimulator(SOC_MOTIV_PAR)
    pol = FixedHomogeneous(CoherenceMode.NON_COH_DMA)
    r1 = sim.run(_parallel_app(1), pol, train=False)
    r12 = sim.run(_parallel_app(12), pol, train=False)
    per1 = r1.total_offchip / len(r1.phases[0].invocations)
    per12 = r12.total_offchip / len(r12.phases[0].invocations)
    assert abs(per12 - per1) / per1 < 0.35


def test_all_socs_simulate():
    """Every Table-4 SoC builds and runs an application end to end."""
    for name, soc in SOCS.items():
        sim = SoCSimulator(soc, seed=1)
        app = make_application(soc, seed=0, n_phases=2)
        res = sim.run(app, FixedHomogeneous(CoherenceMode.NON_COH_DMA),
                      train=False)
        assert res.total_time > 0, name
        assert all(len(p.invocations) > 0 for p in res.phases), name


def test_soc3_masks_fully_coh():
    """SoC3: five accelerators lack a private cache -> FULLY_COH masked."""
    soc = SOCS["SoC3"]
    sim = SoCSimulator(soc, seed=1)
    for i in soc.no_private_cache:
        assert not sim.masks[i][CoherenceMode.FULLY_COH]
    app = make_application(soc, seed=0, n_phases=2)
    res = sim.run(app, FixedHomogeneous(CoherenceMode.FULLY_COH),
                  train=False)
    for ph in res.phases:
        for r in ph.invocations:
            if r.acc_id in soc.no_private_cache:
                assert r.mode != CoherenceMode.FULLY_COH
