"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated at a REDUCED config of the same
family (same layer pattern / features, small dims) and run for one forward
+ train-ish step on CPU, asserting output shapes and finiteness.  The FULL
configs are exercised only by the dry-run (ShapeDtypeStruct, no alloc).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, smoke_config
from repro.models import decode_step, init_params, loss_fn, prefill
from repro.models.transformer import forward, lm_logits

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.n_codebooks:
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, cfg.n_codebooks, S)), jnp.int32)
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = 0.1 * jnp.ones(
            (B, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
        batch["mrope_positions"] = jnp.tile(
            jnp.arange(S)[None, None, :], (3, B, 1))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) >= 0.0
    h, _ = forward(cfg, params, batch)
    assert h.shape[:2] == batch["tokens"].shape[:1] + (32,)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    """One SGD step must produce finite grads for every leaf."""
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    def loss_of(p):
        return loss_fn(cfg, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    assert np.isfinite(float(loss))
    flat, _ = jax.tree_util.tree_flatten(grads)
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_matches_forward(arch):
    """Greedy decode with cache must reproduce the parallel forward logits
    (MoE archs checked dropless — capacity drops are train-time only)."""
    cfg = smoke_config(arch)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=100.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = make_batch(cfg, B=B, S=S)
    h, _ = forward(cfg, params, batch)
    full_logits = lm_logits(cfg, params, h)

    pre = S - 4
    pbatch = dict(batch)
    pbatch["tokens"] = batch["tokens"][..., :pre]
    if cfg.family == "vlm":
        pbatch["mrope_positions"] = batch["mrope_positions"][..., :pre]
    cache, plog = prefill(cfg, params, pbatch, max_len=S)

    ref = (full_logits[..., pre - 1, :] if not cfg.n_codebooks
           else full_logits[:, :, pre - 1, :])
    got = plog[..., 0, :] if not cfg.n_codebooks else plog[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

    for t in range(pre, S):
        dbatch = {"tokens": batch["tokens"][..., t:t + 1]}
        if cfg.family == "vlm":
            dbatch["mrope_positions"] = batch["mrope_positions"][..., t:t + 1]
        cache, dlog = decode_step(cfg, params, cache, dbatch, jnp.int32(t))
        ref = (full_logits[..., t, :] if not cfg.n_codebooks
               else full_logits[:, :, t, :])
        got = dlog[..., 0, :] if not cfg.n_codebooks else dlog[:, :, 0, :]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_fields(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_arch(arch)
    spec = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == spec
