"""Paper §6 "Cohmeleon Overhead": decision-path cost per invocation.

Paper anchors: 3-6% of total execution time for small (16KB) workloads,
<0.1% for large (4MB).  We measure the host-side decide+update time of the
Q-policy inside the simulator and compare to simulated invocation times;
also measures the beyond-paper autotuner's decision overhead.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import train_cohmeleon
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR, WORKLOAD_LARGE, WORKLOAD_SMALL
from repro.soc.des import SoCSimulator


def run(quick: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    t0 = time.perf_counter()
    policy, _ = train_cohmeleon(sim, iterations=2, seed=0, n_phases=4)
    app = make_application(sim.soc, seed=77, n_phases=4)
    res = sim.run(app, policy, seed=1, train=False)
    us_decide = res.decide_overhead_s * 1e6

    # compare against simulated invocation wall times (cycle_time 10 ns)
    small_cycles, large_cycles = [], []
    for ph in res.phases:
        for r in ph.invocations:
            if r.footprint <= WORKLOAD_SMALL * 2:
                small_cycles.append(r.exec_time)
            elif r.footprint >= WORKLOAD_LARGE / 4:
                large_cycles.append(r.exec_time)
    cyc = 1e-8
    small_s = float(np.mean(small_cycles)) * cyc if small_cycles else None
    large_s = float(np.mean(large_cycles)) * cyc if large_cycles else None
    frac_small = (res.decide_overhead_s / small_s) if small_s else None
    frac_large = (res.decide_overhead_s / large_s) if large_s else None
    us = (time.perf_counter() - t0) * 1e6
    save_report("overhead", {
        "decide_overhead_us": us_decide,
        "frac_small": frac_small, "frac_large": frac_large,
        "paper": "3-6% small, <0.1% large",
    })
    return csv_row("overhead", us_decide,
                   f"frac_small={frac_small if frac_small is None else f'{frac_small:.3f}'} "
                   f"frac_large={frac_large if frac_large is None else f'{frac_large:.4f}'}")


if __name__ == "__main__":
    print(run())
