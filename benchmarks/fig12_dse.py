"""Beyond-paper Fig. 12: generative SoC design-space co-search.

The paper evaluates eight hand-written SoCs; this figure samples
hundreds of SoC architectures under a lumos-style area/bandwidth budget
(:func:`repro.soc.dse.sample_socs`), trains one Cohmeleon agent per SoC
and evaluates the full policy suite through k-way bucketed
``StackedVecEnv`` calls — at most ``max_buckets`` batched (train, eval)
call pairs for the WHOLE sweep, asserted below — and reports which
architectures, and which sampler axes, make learned coherence win
biggest (speedup and off-chip reduction vs the NON_COH baseline).

The committed report also records the sweep's padded-waste reduction
from k-way bucketing vs a single stacked call on the same sample, and
its steps/s, so future ``--check-regression``-style gates can compare
against it.

``--quick`` keeps the >= 200-SoC scale (the acceptance protocol) but
shrinks apps/iterations; it is the CI smoke job.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.soc.config import DEFAULT_BUDGET
from repro.soc.dse import EVAL_FAMILIES, run_sweep, sample_socs

TOP_N = 10


def _per_soc_rows(samples, out) -> list[dict]:
    nt, nm = out["norm_time"], out["norm_mem"]
    n_fixed = len(EVAL_FAMILIES) - 3
    rows = []
    for i, s in enumerate(samples):
        rows.append({
            "name": s.config.name,
            "seed": s.seed,
            "axes": {k: (round(v, 4) if isinstance(v, float) else v)
                     for k, v in s.axes.items()},
            "cohmeleon": [float(nt[i, -1]), float(nm[i, -1])],
            "manual": [float(nt[i, -2]), float(nm[i, -2])],
            "fixed_mean": [float(nt[i, :n_fixed].mean()),
                           float(nm[i, :n_fixed].mean())],
            "best_fixed": [float(nt[i, :n_fixed].min()),
                           float(nm[i, :n_fixed].min())],
            "speedup_vs_noncoh":
                float(out["margins"]["speedup_vs_noncoh"][i]),
            "offchip_reduction_vs_noncoh":
                float(out["margins"]["offchip_reduction_vs_noncoh"][i]),
            "speedup_vs_best_fixed":
                float(out["margins"]["speedup_vs_best_fixed"][i]),
        })
    return rows


def run(quick: bool = False, n: int | None = None, max_buckets: int = 4,
        key: int = 0):
    n = n if n is not None else (200 if quick else 256)
    iters = 2 if quick else 3
    n_phases = 2 if quick else 3

    t0 = time.perf_counter()
    samples = sample_socs(key, n)
    out = run_sweep(samples, iters=iters, n_phases=n_phases,
                    max_buckets=max_buckets)
    us = (time.perf_counter() - t0) * 1e6 / n

    # Acceptance protocol: hundreds of SoCs, and the whole sweep is at
    # most ``max_buckets`` batched train/eval call pairs — one pair per
    # bucket, never one per SoC.
    calls = out["calls"]
    calls_ok = (calls["train"] == calls["n_buckets"]
                and calls["eval"] == calls["n_buckets"]
                and calls["n_buckets"] <= max_buckets)
    assert calls_ok, f"one train+eval call pair per bucket violated: {calls}"
    if quick or n >= 200:
        assert n >= 200, f"sweep must cover >= 200 SoCs, got {n}"

    margins = out["margins"]
    rows = _per_soc_rows(samples, out)
    order = np.argsort(-margins["speedup_vs_noncoh"])
    results = {
        "_engine": {
            "path": "vecenv-bucketed",
            "n_socs": n,
            "key": key,
            "iters": iters,
            "n_phases": n_phases,
            "max_buckets": max_buckets,
            "bucket_sizes": [len(g) for g in out["groups"]],
            "train_calls": calls["train"],
            "eval_calls": calls["eval"],
            "calls_ok": calls_ok,
        },
        "budget": dataclasses.asdict(DEFAULT_BUDGET),
        "waste": out["waste"],
        "throughput": out["timing"],
        "_headline": {
            "mean_speedup_vs_noncoh":
                float(np.mean(margins["speedup_vs_noncoh"])),
            "mean_offchip_reduction_vs_noncoh":
                float(np.mean(margins["offchip_reduction_vs_noncoh"])),
            "mean_speedup_vs_fixed_mean":
                float(np.mean(margins["speedup_vs_fixed_mean"])),
            "frac_learned_beats_all_fixed":
                float(np.mean(margins["speedup_vs_best_fixed"] > 0)),
            "frac_learned_beats_noncoh":
                float(np.mean(margins["speedup_vs_noncoh"] > 0)),
        },
        "axis_ranking": out["axis_ranking"],
        "top_socs_by_learned_margin": [rows[i] for i in order[:TOP_N]],
        "bottom_socs_by_learned_margin": [rows[i] for i in order[-3:]],
        "per_soc": rows,
    }
    save_report("fig12_dse", results)

    head = results["_headline"]
    top_axis = out["axis_ranking"]["speedup_vs_noncoh"][
        "ranked_coefficients"][0]
    return csv_row(
        "fig12_dse", us,
        f"n_socs={n} buckets={calls['n_buckets']}/{max_buckets} "
        f"calls_ok={calls_ok} "
        f"speedup_vs_noncoh={head['mean_speedup_vs_noncoh'] * 100:.0f}% "
        f"offchip_red={head['mean_offchip_reduction_vs_noncoh'] * 100:.0f}% "
        f"waste={out['waste']['padded_waste_single_call'] * 100:.0f}%"
        f"->{out['waste']['padded_waste_bucketed'] * 100:.0f}% "
        f"top_axis={top_axis[0]}:{top_axis[1]:+.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=None,
                    help="sample count (default 256, 200 in --quick)")
    ap.add_argument("--max-buckets", type=int, default=4)
    ap.add_argument("--key", type=int, default=0)
    args = ap.parse_args()
    print(run(quick=args.quick, n=args.n, max_buckets=args.max_buckets,
              key=args.key))
