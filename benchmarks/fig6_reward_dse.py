"""Paper Fig. 6: design-space exploration of the reward function.

Trains one model per (x, y, z) reward weighting and plots (normalized exec
time, normalized off-chip accesses) of the frozen policy.  Paper anchors:
a large near-optimal cluster; only >90%-memory-weighted points degrade;
both (67.5, 7.5, 25) and (12.5, 12.5, 75) are near-Pareto.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import compare_policies, train_cohmeleon
from repro.core.rewards import RewardWeights
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator

WEIGHTS = [
    (0.675, 0.075, 0.25), (0.125, 0.125, 0.75), (1.0, 0.0, 0.0),
    (0.0, 0.0, 1.0), (0.05, 0.05, 0.90), (0.33, 0.33, 0.34),
    (0.5, 0.25, 0.25), (0.25, 0.5, 0.25), (0.8, 0.1, 0.1),
    (0.1, 0.8, 0.1), (0.45, 0.1, 0.45), (0.6, 0.0, 0.4),
    (0.9, 0.05, 0.05), (0.2, 0.2, 0.6), (0.4, 0.4, 0.2),
]


def run(quick: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    weights = WEIGHTS[:4] if quick else WEIGHTS
    iters = 3 if quick else 10
    test_app = make_application(sim.soc, seed=900, n_phases=6)
    points = {}
    t0 = time.perf_counter()
    for (x, y, z) in weights:
        policy, _ = train_cohmeleon(
            sim, iterations=iters, seed=11,
            weights=RewardWeights(x, y, z), n_phases=6)
        cmp = compare_policies(sim, test_app, [policy], seed=5)
        t, m = cmp.geomean("cohmeleon")
        points[f"{x}/{y}/{z}"] = {"time": t, "mem": m}
    us = (time.perf_counter() - t0) * 1e6 / len(weights)

    times = [p["time"] for p in points.values()]
    spread = max(times) / min(times)
    save_report("fig6_reward_dse", points)
    return csv_row("fig6_reward_dse", us,
                   f"n_points={len(points)} time_spread={spread:.2f}x")


if __name__ == "__main__":
    print(run())
