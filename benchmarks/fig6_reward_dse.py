"""Paper Fig. 6: design-space exploration of the reward function.

Trains one model per (x, y, z) reward weighting and reports (normalized
exec time, normalized off-chip accesses) of the frozen policy.  Paper
anchors: a large near-optimal cluster; only >90%-memory-weighted points
degrade; both (67.5, 7.5, 25) and (12.5, 12.5, 75) are near-Pareto.

Default path is the vectorized environment: the full sweep trains
|weights| x seeds agents (>= 100) in ONE batched ``vmap(scan(...))`` call
(``train_cohmeleon_batched``).  ``--fidelity`` runs the original serial
DES loop; ``--quick`` additionally runs both paths and reports whether
they classify every weighting identically (near-Pareto vs degraded).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import (compare_policies, train_cohmeleon,
                                     train_cohmeleon_batched)
from repro.core.rewards import RewardWeights
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator

WEIGHTS = [
    (0.675, 0.075, 0.25), (0.125, 0.125, 0.75), (1.0, 0.0, 0.0),
    (0.0, 0.0, 1.0), (0.05, 0.05, 0.90), (0.33, 0.33, 0.34),
    (0.5, 0.25, 0.25), (0.25, 0.5, 0.25), (0.8, 0.1, 0.1),
    (0.1, 0.8, 0.1), (0.45, 0.1, 0.45), (0.6, 0.0, 0.4),
    (0.9, 0.05, 0.05), (0.2, 0.2, 0.6), (0.4, 0.4, 0.2),
]

# A weighting is "degraded" when its frozen policy fails to beat the fixed
# non-coherent-DMA baseline on execution time (normalized time >= 1).  This
# operationalizes the paper's Fig. 6 reading — a large near-optimal cluster
# well below the baseline, with only the >90%-memory weightings falling out
# of it — through an absolute anchor, which keeps the classification stable
# under the seed-to-seed training noise that relative-to-best thresholds
# are hostage to.
DEGRADED_TIME = 1.0


def classify(points: dict) -> dict:
    return {k: ("degraded" if p["time"] >= DEGRADED_TIME else "near-pareto")
            for k, p in points.items()}


def _des_points(weights, iters) -> dict:
    """Fidelity path: one serial DES training run per weighting."""
    sim = SoCSimulator(SOC_MOTIV_PAR)
    test_app = make_application(sim.soc, seed=900, n_phases=6)
    points = {}
    for (x, y, z) in weights:
        policy, _ = train_cohmeleon(
            sim, iterations=iters, seed=11,
            weights=RewardWeights(x, y, z), n_phases=6)
        cmp = compare_policies(sim, test_app, [policy], seed=5)
        t, m = cmp.geomean("cohmeleon")
        points[f"{x}/{y}/{z}"] = {"time": t, "mem": m}
    return points


def _batched_points(weights, iters, n_seeds) -> tuple[dict, int]:
    """Scale path: the whole sweep is one vmap-parallel training call."""
    res = train_cohmeleon_batched(
        SOC_MOTIV_PAR, iterations=iters, seed=11, weights=weights,
        n_seeds=n_seeds, n_phases=6)
    test_app = make_application(res.env.soc, seed=900, n_phases=6)
    nt, nm = res.evaluate(test_app, seed=5)
    t_w, m_w = res.per_weight(nt), res.per_weight(nm)
    points = {
        f"{x}/{y}/{z}": {"time": float(t), "mem": float(m)}
        for (x, y, z), t, m in zip(weights, t_w, m_w)
    }
    return points, res.n_agents


def run(quick: bool = False, fidelity: bool = False):
    weights = WEIGHTS[:4] if quick else WEIGHTS
    iters = 3 if quick else 10
    t0 = time.perf_counter()
    if fidelity:
        points = _des_points(weights, iters)
        n_agents, path = len(weights), "des"
    else:
        points, n_agents = _batched_points(weights, iters,
                                           n_seeds=2 if quick else 8)
        path = "vecenv"
    us = (time.perf_counter() - t0) * 1e6 / len(weights)

    classes = classify(points)
    payload = {"path": path, "n_agents": n_agents, "points": points,
               "classification": classes}
    derived = (f"path={path} n_points={len(points)} agents={n_agents} "
               f"degraded={sum(c == 'degraded' for c in classes.values())}")

    if quick and not fidelity:
        # Cross-check: the batched path must classify every weighting the
        # same way the fidelity path does.
        des_points = _des_points(weights, iters)
        des_classes = classify(des_points)
        agree = des_classes == classes
        payload.update(des_points=des_points, des_classification=des_classes,
                       classification_agreement=agree)
        derived += f" des_agreement={agree}"

    times = [p["time"] for p in points.values()]
    derived += f" time_spread={max(times) / min(times):.2f}x"
    save_report("fig6_reward_dse", payload)
    return csv_row("fig6_reward_dse", us, derived)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="serial discrete-event path instead of vecenv")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
