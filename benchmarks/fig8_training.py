"""Paper Fig. 8: performance vs training iterations.

Alternates one training iteration with a frozen-policy evaluation on a
different application instance.  Paper anchors: sharp improvement after one
iteration (each has hundreds of invocations); ~10 iterations suffice.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import train_cohmeleon
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


def run(quick: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    iters = 4 if quick else 10
    t0 = time.perf_counter()
    _, hist = train_cohmeleon(sim, iterations=iters, seed=2,
                              eval_each_iteration=True,
                              n_phases=4 if quick else 8)
    us = (time.perf_counter() - t0) * 1e6 / max(iters, 1)
    save_report("fig8_training", {
        "iteration": hist.iteration,
        "norm_time": hist.exec_time,
        "norm_mem": hist.offchip,
    })
    first, last = hist.exec_time[0], hist.exec_time[-1]
    return csv_row("fig8_training", us,
                   f"iter1_time={first:.2f} iter{iters}_time={last:.2f} "
                   f"(fast initial drop, plateau ~10)")


if __name__ == "__main__":
    print(run())
