"""Paper Fig. 8: performance vs training iterations.

Alternates one training iteration with a frozen-policy evaluation on a
different application instance.  Paper anchors: sharp improvement after one
iteration (each has hundreds of invocations); ~10 iterations suffice.

Default path runs the whole curve inside one jitted ``lax.scan`` over
iterations (soc.vecenv), twice: once with true per-invocation off-chip
counts feeding the reward and once with ``VecEnv(ddr_attribution=True)``
— the DES's prorated per-tile DDR attribution ported into the scan step —
to measure what the paper's noisy monitor attribution does to training
quality.  ``--fidelity`` keeps the original host-Python DES loop.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import train_cohmeleon, train_cohmeleon_batched
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


def run(quick: bool = False, fidelity: bool = False):
    iters = 4 if quick else 10
    n_phases = 4 if quick else 8
    t0 = time.perf_counter()
    if fidelity:
        sim = SoCSimulator(SOC_MOTIV_PAR)
        _, hist = train_cohmeleon(sim, iterations=iters, seed=2,
                                  eval_each_iteration=True,
                                  n_phases=n_phases)
        iteration, norm_time, norm_mem = (hist.iteration, hist.exec_time,
                                          hist.offchip)
        path = "des"
    else:
        res = train_cohmeleon_batched(
            SOC_MOTIV_PAR, iterations=iters, seed=2, n_phases=n_phases,
            eval_each_iteration=True)
        iteration = list(range(1, iters + 1))
        norm_time = [float(v) for v in res.hist_time[0]]
        norm_mem = [float(v) for v in res.hist_mem[0]]
        path = "vecenv"
    us = (time.perf_counter() - t0) * 1e6 / max(iters, 1)
    payload = {
        "path": path,
        "iteration": iteration,
        "norm_time": norm_time,
        "norm_mem": norm_mem,
    }
    if not fidelity:
        # Same protocol with the DES's prorated DDR attribution feeding
        # the reward (training-signal noise only; metrics stay true).
        from repro.soc import vecenv as vec

        res_a = train_cohmeleon_batched(
            SOC_MOTIV_PAR, iterations=iters, seed=2, n_phases=n_phases,
            eval_each_iteration=True,
            env=vec.VecEnv(SOC_MOTIV_PAR, ddr_attribution=True))
        at = [float(v) for v in res_a.hist_time[0]]
        am = [float(v) for v in res_a.hist_mem[0]]
        payload["ddr_attribution"] = {
            "norm_time": at,
            "norm_mem": am,
            # effect of attribution noise on converged training quality
            "final_time_delta": at[-1] - norm_time[-1],
            "final_mem_delta": am[-1] - norm_mem[-1],
        }
    save_report("fig8_training", payload)
    first, last = norm_time[0], norm_time[-1]
    return csv_row("fig8_training", us,
                   f"path={path} iter1_time={first:.2f} "
                   f"iter{iters}_time={last:.2f} "
                   f"(fast initial drop, plateau ~10)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="serial discrete-event path instead of vecenv")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
