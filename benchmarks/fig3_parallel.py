"""Paper Fig. 3: degradation under concurrent accelerator execution.

1/4/8/12 concurrent medium-workload accelerators per fixed mode; reports
slowdown vs the mode's own single-accelerator case.  Paper anchors:
NON_COH ~2.4x at 12, COH_DMA worst (~8x).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.modes import CoherenceMode, MODE_NAMES
from repro.core.policies import FixedHomogeneous
from repro.soc.config import SOC_MOTIV_PAR, WORKLOAD_MEDIUM
from repro.soc.des import Application, Invocation, Phase, SoCSimulator, Thread


def _app(n):
    threads = [Thread(chain=[Invocation(acc_id=i,
                                        footprint=WORKLOAD_MEDIUM)], loops=6)
               for i in range(n)]
    return Application(name=f"par{n}",
                       phases=[Phase(name="p", threads=threads)])


def run(quick: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    counts = (1, 12) if quick else (1, 4, 8, 12)
    out = {}
    t0 = time.perf_counter()
    for mode in CoherenceMode:
        pol = FixedHomogeneous(mode)
        iso_t = None
        for n in counts:
            res = sim.run(_app(n), pol, train=False)
            t = float(np.mean([r.exec_time
                               for r in res.phases[0].invocations]))
            if n == 1:
                iso_t = t
            out[f"{MODE_NAMES[mode]}|{n}"] = {
                "slowdown": t / iso_t,
                "offchip": res.total_offchip,
            }
    us = (time.perf_counter() - t0) / (len(counts) * 4) * 1e6
    nc12 = out["non-coh-dma|12"]["slowdown"]
    cd12 = out["coh-dma|12"]["slowdown"]
    save_report("fig3_parallel", out)
    return csv_row("fig3_parallel", us,
                   f"non_coh@12={nc12:.2f}x(paper~2.4) "
                   f"coh_dma@12={cd12:.2f}x(paper~8;worst)")


if __name__ == "__main__":
    print(run())
