"""Fig. 10 (beyond-paper): policy robustness under injected faults.

Trains a Cohmeleon agent *inside a fault storm* (accelerator slowdown,
DDR throttling, LLC contention, dropped invocations with bounded retry —
:mod:`repro.soc.faults`) and compares it against the fixed-homogeneous
and manual baselines evaluated under the **same** storm, at increasing
intensity.  Everything is normalized to the NON_COH baseline run under
the same storm, so the ratios isolate the policy's contribution from the
storm's raw slowdown.

The question the figure answers: does the learned policy's advantage
survive a degraded SoC (watchdog + fallback engaged), or does it decay
toward the fixed policies as the timing model it learned stops matching
the machine?

``--fidelity`` additionally replays the deterministic policy families
through the discrete-event simulator under every storm and cross-checks
phase times against the vectorized environment (the DES accepts the same
``FaultSpec``); ``--quick`` shrinks the training budget and runs the
cross-check on one storm.  Both paths print ``des_agree=`` — CI greps
for it.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, load_report, save_report
from repro.core.modes import CoherenceMode
from repro.core.policies import FixedHomogeneous
from repro.soc.apps import make_application, make_phase
from repro.soc.config import SOCS
from repro.soc.des import Application, SoCSimulator

SOC_NAME = "SoC1"
TILE_SEED = 7
INTENSITIES = [("healthy", None), ("mild", 0.25),
               ("moderate", 0.5), ("severe", 1.0)]


def _storm(n_steps: int, intensity):
    import jax

    from repro.soc import faults

    if intensity is None:
        return None
    return faults.storm(n_steps, intensity, jax.random.PRNGKey(42))


def _norm_row(res, i, base_i):
    """Normalized (time, mem) of policy row ``i`` vs baseline row."""
    import jax

    from repro.soc import vecenv as vec

    row = jax.tree_util.tree_map(lambda x: x[i], res)
    base = jax.tree_util.tree_map(lambda x: x[base_i], res)
    nt, nm = vec.normalized_metrics(row, base)
    return float(nt), float(nm)


def _run(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import qlearn
    from repro.core.rewards import PAPER_DEFAULT_WEIGHTS, stack_weights
    from repro.soc import vecenv

    soc = SOCS[SOC_NAME]
    sim = SoCSimulator(soc, seed=1, flavor="mixed")
    env = vecenv.VecEnv.from_simulator(sim)
    n_phases = 4 if quick else 8
    iters = 3 if quick else 10

    train_app = make_application(soc, seed=0, n_phases=n_phases)
    train_apps = [vecenv.compile_app(train_app, soc, seed=it)
                  for it in range(iters)]
    eval_app = vecenv.compile_app(
        make_application(soc, seed=50, n_phases=n_phases), soc, seed=4)
    # Reward-collapse watchdog armed: a fault-degraded episode re-opens
    # epsilon instead of locking in the stale table.
    cfg = qlearn.QConfig(decay_steps=train_apps[0].n_steps * iters,
                        collapse_frac=0.25)
    wb = stack_weights([PAPER_DEFAULT_WEIGHTS])
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(1))

    fixed = list(CoherenceMode)
    names = [FixedHomogeneous(m).name for m in fixed]
    names += ["manual", "cohmeleon"]
    base_idx = names.index(
        FixedHomogeneous(CoherenceMode.NON_COH_DMA).name)

    results = {}
    for label, intensity in INTENSITIES:
        fs = _storm(eval_app.n_steps, intensity)
        qs, _ = env.train_batched(train_apps, cfg, wb, keys,
                                  eval_app=eval_app, faults=fs)
        agent = qlearn.freeze(jax.tree_util.tree_map(lambda x: x[0], qs))
        specs = vecenv.stack_specs(
            [env.lower(eval_app, "fixed", fixed_modes=m) for m in fixed]
            + [env.lower(eval_app, "manual"),
               env.lower(eval_app, "q", qstate=agent, cfg=cfg)])
        res = env.episodes(eval_app, specs, cfg, faults=fs)

        all_norms = {name: _norm_row(res, i, base_idx)
                     for i, name in enumerate(names)}
        fixed_t = [t for n, (t, _) in all_norms.items()
                   if n.startswith("fixed")]
        fixed_m = [m for n, (_, m) in all_norms.items()
                   if n.startswith("fixed")]
        ct, cm = all_norms["cohmeleon"]
        results[label] = {
            "intensity": intensity,
            "cohmeleon": (ct, cm),
            "manual": all_norms["manual"],
            "fixed_mean": (float(np.mean(fixed_t)), float(np.mean(fixed_m))),
            "q_delta_vs_fixed": float(
                (np.mean(fixed_t) - ct) / np.mean(fixed_t)),
            "mem_delta_vs_fixed": float(
                (np.mean(fixed_m) - cm) / np.mean(fixed_m)),
            # absolute slowdown of the storm itself: the NON_COH baseline's
            # wall time under faults vs healthy, directly comparable rows
            "baseline_time": float(jnp.sum(res.phase_time[base_idx])),
            "all": all_norms,
        }

    healthy_base = results["healthy"]["baseline_time"]
    for label, _ in INTENSITIES:
        results[label]["storm_slowdown"] = float(
            results[label]["baseline_time"] / healthy_base)
    return results


def _des_crosscheck(quick: bool, fidelity: bool) -> dict:
    """Deterministic policy families through DES vs vecenv under the same
    FaultSpec, per phase.  Single-thread chain apps — the regime where the
    vectorized lockstep model is exact — so any disagreement is a fault-
    model divergence, not a concurrency artifact."""
    from repro.core.policies import ManualPolicy
    from repro.soc import vecenv

    soc = SOCS[SOC_NAME]
    sim = SoCSimulator(soc, seed=1, flavor="mixed")
    env = vecenv.VecEnv.from_simulator(sim)
    rng = np.random.default_rng(100)
    phases = [make_phase(rng, soc, name=f"p{j}", n_threads=1,
                         size_classes=[c], chain_len=3, loops=2)
              for j, c in enumerate(("S", "M", "L"))]
    app = Application(name=f"{soc.name}-fault-xcheck", phases=phases)
    compiled = vecenv.compile_app(app, soc, seed=TILE_SEED)

    if fidelity:
        storms = [i for _, i in INTENSITIES]
        suite = ([("fixed", m) for m in CoherenceMode]
                 + [("manual", None)])
    else:
        storms = [None, 0.5]
        suite = [("fixed", CoherenceMode.NON_COH_DMA),
                 ("fixed", CoherenceMode.FULLY_COH), ("manual", None)]

    max_rel = 0.0
    for intensity in storms:
        fs = _storm(compiled.n_steps, intensity)
        for kind, mode in suite:
            pol = (FixedHomogeneous(mode) if kind == "fixed"
                   else ManualPolicy())
            des = sim.run(app, pol, seed=TILE_SEED, train=False, faults=fs)
            _, res = env.episode(compiled, policy=kind, fixed_modes=mode,
                                 faults=fs)
            dt = np.array([p.wall_time for p in des.phases])
            max_rel = max(max_rel, float(np.max(
                np.abs(np.asarray(res.phase_time) - dt)
                / np.maximum(dt, 1e-30))))
    return {"max_rel_err": max_rel, "agree": bool(max_rel < 1e-3),
            "storms": len(storms), "families": len(suite)}


def run(quick: bool = False, fidelity: bool = False):
    t0 = time.perf_counter()
    results = _run(quick)
    results["_des_crosscheck"] = _des_crosscheck(quick, fidelity)
    results["_engine"] = {"path": "vecenv", "soc": SOC_NAME,
                          "quick": quick, "fidelity": fidelity}
    us = (time.perf_counter() - t0) * 1e6 / len(INTENSITIES)

    prev = load_report("fig10_faults")
    if (prev is not None
            and prev.get("_engine", {}).get("quick") == quick):
        drift = 0.0
        for label, row in results.items():
            if label.startswith("_") or label not in prev:
                continue
            for fam in ("fixed_mean", "manual"):
                drift = max(drift, float(np.max(np.abs(
                    np.asarray(row[fam]) - np.asarray(prev[label][fam])))))
        results["_vs_previous"] = {"max_abs_family_delta": drift}
    save_report("fig10_faults", results)

    sev = results["severe"]
    return csv_row(
        "fig10_faults", us,
        f"q_delta_healthy={results['healthy']['q_delta_vs_fixed'] * 100:.0f}% "
        f"q_delta_severe={sev['q_delta_vs_fixed'] * 100:.0f}% "
        f"storm_slowdown={sev['storm_slowdown']:.2f}x "
        f"des_agree={results['_des_crosscheck']['agree']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="cross-check every policy family against the DES "
                         "under every storm intensity")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
