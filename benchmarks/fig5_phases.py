"""Paper Fig. 5: per-phase policy comparison on SoC0-style workloads.

Four phases varying thread count and workload size; all policies normalized
per phase to Fixed non-coherent DMA.  Paper anchors: manual and Cohmeleon
match-or-beat the best fixed policy per phase; Cohmeleon needs fewer
off-chip accesses than manual.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import (compare_policies, standard_policy_suite,
                                     train_cohmeleon)
from repro.soc.apps import make_fig5_phases
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


def run(quick: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    t0 = time.perf_counter()
    policy, _ = train_cohmeleon(sim, iterations=3 if quick else 10, seed=0,
                                n_phases=4 if quick else 8)
    app = make_fig5_phases(sim.soc, seed=7)
    suite = standard_policy_suite(sim, include_profiled=not quick)
    suite.append(policy)
    cmp = compare_policies(sim, app, suite, seed=3)
    us = (time.perf_counter() - t0) * 1e6 / max(len(suite), 1)

    payload = {"phases": [p.name for p in app.phases],
               "norm_time": cmp.norm_time, "norm_mem": cmp.norm_mem}
    save_report("fig5_phases", payload)
    ct, cm = cmp.geomean("cohmeleon")
    mt, mm = cmp.geomean("manual")
    return csv_row(
        "fig5_phases", us,
        f"cohmeleon_time={ct:.2f} manual_time={mt:.2f} "
        f"cohmeleon_mem={cm:.2f} manual_mem={mm:.2f}")


if __name__ == "__main__":
    print(run())
