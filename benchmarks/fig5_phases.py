"""Paper Fig. 5: per-phase policy comparison on SoC0-style workloads.

Four phases varying thread count and workload size; all policies normalized
per phase to Fixed non-coherent DMA.  Paper anchors: manual and Cohmeleon
match-or-beat the best fixed policy per phase; Cohmeleon needs fewer
off-chip accesses than manual.

Default engine is the vectorized environment: training runs as one jitted
``vmap(scan(...))`` call (``train_cohmeleon_batched``) and the whole
policy suite — fixed baselines, manual, random, the trained agent —
lowers into ``PolicySpec``s and replays as ONE batched call inside
``compare_policies(backend="vecenv")``.  ``--fidelity`` keeps the
original serial DES loop.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import (compare_policies, standard_policy_suite,
                                     train_cohmeleon,
                                     train_cohmeleon_batched)
from repro.soc.apps import make_fig5_phases
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


def run(quick: bool = False, fidelity: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    iters = 3 if quick else 10
    n_phases = 4 if quick else 8
    backend = "des" if fidelity else "vecenv"
    t0 = time.perf_counter()
    if fidelity:
        policy, _ = train_cohmeleon(sim, iterations=iters, seed=0,
                                    n_phases=n_phases)
    else:
        policy = train_cohmeleon_batched(
            sim, iterations=iters, seed=0, n_phases=n_phases).qpolicy(0)
    app = make_fig5_phases(sim.soc, seed=7)
    suite = standard_policy_suite(sim, include_profiled=not quick,
                                  backend=backend)
    suite.append(policy)
    cmp = compare_policies(sim, app, suite, seed=3, backend=backend)
    us = (time.perf_counter() - t0) * 1e6 / max(len(suite), 1)

    payload = {"path": backend,
               # vecenv: the suite (incl. the NON_COH baseline) is one
               # batched heterogeneous-PolicySpec episode call.
               "suite_episode_calls": 1 if backend == "vecenv"
               else len(suite) + 1,
               "phases": [p.name for p in app.phases],
               "norm_time": cmp.norm_time, "norm_mem": cmp.norm_mem}
    save_report("fig5_phases", payload)
    ct, cm = cmp.geomean("cohmeleon")
    mt, mm = cmp.geomean("manual")
    return csv_row(
        "fig5_phases", us,
        f"path={backend} cohmeleon_time={ct:.2f} manual_time={mt:.2f} "
        f"cohmeleon_mem={cm:.2f} manual_mem={mm:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="serial discrete-event path instead of vecenv")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
