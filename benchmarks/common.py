"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time

import numpy as np

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "benchmarks")


def save_report(name: str, payload: dict) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return os.path.abspath(path)


def load_report(name: str) -> dict | None:
    """The committed JSON report for ``name``, or None.

    Used by benchmarks that compare a fresh run against the committed
    baseline (throughput regression gate, fig9's per-family drift check
    across refactors)."""
    path = os.path.join(REPORT_DIR, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _np_default(o):
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def timed(fn, *args, repeats: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
