"""Paper Fig. 7: breakdown of coherence decisions by workload-size class.

Paper anchors: heavy reliance on coh-dma / non-coh-dma overall; Cohmeleon
leans less on non-coh and more on (llc-)coh-dma than manual except at XL.

Default engine is the vectorized environment (batched training + a single
mixed-family ``PolicySpec`` replay call inside
``compare_policies(backend="vecenv")``, whose episode traces lift into
the DES's RunResult shape so ``mode_breakdown`` works unchanged).
``--fidelity`` keeps the original serial DES loop.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.modes import MODE_NAMES
from repro.core.orchestrator import (compare_policies, mode_breakdown,
                                     train_cohmeleon,
                                     train_cohmeleon_batched)
from repro.core.policies import ManualPolicy
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


def run(quick: bool = False, fidelity: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    iters = 3 if quick else 10
    n_phases = 4 if quick else 8
    backend = "des" if fidelity else "vecenv"
    t0 = time.perf_counter()
    if fidelity:
        policy, _ = train_cohmeleon(sim, iterations=iters, seed=0,
                                    n_phases=n_phases)
    else:
        policy = train_cohmeleon_batched(
            sim, iterations=iters, seed=0, n_phases=n_phases).qpolicy(0)
    app = make_application(sim.soc, seed=123, n_phases=n_phases)
    cmp = compare_policies(sim, app, [ManualPolicy(), policy], seed=9,
                           backend=backend)
    us = (time.perf_counter() - t0) * 1e6

    out = {"path": backend}
    for pol in ("manual", "cohmeleon"):
        bd = mode_breakdown(cmp.raw[pol], sim.soc)
        out[pol] = {k: dict(zip(MODE_NAMES, v.tolist()))
                    for k, v in bd.items()}
    save_report("fig7_breakdown", out)

    c_tot = out["cohmeleon"]["total"]
    dma_heavy = c_tot["coh-dma"] + c_tot["non-coh-dma"]
    return csv_row("fig7_breakdown", us,
                   f"path={backend} cohmeleon_dma_share={dma_heavy:.2f} "
                   f"(paper: heavy coh-dma+non-coh reliance)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="serial discrete-event path instead of vecenv")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
