"""Paper Fig. 7: breakdown of coherence decisions by workload-size class.

Paper anchors: heavy reliance on coh-dma / non-coh-dma overall; Cohmeleon
leans less on non-coh and more on (llc-)coh-dma than manual except at XL.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.modes import MODE_NAMES
from repro.core.orchestrator import (compare_policies, mode_breakdown,
                                     train_cohmeleon)
from repro.core.policies import ManualPolicy
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


def run(quick: bool = False):
    sim = SoCSimulator(SOC_MOTIV_PAR)
    t0 = time.perf_counter()
    policy, _ = train_cohmeleon(sim, iterations=3 if quick else 10, seed=0,
                                n_phases=4 if quick else 8)
    app = make_application(sim.soc, seed=123, n_phases=4 if quick else 8)
    cmp = compare_policies(sim, app, [ManualPolicy(), policy], seed=9)
    us = (time.perf_counter() - t0) * 1e6

    out = {}
    for pol in ("manual", "cohmeleon"):
        bd = mode_breakdown(cmp.raw[pol], sim.soc)
        out[pol] = {k: dict(zip(MODE_NAMES, v.tolist()))
                    for k, v in bd.items()}
    save_report("fig7_breakdown", out)

    c_tot = out["cohmeleon"]["total"]
    dma_heavy = c_tot["coh-dma"] + c_tot["non-coh-dma"]
    return csv_row("fig7_breakdown", us,
                   f"cohmeleon_dma_share={dma_heavy:.2f} "
                   f"(paper: heavy coh-dma+non-coh reliance)")


if __name__ == "__main__":
    print(run())
