"""Aggregate the dry-run roofline reports into the §Roofline table.

Reads reports/dryrun/*.json (written by launch/dryrun.py) and emits the
per-(arch x shape) single-pod table with the three terms, dominant
bottleneck, useful-flops ratio, and roofline fraction; also computes the
flash-kernel-adjusted memory term (the XLA path materializes S^2 attention
scores that the Pallas flash kernel never writes to HBM).
"""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import csv_row, save_report
from repro.configs import ARCHS
from repro.configs.shapes import SHAPES
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def _attention_score_bytes(cfg, spec) -> float:
    """fp32 S^2 score traffic the flash kernel avoids (approximation:
    ~6 passes train [write+read fwd, 4 bwd], 3 prefill, 0 decode)."""
    if spec.kind == "decode":
        return 0.0
    if cfg.family == "ssm":
        return 0.0
    passes = 6.0 if spec.kind == "train" else 3.0
    s = spec.seq_len
    b = spec.global_batch
    # local layers only attend within the window
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.rg_pattern, 1)
    win_frac = 1.0
    if cfg.global_every and cfg.sliding_window:
        local = (cfg.global_every - 1) / cfg.global_every
        win_frac = (1 - local) + local * min(1.0, cfg.sliding_window / s)
    elif cfg.family == "hybrid" and cfg.sliding_window:
        win_frac = min(1.0, cfg.sliding_window / s)
    return passes * b * cfg.n_heads * s * s * 4.0 * n_attn * win_frac


def run(quick: bool = False):
    t0 = time.perf_counter()
    rows = []
    table = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              "*__pod16x16.json"))):
        d = json.load(open(path))
        cfg = ARCHS[d["arch"]]
        spec = SHAPES[d["shape"]]
        adj_bytes = max(
            d["hlo_bytes"] - _attention_score_bytes(cfg, spec), 0.0)
        t_mem_adj = adj_bytes / (d["chips"] * HBM_BW)
        dom = max(("compute", d["t_comp"]), ("memory", t_mem_adj),
                  ("collective", d["t_coll"]), key=lambda kv: kv[1])
        frac = d["t_comp"] / max(d["t_comp"], t_mem_adj, d["t_coll"])
        table[f"{d['arch']}|{d['shape']}"] = {
            **{k: d[k] for k in ("t_comp", "t_mem", "t_coll", "useful_ratio",
                                 "bytes_per_device", "dominant")},
            "t_mem_flashadj": t_mem_adj,
            "dominant_flashadj": dom[0],
            "roofline_fraction_flashadj": frac,
        }
    save_report("roofline_table", table)
    n = len(table)
    worst = sorted(table.items(),
                   key=lambda kv: kv[1]["roofline_fraction_flashadj"])[:3]
    us = (time.perf_counter() - t0) * 1e6
    return csv_row(
        "roofline_table", us,
        f"cells={n} worst3=" + ";".join(
            f"{k}({v['roofline_fraction_flashadj']:.3f})"
            for k, v in worst))


if __name__ == "__main__":
    print(run())
