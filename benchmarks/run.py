"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark.  ``--quick`` trims
training iterations and sweep sizes (used by tests); the full run is what
EXPERIMENTS.md cites.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (fig2_isolation, fig3_parallel, fig5_phases,
                        fig6_reward_dse, fig7_breakdown, fig8_training,
                        fig9_socs, fig10_faults, fig12_dse, kernels_bench,
                        overhead, roofline_table, vecenv_throughput)

ALL = [
    ("fig2_isolation", fig2_isolation.run),
    ("fig3_parallel", fig3_parallel.run),
    ("fig5_phases", fig5_phases.run),
    ("fig6_reward_dse", fig6_reward_dse.run),
    ("fig7_breakdown", fig7_breakdown.run),
    ("fig8_training", fig8_training.run),
    ("fig9_socs", fig9_socs.run),
    ("fig10_faults", fig10_faults.run),
    ("fig12_dse", fig12_dse.run),
    ("vecenv_throughput", vecenv_throughput.run),
    ("overhead", overhead.run),
    ("kernels", kernels_bench.run),
    ("roofline_table", roofline_table.run),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in ALL:
        if args.only and args.only not in name:
            continue
        try:
            print(fn(quick=args.quick), flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},nan,FAILED", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
