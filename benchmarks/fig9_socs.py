"""Paper Fig. 9 + headline claim: Cohmeleon across SoC configurations.

Runs the full policy comparison on eight SoC configurations (SoC0 streaming
/ irregular traffic-gen variants, SoC1-3 mixed traffic-gen, case-study
SoC4-6) and reports the paper's headline numbers: mean speedup and
off-chip-access reduction of Cohmeleon vs the five fixed policies
(paper: 38% and 66%).

Default engine is the stacked vectorized environment
(:mod:`repro.soc.stacked`): all SoCs train in ONE batched
``vmap(scan(...))`` call and each policy family evaluates every SoC in a
single batched call (fixed suite: one call for all SoCs x all fixed
policies).  ``--fidelity`` runs the original serial DES loop instead;
``--quick`` additionally cross-checks vecenv == DES per phase on
single-thread applications (where the lockstep model is exact).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.modes import CoherenceMode
from repro.core.orchestrator import (compare_policies,
                                     profile_fixed_heterogeneous,
                                     standard_policy_suite, train_cohmeleon)
from repro.core.policies import FixedHomogeneous, ManualPolicy
from repro.soc.apps import make_application, make_case_study_app, make_phase
from repro.soc.config import SOCS
from repro.soc.des import Application, SoCSimulator

SOC_FLAVORS = [
    ("SoC0", "streaming"), ("SoC0", "irregular"),
    ("SoC1", "mixed"), ("SoC2", "mixed"), ("SoC3", "mixed"),
    ("SoC4", "mixed"), ("SoC5", "mixed"), ("SoC6", "mixed"),
]
CASE_STUDY = ("SoC4", "SoC5", "SoC6")


def _norms(pt, po, base_t, base_m) -> tuple[float, float]:
    """Per-phase normalization to the NON_COH baseline, then geomean — the
    canonical arithmetic (vecenv.normalized_metrics), not a local copy."""
    import jax.numpy as jnp

    from repro.soc import vecenv as vec

    def res(t, o):
        return vec.EpisodeResult(
            phase_time=jnp.asarray(np.asarray(t)),
            phase_offchip=jnp.asarray(np.asarray(o)),
            mode=None, state_idx=None, exec_time=None, offchip=None,
            reward=None)

    nt, nm = vec.normalized_metrics(res(pt, po), res(base_t, base_m))
    return float(nt), float(nm)


def _eval_app(sim, soc_name: str, n_phases: int) -> Application:
    if soc_name in CASE_STUDY:
        return make_case_study_app(sim.soc, seed=50)
    return make_application(sim.soc, seed=50, n_phases=n_phases)


def _headline(results: dict, speedups, mem_reductions) -> tuple[float, float]:
    mean_speedup = float(np.mean(speedups))
    mean_memred = float(np.mean(mem_reductions))
    results["_headline"] = {
        "mean_speedup_vs_fixed": mean_speedup,
        "mean_mem_reduction_vs_fixed": mean_memred,
        "paper_claim": {"speedup": 0.38, "mem_reduction": 0.66},
    }
    return mean_speedup, mean_memred


def _run_vecenv(flavors, iters: int, quick: bool) -> dict:
    """All SoCs through the stacked scale path in batched calls."""
    import jax
    import jax.numpy as jnp

    from repro.core import qlearn
    from repro.core.rewards import PAPER_DEFAULT_WEIGHTS, stack_weights
    from repro.soc.stacked import StackedVecEnv

    sims = [SoCSimulator(SOCS[n], seed=1, flavor=f) for n, f in flavors]
    env = StackedVecEnv.from_simulators(sims)
    n_phases = 4 if quick else 8
    K = len(sims)

    # ---- training: every SoC's agent in ONE vmapped call.
    train_apps = [make_application(sim.soc, seed=0, n_phases=n_phases)
                  for sim in sims]
    stacked_iters = [env.compile(train_apps, seed=it) for it in range(iters)]
    cfg = qlearn.QConfig(decay_steps=jnp.asarray(
        [s * iters for s in stacked_iters[0].n_steps], jnp.int32))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(K)).reshape(K, 1, 2)
    qs, _ = env.train_batched(stacked_iters, cfg,
                              stack_weights([PAPER_DEFAULT_WEIGHTS]), keys)

    # ---- evaluation: one batched call per policy family, all SoCs.
    eval_apps = [_eval_app(sim, n, n_phases)
                 for sim, (n, _) in zip(sims, flavors)]
    stacked_eval = env.compile(eval_apps, seed=4)

    fixed_names = [FixedHomogeneous(m).name for m in CoherenceMode]
    rows = [np.full((K, env.n_accs), int(m), np.int32)
            for m in CoherenceMode]
    if not quick:
        hetero = []
        for k, sim in enumerate(sims):
            pol = profile_fixed_heterogeneous(sim, backend="vecenv",
                                              env=env.envs[k])
            modes = [int(pol.assignment.get(p.name,
                                            CoherenceMode.NON_COH_DMA))
                     for p in sim.profiles]
            modes += [int(CoherenceMode.NON_COH_DMA)] * (env.n_accs
                                                         - len(modes))
            hetero.append(modes)
        rows.append(np.asarray(hetero, np.int32))
        fixed_names.append("fixed-heterogeneous")
    fm = np.stack(rows, axis=1)                      # (K, N_fixed, A)
    res_fixed = env.episodes_fixed(stacked_eval, fm)
    res_manual = env.episodes_manual(stacked_eval)
    # Random (untrained all-ties table) + Cohmeleon agents: one q call.
    q0 = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K, 1) + x.shape),
        qlearn.init_qstate(qlearn.QConfig()))
    q_all = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=1), q0, qs)
    res_q = env.episodes_q(stacked_eval, q_all, cfg)

    base_idx = list(CoherenceMode).index(CoherenceMode.NON_COH_DMA)
    results, speedups, mem_reductions = {}, [], []
    for k, (soc_name, flavor) in enumerate(flavors):
        pt_f, po_f = env.lane_phase_metrics(stacked_eval, res_fixed, k)
        base_t, base_m = pt_f[base_idx], po_f[base_idx]
        all_norms = {name: _norms(pt_f[i], po_f[i], base_t, base_m)
                     for i, name in enumerate(fixed_names)}
        pt, po = env.lane_phase_metrics(stacked_eval, res_manual, k)
        all_norms["manual"] = _norms(pt, po, base_t, base_m)
        pt, po = env.lane_phase_metrics(stacked_eval, res_q, k)
        all_norms["random"] = _norms(pt[0], po[0], base_t, base_m)
        all_norms["cohmeleon"] = _norms(pt[1], po[1], base_t, base_m)

        fixed_t = [t for n, (t, _) in all_norms.items()
                   if n.startswith("fixed")]
        fixed_m = [m for n, (_, m) in all_norms.items()
                   if n.startswith("fixed")]
        ct, cm = all_norms["cohmeleon"]
        speedup = (np.mean(fixed_t) - ct) / np.mean(fixed_t)
        mem_red = (np.mean(fixed_m) - cm) / np.mean(fixed_m)
        speedups.append(speedup)
        mem_reductions.append(mem_red)
        results[f"{soc_name}-{flavor}"] = {
            "cohmeleon": all_norms["cohmeleon"],
            "manual": all_norms["manual"],
            "fixed_mean": (float(np.mean(fixed_t)), float(np.mean(fixed_m))),
            "speedup_vs_fixed": float(speedup),
            "mem_reduction_vs_fixed": float(mem_red),
            "all": all_norms,
        }

    if quick:
        results["_des_crosscheck"] = _des_crosscheck(env, sims)
    results["_engine"] = {"path": "vecenv", "lanes": K,
                          "train_calls": 1,
                          "eval_calls_per_policy_family": 1}
    _headline(results, speedups, mem_reductions)
    return results


def _des_crosscheck(env, sims) -> dict:
    """Single-thread chain apps: stacked vecenv must match the DES per
    phase on every fixed mode and on manual (the exactness regime)."""
    import jax.numpy as jnp

    apps = []
    for i, sim in enumerate(sims):
        rng = np.random.default_rng(100 + i)
        phases = [make_phase(rng, sim.soc, name=f"p{j}", n_threads=1,
                             size_classes=[c], chain_len=3, loops=2)
                  for j, c in enumerate(("S", "M", "L"))]
        apps.append(Application(name=f"{sim.soc.name}-xcheck",
                                phases=phases))
    stacked = env.compile(apps, seed=7)
    fm = np.stack([np.full((len(sims), env.n_accs), int(m), np.int32)
                   for m in CoherenceMode], axis=1)
    res_fixed = env.episodes_fixed(stacked, fm)
    res_manual = env.episodes_manual(stacked)

    max_rel = 0.0
    for k, (sim, app) in enumerate(zip(sims, apps)):
        pt_f, _ = env.lane_phase_metrics(stacked, res_fixed, k)
        for mi, mode in enumerate(CoherenceMode):
            des = sim.run(app, FixedHomogeneous(mode), seed=7, train=False)
            dt = np.array([p.wall_time for p in des.phases])
            max_rel = max(max_rel, float(np.max(
                np.abs(pt_f[mi] - dt) / np.maximum(dt, 1e-30))))
        des = sim.run(app, ManualPolicy(), seed=7, train=False)
        dt = np.array([p.wall_time for p in des.phases])
        pt_m, _ = env.lane_phase_metrics(stacked, res_manual, k)
        max_rel = max(max_rel, float(np.max(
            np.abs(pt_m - dt) / np.maximum(dt, 1e-30))))
    return {"max_rel_err": max_rel, "agree": bool(max_rel < 1e-3)}


def _run_des(flavors, iters: int, quick: bool) -> dict:
    """The original serial fidelity path (one DES agent at a time)."""
    results, speedups, mem_reductions = {}, [], []
    for soc_name, flavor in flavors:
        soc = SOCS[soc_name]
        sim = SoCSimulator(soc, seed=1, flavor=flavor)
        policy, _ = train_cohmeleon(sim, iterations=iters, seed=0,
                                    n_phases=4 if quick else 8)
        app = _eval_app(sim, soc_name, 4 if quick else 8)
        suite = standard_policy_suite(sim, include_profiled=not quick)
        suite.append(policy)
        cmp = compare_policies(sim, app, suite, seed=4)

        fixed_t, fixed_m = [], []
        for name in cmp.policies:
            t, m = cmp.geomean(name)
            if name.startswith("fixed"):
                fixed_t.append(t)
                fixed_m.append(m)
        ct, cm = cmp.geomean("cohmeleon")
        mt, mm = cmp.geomean("manual")
        speedup = (np.mean(fixed_t) - ct) / np.mean(fixed_t)
        mem_red = (np.mean(fixed_m) - cm) / np.mean(fixed_m)
        speedups.append(speedup)
        mem_reductions.append(mem_red)
        results[f"{soc_name}-{flavor}"] = {
            "cohmeleon": (ct, cm), "manual": (mt, mm),
            "fixed_mean": (float(np.mean(fixed_t)), float(np.mean(fixed_m))),
            "speedup_vs_fixed": float(speedup),
            "mem_reduction_vs_fixed": float(mem_red),
            "all": {n: cmp.geomean(n) for n in cmp.policies},
        }
    results["_engine"] = {"path": "des", "lanes": len(flavors)}
    _headline(results, speedups, mem_reductions)
    return results


def run(quick: bool = False, fidelity: bool = False):
    flavors = SOC_FLAVORS[:3] if quick else SOC_FLAVORS
    iters = 3 if quick else 10
    t0 = time.perf_counter()
    if fidelity:
        results = _run_des(flavors, iters, quick)
    else:
        results = _run_vecenv(flavors, iters, quick)
    us = (time.perf_counter() - t0) * 1e6 / len(flavors)

    head = results["_headline"]
    mean_speedup = head["mean_speedup_vs_fixed"]
    mean_memred = head["mean_mem_reduction_vs_fixed"]
    save_report("fig9_socs", results)
    extra = ""
    if "_des_crosscheck" in results:
        extra = f" des_agree={results['_des_crosscheck']['agree']}"
    return csv_row(
        "fig9_socs", us,
        f"path={results['_engine']['path']} "
        f"speedup={mean_speedup * 100:.0f}%(paper38%) "
        f"mem_red={mean_memred * 100:.0f}%(paper66%)" + extra)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="serial discrete-event path instead of vecenv")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
