"""Paper Fig. 9 + headline claim: Cohmeleon across SoC configurations.

Runs the full policy comparison on eight SoC configurations (SoC0 streaming
/ irregular traffic-gen variants, SoC1-3 mixed traffic-gen, case-study
SoC4-6) and reports the paper's headline numbers: mean speedup and
off-chip-access reduction of Cohmeleon vs the five fixed policies
(paper: 38% and 66%).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.orchestrator import (compare_policies, standard_policy_suite,
                                     train_cohmeleon)
from repro.soc.apps import make_application, make_case_study_app
from repro.soc.config import SOCS
from repro.soc.des import SoCSimulator

SOC_FLAVORS = [
    ("SoC0", "streaming"), ("SoC0", "irregular"),
    ("SoC1", "mixed"), ("SoC2", "mixed"), ("SoC3", "mixed"),
    ("SoC4", "mixed"), ("SoC5", "mixed"), ("SoC6", "mixed"),
]


def run(quick: bool = False):
    flavors = SOC_FLAVORS[:3] if quick else SOC_FLAVORS
    iters = 3 if quick else 10
    results = {}
    speedups, mem_reductions = [], []
    t0 = time.perf_counter()
    for soc_name, flavor in flavors:
        soc = SOCS[soc_name]
        sim = SoCSimulator(soc, seed=1, flavor=flavor)
        policy, _ = train_cohmeleon(sim, iterations=iters, seed=0,
                                    n_phases=4 if quick else 8)
        if soc_name in ("SoC4", "SoC5", "SoC6"):
            app = make_case_study_app(soc, seed=50)
        else:
            app = make_application(soc, seed=50, n_phases=4 if quick else 8)
        suite = standard_policy_suite(sim, include_profiled=not quick)
        suite.append(policy)
        cmp = compare_policies(sim, app, suite, seed=4)

        fixed_t, fixed_m = [], []
        for name in cmp.policies:
            t, m = cmp.geomean(name)
            if name.startswith("fixed"):
                fixed_t.append(t)
                fixed_m.append(m)
        ct, cm = cmp.geomean("cohmeleon")
        mt, mm = cmp.geomean("manual")
        speedup = (np.mean(fixed_t) - ct) / np.mean(fixed_t)
        mem_red = (np.mean(fixed_m) - cm) / np.mean(fixed_m)
        speedups.append(speedup)
        mem_reductions.append(mem_red)
        results[f"{soc_name}-{flavor}"] = {
            "cohmeleon": (ct, cm), "manual": (mt, mm),
            "fixed_mean": (float(np.mean(fixed_t)), float(np.mean(fixed_m))),
            "speedup_vs_fixed": float(speedup),
            "mem_reduction_vs_fixed": float(mem_red),
            "all": {n: cmp.geomean(n) for n in cmp.policies},
        }
    us = (time.perf_counter() - t0) * 1e6 / len(flavors)

    mean_speedup = float(np.mean(speedups))
    mean_memred = float(np.mean(mem_reductions))
    results["_headline"] = {
        "mean_speedup_vs_fixed": mean_speedup,
        "mean_mem_reduction_vs_fixed": mean_memred,
        "paper_claim": {"speedup": 0.38, "mem_reduction": 0.66},
    }
    save_report("fig9_socs", results)
    return csv_row(
        "fig9_socs", us,
        f"speedup={mean_speedup * 100:.0f}%(paper38%) "
        f"mem_red={mean_memred * 100:.0f}%(paper66%)")


if __name__ == "__main__":
    print(run())
