"""Paper Fig. 9 + headline claim: Cohmeleon across SoC configurations.

Runs the full policy comparison on eight SoC configurations (SoC0 streaming
/ irregular traffic-gen variants, SoC1-3 mixed traffic-gen, case-study
SoC4-6) and reports the paper's headline numbers: mean speedup and
off-chip-access reduction of Cohmeleon vs the five fixed policies
(paper: 38% and 66%).

Default engine is the stacked vectorized environment
(:mod:`repro.soc.stacked`) and the whole figure is TWO jitted calls: all
SoCs train in one batched ``vmap(scan(...))`` call, and every policy —
the four fixed-homogeneous baselines, profiled heterogeneous, random,
manual, and the trained Cohmeleon agents — lowers into a
``PolicySpec`` and evaluates across all SoCs in ONE
``StackedVecEnv.episodes`` call (the NON_COH normalization baseline is
just that call's NON_COH row).  ``--fidelity`` runs the original serial
DES loop instead; ``--quick`` additionally asserts the one-train-one-eval
call counts and cross-checks vecenv == DES per phase on single-thread
applications (where the lockstep model is exact).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, load_report, save_report
from repro.core.modes import CoherenceMode
from repro.core.orchestrator import (compare_policies,
                                     profile_fixed_heterogeneous,
                                     standard_policy_suite, train_cohmeleon)
from repro.core.policies import FixedHomogeneous, ManualPolicy
from repro.soc.apps import make_application, make_case_study_app, make_phase
from repro.soc.config import SOCS
from repro.soc.des import Application, SoCSimulator

SOC_FLAVORS = [
    ("SoC0", "streaming"), ("SoC0", "irregular"),
    ("SoC1", "mixed"), ("SoC2", "mixed"), ("SoC3", "mixed"),
    ("SoC4", "mixed"), ("SoC5", "mixed"), ("SoC6", "mixed"),
]
CASE_STUDY = ("SoC4", "SoC5", "SoC6")


def _norms(pt, po, base_t, base_m) -> tuple[float, float]:
    """Per-phase normalization to the NON_COH baseline, then geomean — the
    canonical arithmetic (vecenv.normalized_metrics), not a local copy."""
    import jax.numpy as jnp

    from repro.soc import vecenv as vec

    def res(t, o):
        return vec.EpisodeResult(
            phase_time=jnp.asarray(np.asarray(t)),
            phase_offchip=jnp.asarray(np.asarray(o)),
            mode=None, state_idx=None, exec_time=None, offchip=None,
            reward=None)

    nt, nm = vec.normalized_metrics(res(pt, po), res(base_t, base_m))
    return float(nt), float(nm)


def _eval_app(sim, soc_name: str, n_phases: int) -> Application:
    if soc_name in CASE_STUDY:
        return make_case_study_app(sim.soc, seed=50)
    return make_application(sim.soc, seed=50, n_phases=n_phases)


def _headline(results: dict, speedups, mem_reductions) -> tuple[float, float]:
    mean_speedup = float(np.mean(speedups))
    mean_memred = float(np.mean(mem_reductions))
    results["_headline"] = {
        "mean_speedup_vs_fixed": mean_speedup,
        "mean_mem_reduction_vs_fixed": mean_memred,
        "paper_claim": {"speedup": 0.38, "mem_reduction": 0.66},
    }
    return mean_speedup, mean_memred


def _run_vecenv(flavors, iters: int, quick: bool) -> dict:
    """All SoCs through the stacked scale path: one training call, then
    every policy family lowered into PolicySpecs and evaluated in one
    batched call."""
    import jax
    import jax.numpy as jnp

    from repro.core import qlearn
    from repro.core.policies import QPolicy, RandomPolicy
    from repro.core.rewards import PAPER_DEFAULT_WEIGHTS, stack_weights
    from repro.soc.stacked import StackedVecEnv

    sims = [SoCSimulator(SOCS[n], seed=1, flavor=f) for n, f in flavors]
    env = StackedVecEnv.from_simulators(sims)
    n_phases = 4 if quick else 8
    K = len(sims)

    # ---- training: every SoC's agent in ONE vmapped call.
    train_apps = [make_application(sim.soc, seed=0, n_phases=n_phases)
                  for sim in sims]
    stacked_iters = [env.compile(train_apps, seed=it) for it in range(iters)]
    cfg = qlearn.QConfig(decay_steps=jnp.asarray(
        [s * iters for s in stacked_iters[0].n_steps], jnp.int32))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(K)).reshape(K, 1, 2)
    qs, _ = env.train_batched(stacked_iters, cfg,
                              stack_weights([PAPER_DEFAULT_WEIGHTS]), keys)

    # ---- evaluation: EVERY policy family, every SoC, ONE call.  The
    # profiled-heterogeneous baseline (skipped in quick mode) is design-
    # time work, not an episode; the NON_COH normalization baseline is the
    # eval call's own fixed-non-coh row.
    eval_apps = [_eval_app(sim, n, n_phases)
                 for sim, (n, _) in zip(sims, flavors)]
    stacked_eval = env.compile(eval_apps, seed=4)

    names = [FixedHomogeneous(m).name for m in CoherenceMode]
    if not quick:
        hetero = [profile_fixed_heterogeneous(sim, backend="vecenv",
                                              env=env.envs[k])
                  for k, sim in enumerate(sims)]
        names.append("fixed-heterogeneous")
    names += ["random", "manual", "cohmeleon"]
    per_lane = []
    for k in range(K):
        agent = QPolicy(qlearn.QConfig())
        agent.qs = jax.tree_util.tree_map(lambda x, k=k: x[k, 0], qs)
        pols = [FixedHomogeneous(m) for m in CoherenceMode]
        if not quick:
            pols.append(hetero[k])
        pols += [RandomPolicy(), ManualPolicy(), agent]
        per_lane.append(pols)
    specs = env.lower(stacked_eval, per_lane)
    # Default (K, N) evaluation key grid.  (The transitional override
    # that replayed the pre-PolicySpec per-family q keys is gone: the
    # deterministic families ignore their keys entirely, and the learned
    # families' committed report was regenerated under the default
    # protocol.)
    res = env.episodes(stacked_eval, specs, cfg)

    train_calls = env.calls["train"]
    eval_calls = env.calls["episodes"]
    if quick:
        assert train_calls == 1 and eval_calls == 1, (
            f"fig9 must be one train + one eval call, got "
            f"{train_calls} + {eval_calls}")

    base_idx = names.index(
        FixedHomogeneous(CoherenceMode.NON_COH_DMA).name)
    results, speedups, mem_reductions = {}, [], []
    for k, (soc_name, flavor) in enumerate(flavors):
        pt, po = env.lane_phase_metrics(stacked_eval, res, k)
        base_t, base_m = pt[base_idx], po[base_idx]
        all_norms = {name: _norms(pt[i], po[i], base_t, base_m)
                     for i, name in enumerate(names)}

        fixed_t = [t for n, (t, _) in all_norms.items()
                   if n.startswith("fixed")]
        fixed_m = [m for n, (_, m) in all_norms.items()
                   if n.startswith("fixed")]
        ct, cm = all_norms["cohmeleon"]
        speedup = (np.mean(fixed_t) - ct) / np.mean(fixed_t)
        mem_red = (np.mean(fixed_m) - cm) / np.mean(fixed_m)
        speedups.append(speedup)
        mem_reductions.append(mem_red)
        results[f"{soc_name}-{flavor}"] = {
            "cohmeleon": all_norms["cohmeleon"],
            "manual": all_norms["manual"],
            "fixed_mean": (float(np.mean(fixed_t)), float(np.mean(fixed_m))),
            "speedup_vs_fixed": float(speedup),
            "mem_reduction_vs_fixed": float(mem_red),
            "all": all_norms,
        }

    if quick:
        results["_des_crosscheck"] = _des_crosscheck(env, sims)
    results["_engine"] = {"path": "vecenv", "lanes": K,
                          "train_calls": int(train_calls),
                          "eval_calls": int(eval_calls)}
    _headline(results, speedups, mem_reductions)
    return results


def _des_crosscheck(env, sims) -> dict:
    """Single-thread chain apps: the lowered-spec episodes must match the
    DES per phase on every fixed mode and on manual (the exactness
    regime) — one mixed-family batched call vs serial DES replays."""
    apps = []
    for i, sim in enumerate(sims):
        rng = np.random.default_rng(100 + i)
        phases = [make_phase(rng, sim.soc, name=f"p{j}", n_threads=1,
                             size_classes=[c], chain_len=3, loops=2)
                  for j, c in enumerate(("S", "M", "L"))]
        apps.append(Application(name=f"{sim.soc.name}-xcheck",
                                phases=phases))
    stacked = env.compile(apps, seed=7)
    suite = [FixedHomogeneous(m) for m in CoherenceMode] + [ManualPolicy()]
    res = env.episodes(stacked, env.lower(stacked, suite))

    max_rel = 0.0
    for k, (sim, app) in enumerate(zip(sims, apps)):
        pt, _ = env.lane_phase_metrics(stacked, res, k)
        for i, pol in enumerate(suite):
            des = sim.run(app, pol, seed=7, train=False)
            dt = np.array([p.wall_time for p in des.phases])
            max_rel = max(max_rel, float(np.max(
                np.abs(pt[i] - dt) / np.maximum(dt, 1e-30))))
    return {"max_rel_err": max_rel, "agree": bool(max_rel < 1e-3)}


def _run_des(flavors, iters: int, quick: bool) -> dict:
    """The original serial fidelity path (one DES agent at a time)."""
    results, speedups, mem_reductions = {}, [], []
    for soc_name, flavor in flavors:
        soc = SOCS[soc_name]
        sim = SoCSimulator(soc, seed=1, flavor=flavor)
        policy, _ = train_cohmeleon(sim, iterations=iters, seed=0,
                                    n_phases=4 if quick else 8)
        app = _eval_app(sim, soc_name, 4 if quick else 8)
        suite = standard_policy_suite(sim, include_profiled=not quick)
        suite.append(policy)
        cmp = compare_policies(sim, app, suite, seed=4)

        fixed_t, fixed_m = [], []
        for name in cmp.policies:
            t, m = cmp.geomean(name)
            if name.startswith("fixed"):
                fixed_t.append(t)
                fixed_m.append(m)
        ct, cm = cmp.geomean("cohmeleon")
        mt, mm = cmp.geomean("manual")
        speedup = (np.mean(fixed_t) - ct) / np.mean(fixed_t)
        mem_red = (np.mean(fixed_m) - cm) / np.mean(fixed_m)
        speedups.append(speedup)
        mem_reductions.append(mem_red)
        results[f"{soc_name}-{flavor}"] = {
            "cohmeleon": (ct, cm), "manual": (mt, mm),
            "fixed_mean": (float(np.mean(fixed_t)), float(np.mean(fixed_m))),
            "speedup_vs_fixed": float(speedup),
            "mem_reduction_vs_fixed": float(mem_red),
            "all": {n: cmp.geomean(n) for n in cmp.policies},
        }
    results["_engine"] = {"path": "des", "lanes": len(flavors)}
    _headline(results, speedups, mem_reductions)
    return results


def run(quick: bool = False, fidelity: bool = False):
    flavors = SOC_FLAVORS[:3] if quick else SOC_FLAVORS
    iters = 3 if quick else 10
    t0 = time.perf_counter()
    if fidelity:
        results = _run_des(flavors, iters, quick)
    else:
        results = _run_vecenv(flavors, iters, quick)
    us = (time.perf_counter() - t0) * 1e6 / len(flavors)

    head = results["_headline"]
    mean_speedup = head["mean_speedup_vs_fixed"]
    mean_memred = head["mean_mem_reduction_vs_fixed"]
    prev = load_report("fig9_socs")
    if (prev is not None and prev.get("_engine", {}).get("path")
            == results["_engine"]["path"]
            and prev["_engine"].get("lanes")
            == results["_engine"]["lanes"]):
        # Per-family drift vs the committed report — the redesign
        # guardrail (deterministic families are bitwise-stable; learned
        # families use the default (K, N) evaluation key grid).
        drift = 0.0
        for soc, row in results.items():
            if soc.startswith("_") or soc not in prev:
                continue
            for fam in ("cohmeleon", "manual", "fixed_mean"):
                drift = max(drift, float(np.max(np.abs(
                    np.asarray(row[fam]) - np.asarray(prev[soc][fam])))))
        results["_vs_previous"] = {"max_abs_family_delta": drift}
    save_report("fig9_socs", results)
    extra = ""
    if "_des_crosscheck" in results:
        extra = f" des_agree={results['_des_crosscheck']['agree']}"
    return csv_row(
        "fig9_socs", us,
        f"path={results['_engine']['path']} "
        f"speedup={mean_speedup * 100:.0f}%(paper38%) "
        f"mem_red={mean_memred * 100:.0f}%(paper66%)" + extra)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="serial discrete-event path instead of vecenv")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
