"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU perf —
the derived column carries the roofline-relevant arithmetic intensity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, save_report
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rwkv6_scan.ops import rwkv6_scan


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []
    out = {}

    # flash attention: B=1 H=2 S=256 hd=64
    b, h, s, hd = 1, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    o = flash_attention(q, q, q, block_q=64, block_kv=64)
    t0 = time.perf_counter()
    jax.block_until_ready(flash_attention(q, q, q, block_q=64, block_kv=64))
    dt = time.perf_counter() - t0
    flops = 4 * b * h * s * s * hd / 2   # causal
    ai = flops / (3 * q.nbytes + o.nbytes)
    rows.append(csv_row("kernel_flash_attention", dt * 1e6,
                        f"arith_intensity={ai:.0f}flops/B"))
    out["flash_attention"] = {"seconds_interp": dt, "ai": ai}

    # rwkv6 scan
    bh, hh, t_, k = 1, 2, 128, 32
    r = jnp.asarray(rng.normal(size=(bh, hh, t_, k)), jnp.float32)
    lw = jnp.maximum(jnp.asarray(-np.exp(rng.normal(size=(bh, hh, t_, k))),
                                 jnp.float32), -4.0)
    u = jnp.asarray(rng.normal(size=(hh, k)), jnp.float32)
    t0 = time.perf_counter()
    y, st = rwkv6_scan(r, r, r, lw, u)
    jax.block_until_ready(y)
    dt = time.perf_counter() - t0
    rows.append(csv_row("kernel_rwkv6_scan", dt * 1e6,
                        f"state_bytes={st.nbytes}"))

    # rglru scan
    la = jnp.asarray(-np.exp(rng.normal(size=(2, 256, 64))), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(2, 256, 64)), jnp.float32)
    t0 = time.perf_counter()
    yy, hf = rglru_scan(la, bb, chunk=128)
    jax.block_until_ready(yy)
    dt = time.perf_counter() - t0
    rows.append(csv_row("kernel_rglru_scan", dt * 1e6, "diag_recurrence"))

    # moe gmm with half-empty groups (the skip win)
    e, c, d, f = 8, 64, 128, 128
    x = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
    sizes = jnp.asarray([64, 0, 0, 32, 64, 0, 8, 0], jnp.int32)
    t0 = time.perf_counter()
    g = moe_gmm(x, w, sizes, block_c=32, block_f=64, block_d=64)
    jax.block_until_ready(g)
    dt = time.perf_counter() - t0
    occupancy = float(sizes.sum()) / (e * c)
    rows.append(csv_row("kernel_moe_gmm", dt * 1e6,
                        f"row_occupancy={occupancy:.2f}(skipped_tiles_win)"))
    save_report("kernels", out)
    return "\n".join(rows)


if __name__ == "__main__":
    print(run())
