"""Fig. 11 (beyond-paper): always-on serving under an offered-load sweep.

Puts the continuous-traffic subsystem (:mod:`repro.soc.traffic` +
``vecenv.ServeEnv``) through an offered-load sweep from 0.2x to 2x the
SoC's calibrated service capacity and records, per policy family
(fixed NON_COH, fixed FULLY_COH, manual, frozen Cohmeleon agent):

  * throughput (served requests per Mcycle) and the served fraction;
  * p50/p99 latency of served requests — p99 must stay *bounded* by the
    admission queue (``queue_cap`` in-flight finishes + the retry
    backoff budget), because anything the queue cannot absorb before the
    deadline is shed instead of queued without bound;
  * the shed fraction and the degraded-served fraction (requests forced
    to NON_COH by the overload watchdog) — at >=1.5x offered load the
    spec's acceptance point: bounded p99 *with* a reported shed
    fraction, i.e. graceful degradation instead of latency collapse.

The traffic is 2-tenant MMPP-2 bursty: a latency-sensitive tenant with
a deadline and priority 1.0, and a batch tenant with no deadline at
priority 0.25 (the ``prio_reserve`` head-of-queue reservation is what
keeps the batch tenant from starving the sensitive one at overload).
All five load points reuse ONE compiled program — every
:class:`~repro.soc.traffic.TrafficSpec` leaf is traced, and the report
records the jit cache size after the sweep to pin it.

``--fidelity`` replays single-tenant Poisson streams through the DES
host mirror (``SoCSimulator.serve``) for the fixed policy families at
several load points and cross-checks admission decisions and latencies
against the vectorized path (same pre-sampled ``Arrivals`` table, so
both paths see bit-identical offered traffic); ``--quick`` shrinks the
request budget and checks one load point.  Both paths print
``des_agree=`` — CI greps for it.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row, load_report, save_report
from repro.core.modes import CoherenceMode
from repro.core.policies import FixedHomogeneous
from repro.soc.apps import make_application
from repro.soc.config import SOCS
from repro.soc.des import SoCSimulator

SOC_NAME = "SoC1"
LOADS = [0.2, 0.5, 1.0, 1.5, 2.0]   # offered-load multipliers vs capacity
QUEUE_CAP = 8
_MAX_RETRIES = 3                     # serve-step admission attempts - 1


def _traffic(rate: float, deadline: float, backoff: float, seed: int = 3):
    """The figure's 2-tenant bursty spec at offered ``rate`` req/cycle."""
    from repro.soc import traffic

    return traffic.bursty(
        rate, burst_rate=4.0, p_burst=0.05, p_calm=0.25,
        mix=(0.7, 0.3),
        deadline=(deadline, 0.0),    # batch tenant: no deadline
        priority=(1.0, 0.25),
        backoff=backoff, overload_frac=0.35, prio_reserve=0.25,
        seed=seed)


def _policy_metrics(res, i, t_span, queue_cap, backoff) -> dict:
    """Per-policy serving metrics from row ``i`` of a serve_specs batch.

    Throughput counts requests that *finish* inside the arrival window —
    counting admissions would credit the still-queued backlog and report
    above-capacity throughput at overload."""
    ex = np.asarray(res.executed[i])
    lat = np.asarray(res.latency[i])[ex]
    exec_t = np.asarray(res.exec_time[i])[ex]
    t_end = float(np.asarray(res.t_arr[i])[-1])
    completed = int((ex & (np.asarray(res.finish[i]) <= t_end)).sum())
    n = ex.shape[0]
    served = int(ex.sum())
    # Admission bounds the wait: at most queue_cap in-flight finishes
    # drain ahead of an admitted request, plus the full backoff budget.
    bound = (backoff * (2.0 ** _MAX_RETRIES - 1.0)
             + (queue_cap + 1) * float(exec_t.max()) if served else 0.0)
    p50, p99 = (map(float, np.percentile(lat, [50, 99]))
                if served else (0.0, 0.0))
    return {
        "offered": n,
        "served": served,
        "shed_frac": float(1.0 - served / n),
        "throughput_per_mcycle": float(completed / t_span * 1e6),
        "p50_latency": p50,
        "p99_latency": p99,
        "p99_bound": float(bound),
        "p99_bounded": bool(p99 <= bound) if served else True,
        "degraded_frac": float(
            np.asarray(res.degraded[i])[ex].mean()) if served else 0.0,
        "mean_retries": float(
            np.asarray(res.retries[i])[ex].mean()) if served else 0.0,
        "mean_exec": float(exec_t.mean()) if served else 0.0,
    }


def _run(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import qlearn
    from repro.core.rewards import PAPER_DEFAULT_WEIGHTS, stack_weights
    from repro.soc import traffic, vecenv

    soc = SOCS[SOC_NAME]
    sim = SoCSimulator(soc, seed=1, flavor="mixed")
    env = vecenv.VecEnv.from_simulator(sim)
    n_phases = 4 if quick else 8
    iters = 3 if quick else 10
    n_requests = 256 if quick else 1024

    train_app = make_application(soc, seed=0, n_phases=n_phases)
    train_apps = [vecenv.compile_app(train_app, soc, seed=it)
                  for it in range(iters)]
    eval_app = vecenv.compile_app(
        make_application(soc, seed=50, n_phases=n_phases), soc, seed=4)
    cfg = qlearn.QConfig(decay_steps=train_apps[0].n_steps * iters,
                        collapse_frac=0.25)
    wb = stack_weights([PAPER_DEFAULT_WEIGHTS])
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(1))
    qs, _ = env.train_batched(train_apps, cfg, wb, keys, eval_app=eval_app)
    agent = qlearn.freeze(jax.tree_util.tree_map(lambda x: x[0], qs))

    serve_env = vecenv.ServeEnv(env, queue_cap=QUEUE_CAP,
                                n_requests=n_requests)

    # ---- capacity calibration, two probes under the NON_COH baseline.
    # A near-idle Poisson probe fixes the mean service time; then a
    # deadline-free 10x-overload probe measures the SoC's *achievable*
    # completion rate (finishes per cycle with every queue saturated).
    # The naive n_accs/mean_exec estimate overstates capacity badly when
    # the exec distribution is heavy-tailed — one giant schedule row jams
    # its accelerator while the mean says the system is loaded — so the
    # load sweep is anchored to the measured saturation throughput.
    probe = env.lower(eval_app, "fixed",
                      fixed_modes=CoherenceMode.NON_COH_DMA)
    _, _, pres = serve_env.serve(
        eval_app, probe, traffic.poisson(1e-9, seed=3), cfg=cfg,
        key=jax.random.PRNGKey(7))
    ex = np.asarray(pres.executed)
    mean_exec = float(np.asarray(pres.exec_time)[ex].mean())
    _, _, hres = serve_env.serve(
        eval_app, probe,
        traffic.poisson(10.0 * soc.n_accs / mean_exec, seed=3), cfg=cfg,
        key=jax.random.PRNGKey(7))
    t0_h, t1_h = float(hres.t_arr[0]), float(hres.t_arr[-1])
    done = np.asarray(hres.executed) & (np.asarray(hres.finish) <= t1_h)
    cap_rate = float(done.sum()) / (t1_h - t0_h)
    svc = soc.n_accs / cap_rate        # effective per-server service time
    # Sensitive tenant's budget: one full queue drain.  Looser and the
    # deadline never binds (retry-with-backoff absorbs a 2x overload into
    # latency); tighter and the sweep sheds even at light load.
    deadline = QUEUE_CAP * svc
    backoff = 0.25 * svc

    names = ["fixed_non_coh", "fixed_fully_coh", "manual", "cohmeleon"]
    specs = vecenv.stack_specs([
        env.lower(eval_app, "fixed", fixed_modes=CoherenceMode.NON_COH_DMA),
        env.lower(eval_app, "fixed", fixed_modes=CoherenceMode.FULLY_COH),
        env.lower(eval_app, "manual"),
        env.lower(eval_app, "q", qstate=agent, cfg=cfg)])

    _, batched = serve_env._serve_fn(n_requests)
    results: dict = {}
    cache_after_first = None
    for mult in LOADS:
        tspec = _traffic(mult * cap_rate, deadline, backoff)
        _, _, res = serve_env.serve_specs(eval_app, specs, tspec, cfg=cfg)
        jax.block_until_ready(res)
        if cache_after_first is None:
            cache_after_first = batched._cache_size()
        t_span = float(res.t_arr[0, -1] - res.t_arr[0, 0])
        results[f"{mult:g}x"] = {
            "load_mult": mult,
            "offered_rate_per_mcycle": float(mult * cap_rate * 1e6),
            **{name: _policy_metrics(res, i, t_span, QUEUE_CAP, backoff)
               for i, name in enumerate(names)},
        }
    results["_capacity"] = {
        "mean_exec_cycles": mean_exec,
        "effective_service_cycles": svc,
        "capacity_per_mcycle": float(cap_rate * 1e6),
        "deadline_cycles": deadline,
        "queue_cap": QUEUE_CAP,
        "n_requests": n_requests,
    }
    # The whole sweep — five offered loads, different rate/deadline
    # leaves — must reuse the single compiled serving program.
    results["_retrace"] = {
        "cache_entries_after_first_load": int(cache_after_first),
        "cache_entries_after_sweep": int(batched._cache_size()),
        "no_retrace": bool(batched._cache_size() == cache_after_first),
    }

    # ---- traffic=None identity: a serve with no TrafficSpec *is* the
    # episodic path, bitwise (qstate + every EpisodeResult leaf).
    k = jax.random.PRNGKey(5)
    spec_q = env.lower(eval_app, "q", qstate=agent, cfg=cfg)
    qs_a, res_a = serve_env.serve(eval_app, spec_q, None, cfg=cfg, key=k)
    qs_b, res_b = env.episode_spec(eval_app, spec_q, cfg=cfg, key=k)
    same = jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda x, y: jnp.all(x == y), (qs_a, res_a), (qs_b, res_b)))
    results["_identity"] = {"traffic_none_bitwise": bool(same)}
    return results


def _des_crosscheck(quick: bool, fidelity: bool) -> dict:
    """Vectorized serving vs the DES host mirror on single-tenant
    Poisson streams: both consume the SAME pre-sampled Arrivals table,
    so admission decisions must match exactly and latencies to float
    tolerance.  Fixed policy families only — their mode choice is
    context-free, so any disagreement is a serving-model divergence, not
    a policy-sense artifact."""
    import jax

    from repro.core import qlearn
    from repro.soc import traffic, vecenv

    soc = SOCS[SOC_NAME]
    sim = SoCSimulator(soc, seed=1, flavor="mixed")
    env = vecenv.VecEnv.from_simulator(sim)
    eval_app = vecenv.compile_app(
        make_application(soc, seed=50, n_phases=4), soc, seed=4)
    n = 128 if quick else 512
    queue_cap = 4
    serve_env = vecenv.ServeEnv(env, queue_cap=queue_cap, n_requests=n)
    cfg = qlearn.QConfig()

    # Calibrate a 1x rate from a quick probe so the crosscheck exercises
    # real contention (queues filling, some sheds) rather than idling.
    probe = env.lower(eval_app, "fixed",
                      fixed_modes=CoherenceMode.NON_COH_DMA)
    _, _, pres = serve_env.serve(eval_app, probe,
                                 traffic.poisson(1e-9, seed=3), cfg=cfg)
    ex = np.asarray(pres.executed)
    mean_exec = float(np.asarray(pres.exec_time)[ex].mean())
    rate_1x = soc.n_accs / mean_exec

    mults = [0.5, 1.0, 1.5] if fidelity else [1.0]
    modes = (list(CoherenceMode) if fidelity
             else [CoherenceMode.NON_COH_DMA])
    n_rows = eval_app.schedule.acc_id.shape[0]
    max_rel, mismatches, checked = 0.0, 0, 0
    for mult in mults:
        tp = traffic.poisson(
            mult * rate_1x, deadline=3.0 * queue_cap * mean_exec,
            backoff=0.5 * mean_exec, seed=11)
        arr = traffic.sample_arrivals(tp, n, n_rows)
        for mode in modes:
            spec = env.lower(eval_app, "fixed", fixed_modes=mode)
            _, _, res = serve_env.serve(eval_app, spec, tp, cfg=cfg)
            des = sim.serve(eval_app.schedule, FixedHomogeneous(mode),
                            arr, queue_cap=queue_cap,
                            backoff=float(tp.backoff))
            v_ex = np.asarray(res.executed)
            d_ex = np.array([r["executed"] for r in des])
            mismatches += int((v_ex != d_ex).sum())
            both = v_ex & d_ex
            v_lat = np.asarray(res.latency)[both]
            d_lat = np.array([r["latency"] for r in des])[both]
            # The vec clock is float32 and the DES clock float64: a
            # latency is a difference of two ~t_end-sized stamps, so the
            # comparison owes the f32 ulp at the stream clock on top of
            # the relative budget (1e-3-relative alone flakes once
            # t_end/latency > 1e3/ulp).
            ulp = float(np.spacing(np.float32(res.t_arr[-1])))
            err = np.abs(v_lat - d_lat)
            max_excess = float(np.max(
                err / (1e-3 * np.maximum(d_lat, 1e-30) + 8.0 * ulp)))
            max_rel = max(max_rel, max_excess)
            checked += n
    return {"max_err_vs_tolerance": max_rel,
            "admission_mismatches": mismatches,
            "requests_checked": checked,
            "agree": bool(mismatches == 0 and max_rel <= 1.0),
            "loads": len(mults), "families": len(modes)}


def run(quick: bool = False, fidelity: bool = False):
    t0 = time.perf_counter()
    results = _run(quick)
    results["_des_crosscheck"] = _des_crosscheck(quick, fidelity)
    results["_engine"] = {"path": "vecenv.serve", "soc": SOC_NAME,
                          "quick": quick, "fidelity": fidelity}
    us = (time.perf_counter() - t0) * 1e6 / len(LOADS)

    prev = load_report("fig11_serving")
    if (prev is not None
            and prev.get("_engine", {}).get("quick") == quick):
        drift = 0.0
        for label, row in results.items():
            if label.startswith("_") or label not in prev:
                continue
            for fam in ("fixed_non_coh", "cohmeleon"):
                for k in ("shed_frac", "degraded_frac"):
                    drift = max(drift, abs(row[fam][k]
                                           - prev[label][fam][k]))
        results["_vs_previous"] = {"max_abs_frac_delta": drift}
    save_report("fig11_serving", results)

    hot = results["2x"]["cohmeleon"]
    return csv_row(
        "fig11_serving", us,
        f"shed_2x={hot['shed_frac'] * 100:.0f}% "
        f"p99_bounded_2x={hot['p99_bounded']} "
        f"degraded_2x={hot['degraded_frac'] * 100:.0f}% "
        f"no_retrace={results['_retrace']['no_retrace']} "
        f"traffic_none_bitwise="
        f"{results['_identity']['traffic_none_bitwise']} "
        f"des_agree={results['_des_crosscheck']['agree']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fidelity", action="store_true",
                    help="cross-check fixed policy families against the "
                         "DES serving mirror at several load points")
    args = ap.parse_args()
    print(run(quick=args.quick, fidelity=args.fidelity))
