"""Micro-benchmark: training invocations/sec across the simulation engines.

Pins the speedups the scale path exists for, on the same Fig. 6 workload
(SOC_MOTIV_PAR, 6-phase application):

  * serial DES (host-Python event loop, one agent) — the fidelity path;
  * the vecenv scan step *before* this repo's hot-path work
    (``pr1_step``: per-step RNG splitting + per-slot ``dma_demand``
    recompute every step);
  * the step with only the demand recompute left (``demand_recompute``) —
    isolates the carry-cache's contribution;
  * the optimized pure-XLA step (``unfused``: carry-cached per-slot
    demand + pre-sampled episode noise, ``fused_step=False``) — the
    reference / ``--fidelity`` formulation;
  * the default step (``fast``: same flags with the fused soc_step
    episode, ``repro.kernels.soc_step``), >=100 agents per jitted call —
    the fused-vs-unfused ablation is recorded separately;
  * the shard_map scale-out (``repro.soc.shard``): the same batched call
    split across ``jax.device_count()`` devices over the lane mesh, plus
    the forced single-device shard_map overhead check (on a 1-device
    host the default path falls back to vmap, bitwise);
  * the stacked multi-SoC axis: the Fig. 9 SoC set trained in ONE
    ``vmap``-over-lanes call vs one batched call per SoC in sequence,
    and vs length-bucketed lanes (``soc.stacked.length_buckets``: two
    tight stacked calls instead of one padded to the global max — the
    padded-step waste each variant pays is recorded alongside its rate).

``--check-regression`` compares the measured steady-state fast rate —
and, when the committed baseline records one, the fused-step rate —
against the committed JSON baseline (reports/benchmarks/) and exits
non-zero on a regression beyond 30% plus the baseline's own recorded
noise floor — the CI guard for the hot path.  Every rate is the MEDIAN
of N timed repeats (best-of-N made the gate one lucky scheduler tick
wide on 1-core CI hosts), and the JSON records each measurement's
relative rep spread under ``timing.noise_rel``.  It also
gates the fault-injection tax *within the run*: training under an
all-neutral ``soc.faults.no_faults()`` spec must stay within 10% of the
same run's no-fault fast rate (the neutral rows are IEEE no-ops, so the
only cost is the extra scan xs) — within-run because a cross-run ratio
would double-count host noise.  The JSON also records the measured delta
of the fused ``(4, n_accs)`` reward-extrema carry vs the committed
(split-array) baseline rate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import REPORT_DIR, csv_row, load_report, save_report
from benchmarks.fig9_socs import SOC_FLAVORS
from repro.core import qlearn, rewards
from repro.core.policies import QPolicy
from repro.soc import shard as soc_shard
from repro.soc import vecenv
from repro.soc.apps import make_application
from repro.soc.config import SOCS, SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator
from repro.soc.stacked import StackedVecEnv

REGRESSION_TOLERANCE = 0.30     # CI fails below (1 - this) x baseline
FAULT_OVERHEAD_TOLERANCE = 0.10  # all-zeros FaultSpec tax vs same-run fast


# Per-measurement relative spread ((max - min) / median over the timed
# reps), keyed by measurement label.  Recorded in the JSON payload so the
# committed baseline carries its own noise floor and the regression gate
# can widen its tolerance by it instead of flaking on a noisy host.
_NOISE: dict[str, float] = {}


def _steady_rate(fn, total_inv: int, reps: int = 5,
                 label: str | None = None) -> tuple[float, float]:
    """(invocations/sec of the MEDIAN rep, first-call secs incl. compile).

    Median-of-N, not best-of-N: on a contended 1-core host best-of is one
    lucky tick, and a baseline recorded from a lucky tick makes every
    honest re-measurement look like a regression.  The rep spread lands
    in :data:`_NOISE` under ``label``."""
    t0 = time.perf_counter()
    fn()
    t_first = time.perf_counter() - t0
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    med = float(np.median(times))
    if label is not None:
        _NOISE[label] = float((max(times) - min(times)) / med)
    return total_inv / med, t_first


def _stacked_rates(quick: bool, reps: int) -> dict:
    """One vmapped call over all SoC lanes vs one batched call per SoC."""
    flavors = SOC_FLAVORS[:3] if quick else SOC_FLAVORS
    iters, B, n_phases = 2, 4, 4
    sims = [SoCSimulator(SOCS[n], seed=1, flavor=f) for n, f in flavors]
    env = StackedVecEnv.from_simulators(sims)
    train_apps = [make_application(sim.soc, seed=0, n_phases=n_phases)
                  for sim in sims]
    stacked_iters = [env.compile(train_apps, seed=it) for it in range(iters)]
    n_steps = stacked_iters[0].n_steps
    cfg = qlearn.QConfig(decay_steps=jnp.asarray(
        [s * iters for s in n_steps], jnp.int32))
    wb = rewards.stack_weights([rewards.PAPER_DEFAULT_WEIGHTS] * B)
    K = len(sims)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(K * B)).reshape(K, B, 2)
    total_inv = sum(n_steps) * B * iters

    def one_call():
        qs, _ = env.train_batched(stacked_iters, cfg, wb, keys)
        qs.qtable.block_until_ready()

    stacked_rate, t_compile = _steady_rate(one_call, total_inv, reps,
                                           label="stacked")

    # Sequential reference: one batched (B agents) call per SoC.
    per_lane = []
    for k, sim in enumerate(sims):
        lane_env = env.envs[k]
        compiled = [vecenv.compile_app(train_apps[k], sim.soc, seed=it)
                    for it in range(iters)]
        lane_cfg = qlearn.QConfig(decay_steps=compiled[0].n_steps * iters)
        per_lane.append((lane_env, compiled, lane_cfg, keys[k]))

    def sequential():
        for lane_env, compiled, lane_cfg, lane_keys in per_lane:
            qs, _ = lane_env.train_batched(compiled, lane_cfg, wb, lane_keys)
            qs.qtable.block_until_ready()

    seq_rate, _ = _steady_rate(sequential, total_inv, reps,
                              label="sequential")

    # Length-bucketed lanes: split the one padded call into (up to) two
    # tight ones when schedule lengths diverge; same total real
    # invocations, fewer padded no-op steps per scan.
    from repro.soc import stacked as stk

    groups = stk.length_buckets(n_steps)
    buckets = []
    for g in groups:
        sub_env = env.sublanes(g)
        sub_iters = [sub_env.compile([train_apps[i] for i in g], seed=it)
                     for it in range(iters)]
        sub_cfg = qlearn.QConfig(decay_steps=jnp.asarray(
            [n_steps[i] * iters for i in g], jnp.int32))
        buckets.append((sub_env, sub_iters, sub_cfg, keys[np.asarray(g)]))

    def bucketed():
        for sub_env, sub_iters, sub_cfg, sub_keys in buckets:
            qs, _ = sub_env.train_batched(sub_iters, sub_cfg, wb, sub_keys)
            qs.qtable.block_until_ready()

    bucketed_rate, _ = _steady_rate(bucketed, total_inv, reps,
                                   label="bucketed")
    waste_single = stk.padded_waste(stacked_iters[0])
    real = sum(n_steps)
    scan_vol = sum(len(g) * max(n_steps[i] for i in g) for g in groups)
    waste_bucketed = 1.0 - real / float(scan_vol)
    return {
        "lanes": K,
        "agents_per_lane": B,
        "invocations": int(total_inv),
        "stacked_compile_plus_run_s": t_compile,
        "stacked_inv_per_s": stacked_rate,
        "sequential_inv_per_s": seq_rate,
        "stacking_speedup": stacked_rate / seq_rate,
        "length_buckets": [list(map(int, g)) for g in groups],
        "bucketed_inv_per_s": bucketed_rate,
        "bucketing_speedup": bucketed_rate / stacked_rate,
        "padded_waste_single_call": waste_single,
        "padded_waste_bucketed": waste_bucketed,
    }


def run(quick: bool = False, check_regression: bool = False,
        baseline_path: str | None = None):
    soc = SOC_MOTIV_PAR
    sim = SoCSimulator(soc)
    app = make_application(soc, seed=11, n_phases=6)   # Fig. 6 workload
    compiled = vecenv.compile_app(app, soc, seed=11)
    n_inv = compiled.n_steps
    cfg = qlearn.QConfig(decay_steps=n_inv)
    # Median-of-N timing: the timed calls are cheap (the serial DES
    # episode dominates the run), so quick mode keeps the full rep count —
    # the CI regression gate rides out transient machine-load spikes.
    reps = 5

    # --- serial fidelity path: one DES training episode, one agent.
    policy = QPolicy(cfg, seed=0)
    t0 = time.perf_counter()
    sim.run(app, policy, seed=11, train=True)
    t_des = time.perf_counter() - t0
    des_rate = n_inv / t_des

    # --- scan-step variants: B agents, one batched call each.
    n_agents = 128
    wb = rewards.stack_weights([rewards.PAPER_DEFAULT_WEIGHTS] * n_agents)
    keys = jax.vmap(jax.random.PRNGKey)(np.arange(n_agents))
    variants = {
        "pr1_step": dict(demand_cache=False, presample_noise=False),
        "demand_recompute": dict(demand_cache=False),
        "unfused": dict(fused_step=False),
        "fast": {},                      # default config: fused soc_step
    }
    step_rates, compile_s, envs = {}, {}, {}
    for name, kw in variants.items():
        env = vecenv.VecEnv.from_simulator(sim, **kw)
        envs[name] = env

        def one_call(env=env):
            qs, _ = env.train_batched([compiled], cfg, wb, keys)
            qs.qtable.block_until_ready()

        step_rates[name], compile_s[name] = _steady_rate(
            one_call, n_agents * n_inv, reps, label=name)

    vec_rate = step_rates["fast"]
    carry_cache_speedup = vec_rate / step_rates["pr1_step"]

    # --- fault-injection tax: the default path with an all-neutral
    # FaultSpec threaded through (extra per-step fault rows in the scan
    # xs, arithmetic that reduces to IEEE no-ops).  Compared against the
    # fast rate from THIS run, so the gate doesn't double-count host
    # noise across runs.
    from repro.soc import faults as fault_mod

    zero_spec = fault_mod.no_faults()

    def fault_zero_call():
        qs, _ = envs["fast"].train_batched([compiled], cfg, wb, keys,
                                           faults=zero_spec)
        qs.qtable.block_until_ready()

    def fast_call():
        qs, _ = envs["fast"].train_batched([compiled], cfg, wb, keys)
        qs.qtable.block_until_ready()

    # Interleaved median-of-reps: alternating the two calls puts
    # transient load spikes on both sides of the ratio, which separate
    # timing loops (each seeing different spikes) would turn into a flaky
    # gate; the median then discards the spikes both sides still caught.
    fault_zero_call()   # compile
    t_fast, t_zero = [], []
    for _ in range(2 * reps):
        t0 = time.perf_counter()
        fast_call()
        t_fast.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fault_zero_call()
        t_zero.append(time.perf_counter() - t0)
    med_zero = float(np.median(t_zero))
    _NOISE["fault_zero"] = float((max(t_zero) - min(t_zero)) / med_zero)
    fault_zero_rate = n_agents * n_inv / med_zero
    fault_zero_ratio = float(np.median(t_fast)) / med_zero

    stacked = _stacked_rates(quick, reps)

    # --- shard_map scale-out: same batched call over the lane mesh.  On a
    # single-device host the default path IS the vmap call (bitwise
    # fallback); the forced entry measures the shard_map wrapper itself.
    mesh = soc_shard.lane_mesh()

    def sharded_call(force):
        def call():
            qs, _ = soc_shard.sharded_train_batched(
                envs["fast"], [compiled], cfg, wb, keys, mesh=mesh,
                force_shard_map=force)
            qs.qtable.block_until_ready()
        return call

    shard_default_rate, _ = _steady_rate(
        sharded_call(False), n_agents * n_inv, reps, label="shard_default")
    shard_forced_rate, _ = _steady_rate(
        sharded_call(True), n_agents * n_inv, reps, label="shard_forced")
    sharded = {
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "mesh_axes": {"lanes": int(mesh.devices.size)},
        "default_path": ("vmap-fallback" if mesh.devices.size == 1
                         else "shard_map"),
        "default_inv_per_s": shard_default_rate,
        "forced_shard_map_inv_per_s": shard_forced_rate,
    }

    # Reward-extrema fusion: the committed baseline was measured with the
    # four split per-accelerator extrema arrays in the scan carry; the
    # current step carries one fused (4, n_accs) array.  Record the
    # measured delta against that baseline.
    committed = load_report("vecenv_throughput")
    fusion = {"fast_inv_per_s": vec_rate}
    if committed is not None:
        fusion["committed_fast_inv_per_s"] = committed["vecenv_inv_per_s"]
        fusion["speedup_vs_committed"] = (
            vec_rate / committed["vecenv_inv_per_s"])

    payload = {
        "workload": app.name,
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "invocations_per_episode": n_inv,
        "des_episode_s": t_des,
        "des_inv_per_s": des_rate,
        "vecenv_agents": n_agents,
        "vecenv_compile_plus_run_s": compile_s["fast"],
        "vecenv_inv_per_s": vec_rate,
        "speedup": vec_rate / des_rate,
        "step_variants_inv_per_s": step_rates,
        # fused-vs-unfused ablation on THIS host in THIS run (both rates
        # above): on CPU the fused episode lowers to the same XLA scan
        # formulation and lands within measurement noise of unfused; the
        # Pallas kernel lowering engages on accelerator backends.
        "fused_step": {
            "enabled_by_default": bool(envs["fast"].fused_step),
            "fused_inv_per_s": vec_rate,
            "unfused_inv_per_s": step_rates["unfused"],
            "fused_vs_unfused": vec_rate / step_rates["unfused"],
        },
        "fault_injection": {
            "fault_zero_inv_per_s": fault_zero_rate,
            "fault_zero_vs_fast": fault_zero_ratio,
        },
        "sharded": sharded,
        # before/after of this repo's scan-step optimization: 'before' is
        # the original step (per-step RNG + per-slot demand recompute),
        # 'after' keeps per-slot demand in the scan carry and pre-samples
        # the episode noise.  The isolated ratio toggles only the cache.
        "carry_cache_speedup": carry_cache_speedup,
        "carry_cache_isolated_speedup": (
            vec_rate / step_rates["demand_recompute"]),
        "reward_extrema_fusion": fusion,
        "multi_soc": stacked,
        # Deflaked-gate provenance: every rate above is the MEDIAN of
        # `reps` timed calls, and noise_rel records each measurement's
        # relative rep spread ((max - min) / median).  The committed
        # noise_floor_rel is the spread of the GATED measurement (the
        # fast rate) when the baseline was recorded — re-checks widen the
        # gate's tolerance by it; the other labels' spreads are recorded
        # for diagnosis only (the interleaved fault_zero ratio in
        # particular runs much noisier than the rate it gates).
        "timing": {
            "estimator": "median",
            "reps": reps,
            "noise_rel": dict(_NOISE),
            "noise_floor_rel": _NOISE["fast"],
        },
    }

    if check_regression:
        path = baseline_path or os.path.join(REPORT_DIR,
                                             "vecenv_throughput.json")
        with open(path) as f:
            base = json.load(f)
        # Gate the default (fused) rate always; gate the fused-step entry
        # explicitly when the committed baseline records one (baselines
        # from before the fused step only carry vecenv_inv_per_s).
        # Tolerance widens by the baseline's own recorded noise floor
        # (older baselines without one get the bare tolerance), capped so
        # a garbage baseline can't disable the gate outright.
        base_noise = float(base.get("timing", {}).get(
            "noise_floor_rel", 0.0))
        tol = min(0.5, REGRESSION_TOLERANCE + base_noise)
        gates = [("fast", vec_rate, base["vecenv_inv_per_s"])]
        base_fused = base.get("fused_step", {}).get("fused_inv_per_s")
        if base_fused is not None:
            gates.append(
                ("fused_step", payload["fused_step"]["fused_inv_per_s"],
                 base_fused))
        failures = []
        for name, rate, base_rate in gates:
            floor = base_rate * (1.0 - tol)
            status = "ok" if rate >= floor else "REGRESSION"
            print(f"regression check [{name}]: {rate:.0f} inv/s, "
                  f"baseline={base_rate:.0f}, floor={floor:.0f} "
                  f"(tol={tol:.2f} incl. baseline noise "
                  f"{base_noise:.2f}) -> {status}", file=sys.stderr)
            if rate < floor:
                failures.append(
                    f"{name}: {rate:.0f} < {floor:.0f} inv/s "
                    f"(baseline {base_rate:.0f})")
        # Within-run gate: the all-zeros FaultSpec path vs this run's own
        # fast rate — a >10% tax means the neutral fault rows stopped
        # being free on the hot path.
        floor = 1.0 - FAULT_OVERHEAD_TOLERANCE
        status = "ok" if fault_zero_ratio >= floor else "REGRESSION"
        print(f"regression check [fault_zero]: "
              f"{fault_zero_ratio:.3f}x of fast (floor={floor:.2f}) "
              f"-> {status}", file=sys.stderr)
        if fault_zero_ratio < floor:
            failures.append(
                f"fault_zero: {fault_zero_ratio:.3f}x of fast rate "
                f"< {floor:.2f}x")
        if failures:
            raise SystemExit(
                "vecenv steady-state throughput regressed >"
                f"{tol:.0%}: " + "; ".join(failures))
    else:
        save_report("vecenv_throughput", payload)

    return csv_row(
        "vecenv_throughput", 1e6 * n_inv / vec_rate,
        f"des={des_rate:.0f}inv/s vecenv={vec_rate:.0f}inv/s "
        f"agents={n_agents} speedup={vec_rate / des_rate:.1f}x "
        f"carry_cache={carry_cache_speedup:.1f}x "
        f"fused_vs_unfused={vec_rate / step_rates['unfused']:.2f}x "
        f"fault_zero={fault_zero_ratio:.2f}x "
        f"stacking={stacked['stacking_speedup']:.1f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check-regression", action="store_true",
                    help="compare against the committed JSON baseline and "
                         "exit non-zero on a >30%% throughput regression")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON path (default: the committed "
                         "reports/benchmarks/vecenv_throughput.json)")
    args = ap.parse_args()
    print(run(quick=args.quick, check_regression=args.check_regression,
              baseline_path=args.baseline))
