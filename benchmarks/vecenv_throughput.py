"""Micro-benchmark: training invocations/sec, serial DES vs batched vecenv.

Pins the speedup the scale path exists for: the same Fig. 6 workload
(SOC_MOTIV_PAR, 6-phase application) trained by the host-Python
discrete-event simulator one agent at a time, vs >= 100 agents in one
jitted ``vmap(scan(...))`` call.  Reported throughput counts *agent
invocations processed per second of wall clock*; the vecenv's one-off
compile time is reported separately.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core import qlearn, rewards
from repro.core.policies import QPolicy
from repro.soc import vecenv
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator


def run(quick: bool = False):
    soc = SOC_MOTIV_PAR
    sim = SoCSimulator(soc)
    env = vecenv.VecEnv.from_simulator(sim)
    app = make_application(soc, seed=11, n_phases=6)   # Fig. 6 workload
    compiled = vecenv.compile_app(app, soc, seed=11)
    n_inv = compiled.n_steps
    cfg = qlearn.QConfig(decay_steps=n_inv)

    # --- serial fidelity path: one DES training episode, one agent.
    policy = QPolicy(cfg, seed=0)
    t0 = time.perf_counter()
    sim.run(app, policy, seed=11, train=True)
    t_des = time.perf_counter() - t0
    des_rate = n_inv / t_des

    # --- scale path: B agents, one batched call.
    n_agents = 100 if quick else 128
    wb = rewards.stack_weights(
        [rewards.PAPER_DEFAULT_WEIGHTS] * n_agents)
    keys = jax.vmap(jax.random.PRNGKey)(np.arange(n_agents))
    t0 = time.perf_counter()
    qs, _ = env.train_batched([compiled], cfg, wb, keys)
    qs.qtable.block_until_ready()
    t_compile_and_run = time.perf_counter() - t0
    t0 = time.perf_counter()
    qs, _ = env.train_batched([compiled], cfg, wb, keys)
    qs.qtable.block_until_ready()
    t_vec = time.perf_counter() - t0
    vec_rate = n_agents * n_inv / t_vec
    speedup = vec_rate / des_rate

    save_report("vecenv_throughput", {
        "workload": app.name,
        "invocations_per_episode": n_inv,
        "des_episode_s": t_des,
        "des_inv_per_s": des_rate,
        "vecenv_agents": n_agents,
        "vecenv_compile_plus_run_s": t_compile_and_run,
        "vecenv_run_s": t_vec,
        "vecenv_inv_per_s": vec_rate,
        "speedup": speedup,
    })
    return csv_row(
        "vecenv_throughput", t_vec * 1e6 / max(n_agents, 1),
        f"des={des_rate:.0f}inv/s vecenv={vec_rate:.0f}inv/s "
        f"agents={n_agents} speedup={speedup:.1f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print(run(quick=args.quick))
