"""Beyond-paper Fig. 13: held-out generalization of the neural policy.

The tabular Cohmeleon agent can only serve Table-3 buckets it has
visited: on an unseen application — or an unseen, DSE-sampled SoC
architecture — it lands in optimistic all-tie rows and degrades toward
the Random policy.  This figure trains ONE shared function-approximation
agent (:func:`repro.soc.nn.train_portfolio`, federated averaging of the
packed MLP across a portfolio of (SoC x app) pairs) against a shared
tabular agent trained on exactly the same episode stream, then freezes
both and evaluates them on:

  * **held-out apps** — unseen application seeds on the training SoCs;
  * **held-out SoCs** — fresh ``dse.sample_socs`` design points disjoint
    from the training portfolio, with their own unseen apps.

Reported per set and per agent: mean speedup and off-chip reduction vs
the NON_COH baseline.  ``heldout_ok`` (the CI smoke gate) requires the
portfolio MLP to post POSITIVE mean speedup and off-chip reduction on
BOTH held-out sets and to beat the shared tabular agent's speedup on
both — the generalization claim this subsystem exists to make.

``--quick`` shrinks portfolio sizes/iterations; it is the CI smoke job.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core import qlearn
from repro.core.modes import CoherenceMode
from repro.soc import dse, nn as socnn, vecenv as vec
from repro.soc.apps import make_application

TILE_SEED = 11
APP_HELDOUT_OFFSET = 101     # unseen-app seed offset (trained on seed, seed+1)


def _compile(soc, seed, n_phases):
    app = make_application(soc, seed=seed, n_phases=n_phases)
    return vec.compile_app(app, soc, seed=TILE_SEED)


def _train_shared_table(items, cfg, iterations, key):
    """The tabular control: ONE shared Q-table trained over the same
    (pair x iteration) episode stream the MLP portfolio sees."""
    qs = qlearn.init_qstate(cfg)
    for it in range(iterations):
        for j, (env, comps) in enumerate(items):
            comp = comps[it % len(comps)]
            k = jax.random.fold_in(key, it * len(items) + j)
            qs, _ = env.episode(comp, policy="q", qstate=qs, cfg=cfg, key=k)
    return qlearn.freeze(qs)


def _eval_agents(env, comp, qs, mlp, seed):
    """(speedup, offchip_reduction) vs NON_COH for the tabular and MLP
    agents on one (SoC, app); all three specs share the episode key."""
    key = jax.random.PRNGKey(seed % (2 ** 31 - 1))
    base_spec = env.lower(comp, "fixed",
                          fixed_modes=int(CoherenceMode.NON_COH_DMA))
    _, rb = env.episode_spec(comp, base_spec, key=key)
    _, rt = env.episode_spec(comp, vec.learned_policy_spec(qs, comp.schedule),
                             key=key)
    (_, _), rm = env.episode_spec(
        comp, vec.mlp_policy_spec(socnn.freeze(mlp), comp.schedule), key=key)
    tb = float(np.sum(np.asarray(rb.phase_time)))
    mb = float(np.sum(np.asarray(rb.phase_offchip)))
    out = {}
    for name, r in (("tabular", rt), ("mlp", rm)):
        t = float(np.sum(np.asarray(r.phase_time)))
        m = float(np.sum(np.asarray(r.phase_offchip)))
        out[name] = (1.0 - t / tb, 1.0 - m / max(mb, 1e-9))
    return out


def _set_summary(rows):
    sp = {k: float(np.mean([r[k][0] for r in rows]))
          for k in ("tabular", "mlp")}
    off = {k: float(np.mean([r[k][1] for r in rows]))
           for k in ("tabular", "mlp")}
    return {"mean_speedup_vs_noncoh": sp,
            "mean_offchip_reduction_vs_noncoh": off,
            "n": len(rows)}


def run(quick: bool = False, key: int = 0):
    n_train = 4 if quick else 8
    n_heldout = 3 if quick else 6
    n_phases = 2 if quick else 3
    iterations = 12
    batch = 2 if quick else 4

    t0 = time.perf_counter()
    samples = dse.sample_socs(key, n_train + n_heldout)
    train_s, held_s = samples[:n_train], samples[n_train:]

    # ---- portfolio: two training apps per SoC, rotated per iteration
    items, envs = [], []
    for s in train_s:
        env = vec.VecEnv(s.config, seed=0)
        envs.append(env)
        comps = [_compile(s.config, s.seed + d, n_phases) for d in (0, 1)]
        items.append((env, comps))
    total_steps = sum(c.n_steps for _, cs in items for c in cs) // 2
    cfg = qlearn.QConfig(decay_steps=total_steps * iterations)

    mlp, hist = socnn.train_portfolio(
        items, cfg, iterations=iterations, batch=batch,
        key=jax.random.PRNGKey(key + 1))
    qs = _train_shared_table(items, cfg, iterations,
                             jax.random.PRNGKey(key + 1))
    t_train = time.perf_counter() - t0

    # ---- held-out apps on the training SoCs
    t0 = time.perf_counter()
    rows_apps = []
    for s, env in zip(train_s, envs):
        comp = _compile(s.config, s.seed + APP_HELDOUT_OFFSET, n_phases)
        rows_apps.append(_eval_agents(env, comp, qs, mlp, s.seed))
    # ---- held-out SoC architectures (their apps are unseen a fortiori)
    rows_socs = []
    for s in held_s:
        env = vec.VecEnv(s.config, seed=0)
        comp = _compile(s.config, s.seed + APP_HELDOUT_OFFSET, n_phases)
        rows_socs.append(_eval_agents(env, comp, qs, mlp, s.seed))
    t_eval = time.perf_counter() - t0

    apps_sum = _set_summary(rows_apps)
    socs_sum = _set_summary(rows_socs)
    mlp_sp_a = apps_sum["mean_speedup_vs_noncoh"]["mlp"]
    mlp_sp_s = socs_sum["mean_speedup_vs_noncoh"]["mlp"]
    mlp_off_a = apps_sum["mean_offchip_reduction_vs_noncoh"]["mlp"]
    mlp_off_s = socs_sum["mean_offchip_reduction_vs_noncoh"]["mlp"]
    tab_sp_a = apps_sum["mean_speedup_vs_noncoh"]["tabular"]
    tab_sp_s = socs_sum["mean_speedup_vs_noncoh"]["tabular"]
    heldout_ok = bool(
        mlp_sp_a > 0 and mlp_sp_s > 0 and mlp_off_a > 0 and mlp_off_s > 0
        and mlp_sp_a > tab_sp_a and mlp_sp_s > tab_sp_s)

    n_evals = len(rows_apps) + len(rows_socs)
    us = (t_train + t_eval) * 1e6 / max(n_evals, 1)
    results = {
        "_engine": {
            "path": "vecenv-portfolio",
            "key": key,
            "n_train_socs": n_train,
            "n_heldout_socs": n_heldout,
            "n_phases": n_phases,
            "iterations": iterations,
            "batch": batch,
            "mlp": {"features": mlp.cfg.features,
                    "hidden": list(mlp.cfg.hidden),
                    "lr": float(mlp.cfg.lr),
                    "pack_shape": list(mlp.wpack.shape),
                    "final_step": int(mlp.step)},
            "train_s": t_train,
            "eval_s": t_eval,
        },
        "train_reward_history": [float(h) for h in np.asarray(hist)],
        "heldout_apps": apps_sum,
        "heldout_socs": socs_sum,
        "_headline": {
            "heldout_ok": heldout_ok,
            "mlp_speedup_heldout_apps": mlp_sp_a,
            "mlp_speedup_heldout_socs": mlp_sp_s,
            "mlp_offchip_reduction_heldout_apps": mlp_off_a,
            "mlp_offchip_reduction_heldout_socs": mlp_off_s,
            "tabular_speedup_heldout_apps": tab_sp_a,
            "tabular_speedup_heldout_socs": tab_sp_s,
        },
        "per_soc": {
            "heldout_apps": [
                {"name": s.config.name, "tabular": list(r["tabular"]),
                 "mlp": list(r["mlp"])}
                for s, r in zip(train_s, rows_apps)],
            "heldout_socs": [
                {"name": s.config.name, "tabular": list(r["tabular"]),
                 "mlp": list(r["mlp"])}
                for s, r in zip(held_s, rows_socs)],
        },
    }
    save_report("fig13_generalize", results)

    return csv_row(
        "fig13_generalize", us,
        f"heldout_ok={heldout_ok} "
        f"mlp_speedup_apps={mlp_sp_a * 100:.1f}% "
        f"mlp_speedup_socs={mlp_sp_s * 100:.1f}% "
        f"mlp_offchip_apps={mlp_off_a * 100:.1f}% "
        f"mlp_offchip_socs={mlp_off_s * 100:.1f}% "
        f"tab_speedup_apps={tab_sp_a * 100:.1f}% "
        f"tab_speedup_socs={tab_sp_s * 100:.1f}%")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--key", type=int, default=0)
    args = ap.parse_args()
    print(run(quick=args.quick, key=args.key))
