"""Paper Fig. 2: accelerators in isolation x 4 modes x 3 workload sizes.

Emits normalized (to NON_COH_DMA) execution time and off-chip accesses per
(accelerator, size, mode) cell, the direct analogue of the paper's bars.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_report
from repro.core.modes import CoherenceMode, MODE_NAMES
from repro.core.orchestrator import run_isolated
from repro.soc.config import (SOC_MOTIV_ISO, WORKLOAD_LARGE,
                              WORKLOAD_MEDIUM, WORKLOAD_SMALL)
from repro.soc.des import SoCSimulator

SIZES = {"S": WORKLOAD_SMALL, "M": WORKLOAD_MEDIUM, "L": WORKLOAD_LARGE}


def run(quick: bool = False):
    sim = SoCSimulator(SOC_MOTIV_ISO)
    accs = range(len(sim.profiles)) if not quick else range(4)
    table = {}
    t0 = time.perf_counter()
    n = 0
    for acc in accs:
        name = sim.profiles[acc].name
        for label, fp in SIZES.items():
            base = run_isolated(sim, acc, CoherenceMode.NON_COH_DMA, fp)
            for mode in CoherenceMode:
                res = run_isolated(sim, acc, mode, fp)
                n += 1
                table[f"{name}|{label}|{MODE_NAMES[mode]}"] = {
                    "norm_time": res.total_time / base.total_time,
                    "norm_mem": (res.total_offchip
                                 / max(base.total_offchip, 1e-9)),
                }
    us = (time.perf_counter() - t0) / max(n, 1) * 1e6

    # Paper headline: the best mode varies across accelerators and sizes.
    winners = {}
    for key, v in table.items():
        acc, size, mode = key.split("|")
        cur = winners.get((acc, size))
        if cur is None or v["norm_time"] < cur[1]:
            winners[(acc, size)] = (mode, v["norm_time"])
    distinct = len({w[0] for w in winners.values()})
    save_report("fig2_isolation", {"cells": table,
                                   "winners": {f"{a}|{s}": w[0] for (a, s), w
                                               in winners.items()}})
    return csv_row("fig2_isolation", us,
                   f"distinct_winning_modes={distinct}/4")


if __name__ == "__main__":
    print(run())
