"""Optimizers and gradient transforms (pure JAX)."""
from repro.optim import adafactor, adamw, compress, schedule

__all__ = ["adamw", "adafactor", "schedule", "compress"]
