"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce: before the data-parallel reduction each
worker quantizes its gradient shard to int8 with a per-block fp32 scale and
keeps the quantization residual locally, adding it back into the next
step's gradient (error feedback, Seide et al. / Karimireddy et al.) — the
residual makes the compression unbiased over time and preserves
convergence.

The quantize/dequantize pair is exposed both as a plain transform (tested
for the EF contraction property) and as a hook for the train step: with
``compress_grads=True`` the DP all-reduce operand is the int8 tensor, a 4x
reduction of the dominant collective's bytes (visible in §Perf roofline
iterations).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class EFState(NamedTuple):
    residual: dict   # pytree matching params


def init_ef(params) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x):
    """Block-wise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_leaf(g, r):
    """EF-compress one gradient leaf. Returns (g_compressed, new_residual)."""
    g32 = g.astype(jnp.float32) + r
    q, scale = quantize_int8(g32)
    deq = dequantize_int8(q, scale, g32.shape)
    return deq.astype(g.dtype), g32 - deq


def compress_grads(grads, ef: EFState):
    """Apply EF int8 compression to a whole gradient pytree."""
    out = jax.tree_util.tree_map(compress_leaf, grads, ef.residual)
    outer = jax.tree_util.tree_structure(grads)
    inner = jax.tree_util.tree_structure((0, 0))
    new_grads, new_residual = jax.tree_util.tree_transpose(outer, inner, out)
    return new_grads, EFState(residual=new_residual)
