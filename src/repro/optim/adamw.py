"""AdamW with decoupled weight decay and global-norm clipping.

Pure-functional: state is a pytree mirroring the params (so GSPMD shards
optimizer state exactly like the parameters — ZeRO comes for free from the
FSDP param sharding rules).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    mu: dict             # first moment, per-param
    nu: dict             # second moment, per-param


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # bf16 halves optimizer HBM if needed


def init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(grads, state: AdamWState, params,
           cfg: AdamWConfig = AdamWConfig(), lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    # Transpose {param-tree of (p, m, v)} -> ((p-tree), (m-tree), (v-tree)).
    # (params contain NamedTuples, so an is_leaf=tuple trick would mis-fire.)
    outer = jax.tree_util.tree_structure(params)
    inner = jax.tree_util.tree_structure((0, 0, 0))
    new_params, new_mu, new_nu = jax.tree_util.tree_transpose(
        outer, inner, out)
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
