"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

For a (n, m) matrix the second moment is stored as row/col running means
(n + m floats instead of n*m), which is what lets the 480B-class arctic
config keep optimizer state within 16 GB/chip HBM at 256 chips.  1-D (and
0-D) params fall back to full second moments.  Includes the standard
update-clipping (d=1.0) and relative step size.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdafactorConfig(NamedTuple):
    lr: float = 1e-2             # relative step scale
    decay: float = 0.8           # beta2_t = 1 - t^-decay
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 128


class _LeafState(NamedTuple):
    vr: jax.Array    # row means (or full v for unfactored)
    vc: jax.Array    # col means (or () for unfactored)


class AdafactorState(NamedTuple):
    step: jax.Array
    v: dict          # pytree of _LeafState


def _factored(shape, cfg: AdafactorConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor
            and shape[-2] >= cfg.min_dim_size_to_factor)


def init(params, cfg: AdafactorConfig = AdafactorConfig()) -> AdafactorState:
    def leaf(p):
        if _factored(p.shape, cfg):
            return _LeafState(
                vr=jnp.zeros(p.shape[:-1], jnp.float32),
                vc=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return _LeafState(vr=jnp.zeros(p.shape, jnp.float32),
                          vc=jnp.zeros((0,), jnp.float32))

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        v=jax.tree_util.tree_map(leaf, params),
    )


def update(grads, state: AdafactorState, params,
           cfg: AdafactorConfig = AdafactorConfig(), lr_scale=1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)
    lr = cfg.lr * lr_scale

    def upd(g, s, p):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.eps1
        if _factored(g.shape, cfg):
            vr = beta2 * s.vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * s.vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps1)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            new_s = _LeafState(vr, vc)
        else:
            vhat = beta2 * s.vr + (1 - beta2) * g2
            new_s = _LeafState(vhat, s.vc)
        u = g32 * jax.lax.rsqrt(vhat + cfg.eps1)
        # Update clipping (RMS(u) <= clip_threshold).
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        p32 = p.astype(jnp.float32)
        scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(p32))), cfg.eps2)
        p_new = p32 - lr * scale * u - lr * cfg.weight_decay * p32
        return p_new.astype(p.dtype), new_s

    class _Pair:  # opaque (not a pytree): lets us unzip without transpose
        __slots__ = ("p", "s")

        def __init__(self, p, s):
            self.p, self.s = p, s

    out = jax.tree_util.tree_map(
        lambda g, s, p: _Pair(*upd(g, s, p)),
        grads, state.v, params,
        is_leaf=lambda x: isinstance(x, _LeafState))
    is_pair = lambda x: isinstance(x, _Pair)
    new_params = jax.tree_util.tree_map(lambda x: x.p, out, is_leaf=is_pair)
    new_v = jax.tree_util.tree_map(lambda x: x.s, out, is_leaf=is_pair)
    return new_params, AdafactorState(step, new_v), {}
