"""Serving driver: batched prefill + greedy decode with KV/recurrent caches.

Runs a small model end-to-end on host devices: batches requests, prefills
the prompt, then decodes autoregressively, reporting per-phase latency and
tokens/s.  The same step functions are what the decode_* dry-run cells
lower at production shapes.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.data.synthetic import DataConfig, host_batch
from repro.launch import steps as steps_lib
from repro.models import transformer


def serve(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0):
    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    data = host_batch(cfg, DataConfig(prompt_len, batch, seed=seed), 0)
    prompt = {k: jnp.asarray(v) for k, v in data.items()
              if k not in ("labels",)}

    max_len = prompt_len + gen
    prefill_fn = jax.jit(steps_lib.make_prefill_step(cfg, max_len=max_len))
    decode_fn = jax.jit(steps_lib.make_decode_step(cfg))

    t0 = time.time()
    cache, logits = prefill_fn(params, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    generated = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # greedy
    t1 = time.time()
    for i in range(gen):
        pos = jnp.int32(prompt_len + i)
        step_batch = {"tokens": tok}
        if cfg.family == "vlm":
            step_batch["mrope_positions"] = jnp.full((3, batch, 1),
                                                     prompt_len + i,
                                                     jnp.int32)
        cache, logits = decode_fn(params, cache, step_batch, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.time() - t1

    toks_per_s = batch * gen / max(t_decode, 1e-9)
    return {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": toks_per_s,
        "generated": np.concatenate(
            [g.reshape(batch, -1) for g in generated], axis=-1)
        if not cfg.n_codebooks else np.stack(generated),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    out = serve(cfg, args.batch, args.prompt_len, args.gen)
    print(f"prefill {out['prefill_s'] * 1e3:.1f} ms | "
          f"decode {out['decode_s'] * 1e3:.1f} ms "
          f"({out['decode_tok_per_s']:.0f} tok/s) | "
          f"sample tokens: {out['generated'].reshape(-1)[:16]}")
    return out


if __name__ == "__main__":
    main()
