import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
# (No `from __future__ import annotations` here for the same reason — the
# env var assignment must be the first statements of the module.)

# Multi-pod dry-run docs follow.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds abstract state/batch specs, jits the step
with explicit in/out shardings, ``.lower().compile()``s it against the
production mesh (16x16 single-pod and 2x16x16 multi-pod), prints
``memory_analysis()`` / ``cost_analysis()``, and extracts the three
roofline terms (launch/roofline.py) into reports/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.configs.shapes import SHAPES, applicable_shapes
from repro.distributed.sharding import activation_mesh
from repro.launch import roofline, steps
from repro.launch.mesh import make_production_mesh

REPORT_DIR = "reports/dryrun"


def lower_cell(arch: str, shape: str, multi_pod: bool, cfg=None):
    """Lower + compile one cell. Returns (lowered, compiled, cfg, spec)."""
    cfg = cfg or get_arch(arch)
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)

    with mesh, activation_mesh(mesh):
        if spec.kind == "train":
            state_sh, batch_sh = steps.train_shardings(cfg, mesh, spec)
            step = steps.make_train_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            state_specs = steps.train_state_specs(cfg)
            batch_specs = steps.input_specs(cfg, spec)
            lowered = jitted.lower(state_specs, batch_specs)
        elif spec.kind == "prefill":
            p_sh, c_sh, b_sh = steps.serve_shardings(cfg, mesh, spec)
            step = steps.make_prefill_step(cfg, max_len=spec.seq_len)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(c_sh, None))
            params_specs = jax.eval_shape(
                lambda: __import__("repro.models", fromlist=["transformer"])
                .transformer.init_params(cfg, jax.random.PRNGKey(0)))
            batch_specs = steps.input_specs(cfg, spec)
            lowered = jitted.lower(params_specs, batch_specs)
        else:  # decode
            p_sh, c_sh, b_sh = steps.serve_shardings(cfg, mesh, spec)
            step = steps.make_decode_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, c_sh, b_sh, None),
                             out_shardings=(c_sh, None),
                             donate_argnums=(1,))
            from repro.models import transformer
            params_specs = jax.eval_shape(
                lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
            cache_sp = steps.cache_specs(cfg, spec)
            batch_specs = steps.input_specs(cfg, spec)
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jitted.lower(params_specs, cache_sp, batch_specs,
                                   pos_spec)
        compiled = lowered.compile()
    return lowered, compiled, cfg, spec, mesh


def _cell_metrics(compiled):
    cost = roofline.cost_dict(compiled)
    coll = roofline.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _exact_cost(arch: str, shape: str, multi_pod: bool, cfg):
    """flops / bytes / collective-bytes of the full-depth cell, from two
    unrolled reduced-depth lowers (exact — superblocks are identical)."""
    from repro.models.transformer import superblock_layout
    pattern, n_super, tail = superblock_layout(cfg)
    span = len(pattern)
    if n_super <= 2:
        _, compiled, *_ = lower_cell(
            arch, shape, multi_pod, cfg=cfg.replace(scan_layers=False))
        return _cell_metrics(compiled)
    cfg1 = cfg.replace(n_layers=1 * span + tail, scan_layers=False)
    cfg2 = cfg.replace(n_layers=2 * span + tail, scan_layers=False)
    _, c1, *_ = lower_cell(arch, shape, multi_pod, cfg=cfg1)
    _, c2, *_ = lower_cell(arch, shape, multi_pod, cfg=cfg2)
    f1, b1, k1 = _cell_metrics(c1)
    f2, b2, k2 = _cell_metrics(c2)

    def extrap(v1, v2):
        return v1 + (v2 - v1) * (n_super - 1)

    coll = {k: extrap(k1.get(k, 0.0), k2.get(k, 0.0))
            for k in set(k1) | set(k2)}
    return extrap(f1, f2), extrap(b1, b2), coll


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             with_cost: bool = True):
    t0 = time.time()
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    lowered, compiled, cfg, spec, mesh = lower_cell(arch, shape, multi_pod)
    chips = mesh.devices.size

    mem = compiled.memory_analysis()
    if verbose:
        print(f"--- {arch} x {shape} x {mesh_name} "
              f"(compile {time.time() - t0:.1f}s)")
        print("memory_analysis:", mem)

    # HloCostAnalysis counts while-loop (scan) bodies ONCE, not x trip
    # count, so the scanned compile can't be used for flop/byte/collective
    # accounting.  Superblocks are identical, so lower two UNROLLED
    # reduced-depth variants (1 and 2 superblocks + the arch's tail) and
    # extrapolate exactly:  metric(n_super) = m1 + (m2 - m1)*(n_super - 1).
    if with_cost:
        flops, nbytes, coll = _exact_cost(arch, shape, multi_pod, cfg)
    else:
        # multi-pod pass proves compile/sharding only (roofline table is
        # single-pod per the task spec) — skip the unrolled cost lowers.
        flops, nbytes, coll = _cell_metrics(compiled)

    terms = roofline.RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops * chips,              # cost_analysis is per-device
        hlo_bytes=nbytes * chips,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=roofline.model_flops_for(cfg, spec),
        bytes_per_device=roofline.extract_memory_bytes(mem),
    )
    os.makedirs(REPORT_DIR, exist_ok=True)
    out = terms.to_dict()
    out["compile_seconds"] = time.time() - t0
    path = os.path.join(REPORT_DIR, f"{arch}__{shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    if verbose:
        print(f"T_comp={terms.t_comp * 1e3:.2f}ms T_mem={terms.t_mem * 1e3:.2f}ms "
              f"T_coll={terms.t_coll * 1e3:.2f}ms dominant={terms.dominant} "
              f"useful={terms.useful_ratio:.2f} -> {path}")
    return out


def all_cells(include_multipod: bool = True):
    cells = []
    for arch, cfg in ARCHS.items():
        for spec in applicable_shapes(cfg.family):
            cells.append((arch, spec.name, False))
            if include_multipod:
                cells.append((arch, spec.name, True))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="compile-proof only (skip unrolled cost lowers)")
    args = ap.parse_args()

    if args.all:
        pods = {"single": [False], "multi": [True],
                "both": [False, True]}[args.mesh]
        cells = [(a, s_, mp) for a, s_, _ in all_cells(False) for mp in pods]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pods = {"single": [False], "multi": [True],
                "both": [False, True]}[args.mesh]
        cells = [(args.arch, args.shape, mp) for mp in pods]

    failures = []
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        path = os.path.join(REPORT_DIR, f"{arch}__{shape}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"skip {arch} x {shape} x {mesh_name} (cached)")
            continue
        try:
            run_cell(arch, shape, mp, with_cost=not args.no_cost)
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            failures.append((arch, shape, mesh_name, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled OK")


if __name__ == "__main__":
    main()
