"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, per DESIGN.md §7:

    T_comp = HLO_flops / (chips * 197e12)          [bf16 MXU peak, v5e]
    T_mem  = HLO_bytes / (chips * 819e9)           [HBM bandwidth]
    T_coll = collective_bytes / (chips * 50e9)     [ICI per-link]

flops/bytes come from ``compiled.cost_analysis()``; collective bytes are
NOT in cost_analysis — we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e per-chip hardware constants (task spec).
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?"
    r"(\([^)]*\)|[\w\[\]{}, ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns one properties dict; newer versions return a list of
    per-computation dicts (one entry per partition/program — the first
    carries the whole-module totals).  Either way this returns a plain
    dict, empty when the backend reports nothing."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum of result-shape bytes per collective kind in the HLO module.

    '-start' ops are counted; '-done' ops are skipped (same transfer).
    """
    out: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        line_start = hlo_text.rfind("\n", 0, m.start()) + 1
        line = hlo_text[line_start: hlo_text.find("(", m.end() - 1)]
        if "-done" in line:
            continue
        kind = m.group(2)
        nbytes = _shape_bytes(m.group(1))
        out[kind] = out.get(kind, 0) + nbytes
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # whole-program flops (cost_analysis)
    hlo_bytes: float          # whole-program bytes accessed
    coll_bytes: float         # per-device collective bytes (HLO is SPMD)
    coll_breakdown: dict
    model_flops: float        # 6*N*D (or 6*N_active*D)
    bytes_per_device: float   # from memory_analysis

    @property
    def t_comp(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_mem(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_coll(self) -> float:
        # HLO under SPMD is per-device: coll_bytes already per chip.
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """T_comp / max-term: 1.0 = compute-bound at peak."""
        t = max(self.t_comp, self.t_mem, self.t_coll)
        return self.t_comp / t if t > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "bytes_per_device": self.bytes_per_device,
            "t_comp": self.t_comp, "t_mem": self.t_mem,
            "t_coll": self.t_coll, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, spec) -> float:
    """MODEL_FLOPS: 6*N*D training / 2*N*D inference (N = active params
    EXCLUDING embedding tables, Kaplan convention) + explicit lm-head
    matmul flops (the head is a real matmul even when tied)."""
    n = cfg.active_nonembed_param_count()
    heads = cfg.n_codebooks or 1
    head_flops_per_tok = 2.0 * cfg.d_model * cfg.vocab * heads
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return (6.0 * n + 3.0 * head_flops_per_tok) * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        # prefill computes the head only for the last token per sequence
        return (2.0 * n * tokens
                + head_flops_per_tok * spec.global_batch)
    tokens = spec.global_batch   # decode: one token per sequence
    return (2.0 * n + head_flops_per_tok) * tokens


def extract_memory_bytes(memory_analysis) -> float:
    """Best-effort bytes-per-device from compiled.memory_analysis()."""
    for attr in ("temp_size_in_bytes",):
        if hasattr(memory_analysis, attr):
            total = (getattr(memory_analysis, "argument_size_in_bytes", 0)
                     + getattr(memory_analysis, "output_size_in_bytes", 0)
                     + getattr(memory_analysis, "temp_size_in_bytes", 0)
                     - getattr(memory_analysis, "alias_size_in_bytes", 0))
            return float(total)
    return 0.0
