"""End-to-end training driver.

Runs a real training loop on whatever devices exist (CPU smoke scale or a
TPU slice): synthetic data pipeline with prefetch, jitted train step with
the production sharding rules, async checkpointing with retention,
heartbeat/straggler bookkeeping, optional Cohmeleon memory-mode autotuning
(--autotune) and int8+EF gradient compression (--compress).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-3b --smoke \
      --steps 200 --autotune
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.checkpoint.manager import CheckpointManager
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import PrefetchIterator
from repro.data.synthetic import DataConfig, batch_iterator
from repro.distributed.fault import StragglerDetector
from repro.distributed.sharding import activation_mesh
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 + error-feedback gradient compression")
    ap.add_argument("--autotune", action="store_true",
                    help="Cohmeleon Q-learning over memory modes")
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    spec = ShapeSpec("cli", "train", args.seq, args.batch)
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)

    with mesh, activation_mesh(mesh):
        state_sh, batch_sh = steps_lib.train_shardings(cfg, mesh, spec)
        state = jax.device_put(
            steps_lib.make_train_state(cfg, jax.random.PRNGKey(0)), state_sh)
        if args.compress:
            from repro.optim import compress
            state["ef"] = compress.init_ef(state["params"])
            state_sh["ef"] = jax.tree_util.tree_map(
                lambda _: state_sh["params"], None) if False else None
            state_sh.pop("ef", None)

        manager = None
        start_step = 0
        if args.ckpt_dir:
            manager = CheckpointManager(args.ckpt_dir, keep=3)
            if args.resume and manager.latest_step() is not None:
                start_step = manager.latest_step()
                state = manager.restore(jax.eval_shape(lambda: state),
                                        shardings=None)
                print(f"resumed from step {start_step}")

        if args.autotune:
            from repro.core.autotune import MemoryModeOrchestrator
            orch = MemoryModeOrchestrator(cfg, spec, mesh, seed=0,
                                          total_steps=args.steps)
        else:
            step_fn = jax.jit(
                steps_lib.make_train_step(cfg, grad_compress=args.compress,
                                          total_steps=args.steps),
                donate_argnums=(0,))

        data = PrefetchIterator(
            batch_iterator(cfg, DataConfig(args.seq, args.batch),
                           start_step=start_step), depth=2)
        straggler = StragglerDetector()

        losses = []
        t_start = time.time()
        for step in range(start_step, args.steps):
            batch = next(data)
            t0 = time.time()
            if args.autotune:
                state, metrics = orch.step(state, batch)
            else:
                state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            straggler.record(0, dt)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                print(f"step {step + 1:5d} loss {losses[-1]:.4f} "
                      f"({dt * 1e3:.0f} ms/step)")
            if manager and (step + 1) % args.ckpt_every == 0:
                manager.save(step + 1, state)
        if manager:
            manager.save(args.steps, state)
            manager.wait()

        wall = time.time() - t_start
        print(f"done: {args.steps - start_step} steps in {wall:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        if args.autotune:
            print("autotune decisions:", orch.decision_counts())
        return losses


if __name__ == "__main__":
    main()
