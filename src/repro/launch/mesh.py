"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
*before* any jax initialization; tests and benches see the real 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
