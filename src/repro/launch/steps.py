"""Step functions + abstract input specs for train / prefill / decode.

Everything here works on ShapeDtypeStructs (no allocation): the dry-run
lowers ``jax.jit(step, in_shardings=..., out_shardings=...)`` against these
specs for every (arch x shape x mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.optim import adafactor, adamw, compress, schedule


# ------------------------------------------------------------------ state --
def make_train_state(cfg: ArchConfig, key):
    params = transformer.init_params(cfg, key)
    if cfg.param_dtype != "float32":
        dt = {"bfloat16": jnp.bfloat16}[cfg.param_dtype]
        params = jax.tree_util.tree_map(lambda p: p.astype(dt), params)
    if cfg.optimizer == "adafactor":
        opt = adafactor.init(params)
    else:
        opt = adamw.init(params)
    return {"params": params, "opt": opt}


def train_state_specs(cfg: ArchConfig):
    """Abstract train state via eval_shape (nothing allocated)."""
    return jax.eval_shape(
        lambda: make_train_state(cfg, jax.random.PRNGKey(0)))


# ------------------------------------------------------------------ specs --
def input_specs(cfg: ArchConfig, spec: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    def tok_shape(seq):
        if cfg.n_codebooks:
            return (b, cfg.n_codebooks, seq)
        return (b, seq)

    if spec.kind == "train":
        batch: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct(tok_shape(s), i32),
            "labels": jax.ShapeDtypeStruct(tok_shape(s), i32),
        }
    elif spec.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct(tok_shape(s), i32)}
    else:  # decode: one new token against a seq_len cache
        batch = {"tokens": jax.ShapeDtypeStruct(tok_shape(1), i32)}

    if cfg.family == "vlm":
        seq = s if spec.kind != "decode" else 1
        if spec.kind != "decode":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.vision_dim), f32)
        batch["mrope_positions"] = jax.ShapeDtypeStruct((3, b, seq), i32)
    return batch


def cache_specs(cfg: ArchConfig, spec: ShapeSpec):
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, spec.global_batch, spec.seq_len))


# ------------------------------------------------------------------ steps --
def make_train_step(cfg: ArchConfig, *, grad_compress: bool = False,
                    total_steps: int = 10000):
    """Returns train_step(state, batch) -> (state, metrics)."""
    use_adafactor = cfg.optimizer == "adafactor"

    def train_step(state, batch):
        def lossf(params):
            return transformer.loss_fn(cfg, params, batch)

        (loss, aux), grads = jax.value_and_grad(
            lossf, has_aux=True)(state["params"])
        if grad_compress:
            grads, new_ef = compress.compress_grads(grads, state["ef"])
        step = (state["opt"].step if not use_adafactor
                else state["opt"].step)
        lr_scale = schedule.warmup_cosine(step, total_steps=total_steps)
        if use_adafactor:
            params, opt, om = adafactor.update(
                grads, state["opt"], state["params"], lr_scale=lr_scale)
        else:
            params, opt, om = adamw.update(
                grads, state["opt"], state["params"], lr_scale=lr_scale)
        new_state = {"params": params, "opt": opt}
        if grad_compress:
            new_state["ef"] = new_ef
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        return transformer.prefill(cfg, params, batch, max_len=max_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, batch, pos):
        return transformer.decode_step(cfg, params, cache, batch, pos)
    return decode_step


# -------------------------------------------------------------- shardings --
def train_shardings(cfg: ArchConfig, mesh: Mesh, spec: ShapeSpec):
    """(state_shardings, batch_shardings) NamedSharding pytrees."""
    state_specs = train_state_specs(cfg)
    state_sh = {
        "params": shd.param_shardings(mesh, state_specs["params"]),
        "opt": jax.tree_util.tree_map(
            lambda leaf: _opt_leaf_sharding(mesh, leaf),
            state_specs["opt"]),
    }
    # Optimizer moments mirror the param tree: reuse param rules where the
    # path matches (mu/nu paths contain the original param names).
    if cfg.optimizer != "adafactor":
        opt = state_specs["opt"]
        state_sh["opt"] = type(opt)(
            step=shd.replicated(mesh),
            mu=shd.param_shardings(mesh, opt.mu),
            nu=shd.param_shardings(mesh, opt.nu),
        )
    batch_sh = shd.batch_shardings(mesh, input_specs(cfg, spec))
    return state_sh, batch_sh


def _opt_leaf_sharding(mesh, leaf):
    return shd.replicated(mesh)


def serve_shardings(cfg: ArchConfig, mesh: Mesh, spec: ShapeSpec):
    params_specs = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = shd.param_shardings(mesh, params_specs)
    c_sh = shd.cache_shardings(mesh, cache_specs(cfg, spec))
    b_sh = shd.batch_shardings(mesh, input_specs(cfg, spec))
    return p_sh, c_sh, b_sh
