"""Pre-sampled, jit-compatible fault injection for the SoC environments.

Production SoCs are not always healthy: accelerators brown out (DVFS
throttling, thermal capping), DDR channels lose bandwidth, the LLC sees
contention bursts from co-tenants, and invocations get dropped by flaky
drivers and must be retried.  This module expresses all of that as a
:class:`FaultSpec` pytree that every environment accepts — ``VecEnv``,
``StackedVecEnv``, the fused ``soc_step`` kernel (and its bitwise
``episode_ref``), and the host-Python DES — so the learned policy can be
trained and evaluated under degraded hardware.

Design rules (mirroring ``qlearn.SelectNoise``):

  * **Pre-sampled**: all per-invocation randomness (the drop/retry
    uniforms) comes from ONE threefry draw per episode against the
    spec's OWN ``key``, turned into per-step rows that ride through the
    ``lax.scan`` xs.  The episode's main PRNG stream is never touched,
    which is what makes a *zero* (all-neutral) spec bitwise-identical to
    the no-fault path: every perturbation reduces to ``x * 1.0`` or
    ``x + 0.0`` — IEEE no-ops on the finite positive values involved.
  * **Window-based**: each fault class is an ``[start, end)`` window in
    invocation-start order (the round-major schedule order the compiled
    episode scans in; the DES counts invocation starts the same way).
  * **Per-step lowering**: :func:`sample_fault_arrays` lowers a spec to
    a :class:`StepFault` with ``(n_steps,)`` leaves; the step consumes
    one row.  ``memsys.invocation_perf[_cached]`` take the row as an
    optional ``fault=`` argument — ``None`` keeps the exact pre-fault
    program (a trace-time Python branch, so the healthy path re-traces
    to today's HLO).

Fault classes:

  * **Accelerator slowdown/outage** — multiplies the victim
    accelerator's compute cost per byte (``slow_factor``; a large factor
    models an outage window where the engine barely progresses).
  * **DDR throttling** — scales the SoC's DRAM bandwidth
    (``ddr_scale <= 1``), squeezing both the victim's own transfer and
    the shared-bandwidth contention model.
  * **LLC contention spike** — adds ``llc_extra`` bytes/cycle of foreign
    LLC demand, as if a co-tenant suddenly thrashes the shared cache.
  * **Dropped invocations** — each start in the window independently
    fails with ``drop_prob`` per attempt, up to
    :data:`FAULT_MAX_RETRIES` retries with exponential backoff
    (``backoff * (2**retries - 1)`` extra driver cycles).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Bounded retry budget per invocation (the "bounded retry/backoff" of the
# fault model): at most this many re-submissions before the driver gives
# up and runs the invocation anyway at the accumulated backoff cost.
FAULT_MAX_RETRIES = 3

_ALL_ACCS = -1  # sentinel for "every accelerator is a victim"


class FaultSpec(NamedTuple):
    """One episode's fault scenario (a jit-friendly pytree of scalars).

    All fields are scalar jnp arrays so a spec can be passed as a traced
    argument — changing intensities/windows never retraces.  Windows are
    ``[start, end)`` in invocation-start order; an empty window (end <=
    start) disables that fault class.  ``slow_acc``/``drop_acc`` pick a
    victim accelerator id, or ``-1`` for all.
    """

    # accelerator slowdown / outage window
    slow_start: jnp.ndarray    # () int32
    slow_end: jnp.ndarray      # () int32
    slow_acc: jnp.ndarray      # () int32, -1 = all accelerators
    slow_factor: jnp.ndarray   # () float32, compute-cost multiplier (>= 1)
    # DDR bandwidth throttling window
    ddr_start: jnp.ndarray     # () int32
    ddr_end: jnp.ndarray       # () int32
    ddr_scale: jnp.ndarray     # () float32, dram_bw multiplier (<= 1)
    # LLC contention spike window
    llc_start: jnp.ndarray     # () int32
    llc_end: jnp.ndarray       # () int32
    llc_extra: jnp.ndarray     # () float32, extra LLC bytes/cycle of load
    # dropped invocations with bounded retry/backoff
    drop_start: jnp.ndarray    # () int32
    drop_end: jnp.ndarray      # () int32
    drop_acc: jnp.ndarray      # () int32, -1 = all accelerators
    drop_prob: jnp.ndarray     # () float32, per-attempt drop probability
    backoff: jnp.ndarray       # () float32, driver cycles for first retry
    # the spec's OWN threefry key: drop/retry uniforms come from here, so
    # the episode's main key consumption is untouched by fault injection.
    key: jnp.ndarray           # (2,) uint32


class StepFault(NamedTuple):
    """One invocation's lowered perturbation, consumed by ``memsys``.

    Leaves are scalars per step (or ``(n_steps,)`` for a whole episode's
    rows).  The neutral row (1, 1, 0, 0) is an exact arithmetic no-op.
    """

    exec_scale: jnp.ndarray    # compute-cost multiplier (1.0 = healthy)
    ddr_scale: jnp.ndarray     # dram_bw multiplier (1.0 = healthy)
    llc_extra: jnp.ndarray     # extra LLC bytes/cycle of load (0.0 = none)
    retry_cycles: jnp.ndarray  # extra driver cycles from drop retries


def no_faults(key=None) -> FaultSpec:
    """An all-neutral spec: episodes under it are bitwise-identical to
    episodes with ``faults=None`` (every window is empty and every
    perturbation is an IEEE no-op)."""
    i32 = jnp.int32
    f32 = jnp.float32
    if key is None:
        key = jax.random.PRNGKey(0)
    return FaultSpec(
        slow_start=jnp.asarray(0, i32), slow_end=jnp.asarray(0, i32),
        slow_acc=jnp.asarray(_ALL_ACCS, i32),
        slow_factor=jnp.asarray(1.0, f32),
        ddr_start=jnp.asarray(0, i32), ddr_end=jnp.asarray(0, i32),
        ddr_scale=jnp.asarray(1.0, f32),
        llc_start=jnp.asarray(0, i32), llc_end=jnp.asarray(0, i32),
        llc_extra=jnp.asarray(0.0, f32),
        drop_start=jnp.asarray(0, i32), drop_end=jnp.asarray(0, i32),
        drop_acc=jnp.asarray(_ALL_ACCS, i32),
        drop_prob=jnp.asarray(0.0, f32),
        backoff=jnp.asarray(0.0, f32),
        key=jnp.asarray(key, jnp.uint32),
    )


def neutral_step_fault() -> StepFault:
    """The healthy per-step row (exact no-op when applied)."""
    f32 = jnp.float32
    return StepFault(exec_scale=jnp.asarray(1.0, f32),
                     ddr_scale=jnp.asarray(1.0, f32),
                     llc_extra=jnp.asarray(0.0, f32),
                     retry_cycles=jnp.asarray(0.0, f32))


def storm(n_steps: int, intensity: float, key,
          slow_acc: int = _ALL_ACCS, drop_acc: int = _ALL_ACCS,
          backoff: float = 5000.0) -> FaultSpec:
    """A composite "fault storm" scaled by ``intensity`` in [0, 1].

    Staggers the four fault classes across the episode so most steps see
    at least one active perturbation at full intensity: an accelerator
    brownout over the middle half, DDR throttling over the second third,
    an LLC spike over the first half, and a drop window over the last
    third.  ``intensity=0`` degenerates to a neutral spec.
    """
    n = int(n_steps)
    spec = no_faults(key)
    return spec._replace(
        slow_start=jnp.asarray(n // 4, jnp.int32),
        slow_end=jnp.asarray(n - n // 4, jnp.int32),
        slow_acc=jnp.asarray(slow_acc, jnp.int32),
        slow_factor=jnp.asarray(1.0 + 4.0 * intensity, jnp.float32),
        ddr_start=jnp.asarray(n // 3, jnp.int32),
        ddr_end=jnp.asarray(2 * n // 3, jnp.int32),
        ddr_scale=jnp.asarray(1.0 / (1.0 + 3.0 * intensity), jnp.float32),
        llc_start=jnp.asarray(0, jnp.int32),
        llc_end=jnp.asarray(n // 2, jnp.int32),
        llc_extra=jnp.asarray(4.0 * intensity, jnp.float32),
        drop_start=jnp.asarray(2 * n // 3, jnp.int32),
        drop_end=jnp.asarray(n, jnp.int32),
        drop_acc=jnp.asarray(drop_acc, jnp.int32),
        drop_prob=jnp.asarray(0.5 * intensity, jnp.float32),
        backoff=jnp.asarray(backoff, jnp.float32),
    )


def backoff_cycles(backoff, retries):
    """Bounded exponential backoff cost after ``retries`` failed attempts:
    ``backoff * (1 + 2 + ... + 2**(retries-1)) == backoff * (2**retries - 1)``.

    ``exp2`` of a small non-negative integer is exact in f32; ``retries ==
    0`` gives ``backoff * 0.0 == +0.0``, the additive identity — which is
    what makes the neutral fault row (and a zero-retry admission) an exact
    no-op.  Shared by the fault model's dropped-invocation retries and the
    serving path's admission retry-with-backoff (``soc.traffic``)."""
    one = jnp.asarray(1.0, jnp.float32)
    return backoff * (jnp.exp2(jnp.asarray(retries, jnp.float32)) - one)


def fault_row(spec: FaultSpec, t, acc_id, u_retry) -> StepFault:
    """Lower the spec to one invocation's :class:`StepFault`.

    ``t`` is the global invocation-start index, ``acc_id`` the victim
    candidate, ``u_retry`` a ``(FAULT_MAX_RETRIES,)`` uniform draw (the
    pre-sampled per-attempt drop coins).  All outputs are exact no-ops
    outside the windows, so a neutral spec costs nothing numerically.
    """
    f32 = jnp.float32
    one = jnp.asarray(1.0, f32)

    def in_window(a, b):
        return (t >= a) & (t < b)

    slow_hit = (in_window(spec.slow_start, spec.slow_end)
                & ((spec.slow_acc < 0) | (acc_id == spec.slow_acc)))
    exec_scale = jnp.where(slow_hit, spec.slow_factor, one)

    ddr_hit = in_window(spec.ddr_start, spec.ddr_end)
    ddr_scale = jnp.where(ddr_hit, spec.ddr_scale, one)

    llc_hit = in_window(spec.llc_start, spec.llc_end)
    llc_extra = jnp.where(llc_hit, spec.llc_extra, jnp.asarray(0.0, f32))

    drop_hit = (in_window(spec.drop_start, spec.drop_end)
                & ((spec.drop_acc < 0) | (acc_id == spec.drop_acc)))
    p = jnp.where(drop_hit, spec.drop_prob, jnp.asarray(0.0, f32))
    # Consecutive leading failures: attempt i fails iff u_retry[i] < p
    # AND every earlier attempt failed; the cumprod counts the streak.
    failed = (u_retry < p).astype(f32)
    retries = jnp.sum(jnp.cumprod(failed))
    retry_cycles = backoff_cycles(spec.backoff, retries)

    return StepFault(exec_scale=exec_scale, ddr_scale=ddr_scale,
                     llc_extra=llc_extra, retry_cycles=retry_cycles)


def sample_fault_arrays(spec: FaultSpec, acc_id) -> StepFault:
    """Lower a spec to per-step rows for a whole episode.

    ``acc_id`` is the compiled schedule's ``(n_steps,)`` accelerator-id
    column; the result is a :class:`StepFault` with ``(n_steps,)``
    leaves, fed through the episode scan's xs (one threefry draw total —
    the ``SelectNoise`` discipline).

    Note: the drop coins are drawn for the full (possibly padded)
    schedule length, so the *stochastic* component of a spec is keyed to
    the padded episode length; the deterministic window faults are
    padding-invariant.
    """
    acc_id = jnp.asarray(acc_id, jnp.int32)
    n_steps = acc_id.shape[0]
    u = jax.random.uniform(spec.key, (n_steps, FAULT_MAX_RETRIES),
                           dtype=jnp.float32)
    t = jnp.arange(n_steps, dtype=jnp.int32)
    return jax.vmap(fault_row, in_axes=(None, 0, 0, 0))(spec, t, acc_id, u)


def sample_fault_uniforms(spec: FaultSpec, n_steps: int) -> np.ndarray:
    """Host-side mirror of the per-episode drop-coin draw (for the DES).

    Returns the SAME ``(n_steps, FAULT_MAX_RETRIES)`` uniforms that
    :func:`sample_fault_arrays` consumes, so a DES run under a spec sees
    bitwise-identical retry decisions to the compiled episode.
    """
    u = jax.random.uniform(spec.key, (int(n_steps), FAULT_MAX_RETRIES),
                           dtype=jnp.float32)
    return np.asarray(u)
