"""Memory-system timing model for the ESP-like SoC (pure jnp, jit/vmap-able).

Models one accelerator invocation under each of the four coherence modes
(paper §2) in the presence of a concurrent set of other active accelerators,
producing the four monitor metrics of paper §4.1(4):

  total execution time, off-chip bytes, active cycles, communication cycles.

The model is analytical (service rates + queueing-style proportional
sharing), at the same granularity as the paper's traffic-generator
characterization.  It is calibrated to reproduce the qualitative findings of
paper §3:

  * small/medium warm workloads: cached modes avoid off-chip traffic
    entirely and win; NON_COH pays flush + cold DRAM reads and loses;
  * large workloads: caches thrash (LRU streaming over capacity), eviction
    writebacks double DRAM pressure, and NON_COH's long bursts win;
  * irregular patterns: word-granularity DMA is latency-bound, so cached
    modes win even at large sizes (paper Fig. 9, "irregular" SoC0);
  * concurrency: COH_DMA collapses worst (directory serialization at the
    LLC), NON_COH degrades least (paper Fig. 3: ~8x vs ~2.4x at 12 accs).

All shapes are static so the function nests under lax.scan/vmap in the
vectorized RL environment.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.modes import CoherenceMode
from repro.core.rewards import Measurement
from repro.soc.accelerators import IRREGULAR, PF, STREAMING
from repro.soc.config import SoCConfig


class SoCStatic(NamedTuple):
    """Hashable scalar bundle of SoC + timing constants for jit closures."""

    n_cpus: float
    n_mem_tiles: float
    l2_bytes: float
    llc_slice_bytes: float
    line: float
    dram_lat: float
    dram_bw: float
    llc_hit_lat: float
    llc_bw: float
    l2_hit_lat: float
    l2_bw: float
    noc_hop_lat: float
    noc_bw: float
    driver_base: float
    tlb_per_page: float
    page_bytes: float
    flush_base: float
    flush_bw: float
    dir_lookup: float
    recall_lat: float
    mshr: float

    @classmethod
    def from_config(cls, soc: SoCConfig) -> "SoCStatic":
        t = soc.timings
        return cls(
            n_cpus=float(soc.n_cpus),
            n_mem_tiles=float(soc.n_mem_tiles),
            l2_bytes=float(soc.l2_bytes),
            llc_slice_bytes=float(soc.llc_slice_bytes),
            line=float(t.line_bytes),
            dram_lat=t.dram_lat,
            dram_bw=t.dram_bw,
            llc_hit_lat=t.llc_hit_lat,
            llc_bw=t.llc_bw,
            l2_hit_lat=t.l2_hit_lat,
            l2_bw=t.l2_bw,
            noc_hop_lat=t.noc_hop_lat,
            noc_bw=t.noc_bw,
            driver_base=t.driver_base,
            tlb_per_page=t.tlb_per_page,
            page_bytes=float(t.page_bytes),
            flush_base=t.flush_base,
            flush_bw=t.flush_bw,
            dir_lookup=t.dir_lookup,
            recall_lat=t.recall_lat,
            mshr=float(t.mshr_per_tile),
        )


_WORD = 8.0  # DMA word granularity (bytes) for irregular accesses

# Non-overlappable serial fraction between compute and communication phases.
_SERIAL_FRAC = 0.10
# Outstanding DMA bursts an ESP accelerator keeps in flight.
_DMA_OUTSTANDING = 4.0
# Fraction of LLC capacity consumed by CPU background traffic.
_CPU_LLC_RESERVE = 0.15
# LRU second-pass hit credit when the working set exceeds capacity.
_THRASH_HIT = 0.25


def warmth_after(mode, footprint, cache_capacity_bytes):
    """How warm a producer leaves its output for the next pipeline stage.

    NON_COH DMA lands data off-chip (cold); cached modes leave up to the
    hierarchy's capacity resident.  jnp-compatible; shared by the DES and
    the vectorized environment so the two paths cannot drift.
    """
    return jnp.where(
        mode == int(CoherenceMode.NON_COH_DMA), 0.0,
        jnp.minimum(1.0, cache_capacity_bytes
                    / jnp.maximum(footprint, 1.0)))


def _burst_bw(burst_bytes, lat, peak_bw, outstanding):
    """Effective bandwidth of latency-bound bursts with overlap."""
    t = lat + burst_bytes / peak_bw
    return jnp.minimum(peak_bw, outstanding * burst_bytes / t)


def dma_demand(mode, profile, footprint, s: SoCStatic, *, compute_scale=None):
    """Unconstrained (dram, llc) bytes/cycle an invocation asks for.

    Single-level approximation used to estimate contention caused by *other*
    accelerators; intentionally ignores their own contention (standard
    fixed-point shortcut).  ``compute_scale`` multiplies the compute cost
    per byte (a fault-injected slowdown lowers the demand the engine can
    generate); ``None`` keeps the exact pre-fault expression.
    """
    pattern = profile[PF.PATTERN]
    burst = jnp.where(pattern == IRREGULAR, _WORD, profile[PF.BURST])
    dma_bw = _burst_bw(burst, s.dram_lat, s.dram_bw, _DMA_OUTSTANDING)
    line_bw = _burst_bw(s.line, s.dram_lat + s.llc_hit_lat, s.dram_bw, s.mshr)
    cpb = profile[PF.COMPUTE] / profile[PF.ENGINES]
    if compute_scale is not None:
        cpb = cpb * compute_scale
    compute_bw = 1.0 / jnp.maximum(cpb, 1e-3)

    is_non_coh = mode == int(CoherenceMode.NON_COH_DMA)
    # Cached modes mostly stress the LLC; their DRAM demand is the miss
    # stream plus eviction writebacks.  Approximate miss ratio by footprint
    # vs one LLC slice.
    miss = jnp.clip(footprint / s.llc_slice_bytes, 0.05, 1.0)
    dirty = 1.0 - profile[PF.READ_FRAC]
    dram = jnp.where(is_non_coh,
                     jnp.minimum(dma_bw, compute_bw),
                     jnp.minimum(line_bw, compute_bw) * miss * (1.0 + dirty))
    llc = jnp.where(is_non_coh, 0.0, jnp.minimum(s.llc_bw, compute_bw))
    active = mode >= 0
    return jnp.where(active, dram, 0.0), jnp.where(active, llc, 0.0)


def invocation_perf(
    mode,
    profile,
    footprint,
    my_tiles,
    other_modes,
    other_profiles,
    other_footprints,
    other_tiles,
    warm_frac,
    s: SoCStatic,
    fault=None,
):
    """Timing + monitor metrics for one invocation. Returns (Measurement, aux).

    ``aux`` carries per-quantity breakdowns used by tests and by the
    hardware-monitor attribution model.

    This is the self-contained signature used by the DES: per-slot demand of
    the concurrent set is recomputed from ``other_profiles`` on every call.
    The vectorized environment caches that demand in its scan carry and
    calls :func:`invocation_perf_cached` instead.

    ``fault`` (optional ``repro.soc.faults.StepFault``) perturbs only *my*
    invocation; the concurrent set's demand stays the healthy steady-state
    estimate (the same fixed-point shortcut the contention model already
    takes).
    """
    od_dram, od_llc = jnp.vectorize(
        lambda m, p, fp: dma_demand(m, p, fp, s),
        signature="(),(k),()->(),()",
    )(other_modes, other_profiles, other_footprints)
    return invocation_perf_cached(
        mode, profile, footprint, my_tiles, other_modes, od_dram, od_llc,
        other_footprints, other_tiles, warm_frac, s, fault=fault)


def invocation_perf_cached(
    mode,
    profile,
    footprint,
    my_tiles,
    other_modes,
    other_dram_demand,
    other_llc_demand,
    other_footprints,
    other_tiles,
    warm_frac,
    s: SoCStatic,
    fault=None,
):
    """Fast-path variant of :func:`invocation_perf`.

    Takes the concurrent set's per-slot ``(dram, llc)`` bytes/cycle demand
    precomputed (``other_dram_demand``/``other_llc_demand``, each ``(T,)``)
    instead of the slots' profile rows.  A slot's demand depends only on its
    (mode, profile, footprint), which change exactly when that slot issues a
    new invocation — so the vectorized environment keeps demand in its scan
    carry, writes one slot per step, and skips the O(slots) recomputation
    (Alsop et al.: per-request-class demand is largely static).  Inactive
    slots (``other_modes < 0``) are masked here regardless of the demand
    value passed.  ``aux['demand_dram']``/``aux['demand_llc']`` return this
    invocation's own demand so the caller can cache it for its slot.

    ``fault`` is an optional ``repro.soc.faults.StepFault`` row: the DDR
    throttle rescales ``s.dram_bw`` (squeezing DMA, line-fill and the
    shared-bandwidth cap alike), the accelerator slowdown multiplies the
    compute cost per byte, the LLC spike adds foreign bytes/cycle of LLC
    load, and drop retries add backoff cycles to the driver overhead.
    ``fault=None`` (the default) is a trace-time branch that re-traces to
    the exact pre-fault program; a *neutral* row (1, 1, 0, 0) is a bitwise
    no-op on the arithmetic (``x * 1.0`` / ``x + 0.0`` on finite
    non-negative values), which is what the zero-``FaultSpec`` equivalence
    tests pin.
    """
    f32 = jnp.float32
    fault_scale = None
    if fault is not None:
        s = s._replace(dram_bw=s.dram_bw * fault.ddr_scale)
        fault_scale = fault.exec_scale
    footprint = jnp.maximum(jnp.asarray(footprint, f32), 1.0)
    n_my_tiles = jnp.maximum(jnp.sum(my_tiles.astype(f32)), 1.0)

    pattern = profile[PF.PATTERN]
    reuse = jnp.maximum(profile[PF.REUSE], 1.0)
    read_frac = profile[PF.READ_FRAC]
    afrac = jnp.where(pattern == IRREGULAR, profile[PF.ACCESS_FRAC], 1.0)
    in_place = profile[PF.IN_PLACE]
    compute_per_byte = profile[PF.COMPUTE] / jnp.maximum(profile[PF.ENGINES], 1.0)
    if fault is not None:
        compute_per_byte = compute_per_byte * fault.exec_scale

    read_bytes = footprint * read_frac * reuse      # line-granularity stream
    write_bytes = footprint * (1.0 - read_frac)
    dma_read_bytes = footprint * afrac * read_frac * reuse  # word granularity

    # ------------------------------------------------------------------
    # Contention from the concurrent set (proportional sharing per tile).
    # ------------------------------------------------------------------
    other_active = other_modes >= 0
    od_dram, od_llc = other_dram_demand, other_llc_demand

    overlap = jnp.sum(
        other_tiles.astype(f32) * my_tiles[None, :].astype(f32), axis=-1
    ) / jnp.maximum(jnp.sum(other_tiles.astype(f32), axis=-1), 1.0)

    my_dram_demand, my_llc_demand = dma_demand(
        mode, profile, footprint, s, compute_scale=fault_scale)
    dram_cap = s.dram_bw * n_my_tiles
    llc_cap = s.llc_bw * n_my_tiles

    dram_load = jnp.sum(jnp.where(other_active, od_dram * overlap, 0.0))
    llc_load = jnp.sum(jnp.where(other_active, od_llc * overlap, 0.0))
    if fault is not None:
        llc_load = llc_load + fault.llc_extra
    dram_slow = jnp.maximum(1.0, (dram_load + my_dram_demand) / dram_cap)
    llc_slow = jnp.maximum(1.0, (llc_load + my_llc_demand) / llc_cap)

    # LLC capacity share: my footprint vs all cached footprints on my tiles.
    other_cached = other_active & (other_modes != int(CoherenceMode.NON_COH_DMA))
    cached_fp = jnp.sum(
        jnp.where(other_cached, other_footprints * overlap, 0.0)
    )
    llc_capacity = (
        s.llc_slice_bytes * n_my_tiles * (1.0 - _CPU_LLC_RESERVE)
    )
    my_llc_cap = llc_capacity * footprint / jnp.maximum(footprint + cached_fp, 1.0)

    # Directory serialization: other requesters holding the LLC controller.
    n_llc_users = jnp.sum(jnp.where(other_cached, overlap, 0.0))

    # ------------------------------------------------------------------
    # Shared path bandwidths.
    # ------------------------------------------------------------------
    burst = jnp.where(pattern == IRREGULAR, _WORD, profile[PF.BURST])
    dma_bw = _burst_bw(burst, s.dram_lat + 2 * s.noc_hop_lat, s.dram_bw,
                       _DMA_OUTSTANDING) / dram_slow
    # Cached-mode line-fill path: NoC -> LLC (directory) -> DRAM -> back.
    line_fill_bw = _burst_bw(
        s.line, s.dram_lat + s.llc_hit_lat + 2 * s.noc_hop_lat,
        s.dram_bw, s.mshr,
    ) / dram_slow
    llc_hit_bw = jnp.minimum(s.llc_bw, s.noc_bw * n_my_tiles) / llc_slow

    # ------------------------------------------------------------------
    # Cache hit models.
    # ------------------------------------------------------------------
    warm_llc_bytes = warm_frac * jnp.minimum(footprint, my_llc_cap)
    fits_llc = footprint <= my_llc_cap
    cold_hit = warm_llc_bytes / footprint                       # first pass
    reuse_hit = jnp.where(fits_llc, 1.0, _THRASH_HIT * my_llc_cap / footprint)
    n_pass = jnp.maximum(reuse, 1.0)
    llc_hit_frac = (cold_hit + (n_pass - 1.0) * reuse_hit) / n_pass

    fits_l2 = footprint <= s.l2_bytes
    l2_reuse_hit = jnp.where(fits_l2, 1.0,
                             _THRASH_HIT * s.l2_bytes / footprint)
    l2_hit_frac = ((n_pass - 1.0) * l2_reuse_hit) / n_pass      # cold L2

    # ------------------------------------------------------------------
    # Overheads (driver, TLB preload, flushes) — paper §4.3 Actuate.
    # ------------------------------------------------------------------
    tlb = s.tlb_per_page * jnp.ceil(footprint / s.page_bytes)
    hierarchy = s.llc_slice_bytes * s.n_mem_tiles + s.n_cpus * s.l2_bytes
    full_flush_bytes = warm_frac * jnp.minimum(footprint, hierarchy)
    priv_flush_bytes = warm_frac * jnp.minimum(footprint, s.n_cpus * s.l2_bytes)
    ovh_base = s.driver_base + tlb
    ovh = jnp.select(
        [mode == int(CoherenceMode.NON_COH_DMA),
         mode == int(CoherenceMode.LLC_COH_DMA)],
        [ovh_base + s.flush_base + full_flush_bytes / s.flush_bw,
         ovh_base + s.flush_base + priv_flush_bytes / s.flush_bw],
        ovh_base,
    )
    if fault is not None:
        ovh = ovh + fault.retry_cycles

    # ------------------------------------------------------------------
    # Per-mode communication cycles and off-chip bytes.
    # ------------------------------------------------------------------
    # NON_COH_DMA: word-granularity DMA straight to DRAM.
    nc_offchip = dma_read_bytes + write_bytes + full_flush_bytes
    nc_comm = (dma_read_bytes + write_bytes) / jnp.maximum(dma_bw, 1e-3)

    # LLC paths (shared by the three cached modes).
    llc_miss_bytes = read_bytes * (1.0 - llc_hit_frac)
    llc_hit_bytes = read_bytes * llc_hit_frac
    dirty_frac = jnp.clip((1.0 - read_frac) + 0.25 * in_place, 0.0, 1.0)
    evict_bytes = jnp.where(fits_llc, 0.0, llc_miss_bytes * dirty_frac)
    llc_write_off = jnp.where(fits_llc, 0.0, write_bytes)

    def llc_path(dir_cost_per_line, extra_lat, fill_bw_scale):
        per_line = s.line / s.llc_bw + dir_cost_per_line
        ctl_bw = s.line / per_line / llc_slow
        hit_bw = jnp.minimum(llc_hit_bw, ctl_bw)
        fill = jnp.maximum(line_fill_bw * fill_bw_scale, 1e-3)
        comm = (
            llc_hit_bytes / jnp.maximum(hit_bw, 1e-3)
            + llc_miss_bytes / fill
            + write_bytes / jnp.maximum(ctl_bw, 1e-3)
            + evict_bytes / jnp.maximum(fill, 1e-3)
            + extra_lat
        )
        off = llc_miss_bytes + evict_bytes + llc_write_off
        return comm, off

    lc_comm, lc_off = llc_path(0.0, 0.0, 1.0)

    # COH_DMA: every beat takes a directory action; under sharing the
    # directory serializes (paper Fig. 3's 8x collapse): besides the lookup,
    # each line has a growing probability of needing an owner-check/recall
    # round trip as more cached-mode accelerators churn the same slice.
    # The churn only exists under cache PRESSURE — when the aggregate
    # cached working set fits the LLC, lines are stable and the directory
    # answers from steady state (no evictions/recalls), so the
    # user-scaling term is weighted by occupancy.
    pressure = jnp.clip(
        (cached_fp + footprint) / jnp.maximum(llc_capacity, 1.0), 0.0, 1.0)
    dir_cost = (
        s.dir_lookup * (1.0 + n_llc_users * pressure)
        + s.recall_lat * jnp.minimum(1.0, 0.15 * n_llc_users * pressure)
    )
    recall_bytes = warm_frac * jnp.minimum(footprint, s.n_cpus * s.l2_bytes)
    recall_cycles = (recall_bytes / s.line) * s.recall_lat / _DMA_OUTSTANDING
    cd_comm, cd_off = llc_path(dir_cost, recall_cycles, 1.0)

    # FULLY_COH: private-cache hits absorb traffic; misses traverse the
    # MESI directory.  Cold pass misses into LLC, reuse passes hit L2.
    l2_hit_bytes = read_bytes * l2_hit_frac
    l2_miss_bytes = read_bytes * (1.0 - l2_hit_frac)
    fc_llc_hit = l2_miss_bytes * llc_hit_frac
    fc_llc_miss = l2_miss_bytes * (1.0 - llc_hit_frac)
    fc_dirty = jnp.where(fits_l2, 0.0, l2_miss_bytes * dirty_frac * 0.5)
    per_line_fc = (s.line / s.llc_bw
                   + s.dir_lookup * (1.0 + 0.5 * n_llc_users * pressure))
    fc_ctl_bw = s.line / per_line_fc / llc_slow
    fc_evict = jnp.where(fits_llc, 0.0, fc_llc_miss * dirty_frac)
    fc_write_off = jnp.where(fits_llc, 0.0,
                             jnp.where(fits_l2, 0.0, write_bytes))
    fc_comm = (
        l2_hit_bytes / s.l2_bw
        + fc_llc_hit / jnp.maximum(jnp.minimum(llc_hit_bw, fc_ctl_bw), 1e-3)
        + fc_llc_miss / jnp.maximum(line_fill_bw, 1e-3)
        + (fc_dirty + fc_evict) / jnp.maximum(line_fill_bw, 1e-3)
        + jnp.where(fits_l2, write_bytes / s.l2_bw,
                    write_bytes / jnp.maximum(fc_ctl_bw, 1e-3))
    )
    fc_off = fc_llc_miss + fc_evict + fc_write_off

    comm_cycles = jnp.select(
        [mode == int(CoherenceMode.NON_COH_DMA),
         mode == int(CoherenceMode.LLC_COH_DMA),
         mode == int(CoherenceMode.COH_DMA)],
        [nc_comm, lc_comm, cd_comm],
        fc_comm,
    )
    offchip_bytes = jnp.select(
        [mode == int(CoherenceMode.NON_COH_DMA),
         mode == int(CoherenceMode.LLC_COH_DMA),
         mode == int(CoherenceMode.COH_DMA)],
        [nc_offchip, lc_off, cd_off],
        fc_off,
    )

    compute_cycles = compute_per_byte * footprint * reuse
    hi = jnp.maximum(compute_cycles, comm_cycles)
    lo = jnp.minimum(compute_cycles, comm_cycles)
    active_cycles = hi + _SERIAL_FRAC * lo      # pipelined overlap, §3
    exec_time = ovh + active_cycles

    m = Measurement(
        exec_time=exec_time,
        comm_cycles=comm_cycles,
        total_cycles=active_cycles,
        offchip_accesses=offchip_bytes / s.line,
        footprint=footprint,
    )
    aux = {
        "overhead": ovh,
        "compute_cycles": compute_cycles,
        "dram_slowdown": dram_slow,
        "llc_slowdown": llc_slow,
        "llc_hit_frac": llc_hit_frac,
        "offchip_bytes": offchip_bytes,
        # Own unconstrained demand — callers that cache per-slot demand
        # (soc.vecenv's scan carry) write these to this invocation's slot.
        "demand_dram": my_dram_demand,
        "demand_llc": my_llc_demand,
    }
    return m, aux
