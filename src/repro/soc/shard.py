"""shard_map scale-out for the batched SoC trainer.

:class:`~repro.soc.vecenv.VecEnv` and
:class:`~repro.soc.stacked.StackedVecEnv` already batch (SoC lanes x
reward weights x seeds) with ``vmap`` inside one jitted call; this module
splits that batch across every available device with ``shard_map`` over
the 1-D lane mesh from :func:`repro.distributed.sharding.lane_mesh`.

The batch entries are fully independent (pure data parallelism, no
collectives), so each device runs the unmodified vmapped program on its
slice of the batch:

  * :func:`sharded_train_batched` shards ``VecEnv.train_batched`` over
    the agent axis B (reward-weight / seed pairs);
  * :func:`sharded_train_batched_stacked` shards
    ``StackedVecEnv.train_batched`` over the agent axis B of its (K, B)
    grid (the K SoC-lane parameters ride in the closure, so every device
    keeps all lanes and takes a slice of the agents);
  * :func:`sharded_episodes` shards ``StackedVecEnv.episodes`` over the
    policy axis N of its (K, N) spec grid.

Whenever the mesh has a single device — or the batch axis does not divide
the device count — the wrappers fall back to the plain vmap call, which
is bitwise-identical by construction.  ``force_shard_map=True`` runs
shard_map even on one device; that path recompiles the program under the
shard_map wrapper, so float leaves agree with vmap to roundoff (~1e-7,
XLA refuses in a different order) while integer state (visits, step
counters, modes) stays bitwise — the equivalence tests pin both.
"""
from __future__ import annotations

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import lane_mesh

__all__ = ["lane_mesh", "sharded_train_batched",
           "sharded_train_batched_stacked", "sharded_episodes",
           "sharded_serve"]


def _axis_spec(tree, axis: int | None):
    """P(None, ..., "lanes") at position ``axis`` for every leaf;
    ``axis=None`` replicates the whole tree (``P()``) — how scalar pytrees
    like a FaultSpec ride along without a batch axis."""
    spec = P() if axis is None else P(*([None] * axis + ["lanes"]))
    return jax.tree_util.tree_map(lambda _: spec, tree)


# jit cache for the shard_map wrappers: each public function builds a
# fresh ``run`` closure per call, which would defeat ``jax.jit``'s
# function-identity cache and recompile every invocation.  Entries key on
# the mesh devices, the axis layout and the *identities* of the closure
# constants (env, schedules, cfg, ...); holding strong references to those
# constants keeps their ids from being reused.
_JIT_CACHE: list = []


def _shard_call(fn, mesh: Mesh, args, in_axes, out_axis: int, consts=()):
    """shard_map ``fn`` with each arg split on its ``in_axes`` entry.

    ``out_specs`` comes from ``jax.eval_shape``, so any output pytree
    (QState, EpisodeResult, eval histories or none) shards on
    ``out_axis`` without the caller spelling out its structure.
    ``consts`` are the values ``fn`` closes over — two calls with
    identical consts reuse one jitted program (steady-state calls stop
    paying a retrace)."""
    mesh_key = tuple(d.id for d in mesh.devices.flat)
    for c, mk, ia, oa, jitted in _JIT_CACHE:
        if (mk == mesh_key and ia == in_axes and oa == out_axis
                and len(c) == len(consts)
                and all(a is b for a, b in zip(c, consts))):
            return jitted(*args)
    in_specs = tuple(_axis_spec(a, ax) for a, ax in zip(args, in_axes))
    out_specs = _axis_spec(jax.eval_shape(fn, *args), out_axis)
    sharded = shard_map(fn, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    jitted = jax.jit(sharded)
    _JIT_CACHE.append((tuple(consts), mesh_key, in_axes, out_axis, jitted))
    return jitted(*args)


def _use_mesh(mesh: Mesh | None, batch: int, force: bool):
    """Resolve the mesh; None means 'fall back to plain vmap'."""
    mesh = lane_mesh() if mesh is None else mesh
    n = int(mesh.devices.size)
    if batch % n != 0 or (n == 1 and not force):
        return None
    return mesh


def sharded_train_batched(env, train_apps, cfg, weights_batch, keys, *,
                          eval_app=None, faults=None,
                          mesh: Mesh | None = None,
                          force_shard_map: bool = False):
    """``VecEnv.train_batched`` with the B agents split across devices.

    Same signature and results as the method; ``mesh`` defaults to
    :func:`lane_mesh` over all devices.  Falls back to the plain vmap
    call when the mesh is a single device (unless ``force_shard_map``)
    or B does not divide the device count.

    ``faults`` (a ``soc.faults.FaultSpec``) replicates to every device as
    a *traced* argument (``P()``), so sweeping fault intensities reuses
    one compiled program instead of retracing per spec value.
    """
    mesh = _use_mesh(mesh, int(keys.shape[0]), force_shard_map)
    if mesh is None:
        return env.train_batched(train_apps, cfg, weights_batch, keys,
                                 eval_app, faults)

    if faults is None:
        def run(w, k):
            return env.train_batched(train_apps, cfg, w, k, eval_app)

        return _shard_call(run, mesh, (weights_batch, keys), (0, 0), 0,
                           consts=(env, *train_apps, cfg, eval_app))

    def run(w, k, f):
        return env.train_batched(train_apps, cfg, w, k, eval_app, f)

    return _shard_call(run, mesh, (weights_batch, keys, faults),
                       (0, 0, None), 0,
                       consts=(env, *train_apps, cfg, eval_app, "faulted"))


def sharded_train_batched_stacked(env, stacked_iters, cfg, weights_batch,
                                  keys, *, eval_stacked=None, faults=None,
                                  mesh: Mesh | None = None,
                                  force_shard_map: bool = False):
    """``StackedVecEnv.train_batched`` with the B agents split across
    devices (keys are (K, B, 2); every device keeps all K lanes).
    ``faults`` replicates like in :func:`sharded_train_batched`."""
    mesh = _use_mesh(mesh, int(keys.shape[1]), force_shard_map)
    if mesh is None:
        return env.train_batched(stacked_iters, cfg, weights_batch, keys,
                                 eval_stacked, faults)

    if faults is None:
        def run(w, k):
            return env.train_batched(stacked_iters, cfg, w, k, eval_stacked)

        return _shard_call(run, mesh, (weights_batch, keys), (0, 1), 1,
                           consts=(env, *stacked_iters, cfg, eval_stacked))

    def run(w, k, f):
        return env.train_batched(stacked_iters, cfg, w, k, eval_stacked, f)

    return _shard_call(run, mesh, (weights_batch, keys, faults),
                       (0, 1, None), 1,
                       consts=(env, *stacked_iters, cfg, eval_stacked,
                               "faulted"))


def sharded_episodes(env, stacked, specs, cfg=None, keys=None, *,
                     mesh: Mesh | None = None,
                     force_shard_map: bool = False):
    """``StackedVecEnv.episodes`` with the N policies split across
    devices (specs are (K, N); every device keeps all K lanes)."""
    if keys is None:
        keys = env._default_keys(*specs.learned.shape)
    mesh = _use_mesh(mesh, int(specs.learned.shape[1]), force_shard_map)
    if mesh is None:
        return env.episodes(stacked, specs, cfg, keys)

    def run(sp, k):
        return env.episodes(stacked, sp, cfg, k)

    return _shard_call(run, mesh, (specs, keys), (1, 1), 1,
                       consts=(env, stacked, cfg))


def sharded_serve(env, stacked, specs, traffic, cfg=None, keys=None, *,
                  queue_cap: int = 8, n_requests: int = 1024,
                  mesh: Mesh | None = None,
                  force_shard_map: bool = False):
    """``StackedVecEnv.serve`` with the N policies split across devices
    (specs are (K, N); every device keeps all K lanes and the whole
    offered stream — the TrafficSpec replicates as a scalar pytree, the
    same ``P()`` protocol as a FaultSpec)."""
    if keys is None:
        keys = env._default_keys(*specs.learned.shape)

    def call(sp, k):
        return env.serve(stacked, sp, traffic, cfg, k,
                         queue_cap=queue_cap, n_requests=n_requests)

    mesh = _use_mesh(mesh, int(specs.learned.shape[1]), force_shard_map)
    if mesh is None:
        return call(specs, keys)

    return _shard_call(call, mesh, (specs, keys), (1, 1), 1,
                       consts=(env, stacked, cfg, traffic, queue_cap,
                               n_requests))
