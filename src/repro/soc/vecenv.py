"""Vectorized SoC environment — the scale path of the reproduction.

Where ``soc.des`` is the fidelity path (host-Python event loop, one agent at
a time), this module lowers a whole :class:`~repro.soc.des.Application` to
static arrays once and then runs entire training episodes *inside* jit:

  * :func:`compile_app` traces an application into a flattened (dense,
    round-major) invocation schedule — phases/threads become arrays of
    ``(acc_id, footprint, tile mask, thread slot, phase id, concurrency
    mask)``.  Memory-tile striping uses the DES's rng protocol so that on
    single-thread applications the two paths see bit-identical inputs;
  * every policy family lowers into one :class:`PolicySpec` pytree — a
    per-(phase, thread, step) precomputed mode table, a ``learned`` flag
    and a (possibly placeholder) ``qlearn.QState`` — and
    :meth:`VecEnv.episode` is one ``lax.scan`` over the schedule consuming
    that spec: each step does sense (``core.state.observe``) -> select
    (epsilon-greedy Q, or the spec's precomputed mode, picked by a
    ``lax.select`` on ``learned``) -> ``memsys.invocation_perf_cached``
    timing -> reward (``core.rewards.evaluate``) -> ``core.qlearn``
    update, entirely jitted.  Because the spec is an ordinary pytree,
    *heterogeneous batches of policies* vmap along a spec axis
    (:meth:`VecEnv.episodes`, ``StackedVecEnv.episodes``) — the paper's
    design-time-vs-learned comparisons run as one call;
  * :meth:`VecEnv.train` scans episodes over training iterations, and the
    ``*_batched`` entry points ``vmap`` over (agents/seeds x reward
    weights), so the Fig. 6 reward-DSE and Fig. 8 training curves run as
    one batched call instead of N sequential DES runs;
  * a third ``vmap`` axis over **SoC configurations** lives in
    :mod:`repro.soc.stacked`: every episode/train closure here takes its
    per-SoC constants as a :class:`LaneParams` argument, so the stacked
    environment can pad K SoCs to a common shape and run them in one call
    (Fig. 9's seven SoCs x seeds x reward weights).

Scan-step hot path: the contention model needs each concurrent slot's
unconstrained ``(dram, llc)`` bytes/cycle demand, which depends only on the
slot's (mode, profile, footprint) — values that change exactly when that
slot issues a new invocation.  The step therefore keeps per-slot demand in
the scan carry and writes ("invalidates") only the slot it executes,
instead of recomputing ``memsys.dma_demand`` for every slot every step
(:func:`memsys.invocation_perf_cached` is the matching fast-path timing
signature; the self-contained one stays for the DES).  Construct
``VecEnv(..., demand_cache=False)`` to get the recompute-every-step path —
kept for the before/after comparison in ``benchmarks/vecenv_throughput.py``
and the cache-equivalence tests.

Concurrency model (the one deliberate approximation): threads of a phase
advance in lockstep *rounds*.  The invocations of round ``r`` are mutually
concurrent — thread ``t`` senses threads ``< t`` of its own round and
threads ``> t`` of round ``r-1`` — which mirrors the DES at time zero and
approximates it afterwards (the DES interleaves by continuous completion
times and serializes device collisions).  Phase wall time is the max over
threads of per-thread busy time; for single-thread phases both the
concurrency set and the wall clock are exactly the DES's, which is what
``tests/test_vecenv_equivalence.py`` pins.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn, rewards, state as cstate
from repro.core.modes import CoherenceMode, N_MODES
from repro.core.policies import EXTRA_SMALL_THRESHOLD
from repro.core.state import CacheGeometry
from repro.soc import faults as fault_mod
from repro.soc import traffic as traffic_mod
from repro.soc.accelerators import AccProfile, profile_matrix, resolve_profiles
from repro.soc.config import SoCConfig
from repro.soc.des import Application, SoCSimulator, stripe_tiles
from repro.soc.memsys import (SoCStatic, invocation_perf,
                              invocation_perf_cached, warmth_after)


class Schedule(NamedTuple):
    """Static per-step arrays of a compiled application (scan xs).

    Schedules are dense — every row is a real invocation (compile_app
    skips finished threads rather than padding rounds).  The stacked
    multi-SoC path pads lanes to a common length; ``valid`` is False on
    those padding rows (compile_app emits all-True)."""

    acc_id: jnp.ndarray      # (S,) int32
    footprint: jnp.ndarray   # (S,) float32 bytes
    tiles: jnp.ndarray       # (S, n_tiles) bool — memory-tile striping
    thread: jnp.ndarray      # (S,) int32 thread slot within the phase
    phase_id: jnp.ndarray    # (S,) int32
    fresh: jnp.ndarray       # (S,) bool — thread's first invocation in phase
    others: jnp.ndarray      # (S, T) bool — concurrently-active thread slots
    valid: jnp.ndarray       # (S,) bool — False marks stacked-padding rows


class LaneParams(NamedTuple):
    """Per-SoC constants threaded through the episode closures.

    A single :class:`VecEnv` closes over one of these; the stacked
    multi-SoC environment (:mod:`repro.soc.stacked`) stacks one per SoC
    along a leading axis and ``vmap``s the same closures over it."""

    pmat: jnp.ndarray        # (n_accs, F) accelerator profile matrix
    masks: jnp.ndarray       # (n_accs, N_MODES) action availability
    static: SoCStatic        # scalar leaves ((K,) arrays when stacked)


@dataclasses.dataclass(frozen=True)
class CompiledApp:
    """An Application lowered to static arrays plus host-side metadata."""

    name: str
    schedule: Schedule
    n_phases: int
    n_threads: int           # max thread slots across phases
    n_steps: int             # total (real, non-padding) invocations
    phase_names: tuple


def compile_app(app: Application, soc: SoCConfig, seed: int = 0) -> CompiledApp:
    """Trace ``app`` into a flattened, round-major invocation schedule.

    A thread's looped chain is unrolled; round ``r`` holds each thread's
    ``r``-th invocation.  The per-step concurrency mask encodes the lockstep
    overlap structure described in the module docstring.
    """
    rng = np.random.default_rng(seed)
    n_tiles = soc.n_mem_tiles
    max_threads = max((len(ph.threads) for ph in app.phases), default=1)

    rows: list[tuple] = []
    for ph_i, phase in enumerate(app.phases):
        progs = []
        for th in phase.threads:
            seq = []
            for _ in range(th.loops):
                seq.extend(th.chain)
            progs.append(seq)
        n_rounds = max((len(p) for p in progs), default=0)
        started = [False] * len(progs)
        for r in range(n_rounds):
            for t, prog in enumerate(progs):
                if r >= len(prog):
                    continue
                inv = prog[r]
                tiles = stripe_tiles(rng, n_tiles, inv.footprint)
                others = np.zeros(max_threads, bool)
                for j, pj in enumerate(progs):
                    if j == t:
                        continue
                    if j < t:          # already issued round r
                        others[j] = r < len(pj)
                    else:              # still running round r-1
                        others[j] = r >= 1 and (r - 1) < len(pj)
                rows.append((inv.acc_id, inv.footprint, tiles, t, ph_i,
                             not started[t], others))
                started[t] = True

    if not rows:
        raise ValueError(f"application {app.name!r} has no invocations")
    sched = Schedule(
        acc_id=jnp.asarray([r[0] for r in rows], jnp.int32),
        footprint=jnp.asarray([r[1] for r in rows], jnp.float32),
        tiles=jnp.asarray(np.stack([r[2] for r in rows])),
        thread=jnp.asarray([r[3] for r in rows], jnp.int32),
        phase_id=jnp.asarray([r[4] for r in rows], jnp.int32),
        fresh=jnp.asarray([r[5] for r in rows]),
        others=jnp.asarray(np.stack([r[6] for r in rows])),
        valid=jnp.ones((len(rows),), bool),
    )
    return CompiledApp(
        name=app.name, schedule=sched, n_phases=len(app.phases),
        n_threads=max_threads, n_steps=len(rows),
        phase_names=tuple(ph.name for ph in app.phases))


def stack_schedules(compiled: Sequence[CompiledApp]) -> Schedule:
    """Stack same-shape compiled apps along a leading axis (scan over
    training iterations, each with its own tile-striping seed)."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[c.schedule for c in compiled])


class EpisodeResult(NamedTuple):
    """Per-phase metrics plus per-invocation traces of one episode."""

    phase_time: jnp.ndarray      # (P,) seconds of wall clock
    phase_offchip: jnp.ndarray   # (P,) off-chip line accesses
    mode: jnp.ndarray            # (S,) int32 chosen coherence mode
    state_idx: jnp.ndarray       # (S,) int32 sensed Table-3 state
    exec_time: jnp.ndarray       # (S,) float32 cycles
    offchip: jnp.ndarray         # (S,) float32 line accesses
    reward: jnp.ndarray          # (S,) float32

    @property
    def total_time(self):
        return jnp.sum(self.phase_time)

    @property
    def total_offchip(self):
        return jnp.sum(self.phase_offchip)


def normalized_metrics(res: EpisodeResult, base: EpisodeResult,
                       phase_mask=None):
    """Per-phase geomean (time, offchip) normalized to a baseline episode —
    the paper's Fixed-NON_COH normalization (orchestrator._geomean_ratio).

    ``phase_mask`` (same shape as ``res.phase_time``) restricts the geomean
    to real phases when lanes of a stacked multi-SoC batch were padded to a
    common phase count."""
    lt = jnp.log(jnp.maximum(
        res.phase_time / jnp.maximum(base.phase_time, 1e-30), 1e-12))
    lm = jnp.log(jnp.maximum(
        (res.phase_offchip + 1.0)
        / jnp.maximum(base.phase_offchip + 1.0, 1e-30), 1e-12))
    if phase_mask is None:
        return jnp.exp(jnp.mean(lt)), jnp.exp(jnp.mean(lm))
    w = phase_mask.astype(lt.dtype)
    n = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.exp(jnp.sum(lt * w) / n), jnp.exp(jnp.sum(lm * w) / n)


def _manual_select(s: SoCStatic, footprint, active_modes, active_fp, avail):
    """Paper Algorithm 1 as pure jnp (mirrors policies.ManualPolicy)."""
    active = active_modes >= 0
    n_cd = jnp.sum(active & (active_modes == CoherenceMode.COH_DMA))
    n_fc = jnp.sum(active & (active_modes == CoherenceMode.FULLY_COH))
    n_nc = jnp.sum(active & (active_modes == CoherenceMode.NON_COH_DMA))
    l2 = s.l2_bytes
    llc = s.llc_slice_bytes * s.n_mem_tiles
    mode = jnp.where(
        footprint <= EXTRA_SMALL_THRESHOLD,
        CoherenceMode.FULLY_COH,
        jnp.where(
            footprint <= l2,
            jnp.where(n_cd > n_fc, CoherenceMode.FULLY_COH,
                      CoherenceMode.COH_DMA),
            jnp.where(
                footprint + active_fp > llc,
                CoherenceMode.NON_COH_DMA,
                jnp.where(n_nc >= 2, CoherenceMode.LLC_COH_DMA,
                          CoherenceMode.COH_DMA))))
    return jnp.where(avail[mode], mode, CoherenceMode.NON_COH_DMA)


class PolicySpec(NamedTuple):
    """One lowered policy — the single episode currency of every backend.

    Every policy family (fixed homogeneous/heterogeneous, manual, random,
    Q) lowers into this pytree via ``core.policies.Policy.lower``; the
    unified episode consumes nothing else.  Leaves may carry leading batch
    axes (policy batches, SoC lanes), so heterogeneous *batches of
    policies* are just stacked specs (:func:`stack_specs`).

    * ``modes`` — ``(S,)`` int32, the per-(phase, thread, step) mode table.
      For fixed policies it is ``assignment[acc_id[step]]``; for the manual
      heuristic the whole deterministic Algorithm-1 recursion is
      precomputed against the schedule (:func:`precompute_manual_modes`).
      Ignored (zeros) when ``learned``.
    * ``learned`` — ``()`` bool.  True selects epsilon-greedy Q actions via
      ``lax.select``; the non-taken branch is a few-flop row gather, so
      heterogeneous batches pay negligible dead-branch cost and XLA prunes
      nothing load-bearing when a batch is homogeneous.
    * ``qstate`` — the agent (trains in place when not frozen).  Non-
      learned specs carry ``qlearn.frozen_qstate()``: frozen makes the
      in-scan update a bitwise no-op, so one step serves every family.
    * ``qfun`` / ``mlp`` — the function-approximation branch
      (:mod:`repro.soc.nn`).  ``None`` (the default) is the tabular
      treedef every existing call site produces — those paths compile
      exactly the code they compiled before.  An MLP-lowered spec
      (:func:`mlp_policy_spec`) carries ``qfun=True`` plus the
      :class:`~repro.soc.nn.MLPQState`; the episode then selects from
      ``where(qfun, forward(wpack, features), qtable[state])`` and
      applies the semi-gradient TD update to the weight pack instead of
      the table.  Table specs that must share a treedef with MLP specs
      (stacked/heterogeneous batches) attach a frozen dead-branch
      placeholder via :func:`attach_placeholder_mlp` — ``qfun=False``
      keeps their episode results bitwise-identical to the bare spec.
    """

    modes: jnp.ndarray       # (S,) int32 precomputed per-step modes
    learned: jnp.ndarray     # () bool — Q-selection vs mode-table lookup
    qstate: qlearn.QState
    qfun: jnp.ndarray | None = None   # () bool — MLP Q-function selection
    mlp: object | None = None         # repro.soc.nn.MLPQState | None


def stack_specs(specs: Sequence[PolicySpec]) -> PolicySpec:
    """Stack lowered specs along a new leading policy axis (mixed families
    welcome — that axis is what ``episodes`` vmaps over)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *specs)


def _mask_modes(masks: jnp.ndarray, acc_id: jnp.ndarray,
                action: jnp.ndarray) -> jnp.ndarray:
    """Per-step availability fallback (unavailable -> NON_COH_DMA)."""
    avail = masks[acc_id]                                # (S, N_MODES)
    ok = jnp.take_along_axis(
        avail, action[:, None].astype(jnp.int32), axis=1)[:, 0]
    return jnp.where(ok, action,
                     int(CoherenceMode.NON_COH_DMA)).astype(jnp.int32)


def fixed_policy_spec(params: LaneParams, sched: Schedule,
                      fixed_modes) -> PolicySpec:
    """Lower a per-accelerator mode assignment (scalar broadcasts) into a
    per-step mode table."""
    n_accs = params.masks.shape[0]
    fm = jnp.broadcast_to(jnp.asarray(fixed_modes, jnp.int32), (n_accs,))
    return PolicySpec(
        modes=_mask_modes(params.masks, sched.acc_id, fm[sched.acc_id]),
        learned=jnp.zeros((), bool),
        qstate=qlearn.frozen_qstate())


def precompute_manual_modes(params: LaneParams,
                            sched: Schedule) -> jnp.ndarray:
    """Replay paper Algorithm 1 against a schedule, off the hot path.

    Manual selection depends only on the concurrent slots' (mode,
    footprint) — a deterministic recursion over the static schedule — so
    the whole mode table precomputes in one cheap ``lax.scan`` (no timing
    model, no reward).  The slot-table evolution (including ``valid``
    gating of stacked padding rows) mirrors the episode's exactly, which
    is what makes the lowered episode bitwise-identical to the old inline
    manual kind (``tests/test_policy_spec.py``)."""
    masks, s = params.masks, params.static
    T = sched.others.shape[-1]

    def step(tbl, x):
        tbl_mode, tbl_fp = tbl
        avail = masks[x.acc_id]
        omask = x.others & (tbl_mode >= 0)
        omodes = jnp.where(omask, tbl_mode, -1)
        ofps = jnp.where(omask, tbl_fp, 0.0)
        action = _manual_select(s, x.footprint, omodes, jnp.sum(ofps), avail)
        mode = jnp.where(avail[action], action,
                         CoherenceMode.NON_COH_DMA).astype(jnp.int32)
        new = (tbl_mode.at[x.thread].set(mode),
               tbl_fp.at[x.thread].set(x.footprint))
        new = jax.tree_util.tree_map(
            lambda n, o: jnp.where(x.valid, n, o), new, tbl)
        return new, mode

    tbl0 = (jnp.full((T,), -1, jnp.int32), jnp.zeros((T,), jnp.float32))
    _, modes = jax.lax.scan(step, tbl0, sched)
    return modes


_precompute_manual_modes = jax.jit(precompute_manual_modes)


def manual_policy_spec(params: LaneParams, sched: Schedule) -> PolicySpec:
    """Lower paper Algorithm 1 into a precomputed per-step mode table."""
    return PolicySpec(modes=_precompute_manual_modes(params, sched),
                      learned=jnp.zeros((), bool),
                      qstate=qlearn.frozen_qstate())


def learned_policy_spec(qstate: qlearn.QState,
                        sched: Schedule) -> PolicySpec:
    """Lower a Q agent (mode table is dead weight — zeros)."""
    return PolicySpec(modes=jnp.zeros_like(sched.acc_id),
                      learned=jnp.ones((), bool), qstate=qstate)


def mlp_policy_spec(mlp, sched: Schedule) -> PolicySpec:
    """Lower a function-approximation agent (:class:`repro.soc.nn.
    MLPQState`) — the neural analogue of :func:`learned_policy_spec`.

    The tabular slot carries a frozen placeholder (its in-scan update is
    a bitwise no-op and the episode's write guard keeps the table
    untouched on ``qfun`` specs), so the same unified step serves both
    agent families."""
    return PolicySpec(modes=jnp.zeros_like(sched.acc_id),
                      learned=jnp.zeros((), bool),
                      qstate=qlearn.frozen_qstate(),
                      qfun=jnp.ones((), bool), mlp=mlp)


def attach_placeholder_mlp(spec: PolicySpec, cfg=None) -> PolicySpec:
    """Give a table-lowered spec the MLP treedef without the MLP.

    Stacking heterogeneous specs (:func:`stack_specs`) needs a common
    pytree structure, so table specs that batch next to MLP specs carry
    a frozen zero-lr placeholder with ``qfun=False``.  The placeholder
    branch is dead — selection takes the table row, the TD gate is
    False, and the merged decay schedule reduces to the table's — so
    episode results are bitwise-identical to the bare spec (pinned by
    ``tests/test_policy_spec.py``)."""
    from repro.soc import nn as socnn
    return spec._replace(
        qfun=jnp.zeros((), bool),
        mlp=socnn.frozen_mlp_qstate(cfg or socnn.MLPConfig()))


def build_episode_fn(n_phases: int, n_threads: int,
                     cycle_time: float, demand_cache: bool = True,
                     gated: bool = False, presample_noise: bool = True,
                     ddr_attribution: bool = False,
                     fused: bool = False, debug_finite: bool = False):
    """Build THE jit-compatible episode function for a schedule geometry.

    There is one episode; policies differ only in the :class:`PolicySpec`
    they lowered into.  The returned ``episode(params, sched, spec, cfg,
    weights, key)`` closure takes its per-SoC constants as a
    :class:`LaneParams` argument so it can serve both a single
    :class:`VecEnv` (params closed over by the caller) and the stacked
    multi-SoC environment (params vmapped over a leading lane axis);
    batching over *policies* is just a vmap over the spec (and key) axes.

    ``demand_cache`` selects the fast path: per-slot (dram, llc) demand
    lives in the scan carry and only the executing slot's entry is
    rewritten each step.  ``presample_noise`` draws the whole episode's
    select noise in one batched call instead of splitting keys inside the
    scan; ``False`` restores the original per-step threefry (kept, with
    ``demand_cache=False``, as the pre-optimization reference the
    throughput benchmark measures against).  ``gated`` adds padding-row
    gating for stacked schedules: a ``valid=False`` row leaves the
    Q-table, reward extrema and slot table untouched (padding rows sit at
    the tail of a lane, so the PRNG stream of real rows is unaffected).
    ``ddr_attribution`` feeds the reward the DES's prorated per-tile DDR
    attribution instead of the invocation's true off-chip count (requires
    ``demand_cache``; traces and phase metrics stay ground-truth).

    ``fused`` swaps the inner loop for the fused-step lowering
    (:mod:`repro.kernels.soc_step`): one Q-row gather shared between
    selection and update, the (epsilon, alpha) decay and step-counter
    increments precomputed outside the scan, visits/step reconstructed
    from the trace afterwards, and per-accelerator profile/mask rows
    pregathered into the xs — a Pallas kernel on accelerator backends, a
    single tight XLA scan on CPU.  Results are bitwise-identical to the
    unfused reference step (pinned by the equivalence tests); it requires
    the ``demand_cache`` + ``presample_noise`` fast path.

    The episode closure takes an optional trailing :class:`~repro.soc.
    faults.FaultSpec` — pre-sampled per-step perturbation rows join the
    scan xs and flow into the timing model (``soc.faults`` documents the
    model and the zero-spec bitwise-identity contract).  ``debug_finite``
    adds episode-exit finiteness tripwires on the reward trace and the
    trained Q-table (``qlearn.debug_finite_check``); off by default
    because the host callback forces a device sync per episode.
    """
    if ddr_attribution and not demand_cache:
        raise ValueError("ddr_attribution requires the demand_cache step")
    if fused and not (demand_cache and presample_noise):
        raise ValueError(
            "fused_step requires demand_cache=True and presample_noise=True")
    if fused:
        return _build_fused_episode_fn(n_phases, n_threads, cycle_time,
                                       gated, ddr_attribution, debug_finite)
    T, P = n_threads, n_phases

    def episode(params: LaneParams, sched: Schedule, spec: PolicySpec, cfg,
                weights, key, faults: fault_mod.FaultSpec | None = None):
        qs0 = spec.qstate
        mlp = spec.mlp
        if mlp is not None:
            if not (demand_cache and presample_noise):
                raise ValueError(
                    "MLP PolicySpecs require the demand_cache + "
                    "presample_noise fast path (the sense features read "
                    "the cached per-slot demand)")
            from repro.soc import nn as socnn
            mlp_dims = socnn.mlp_dims(mlp.cfg)
        pmat, masks, s = params.pmat, params.masks, params.static
        n_accs = pmat.shape[0]
        n_tiles = sched.tiles.shape[-1]
        geom = CacheGeometry(
            l2_bytes=s.l2_bytes, llc_slice_bytes=s.llc_slice_bytes,
            n_mem_tiles=s.n_mem_tiles)
        warm_cap = (s.llc_slice_bytes * s.n_mem_tiles
                    + s.n_cpus * s.l2_bytes)

        def step(carry, xs):
            x, pre_mode, noise, fr = xs
            if mlp is not None:
                qs, rs, tbl, mw, mstep = carry
            elif presample_noise:
                qs, rs, tbl = carry
            else:
                qs, rs, key, tbl = carry
            if demand_cache:
                (tbl_mode, tbl_fp, tbl_tiles, warm, tbl_dram, tbl_llc,
                 tbl_fpt) = tbl
            else:
                tbl_acc, tbl_mode, tbl_fp, tbl_tiles, warm = tbl
            acc = x.acc_id
            profile = pmat[acc]
            avail = masks[acc]

            # ---- sense (paper §4.1): fixed-size active-set snapshot.
            omask = x.others & (tbl_mode >= 0)
            omodes = jnp.where(omask, tbl_mode, -1)
            ofps = jnp.where(omask, tbl_fp, 0.0)
            otiles = tbl_tiles & omask[:, None]
            # fp/|tiles| rides the carry next to the demand cache (written
            # only on slot writes); supplying it is bitwise-equal to the
            # in-observe division.
            ofpt = (jnp.where(omask, tbl_fpt, 0.0) if demand_cache
                    else None)
            state_idx = cstate.observe(
                active_modes=omodes, active_footprints=ofps,
                needed_tiles=otiles, target_tiles=x.tiles,
                target_footprint=x.footprint, geom=geom,
                active_fp_per_tile=ofpt)

            warm_t = jnp.where(x.fresh, 1.0, warm[x.thread])
            if demand_cache:
                odram = jnp.where(omask, tbl_dram, 0.0)
                ollc = jnp.where(omask, tbl_llc, 0.0)
            else:
                oprofiles = jnp.where(
                    omask[:, None], pmat[jnp.maximum(tbl_acc, 0)], 0.0)

            def env_half(action):
                """Actuate + time + evaluate for a chosen action (the
                environment half of qlearn.episode_step)."""
                # Degradation safety: a non-finite footprint (fault-
                # corrupted input) forces the non-coherent fallback mode,
                # matching the fused step's guard.  Finite footprints make
                # the extra conjunct a constant True — bitwise no-op.
                mode = jnp.where(avail[action] & jnp.isfinite(x.footprint),
                                 action,
                                 CoherenceMode.NON_COH_DMA).astype(jnp.int32)
                if demand_cache:
                    m, aux = invocation_perf_cached(
                        mode, profile, x.footprint, x.tiles, omodes, odram,
                        ollc, ofps, otiles, warm_t, s, fault=fr)
                else:
                    m, aux = invocation_perf(
                        mode, profile, x.footprint, x.tiles, omodes,
                        oprofiles, ofps, otiles, warm_t, s, fault=fr)
                off_reward = m.offchip_accesses
                if ddr_attribution:
                    # Paper §4.1(4): the monitors attribute the per-tile
                    # DDR counter delta over the invocation's window by
                    # footprint share — my prorated slice of my own plus
                    # the concurrent set's traffic on my tiles (exact when
                    # running alone; "attribution noise" under sharing).
                    myt = x.tiles.astype(jnp.float32)
                    n_my = jnp.maximum(jnp.sum(myt), 1.0)
                    o_nt = jnp.maximum(
                        jnp.sum(otiles.astype(jnp.float32), -1), 1.0)
                    my_fp_t = (x.footprint / n_my) * myt
                    o_fp_t = jnp.sum(ofpt[:, None] * otiles, 0)
                    share = my_fp_t / jnp.maximum(my_fp_t + o_fp_t, 1e-9)
                    my_bpt = (m.offchip_accesses * s.line / n_my) * myt
                    o_bpt = jnp.sum(
                        ((odram * m.exec_time) / o_nt)[:, None] * otiles, 0)
                    off_reward = (jnp.sum(share * (my_bpt + o_bpt))
                                  / s.line)
                meas = rewards.Measurement(
                    exec_time=m.exec_time, comm_cycles=m.comm_cycles,
                    total_cycles=m.total_cycles,
                    offchip_accesses=off_reward,
                    footprint=x.footprint)
                r, rs_new, _ = rewards.evaluate(rs, acc, meas, weights)
                return r, (mode, m.exec_time, m.offchip_accesses, rs_new,
                           aux["demand_dram"], aux["demand_llc"])

            # ---- decide: epsilon-greedy Q vs the spec's precomputed mode
            # (frozen placeholder qstates make the update a bitwise no-op
            # for non-learned specs, so there is exactly one step).
            if mlp is not None:
                # Function-approximation branch: the selected row is
                # where(qfun, forward(wpack, features), qtable[state]),
                # with (eps, alpha) read off the MERGED schedule — the
                # carried counter starts at where(qfun, mlp.step,
                # qs.step) and advances like the live agent's, so both
                # families share one decay stream (bitwise-equal to the
                # fused lowering's decay_arrays precomputation, and to
                # select_presampled on qfun=False specs).
                feats = socnn.step_features(
                    mlp.cfg.features, s, state_idx, footprint=x.footprint,
                    tiles=x.tiles, omask=omask, omodes=omodes, ofps=ofps,
                    odram=odram, warm_t=warm_t, profile=profile,
                    slack=jnp.float32(0.0), reuse=jnp.float32(0.0))
                raw_row = qs.qtable[state_idx]
                row_sel = jnp.where(
                    spec.qfun, socnn.forward_packed(mw, feats, mlp_dims),
                    raw_row)
                frozen_eff = jnp.where(spec.qfun, mlp.frozen, qs.frozen)
                eps_eff, alpha_eff = qlearn.schedule(cfg, mstep)
                eps_eff = jnp.where(frozen_eff, 0.0, eps_eff)
                alpha_eff = jnp.where(frozen_eff, 0.0, alpha_eff)
                q_action = qlearn.row_select_presampled(row_sel, eps_eff,
                                                        noise, avail)
                learned_eff = spec.learned | spec.qfun
            elif presample_noise:
                q_action = qlearn.select_presampled(qs, cfg, state_idx,
                                                    noise, avail)
                learned_eff = spec.learned
            else:
                key, k_sel = jax.random.split(key)
                q_action = qlearn.select(qs, cfg, state_idx, k_sel, avail)
                learned_eff = spec.learned
            action = jax.lax.select(learned_eff, q_action, pre_mode)
            r, (mode, exec_c, off, rs_new, d_dram, d_llc) = env_half(action)
            qs_new = qlearn.update(qs, cfg, state_idx, action, r)
            if mlp is not None:
                # Semi-gradient TD on the weight pack (gate self-selects
                # inside td_update_packed, so no keep-gating below); the
                # table update above is a frozen no-op on qfun specs.
                live = x.valid if gated else jnp.ones((), bool)
                upd_gate = (spec.qfun & x.valid) if gated else spec.qfun
                mw_new = socnn.td_update_packed(
                    mw, feats, action, r, alpha_eff * mlp.lr, mlp_dims,
                    upd_gate)
                mstep_new = mstep + jnp.where(live & ~frozen_eff, 1, 0
                                              ).astype(jnp.int32)

            # ---- bookkeeping: thread slot table + inter-stage warmth +
            # (fast path) this slot's cached demand.
            if demand_cache:
                tbl_new = (
                    tbl_mode.at[x.thread].set(mode),
                    tbl_fp.at[x.thread].set(x.footprint),
                    tbl_tiles.at[x.thread].set(x.tiles),
                    warm.at[x.thread].set(
                        warmth_after(mode, x.footprint, warm_cap)),
                    tbl_dram.at[x.thread].set(d_dram),
                    tbl_llc.at[x.thread].set(d_llc),
                    tbl_fpt.at[x.thread].set(
                        x.footprint / jnp.maximum(jnp.sum(x.tiles), 1)))
            else:
                tbl_new = (
                    tbl_acc.at[x.thread].set(acc),
                    tbl_mode.at[x.thread].set(mode),
                    tbl_fp.at[x.thread].set(x.footprint),
                    tbl_tiles.at[x.thread].set(x.tiles),
                    warm.at[x.thread].set(
                        warmth_after(mode, x.footprint, warm_cap)))

            if gated:
                def keep(new, old):
                    return jnp.where(x.valid, new, old)
                qs_new = jax.tree_util.tree_map(keep, qs_new, qs)
                rs_new = jax.tree_util.tree_map(keep, rs_new, rs)
                tbl_new = jax.tree_util.tree_map(keep, tbl_new, tbl)

            y = (mode, state_idx, exec_c, off, r)
            if mlp is not None:
                return (qs_new, rs_new, tbl_new, mw_new, mstep_new), y
            if presample_noise:
                return (qs_new, rs_new, tbl_new), y
            return (qs_new, rs_new, key, tbl_new), y

        if demand_cache:
            tbl0 = (jnp.full((T,), -1, jnp.int32),
                    jnp.zeros((T,), jnp.float32),
                    jnp.zeros((T, n_tiles), bool),
                    jnp.ones((T,), jnp.float32),
                    jnp.zeros((T,), jnp.float32),
                    jnp.zeros((T,), jnp.float32),
                    jnp.zeros((T,), jnp.float32))
        else:
            tbl0 = (jnp.full((T,), -1, jnp.int32),
                    jnp.full((T,), -1, jnp.int32),
                    jnp.zeros((T,), jnp.float32),
                    jnp.zeros((T, n_tiles), bool),
                    jnp.ones((T,), jnp.float32))
        # Episode randomness is pre-sampled in one batched threefry call —
        # per-step split/categorical inside the scan would dominate the
        # step cost (see qlearn.SelectNoise).  The draw matches the old
        # q-kind episode bit for bit; non-learned specs discard the
        # selection, so their results are key-independent.
        n_steps = sched.acc_id.shape[0]
        if presample_noise:
            noise = qlearn.sample_select_noise(
                key, (n_steps,), masks.shape[-1])
        else:
            noise = qlearn.SelectNoise(
                u_explore=jnp.zeros((n_steps,), jnp.float32),
                g_pick=jnp.zeros((n_steps, 0), jnp.float32),
                g_tie=jnp.zeros((n_steps, 0), jnp.float32))
        # Per-step fault rows are pre-sampled from the spec's OWN key
        # (soc.faults), so the episode's main key stream is untouched and
        # ``faults=None`` stays bitwise-identical to today's path (None
        # scans as an empty pytree — the step sees fr is None).
        frows = (None if faults is None
                 else fault_mod.sample_fault_arrays(faults, sched.acc_id))
        rs0 = rewards.init_reward_state(n_accs)
        if mlp is not None:
            carry = (qs0, rs0, tbl0, mlp.wpack,
                     jnp.where(spec.qfun, mlp.step, qs0.step))
        else:
            carry = ((qs0, rs0, tbl0) if presample_noise
                     else (qs0, rs0, key, tbl0))
        carry, ys = jax.lax.scan(step, carry,
                                 (sched, spec.modes, noise, frows))
        mode, state_idx, exec_c, off, rew = ys
        if debug_finite:
            qlearn.debug_finite_check(
                "vecenv.episode", reward=rew, qtable=carry[0].qtable)

        # Per-phase wall clock: max over threads of per-thread busy time
        # (threads chain serially; phases are sequential).  Padding rows
        # contribute nothing.
        secs = jnp.where(sched.valid, exec_c, 0.0) * cycle_time
        off_real = jnp.where(sched.valid, off, 0.0)
        per_thread = jnp.zeros((P, T), secs.dtype).at[
            sched.phase_id, sched.thread].add(secs)
        phase_time = jnp.max(per_thread, axis=1)
        phase_off = jnp.zeros((P,), off_real.dtype).at[
            sched.phase_id].add(off_real)
        res = EpisodeResult(
            phase_time=phase_time, phase_offchip=phase_off, mode=mode,
            state_idx=state_idx, exec_time=exec_c, offchip=off,
            reward=rew)
        if mlp is not None:
            # MLP-treedef specs return BOTH trained agents; the merged
            # counter only lands in the mlp when it drove the schedule.
            mlp_final = mlp._replace(
                wpack=carry[3],
                step=jnp.where(spec.qfun, carry[4], mlp.step))
            return (carry[0], mlp_final), res
        return carry[0], res

    return episode


def _build_fused_episode_fn(n_phases: int, n_threads: int,
                            cycle_time: float, gated: bool,
                            ddr_attribution: bool,
                            debug_finite: bool = False):
    """The fused-step lowering of :func:`build_episode_fn` (its ``fused``
    paragraph documents the semantics).  The step itself lives in
    :mod:`repro.kernels.soc_step`; this closure owns the episode-level
    pre/post work: noise + decay-schedule precomputation, the profile/mask
    pregather, visits/step replay, and the per-phase metric tail (shared
    verbatim with the unfused episode).  Imported lazily to keep
    ``soc.vecenv`` importable without the kernels package on odd installs.
    """
    from repro.kernels.soc_step import ops as soc_step_ops
    from repro.kernels.soc_step.ref import StepInputs

    T, P = n_threads, n_phases

    def episode(params: LaneParams, sched: Schedule, spec: PolicySpec, cfg,
                weights, key, faults: fault_mod.FaultSpec | None = None):
        qs0 = spec.qstate
        mlp = spec.mlp
        pmat, masks, s = params.pmat, params.masks, params.static
        n_accs = pmat.shape[0]
        n_steps = sched.acc_id.shape[0]

        # Same one-call noise protocol as the unfused episode — identical
        # key consumption, so fused and unfused draw identical variates.
        noise = qlearn.sample_select_noise(key, (n_steps,), masks.shape[-1])
        # Counter increments the in-scan update would apply: zero on frozen
        # agents and (gated schedules) on padding rows.  MLP-treedef specs
        # precompute the MERGED schedule — the live agent's (step0, frozen)
        # drive the decay, and the increments are split afterwards so each
        # family's counter only advances when it drove the episode.  With
        # qfun=False (placeholder MLP) the merge selects the table's
        # values, so eps_t/alpha_t/inc are bitwise the tabular ones.
        live = sched.valid if gated else jnp.ones_like(sched.valid)
        if mlp is None:
            step0_eff, frozen_eff = qs0.step, qs0.frozen
        else:
            step0_eff = jnp.where(spec.qfun, mlp.step, qs0.step)
            frozen_eff = jnp.where(spec.qfun, mlp.frozen, qs0.frozen)
        inc = (live & ~frozen_eff).astype(jnp.int32)
        eps_t, alpha_t = qlearn.decay_arrays(cfg, step0_eff, frozen_eff,
                                             inc)
        # Fault rows ride four trailing xs columns (same pre-sampled draw
        # as the unfused scan, so the lowerings stay bitwise-equal).
        frow = {}
        if faults is not None:
            fr = fault_mod.sample_fault_arrays(faults, sched.acc_id)
            frow = dict(f_exec=fr.exec_scale, f_ddr=fr.ddr_scale,
                        f_llc=fr.llc_extra, f_retry=fr.retry_cycles)
        xs = StepInputs(
            acc_id=sched.acc_id, footprint=sched.footprint,
            tiles=sched.tiles, thread=sched.thread, fresh=sched.fresh,
            others=sched.others, valid=sched.valid, pre_mode=spec.modes,
            profile=pmat[sched.acc_id], avail=masks[sched.acc_id],
            eps=eps_t, alpha=alpha_t, u_explore=noise.u_explore,
            g_pick=noise.g_pick, g_tie=noise.g_tie, **frow)
        if mlp is None:
            qtable, ys = soc_step_ops.fused_episode(
                s, spec.learned, weights, qs0.qtable,
                rewards.init_reward_state(n_accs).extrema, xs,
                ddr_attribution=ddr_attribution, gated=gated)
            inc_tbl = inc
        else:
            qtable, wpack, ys = soc_step_ops.fused_episode(
                s, spec.learned, weights, qs0.qtable,
                rewards.init_reward_state(n_accs).extrema, xs,
                ddr_attribution=ddr_attribution, gated=gated,
                qfun=spec.qfun, mlp=mlp)
            inc_tbl = jnp.where(spec.qfun, 0, inc)
        mode, state_idx, action, exec_c, off, rew = ys
        qs_final = qlearn.replay_visits(qs0, qtable, state_idx, action,
                                        inc_tbl)
        if debug_finite:
            qlearn.debug_finite_check(
                "vecenv.episode", reward=rew, qtable=qs_final.qtable)

        # Per-phase metric tail — identical to the unfused episode's.
        secs = jnp.where(sched.valid, exec_c, 0.0) * cycle_time
        off_real = jnp.where(sched.valid, off, 0.0)
        per_thread = jnp.zeros((P, T), secs.dtype).at[
            sched.phase_id, sched.thread].add(secs)
        phase_time = jnp.max(per_thread, axis=1)
        phase_off = jnp.zeros((P,), off_real.dtype).at[
            sched.phase_id].add(off_real)
        res = EpisodeResult(
            phase_time=phase_time, phase_offchip=phase_off, mode=mode,
            state_idx=state_idx, exec_time=exec_c, offchip=off,
            reward=rew)
        if mlp is not None:
            mlp_final = mlp._replace(
                wpack=wpack,
                step=mlp.step + jnp.sum(jnp.where(spec.qfun, inc, 0)))
            return (qs_final, mlp_final), res
        return qs_final, res

    return episode


class TrainCarry(NamedTuple):
    """Cross-iteration training state beyond the Q-state itself.

    Threading it explicitly (instead of a bare PRNG key) is what makes
    training *chunkable*: ``VecEnv.train_batched_checkpointed`` carries a
    ``(QState, TrainCarry)`` pair across host-side chunks and the resumed
    scan continues bitwise-exactly where the interrupted one stopped.

    * ``key`` — (2,) uint32 main episode key stream (split 3 ways per
      iteration, exactly as before the refactor);
    * ``it`` — () int32 global iteration index.  Fault-injected training
      folds it into the FaultSpec's own key so every iteration draws fresh
      drop coins without touching the main stream;
    * ``best`` — () float32 running best mean episode reward, feeding the
      reward-collapse watchdog (``qlearn.reward_watchdog``).
    """

    key: jnp.ndarray
    it: jnp.ndarray
    best: jnp.ndarray


def init_train_carry(key) -> TrainCarry:
    return TrainCarry(key=key, it=jnp.zeros((), jnp.int32),
                      best=jnp.full((), -jnp.inf, jnp.float32))


def build_train_fn(n_phases: int, n_threads: int, eval_shape,
                   cycle_time: float, demand_cache: bool = True,
                   gated: bool = False, presample_noise: bool = True,
                   ddr_attribution: bool = False, fused: bool = False,
                   debug_finite: bool = False):
    """Build ``train_one(params, train_scheds, eval_sched, base, phase_mask,
    cfg, weights, carry0, q0, faults)``: a scan of training episodes over
    iterations, optionally evaluating the frozen policy each iteration
    against the NON_COH baseline (Fig. 8).  Like :func:`build_episode_fn`
    it is parameterized over :class:`LaneParams` so the stacked environment
    can vmap SoC lanes over it.

    ``carry0`` is a :class:`TrainCarry`; the function returns ``(qs,
    carry_out, hist)`` so chunked (checkpointed) training can resume
    mid-scan.  ``faults`` perturbs both the training and the evaluation
    episodes; its key is re-derived per iteration from ``carry.it``.
    """
    episode = build_episode_fn(n_phases, n_threads, cycle_time,
                               demand_cache, gated, presample_noise,
                               ddr_attribution, fused, debug_finite)
    eval_episode = (build_episode_fn(eval_shape[0], eval_shape[1],
                                     cycle_time, demand_cache, gated,
                                     presample_noise, ddr_attribution,
                                     fused, debug_finite)
                    if eval_shape is not None else None)

    def train_one(params, train_scheds, eval_sched, base, phase_mask, cfg,
                  weights, carry0, q0, faults=None):
        def body(carry, sched_i):
            qs, tc = carry
            key, k_train, k_eval = jax.random.split(tc.key, 3)
            f_i = None
            if faults is not None:
                f_i = faults._replace(
                    key=jax.random.fold_in(faults.key, tc.it))
            qs, er = episode(params, sched_i,
                             learned_policy_spec(qs, sched_i), cfg,
                             weights, k_train, f_i)
            # Reward-collapse watchdog (qlearn.reward_watchdog): mean
            # per-invocation reward of the training episode vs the best
            # seen.  A no-op unless cfg.collapse_frac > 0.
            valid = sched_i.valid
            ep_r = (jnp.sum(jnp.where(valid, er.reward, 0.0))
                    / jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0))
            qs, best = qlearn.reward_watchdog(cfg, qs, ep_r, tc.best)
            if eval_sched is not None:
                _, er2 = eval_episode(
                    params, eval_sched,
                    learned_policy_spec(qlearn.freeze(qs), eval_sched),
                    cfg, weights, k_eval, f_i)
                out = normalized_metrics(er2, base, phase_mask)
            else:
                out = (jnp.float32(0.0), jnp.float32(0.0))
            tc = TrainCarry(key=key, it=tc.it + 1, best=best)
            return (qs, tc), out

        (qs, tc), hist = jax.lax.scan(body, (q0, carry0), train_scheds)
        return qs, tc, hist

    return train_one


class VecEnv:
    """Fully-jitted batched SoC environment over one SoC + accelerator set.

    Mirrors :class:`~repro.soc.des.SoCSimulator`'s construction (same
    profile resolution, action masks and timing constants) so the two paths
    are directly comparable; ``VecEnv.from_simulator`` shares an existing
    simulator's resolved profiles.

    ``demand_cache=True`` (the default) runs the carry-cached scan step;
    ``False`` recomputes every slot's demand each step (the pre-cache hot
    path, kept for benchmarking and equivalence tests — results are
    identical, see ``tests/test_vecenv_equivalence.py``).
    ``presample_noise=False`` additionally restores per-step RNG splitting;
    together with ``demand_cache=False`` that is the original (pre-
    optimization) scan step, the "before" of
    ``benchmarks/vecenv_throughput.py``.  ``ddr_attribution=True`` trains
    rewards on the DES's prorated DDR attribution instead of true
    per-invocation off-chip counts (measured in ``fig8_training``).

    ``fused_step`` selects the :mod:`repro.kernels.soc_step` episode
    lowering (shared Q-row gather, out-of-scan decay schedule, Q-table-only
    carry; a Pallas kernel on accelerator backends).  ``None`` (default)
    auto-enables it whenever the fast path it fuses is active
    (``demand_cache and presample_noise``) — results are bitwise-identical
    to the unfused step, so only benchmarks and equivalence tests pass an
    explicit ``False``.
    """

    def __init__(self, soc: SoCConfig,
                 profiles: Sequence[AccProfile] | None = None,
                 seed: int = 0, flavor: str = "mixed",
                 cycle_time: float = 1e-8,
                 demand_cache: bool = True,
                 presample_noise: bool = True,
                 ddr_attribution: bool = False,
                 fused_step: bool | None = None,
                 debug_finite: bool = False):
        self.soc = soc
        rng = np.random.default_rng(seed)
        self.profiles = list(profiles) if profiles is not None else (
            resolve_profiles(soc.accelerators, rng, flavor))
        assert len(self.profiles) == soc.n_accs
        self.pmat = jnp.asarray(profile_matrix(self.profiles))
        self.static = SoCStatic.from_config(soc)
        self.geom = soc.geometry
        self.cycle_time = float(cycle_time)
        self.demand_cache = bool(demand_cache)
        self.presample_noise = bool(presample_noise)
        self.ddr_attribution = bool(ddr_attribution)
        if self.ddr_attribution and not self.demand_cache:
            raise ValueError("ddr_attribution requires demand_cache=True")
        if fused_step is None:
            fused_step = self.demand_cache and self.presample_noise
        elif fused_step and not (self.demand_cache
                                 and self.presample_noise):
            raise ValueError("fused_step requires demand_cache=True and "
                             "presample_noise=True")
        self.fused_step = bool(fused_step)
        self.debug_finite = bool(debug_finite)
        masks = np.ones((soc.n_accs, N_MODES), bool)
        for i in soc.no_private_cache:
            masks[i, CoherenceMode.FULLY_COH] = False
        self.masks = jnp.asarray(masks)
        self.params = LaneParams(pmat=self.pmat, masks=self.masks,
                                 static=self.static)
        self._episode_cache: dict = {}
        self._train_cache: dict = {}

    @classmethod
    def from_simulator(cls, sim: SoCSimulator,
                       cycle_time: float = 1e-8,
                       demand_cache: bool = True,
                       presample_noise: bool = True,
                       ddr_attribution: bool = False,
                       fused_step: bool | None = None,
                       debug_finite: bool = False) -> "VecEnv":
        return cls(sim.soc, profiles=sim.profiles, cycle_time=cycle_time,
                   demand_cache=demand_cache,
                   presample_noise=presample_noise,
                   ddr_attribution=ddr_attribution,
                   fused_step=fused_step,
                   debug_finite=debug_finite)

    # ------------------------------------------------------------ episode
    def _episode_fn(self, n_phases: int, n_threads: int):
        """Build (and cache) the spec-consuming episode closure (params
        pre-bound).  One closure per schedule geometry serves every policy
        family — the jit cache no longer keys on a policy kind."""
        cache_key = ("ep", n_phases, n_threads)
        if cache_key in self._episode_cache:
            return self._episode_cache[cache_key]
        base_fn = build_episode_fn(n_phases, n_threads,
                                   self.cycle_time, self.demand_cache,
                                   presample_noise=self.presample_noise,
                                   ddr_attribution=self.ddr_attribution,
                                   fused=self.fused_step,
                                   debug_finite=self.debug_finite)
        params = self.params

        def episode(sched, spec, cfg, weights, key, faults=None):
            return base_fn(params, sched, spec, cfg, weights, key, faults)

        self._episode_cache[cache_key] = episode
        return episode

    # -------------------------------------------------------- spec lowering
    def lower(self, compiled: CompiledApp, policy: str = "q",
              qstate: qlearn.QState | None = None,
              fixed_modes=None,
              cfg: qlearn.QConfig | None = None) -> PolicySpec:
        """Lower a policy-kind shorthand onto ``compiled``'s schedule.

        Prefer ``Policy.lower(env, compiled)`` on a real policy object;
        this keeps the string shorthand (`'q' | 'fixed' | 'manual'`) for
        tests and quick calls.  ``cfg`` shapes a fresh Q-state when
        ``policy='q'`` and no ``qstate`` is given (table shape and
        ``q_init`` must come from the cfg the episode will run with)."""
        if policy == "q":
            qstate = (qstate if qstate is not None
                      else qlearn.init_qstate(cfg or qlearn.QConfig()))
            return learned_policy_spec(qstate, compiled.schedule)
        if policy == "fixed":
            if fixed_modes is None:
                fixed_modes = CoherenceMode.NON_COH_DMA
            return fixed_policy_spec(self.params, compiled.schedule,
                                     fixed_modes)
        if policy == "manual":
            return manual_policy_spec(self.params, compiled.schedule)
        raise ValueError(f"unknown policy kind {policy!r}")

    # ----------------------------------------------------- public episodes
    def episode_spec(self, compiled: CompiledApp, spec: PolicySpec,
                     cfg: qlearn.QConfig | None = None,
                     weights: rewards.RewardWeights | None = None,
                     key=None,
                     faults: fault_mod.FaultSpec | None = None
                     ) -> tuple[qlearn.QState, EpisodeResult]:
        """Run one lowered :class:`PolicySpec` episode under jit.

        MLP-treedef specs (``spec.mlp is not None``) return ``((qstate,
        mlp), result)`` — both trained agents — instead of ``(qstate,
        result)``."""
        cfg = cfg or qlearn.QConfig()
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        key = key if key is not None else jax.random.PRNGKey(0)
        jit_key = ("jit", compiled.n_phases, compiled.n_threads)
        if jit_key not in self._episode_cache:
            self._episode_cache[jit_key] = jax.jit(self._episode_fn(
                compiled.n_phases, compiled.n_threads))
        return self._episode_cache[jit_key](
            compiled.schedule, spec, cfg, weights, key, faults)

    def episode(self, compiled: CompiledApp, *, policy: str = "q",
                qstate: qlearn.QState | None = None,
                cfg: qlearn.QConfig | None = None,
                fixed_modes=None,
                weights: rewards.RewardWeights | None = None,
                key=None,
                faults: fault_mod.FaultSpec | None = None
                ) -> tuple[qlearn.QState, EpisodeResult]:
        """Run one episode under jit (shorthand over :meth:`episode_spec`).
        ``policy``:

        * ``'q'`` — the Cohmeleon agent (``qstate`` trains in place unless
          frozen);
        * ``'fixed'`` — per-accelerator mode array (scalar broadcasts), the
          fixed-homogeneous/heterogeneous baselines;
        * ``'manual'`` — paper Algorithm 1.
        """
        spec = self.lower(compiled, policy, qstate=qstate,
                          fixed_modes=fixed_modes, cfg=cfg)
        return self.episode_spec(compiled, spec, cfg=cfg, weights=weights,
                                 key=key, faults=faults)

    def episodes(self, compiled: CompiledApp, specs: PolicySpec,
                 cfg: qlearn.QConfig | None = None,
                 weights: rewards.RewardWeights | None = None,
                 keys=None,
                 faults: fault_mod.FaultSpec | None = None) -> EpisodeResult:
        """A heterogeneous batch of lowered policies on one app, one call.

        ``specs`` leaves carry a leading (N,) policy axis
        (:func:`stack_specs`); returns an :class:`EpisodeResult` with
        (N, ...) leaves.  This is what lets ``compare_policies`` replay a
        whole suite — fixed baselines, manual, random, Cohmeleon — as a
        single jitted call."""
        cfg = cfg or qlearn.QConfig()
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        n = specs.learned.shape[0]
        if keys is None:
            keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n))
        cache_key = ("specs_jit", compiled.n_phases, compiled.n_threads)
        if cache_key not in self._episode_cache:
            ep = self._episode_fn(compiled.n_phases, compiled.n_threads)

            def one(sched, spec, cfg_, w, key, f):
                _, res = ep(sched, spec, cfg_, w, key, f)
                return res

            # faults replicate across the policy batch (in_axes None): one
            # FaultSpec perturbs every lowered policy identically.
            self._episode_cache[cache_key] = jax.jit(jax.vmap(
                one, in_axes=(None, 0, None, None, 0, None)))
        return self._episode_cache[cache_key](compiled.schedule, specs,
                                              cfg, weights, keys, faults)

    def baseline_episode(self, compiled: CompiledApp,
                         faults: fault_mod.FaultSpec | None = None
                         ) -> EpisodeResult:
        """Fixed NON_COH_DMA episode — the paper's normalization baseline."""
        _, res = self.episode(compiled, policy="fixed",
                              fixed_modes=CoherenceMode.NON_COH_DMA,
                              faults=faults)
        return res

    # ------------------------------------------------------------ training
    def _train_fn(self, n_phases: int, n_threads: int, eval_shape):
        cache_key = (n_phases, n_threads, eval_shape)
        if cache_key in self._train_cache:
            return self._train_cache[cache_key]
        base_fn = build_train_fn(n_phases, n_threads, eval_shape,
                                 self.cycle_time, self.demand_cache,
                                 presample_noise=self.presample_noise,
                                 ddr_attribution=self.ddr_attribution,
                                 fused=self.fused_step,
                                 debug_finite=self.debug_finite)
        params = self.params

        def train_one(train_scheds, eval_sched, base, cfg, weights, carry,
                      q0, faults=None):
            return base_fn(params, train_scheds, eval_sched, base, None,
                           cfg, weights, carry, q0, faults)

        # Cache the jitted single-agent and vmapped variants so repeated
        # calls (benchmark timing loops, sweeps) hit the jit cache instead
        # of retracing.  ``None`` eval args trace as empty pytrees, so one
        # callable serves both the eval and no-eval protocols (and None
        # faults the no-fault protocol).  Per-agent carry leaves batch
        # (key, best); the iteration counter and the FaultSpec replicate —
        # every agent sees the same fault storm.
        batched = jax.vmap(
            train_one,
            in_axes=(None, None, None, None,
                     rewards.RewardWeights(0, 0, 0),
                     TrainCarry(key=0, it=None, best=0), 0, None),
            out_axes=(0, TrainCarry(key=0, it=None, best=0), 0))
        fns = (jax.jit(train_one), jax.jit(batched))
        self._train_cache[cache_key] = fns
        return fns

    @staticmethod
    def _batched_carry(keys) -> TrainCarry:
        b = keys.shape[0]
        return TrainCarry(key=jnp.asarray(keys),
                          it=jnp.zeros((), jnp.int32),
                          best=jnp.full((b,), -jnp.inf, jnp.float32))

    def train(self, train_apps: Sequence[CompiledApp],
              cfg: qlearn.QConfig,
              weights: rewards.RewardWeights | None = None,
              key=None,
              eval_app: CompiledApp | None = None,
              faults: fault_mod.FaultSpec | None = None
              ) -> tuple[qlearn.QState, tuple]:
        """Train one agent: scan over per-iteration schedules (each compiled
        with its own tile seed, like the DES's per-iteration run seeds)."""
        scheds = stack_schedules(train_apps)
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        key = key if key is not None else jax.random.PRNGKey(0)
        eval_sched = eval_app.schedule if eval_app is not None else None
        base = (self.baseline_episode(eval_app, faults=faults)
                if eval_app is not None else None)
        single, _ = self._train_fn(
            train_apps[0].n_phases, train_apps[0].n_threads,
            None if eval_app is None else
            (eval_app.n_phases, eval_app.n_threads))
        qs, _, hist = single(scheds, eval_sched, base, cfg, weights,
                             init_train_carry(key), qlearn.init_qstate(cfg),
                             faults)
        return qs, hist

    def train_batched(self, train_apps: Sequence[CompiledApp],
                      cfg: qlearn.QConfig,
                      weights_batch: rewards.RewardWeights,
                      keys,
                      eval_app: CompiledApp | None = None,
                      faults: fault_mod.FaultSpec | None = None
                      ) -> tuple[qlearn.QState, tuple]:
        """Train B agents in one call: ``vmap`` over (reward weights, PRNG
        key) pairs.  ``weights_batch`` has (B,) leaves (rewards.stack_weights)
        and ``keys`` is (B, 2).  Returns a batched QState (leaves with
        leading axis B) and, when ``eval_app`` is given, per-iteration
        (norm_time, norm_mem) histories of shape (B, iterations)."""
        scheds = stack_schedules(train_apps)
        eval_sched = eval_app.schedule if eval_app is not None else None
        base = (self.baseline_episode(eval_app, faults=faults)
                if eval_app is not None else None)
        _, batched = self._train_fn(
            train_apps[0].n_phases, train_apps[0].n_threads,
            None if eval_app is None else
            (eval_app.n_phases, eval_app.n_threads))
        q0 = qlearn.init_qstate_batch(cfg, keys.shape[0])
        qs, _, hist = batched(scheds, eval_sched, base, cfg, weights_batch,
                              self._batched_carry(keys), q0, faults)
        return qs, hist

    def train_batched_checkpointed(self, train_apps: Sequence[CompiledApp],
                                   cfg: qlearn.QConfig,
                                   weights_batch: rewards.RewardWeights,
                                   keys, manager, *,
                                   ckpt_every: int = 1,
                                   eval_app: CompiledApp | None = None,
                                   faults: fault_mod.FaultSpec | None = None
                                   ) -> tuple[qlearn.QState, tuple]:
        """Crash-resumable :meth:`train_batched`.

        Training runs in host-side chunks of ``ckpt_every`` iterations;
        after each chunk the ``(QState, TrainCarry, history)`` snapshot is
        saved through ``manager`` (a ``checkpoint.CheckpointManager``).  On
        entry, the latest restorable checkpoint (if any) is loaded and
        training continues from that iteration — the scan is sequential and
        the carry crosses chunk boundaries unchanged, so an interrupted +
        resumed run returns final Q-tables (and histories) bitwise-equal
        to an uninterrupted :meth:`train_batched` with the same arguments
        (pinned by ``tests/test_train_checkpoint.py``).

        History arrays are preallocated at the full (B, iterations) shape
        and written chunk by chunk, so checkpoints have a fixed tree
        structure regardless of when they were taken.
        """
        iters = len(train_apps)
        if ckpt_every < 1:
            raise ValueError("ckpt_every must be >= 1")
        scheds = stack_schedules(train_apps)
        eval_sched = eval_app.schedule if eval_app is not None else None
        base = (self.baseline_episode(eval_app, faults=faults)
                if eval_app is not None else None)
        _, batched = self._train_fn(
            train_apps[0].n_phases, train_apps[0].n_threads,
            None if eval_app is None else
            (eval_app.n_phases, eval_app.n_threads))
        b = keys.shape[0]
        qs = qlearn.init_qstate_batch(cfg, b)
        carry = self._batched_carry(keys)
        hist_t = jnp.zeros((b, iters), jnp.float32)
        hist_m = jnp.zeros((b, iters), jnp.float32)
        done = 0

        if manager.latest_step() is not None:
            state = manager.restore({
                "qstate": qs, "carry": carry,
                "hist_t": hist_t, "hist_m": hist_m,
                "done": jnp.zeros((), jnp.int32)})
            qs, carry = state["qstate"], state["carry"]
            hist_t, hist_m = state["hist_t"], state["hist_m"]
            done = int(state["done"])

        while done < iters:
            n = min(ckpt_every, iters - done)
            chunk = jax.tree_util.tree_map(
                lambda x: x[done:done + n], scheds)
            qs, carry, (ht, hm) = batched(chunk, eval_sched, base, cfg,
                                          weights_batch, carry, qs, faults)
            hist_t = hist_t.at[:, done:done + n].set(ht)
            hist_m = hist_m.at[:, done:done + n].set(hm)
            done += n
            manager.save(done, {
                "qstate": qs, "carry": carry,
                "hist_t": hist_t, "hist_m": hist_m,
                "done": jnp.asarray(done, jnp.int32)})
        manager.wait()
        return qs, (hist_t, hist_m)

    def evaluate_batched(self, compiled: CompiledApp,
                         qstates: qlearn.QState,
                         cfg: qlearn.QConfig,
                         keys,
                         faults: fault_mod.FaultSpec | None = None
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Frozen-greedy evaluation of B agents on one app in one call;
        returns (norm_time, norm_mem) of shape (B,) vs the NON_COH base
        (itself run under the same ``faults``, so the ratios isolate the
        policy's contribution from the storm's)."""
        base = self.baseline_episode(compiled, faults=faults)
        cache_key = ("batched_eval", compiled.n_phases, compiled.n_threads)
        if cache_key not in self._train_cache:
            episode = self._episode_fn(compiled.n_phases,
                                       compiled.n_threads)
            # rewards don't steer a frozen agent; any weights do
            w = rewards.PAPER_DEFAULT_WEIGHTS

            def eval_one(sched, base_, cfg_, qs, key, f):
                spec = learned_policy_spec(qlearn.freeze(qs), sched)
                _, er = episode(sched, spec, cfg_, w, key, f)
                return normalized_metrics(er, base_)

            self._train_cache[cache_key] = jax.jit(jax.vmap(
                eval_one, in_axes=(None, None, None, 0, 0, None)))
        return self._train_cache[cache_key](compiled.schedule, base, cfg,
                                            qstates, keys, faults)


# ===================================================================== serving
class ServeResult(NamedTuple):
    """Per-request traces of one serving chunk ((n_requests,) leaves).

    Every offered request gets a row; shed requests carry ``executed=
    False``, ``-1`` mode/state/action and zeroed timing columns.  Times
    are simulated cycles (multiply by ``cycle_time`` for seconds);
    ``retries`` counts backed-off admission attempts (``faults.
    FAULT_MAX_RETRIES + 1`` marks a shed request)."""

    t_arr: jnp.ndarray      # (n,) f32 arrival time
    tenant: jnp.ndarray     # (n,) i32
    mode: jnp.ndarray       # (n,) i32 (-1 = shed)
    state_idx: jnp.ndarray  # (n,) i32 (-1 = shed)
    action: jnp.ndarray     # (n,) i32 (-1 = shed)
    exec_time: jnp.ndarray  # (n,) f32 cycles
    offchip: jnp.ndarray    # (n,) f32 line accesses
    reward: jnp.ndarray     # (n,) f32
    executed: jnp.ndarray   # (n,) bool — admitted and served
    latency: jnp.ndarray    # (n,) f32 finish - arrival (0 when shed)
    retries: jnp.ndarray    # (n,) f32 admission attempts used
    depth: jnp.ndarray      # (n,) f32 victim queue depth at arrival
    degraded: jnp.ndarray   # (n,) bool — served under forced NON_COH
    start: jnp.ndarray      # (n,) f32 admitted start time
    finish: jnp.ndarray     # (n,) f32 admitted finish time

    @property
    def served(self):
        return jnp.sum(self.executed.astype(jnp.int32))

    @property
    def shed(self):
        return self.t_arr.shape[-1] - self.served

    @property
    def t_end(self):
        return self.t_arr[..., -1]


# (leaf dtype per ServeResult field — preallocating fixed checkpoint trees)
_SERVE_RESULT_DTYPES = (
    jnp.float32, jnp.int32, jnp.int32, jnp.int32, jnp.int32, jnp.float32,
    jnp.float32, jnp.float32, jnp.bool_, jnp.float32, jnp.float32,
    jnp.float32, jnp.bool_, jnp.float32, jnp.float32)


def _zero_serve_results(n_chunks: int, n_requests: int) -> ServeResult:
    return ServeResult(*(jnp.zeros((n_chunks, n_requests), dt)
                         for dt in _SERVE_RESULT_DTYPES))


def build_serve_fn(n_requests: int, queue_cap: int,
                   ddr_attribution: bool = False, fused: bool = True,
                   debug_finite: bool = False):
    """Build the jit-compatible serving-chunk function.

    The returned ``serve(params, sched, spec, cfg, weights, tspec, carry,
    key, t0, faults)`` runs one chunk of ``n_requests`` offered arrivals
    (``traffic.sample_arrivals`` over the compiled schedule's rows)
    through the fused serving step (:func:`repro.kernels.soc_step.ops.
    fused_serve_episode`): bounded per-accelerator admission queues of
    ``queue_cap`` slots, deadline shedding, retry-with-backoff and the
    overload watchdog — semantics in ``kernels.soc_step.ref.serve_step``.

    Like the episodic closures it takes :class:`LaneParams` first so the
    stacked environment can vmap SoC lanes over it.  Every ``tspec``
    (:class:`~repro.soc.traffic.TrafficSpec`) leaf is traced — offered-
    load sweeps reuse the compiled program.  ``carry=None`` starts a
    fresh stream (idle devices, the spec's Q-table); passing the returned
    :class:`~repro.kernels.soc_step.ref.ServeCarry` back in (with ``t0``
    = the previous chunk's last arrival time) continues it bitwise, which
    is what makes serving checkpointable mid-stream.

    Returns ``(carry, qstate, ServeResult)``; the Q-state is rebuilt from
    the carry (table + watchdog-rewound step counter) plus a visits
    replay over the executed rows, mirroring the fused episode's
    ``qlearn.replay_visits`` contract.  MLP specs (``spec.mlp``) serve
    through the same step — their trained weights ride ``carry.wpack``
    (rebuild the agent with ``mlp._replace(wpack=carry.wpack,
    step=carry.step)``); the returned placeholder ``qstate`` stays
    frozen and untouched.
    """
    from repro.kernels.soc_step import ops as soc_step_ops
    from repro.kernels.soc_step.ref import (SERVE_YCOLS, ServeParams,
                                            StepInputs, init_serve_carry)
    f32 = jnp.float32

    def serve(params: LaneParams, sched: Schedule, spec: PolicySpec, cfg,
              weights, tspec: traffic_mod.TrafficSpec, carry, key, t0,
              faults: fault_mod.FaultSpec | None = None, n_real=None):
        pmat, masks, s = params.pmat, params.masks, params.static
        n_accs = pmat.shape[0]
        # Row sampling spans the lane's REAL rows: stacked lanes pad
        # schedules with valid=False tail rows a request must never
        # invoke, so they pass their real length as a traced ``n_real``.
        n_rows = sched.acc_id.shape[0] if n_real is None else n_real
        qs0 = spec.qstate
        mlp = spec.mlp
        arr = traffic_mod.sample_arrivals(tspec, n_requests, n_rows, t0)
        acc = sched.acc_id[arr.row]

        # Same one-call select-noise protocol as the episodes; faults are
        # pre-sampled against the *request* accelerator stream, so a storm
        # during a load spike composes with admission per-request.
        noise = qlearn.sample_select_noise(key, (n_requests,),
                                           masks.shape[-1])
        frow = {}
        if faults is not None:
            fr = fault_mod.sample_fault_arrays(faults, acc)
            frow = dict(f_exec=fr.exec_scale, f_ddr=fr.ddr_scale,
                        f_llc=fr.llc_extra, f_retry=fr.retry_cycles)
        # thread/fresh/others/valid/eps/alpha are serve-step-owned
        # placeholders (see serve_step): serving concurrency is between
        # accelerators, and the decay schedule evaluates in-carry because
        # the overload watchdog can rewind the counter mid-stream.
        zf = jnp.zeros((n_requests,), f32)
        xs = StepInputs(
            acc_id=acc, footprint=sched.footprint[arr.row],
            tiles=sched.tiles[arr.row],
            thread=jnp.zeros((n_requests,), jnp.int32),
            fresh=jnp.ones((n_requests,), bool),
            others=jnp.zeros((n_requests, n_accs), bool),
            valid=jnp.ones((n_requests,), bool),
            pre_mode=spec.modes[arr.row],
            profile=pmat[acc], avail=masks[acc],
            eps=zf, alpha=zf, u_explore=noise.u_explore,
            g_pick=noise.g_pick, g_tie=noise.g_tie, **frow)
        # MLP specs drive the serve-side decay/freeze off the MERGED agent
        # (the tabular slot is a frozen placeholder); weights ride the
        # carry so chunk chaining and checkpointing work unchanged.
        if mlp is None:
            frozen_eff, step0_eff = qs0.frozen, qs0.step
        else:
            frozen_eff = jnp.where(spec.qfun, mlp.frozen, qs0.frozen)
            step0_eff = jnp.where(spec.qfun, mlp.step, qs0.step)
        sp = ServeParams(
            eps0=jnp.asarray(cfg.epsilon0, f32),
            alpha0=jnp.asarray(cfg.alpha0, f32),
            decay_steps=jnp.asarray(cfg.decay_steps, f32),
            reopen_frac=jnp.asarray(cfg.reopen_frac, f32),
            frozen=frozen_eff.astype(f32),
            backoff=tspec.backoff,
            overload_frac=tspec.overload_frac,
            pressure_beta=tspec.pressure_beta,
            prio_reserve=tspec.prio_reserve)
        if carry is None:
            carry = init_serve_carry(
                qs0.qtable, rewards.init_reward_state(n_accs).extrema,
                n_accs, sched.tiles.shape[-1], queue_cap, step0_eff,
                wpack0=None if mlp is None else mlp.wpack)
        carry, ys = soc_step_ops.fused_serve_episode(
            s, spec.learned, weights, sp, carry, xs, arr.t_arr,
            arr.deadline, arr.priority, ddr_attribution=ddr_attribution,
            kernel=None if fused else False,
            qfun=None if mlp is None else spec.qfun, mlp=mlp)

        cols = {name: ys[:, i] for i, name in enumerate(SERVE_YCOLS)}
        executed = cols["executed"] > 0.0
        # Visits/step replay (the fused-episode contract): shed rows have
        # -1 indices but zero increments — clamp and scatter-add nothing.
        inc = (executed & ~qs0.frozen).astype(jnp.int32)
        sidx = jnp.maximum(cols["state_idx"].astype(jnp.int32), 0)
        act = jnp.maximum(cols["action"].astype(jnp.int32), 0)
        qs = qlearn.QState(qtable=carry.qtable,
                           visits=qs0.visits.at[sidx, act].add(inc),
                           step=carry.step, frozen=qs0.frozen)
        if debug_finite:
            qlearn.debug_finite_check("vecenv.serve",
                                      reward=cols["reward"],
                                      qtable=qs.qtable)
        res = ServeResult(
            t_arr=arr.t_arr, tenant=arr.tenant,
            mode=cols["mode"].astype(jnp.int32),
            state_idx=cols["state_idx"].astype(jnp.int32),
            action=cols["action"].astype(jnp.int32),
            exec_time=cols["exec_time"], offchip=cols["offchip"],
            reward=cols["reward"], executed=executed,
            latency=cols["latency"], retries=cols["retries"],
            depth=cols["depth"], degraded=cols["degraded"] > 0.0,
            start=cols["start"], finish=cols["finish"])
        return carry, qs, res

    return serve


class ServeEnv:
    """Long-lived continuous-traffic serving over a :class:`VecEnv`.

    Where :meth:`VecEnv.episode` replays a closed invocation schedule,
    ``ServeEnv`` keeps the SoC *always on*: requests arrive over
    continuous time from a :class:`~repro.soc.traffic.TrafficSpec`, are
    admitted to bounded per-accelerator queues (``queue_cap`` static ring
    slots in the scan carry), shed when their deadline cannot be met
    (after bounded exponential retry-with-backoff), and — under sustained
    queue-full pressure — served in forced NON_COH mode while the
    epsilon-reopen watchdog un-freezes exploration so the agent re-adapts
    instead of letting latency diverge.

    ``traffic=None`` calls delegate verbatim to the episodic path, so a
    traffic-free ``serve`` is bitwise-identical to :meth:`VecEnv.
    episode_spec` (pinned by ``tests/test_soc_traffic.py``).  Chunks
    chain: ``serve`` returns a ``ServeCarry`` + the final arrival clock,
    and feeding them back continues the stream bitwise —
    :meth:`serve_checkpointed` uses that to make multi-chunk serving
    crash-resumable through a ``checkpoint.CheckpointManager``.
    """

    def __init__(self, env: VecEnv, *, queue_cap: int = 8,
                 n_requests: int = 1024):
        if queue_cap < 1:
            raise ValueError("queue_cap must be >= 1")
        self.env = env
        self.queue_cap = int(queue_cap)
        self.n_requests = int(n_requests)
        self._serve_cache: dict = {}

    # ------------------------------------------------------------- plumbing
    def _serve_fn(self, n_requests: int):
        cache_key = ("serve", n_requests)
        if cache_key in self._serve_cache:
            return self._serve_cache[cache_key]
        env = self.env
        base = build_serve_fn(n_requests, self.queue_cap,
                              ddr_attribution=env.ddr_attribution,
                              fused=env.fused_step,
                              debug_finite=env.debug_finite)
        params = env.params

        def serve(sched, spec, cfg, weights, tspec, carry, key, t0,
                  faults=None):
            return base(params, sched, spec, cfg, weights, tspec, carry,
                        key, t0, faults)

        fns = (jax.jit(serve),
               # Policy batches: specs/keys carry a leading (N,) axis;
               # traffic, carry(None) and faults replicate — every
               # lowered policy faces the identical offered stream.
               jax.jit(jax.vmap(
                   serve,
                   in_axes=(None, 0, None, None, None, None, 0, None,
                            None))))
        self._serve_cache[cache_key] = fns
        return fns

    def init_carry(self, qstate: qlearn.QState, mlp=None, qfun=None):
        """A fresh stream state (idle devices, the agent's Q-table).

        For an MLP-lowered spec pass ``(spec.qstate, spec.mlp,
        spec.qfun)`` — the weight pack joins the carry and the decay
        counter starts at the merged agent's step."""
        from repro.kernels.soc_step.ref import init_serve_carry
        n_accs = self.env.pmat.shape[0]
        step0 = (qstate.step if mlp is None
                 else jnp.where(qfun, mlp.step, qstate.step))
        return init_serve_carry(
            qstate.qtable, rewards.init_reward_state(n_accs).extrema,
            n_accs, self.env.soc.n_mem_tiles, self.queue_cap, step0,
            wpack0=None if mlp is None else mlp.wpack)

    # --------------------------------------------------------------- serving
    def serve(self, compiled: CompiledApp, spec: PolicySpec,
              traffic: traffic_mod.TrafficSpec | None = None, *,
              cfg: qlearn.QConfig | None = None,
              weights: rewards.RewardWeights | None = None,
              key=None, carry=None, t0=0.0,
              n_requests: int | None = None,
              faults: fault_mod.FaultSpec | None = None):
        """Serve one chunk of offered traffic with a lowered policy.

        Returns ``(carry, qstate, ServeResult)``.  With ``traffic=None``
        this *is* :meth:`VecEnv.episode_spec` (returning its ``(qstate,
        EpisodeResult)``) — the episodic path, bitwise."""
        if traffic is None:
            return self.env.episode_spec(compiled, spec, cfg=cfg,
                                         weights=weights, key=key,
                                         faults=faults)
        cfg = cfg or qlearn.QConfig()
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        key = key if key is not None else jax.random.PRNGKey(0)
        fn, _ = self._serve_fn(int(n_requests or self.n_requests))
        return fn(compiled.schedule, spec, cfg, weights, traffic, carry,
                  key, jnp.asarray(t0, jnp.float32), faults)

    def serve_specs(self, compiled: CompiledApp, specs: PolicySpec,
                    traffic: traffic_mod.TrafficSpec, *,
                    cfg: qlearn.QConfig | None = None,
                    weights: rewards.RewardWeights | None = None,
                    keys=None, n_requests: int | None = None,
                    faults: fault_mod.FaultSpec | None = None):
        """A heterogeneous batch of lowered policies against one offered
        stream, one call — the serving analogue of :meth:`VecEnv.
        episodes` (Q vs fixed under identical arrivals).  Returns
        ``(carry, qstate, ServeResult)`` with (N, ...) leaves."""
        cfg = cfg or qlearn.QConfig()
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        n = specs.learned.shape[0]
        if keys is None:
            keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n))
        _, batched = self._serve_fn(int(n_requests or self.n_requests))
        return batched(compiled.schedule, specs, cfg, weights, traffic,
                       None, keys, jnp.zeros((), jnp.float32), faults)

    def serve_checkpointed(self, compiled: CompiledApp, spec: PolicySpec,
                           traffic: traffic_mod.TrafficSpec, manager, *,
                           n_chunks: int,
                           cfg: qlearn.QConfig | None = None,
                           weights: rewards.RewardWeights | None = None,
                           key=None, n_requests: int | None = None,
                           faults: fault_mod.FaultSpec | None = None):
        """Crash-resumable multi-chunk serving (the ``train_batched_
        checkpointed`` pattern on an open stream).

        Chunk ``i`` draws arrivals from ``traffic.key`` fold_in ``i``
        (:func:`repro.soc.traffic.chunk_key`) and select noise from
        ``key`` fold_in ``i``; the ``ServeCarry`` and arrival clock cross
        chunk boundaries unchanged, so an interrupted + resumed run
        returns a final ``(carry, qstate, ServeResult)`` bitwise-equal to
        an uninterrupted one with the same arguments (pinned by
        ``tests/test_soc_traffic.py``).  Result arrays are preallocated
        at the full ``(n_chunks, n_requests)`` shape so checkpoints have
        a fixed tree structure; the returned :class:`ServeResult` leaves
        are flattened to ``(n_chunks * n_requests,)`` request order."""
        if n_chunks < 1:
            raise ValueError("n_chunks must be >= 1")
        cfg = cfg or qlearn.QConfig()
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        key = key if key is not None else jax.random.PRNGKey(0)
        n = int(n_requests or self.n_requests)
        fn, _ = self._serve_fn(n)

        carry = self.init_carry(spec.qstate, spec.mlp, spec.qfun)
        qs = spec.qstate
        results = _zero_serve_results(n_chunks, n)
        t0 = jnp.zeros((), jnp.float32)
        done = 0
        if manager.latest_step() is not None:
            state = manager.restore({
                "carry": carry, "qstate": qs, "results": results,
                "t0": t0, "done": jnp.zeros((), jnp.int32)})
            carry, qs = state["carry"], state["qstate"]
            results, t0 = state["results"], state["t0"]
            done = int(state["done"])

        while done < n_chunks:
            carry, qs, res = fn(
                compiled.schedule, spec._replace(qstate=qs), cfg, weights,
                traffic_mod.chunk_key(traffic, done), carry,
                jax.random.fold_in(key, done), t0, faults)
            results = jax.tree_util.tree_map(
                lambda acc_, r: acc_.at[done].set(r), results, res)
            t0 = res.t_arr[-1]
            done += 1
            manager.save(done, {
                "carry": carry, "qstate": qs, "results": results,
                "t0": t0, "done": jnp.asarray(done, jnp.int32)})
        manager.wait()
        flat = jax.tree_util.tree_map(
            lambda a: a.reshape((-1,) + a.shape[2:]), results)
        return carry, qs, flat
