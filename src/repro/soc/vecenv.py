"""Vectorized SoC environment — the scale path of the reproduction.

Where ``soc.des`` is the fidelity path (host-Python event loop, one agent at
a time), this module lowers a whole :class:`~repro.soc.des.Application` to
static arrays once and then runs entire training episodes *inside* jit:

  * :func:`compile_app` traces an application into a flattened (dense,
    round-major) invocation schedule — phases/threads become arrays of
    ``(acc_id, footprint, tile mask, thread slot, phase id, concurrency
    mask)``.  Memory-tile striping uses the DES's rng protocol so that on
    single-thread applications the two paths see bit-identical inputs;
  * :meth:`VecEnv.episode` is one ``lax.scan`` over that schedule — each
    step does sense (``core.state.observe``) -> select (epsilon-greedy /
    fixed / manual) -> ``memsys.invocation_perf_cached`` timing -> reward
    (``core.rewards.evaluate``) -> ``core.qlearn`` update, entirely jitted;
  * :meth:`VecEnv.train` scans episodes over training iterations, and the
    ``*_batched`` entry points ``vmap`` over (agents/seeds x reward
    weights), so the Fig. 6 reward-DSE and Fig. 8 training curves run as
    one batched call instead of N sequential DES runs;
  * a third ``vmap`` axis over **SoC configurations** lives in
    :mod:`repro.soc.stacked`: every episode/train closure here takes its
    per-SoC constants as a :class:`LaneParams` argument, so the stacked
    environment can pad K SoCs to a common shape and run them in one call
    (Fig. 9's seven SoCs x seeds x reward weights).

Scan-step hot path: the contention model needs each concurrent slot's
unconstrained ``(dram, llc)`` bytes/cycle demand, which depends only on the
slot's (mode, profile, footprint) — values that change exactly when that
slot issues a new invocation.  The step therefore keeps per-slot demand in
the scan carry and writes ("invalidates") only the slot it executes,
instead of recomputing ``memsys.dma_demand`` for every slot every step
(:func:`memsys.invocation_perf_cached` is the matching fast-path timing
signature; the self-contained one stays for the DES).  Construct
``VecEnv(..., demand_cache=False)`` to get the recompute-every-step path —
kept for the before/after comparison in ``benchmarks/vecenv_throughput.py``
and the cache-equivalence tests.

Concurrency model (the one deliberate approximation): threads of a phase
advance in lockstep *rounds*.  The invocations of round ``r`` are mutually
concurrent — thread ``t`` senses threads ``< t`` of its own round and
threads ``> t`` of round ``r-1`` — which mirrors the DES at time zero and
approximates it afterwards (the DES interleaves by continuous completion
times and serializes device collisions).  Phase wall time is the max over
threads of per-thread busy time; for single-thread phases both the
concurrency set and the wall clock are exactly the DES's, which is what
``tests/test_vecenv_equivalence.py`` pins.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn, rewards, state as cstate
from repro.core.modes import CoherenceMode, N_MODES
from repro.core.policies import EXTRA_SMALL_THRESHOLD
from repro.core.state import CacheGeometry
from repro.soc.accelerators import AccProfile, profile_matrix, resolve_profiles
from repro.soc.config import SoCConfig
from repro.soc.des import Application, SoCSimulator, stripe_tiles
from repro.soc.memsys import (SoCStatic, invocation_perf,
                              invocation_perf_cached, warmth_after)


class Schedule(NamedTuple):
    """Static per-step arrays of a compiled application (scan xs).

    Schedules are dense — every row is a real invocation (compile_app
    skips finished threads rather than padding rounds).  The stacked
    multi-SoC path pads lanes to a common length; ``valid`` is False on
    those padding rows (compile_app emits all-True)."""

    acc_id: jnp.ndarray      # (S,) int32
    footprint: jnp.ndarray   # (S,) float32 bytes
    tiles: jnp.ndarray       # (S, n_tiles) bool — memory-tile striping
    thread: jnp.ndarray      # (S,) int32 thread slot within the phase
    phase_id: jnp.ndarray    # (S,) int32
    fresh: jnp.ndarray       # (S,) bool — thread's first invocation in phase
    others: jnp.ndarray      # (S, T) bool — concurrently-active thread slots
    valid: jnp.ndarray       # (S,) bool — False marks stacked-padding rows


class LaneParams(NamedTuple):
    """Per-SoC constants threaded through the episode closures.

    A single :class:`VecEnv` closes over one of these; the stacked
    multi-SoC environment (:mod:`repro.soc.stacked`) stacks one per SoC
    along a leading axis and ``vmap``s the same closures over it."""

    pmat: jnp.ndarray        # (n_accs, F) accelerator profile matrix
    masks: jnp.ndarray       # (n_accs, N_MODES) action availability
    static: SoCStatic        # scalar leaves ((K,) arrays when stacked)


@dataclasses.dataclass(frozen=True)
class CompiledApp:
    """An Application lowered to static arrays plus host-side metadata."""

    name: str
    schedule: Schedule
    n_phases: int
    n_threads: int           # max thread slots across phases
    n_steps: int             # total (real, non-padding) invocations
    phase_names: tuple


def compile_app(app: Application, soc: SoCConfig, seed: int = 0) -> CompiledApp:
    """Trace ``app`` into a flattened, round-major invocation schedule.

    A thread's looped chain is unrolled; round ``r`` holds each thread's
    ``r``-th invocation.  The per-step concurrency mask encodes the lockstep
    overlap structure described in the module docstring.
    """
    rng = np.random.default_rng(seed)
    n_tiles = soc.n_mem_tiles
    max_threads = max((len(ph.threads) for ph in app.phases), default=1)

    rows: list[tuple] = []
    for ph_i, phase in enumerate(app.phases):
        progs = []
        for th in phase.threads:
            seq = []
            for _ in range(th.loops):
                seq.extend(th.chain)
            progs.append(seq)
        n_rounds = max((len(p) for p in progs), default=0)
        started = [False] * len(progs)
        for r in range(n_rounds):
            for t, prog in enumerate(progs):
                if r >= len(prog):
                    continue
                inv = prog[r]
                tiles = stripe_tiles(rng, n_tiles, inv.footprint)
                others = np.zeros(max_threads, bool)
                for j, pj in enumerate(progs):
                    if j == t:
                        continue
                    if j < t:          # already issued round r
                        others[j] = r < len(pj)
                    else:              # still running round r-1
                        others[j] = r >= 1 and (r - 1) < len(pj)
                rows.append((inv.acc_id, inv.footprint, tiles, t, ph_i,
                             not started[t], others))
                started[t] = True

    if not rows:
        raise ValueError(f"application {app.name!r} has no invocations")
    sched = Schedule(
        acc_id=jnp.asarray([r[0] for r in rows], jnp.int32),
        footprint=jnp.asarray([r[1] for r in rows], jnp.float32),
        tiles=jnp.asarray(np.stack([r[2] for r in rows])),
        thread=jnp.asarray([r[3] for r in rows], jnp.int32),
        phase_id=jnp.asarray([r[4] for r in rows], jnp.int32),
        fresh=jnp.asarray([r[5] for r in rows]),
        others=jnp.asarray(np.stack([r[6] for r in rows])),
        valid=jnp.ones((len(rows),), bool),
    )
    return CompiledApp(
        name=app.name, schedule=sched, n_phases=len(app.phases),
        n_threads=max_threads, n_steps=len(rows),
        phase_names=tuple(ph.name for ph in app.phases))


def stack_schedules(compiled: Sequence[CompiledApp]) -> Schedule:
    """Stack same-shape compiled apps along a leading axis (scan over
    training iterations, each with its own tile-striping seed)."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[c.schedule for c in compiled])


class EpisodeResult(NamedTuple):
    """Per-phase metrics plus per-invocation traces of one episode."""

    phase_time: jnp.ndarray      # (P,) seconds of wall clock
    phase_offchip: jnp.ndarray   # (P,) off-chip line accesses
    mode: jnp.ndarray            # (S,) int32 chosen coherence mode
    state_idx: jnp.ndarray       # (S,) int32 sensed Table-3 state
    exec_time: jnp.ndarray       # (S,) float32 cycles
    offchip: jnp.ndarray         # (S,) float32 line accesses
    reward: jnp.ndarray          # (S,) float32

    @property
    def total_time(self):
        return jnp.sum(self.phase_time)

    @property
    def total_offchip(self):
        return jnp.sum(self.phase_offchip)


def normalized_metrics(res: EpisodeResult, base: EpisodeResult,
                       phase_mask=None):
    """Per-phase geomean (time, offchip) normalized to a baseline episode —
    the paper's Fixed-NON_COH normalization (orchestrator._geomean_ratio).

    ``phase_mask`` (same shape as ``res.phase_time``) restricts the geomean
    to real phases when lanes of a stacked multi-SoC batch were padded to a
    common phase count."""
    lt = jnp.log(jnp.maximum(
        res.phase_time / jnp.maximum(base.phase_time, 1e-30), 1e-12))
    lm = jnp.log(jnp.maximum(
        (res.phase_offchip + 1.0)
        / jnp.maximum(base.phase_offchip + 1.0, 1e-30), 1e-12))
    if phase_mask is None:
        return jnp.exp(jnp.mean(lt)), jnp.exp(jnp.mean(lm))
    w = phase_mask.astype(lt.dtype)
    n = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.exp(jnp.sum(lt * w) / n), jnp.exp(jnp.sum(lm * w) / n)


def _manual_select(s: SoCStatic, footprint, active_modes, active_fp, avail):
    """Paper Algorithm 1 as pure jnp (mirrors policies.ManualPolicy)."""
    active = active_modes >= 0
    n_cd = jnp.sum(active & (active_modes == CoherenceMode.COH_DMA))
    n_fc = jnp.sum(active & (active_modes == CoherenceMode.FULLY_COH))
    n_nc = jnp.sum(active & (active_modes == CoherenceMode.NON_COH_DMA))
    l2 = s.l2_bytes
    llc = s.llc_slice_bytes * s.n_mem_tiles
    mode = jnp.where(
        footprint <= EXTRA_SMALL_THRESHOLD,
        CoherenceMode.FULLY_COH,
        jnp.where(
            footprint <= l2,
            jnp.where(n_cd > n_fc, CoherenceMode.FULLY_COH,
                      CoherenceMode.COH_DMA),
            jnp.where(
                footprint + active_fp > llc,
                CoherenceMode.NON_COH_DMA,
                jnp.where(n_nc >= 2, CoherenceMode.LLC_COH_DMA,
                          CoherenceMode.COH_DMA))))
    return jnp.where(avail[mode], mode, CoherenceMode.NON_COH_DMA)


def build_episode_fn(kind: str, n_phases: int, n_threads: int,
                     cycle_time: float, demand_cache: bool = True,
                     gated: bool = False, presample_noise: bool = True):
    """Build a jit-compatible episode function for a policy kind
    (``'q' | 'fixed' | 'manual'``) and schedule geometry.

    The returned ``episode(params, sched, qs, cfg, fixed_modes, weights,
    key)`` closure takes its per-SoC constants as a :class:`LaneParams`
    argument so it can serve both a single :class:`VecEnv` (params closed
    over by the caller) and the stacked multi-SoC environment (params
    vmapped over a leading lane axis).

    ``demand_cache`` selects the fast path: per-slot (dram, llc) demand
    lives in the scan carry and only the executing slot's entry is
    rewritten each step.  ``presample_noise`` draws the whole episode's
    select noise in one batched call instead of splitting keys inside the
    scan; ``False`` restores the original per-step threefry (kept, with
    ``demand_cache=False``, as the pre-optimization reference the
    throughput benchmark measures against).  ``gated`` adds padding-row
    gating for stacked schedules: a ``valid=False`` row leaves the
    Q-table, reward extrema and slot table untouched (padding rows sit at
    the tail of a lane, so the PRNG stream of real rows is unaffected).
    """
    T, P = n_threads, n_phases

    def episode(params: LaneParams, sched: Schedule, qs, cfg, fixed_modes,
                weights, key):
        pmat, masks, s = params.pmat, params.masks, params.static
        n_accs = pmat.shape[0]
        n_tiles = sched.tiles.shape[-1]
        geom = CacheGeometry(
            l2_bytes=s.l2_bytes, llc_slice_bytes=s.llc_slice_bytes,
            n_mem_tiles=s.n_mem_tiles)
        warm_cap = (s.llc_slice_bytes * s.n_mem_tiles
                    + s.n_cpus * s.l2_bytes)

        def step(carry, xs):
            x, noise = xs
            if presample_noise:
                qs, rs, tbl = carry
            else:
                qs, rs, key, tbl = carry
            if demand_cache:
                tbl_mode, tbl_fp, tbl_tiles, warm, tbl_dram, tbl_llc = tbl
            else:
                tbl_acc, tbl_mode, tbl_fp, tbl_tiles, warm = tbl
            acc = x.acc_id
            profile = pmat[acc]
            avail = masks[acc]

            # ---- sense (paper §4.1): fixed-size active-set snapshot.
            omask = x.others & (tbl_mode >= 0)
            omodes = jnp.where(omask, tbl_mode, -1)
            ofps = jnp.where(omask, tbl_fp, 0.0)
            otiles = tbl_tiles & omask[:, None]
            state_idx = cstate.observe(
                active_modes=omodes, active_footprints=ofps,
                needed_tiles=otiles, target_tiles=x.tiles,
                target_footprint=x.footprint, geom=geom)

            warm_t = jnp.where(x.fresh, 1.0, warm[x.thread])
            if demand_cache:
                odram = jnp.where(omask, tbl_dram, 0.0)
                ollc = jnp.where(omask, tbl_llc, 0.0)
            else:
                oprofiles = jnp.where(
                    omask[:, None], pmat[jnp.maximum(tbl_acc, 0)], 0.0)

            def env_half(action):
                """Actuate + time + evaluate for a chosen action (the
                environment half of qlearn.episode_step)."""
                mode = jnp.where(avail[action], action,
                                 CoherenceMode.NON_COH_DMA).astype(jnp.int32)
                if demand_cache:
                    m, aux = invocation_perf_cached(
                        mode, profile, x.footprint, x.tiles, omodes, odram,
                        ollc, ofps, otiles, warm_t, s)
                else:
                    m, aux = invocation_perf(
                        mode, profile, x.footprint, x.tiles, omodes,
                        oprofiles, ofps, otiles, warm_t, s)
                meas = rewards.Measurement(
                    exec_time=m.exec_time, comm_cycles=m.comm_cycles,
                    total_cycles=m.total_cycles,
                    offchip_accesses=m.offchip_accesses,
                    footprint=x.footprint)
                r, rs_new, _ = rewards.evaluate(rs, acc, meas, weights)
                return r, (mode, m.exec_time, m.offchip_accesses, rs_new,
                           aux["demand_dram"], aux["demand_llc"])

            if not presample_noise:
                key, k_sel = jax.random.split(key)
            if kind == "q":
                if presample_noise:
                    qs_new, (_, r,
                             (mode, exec_c, off, rs_new, d_dram, d_llc)) = (
                        qlearn.episode_step_presampled(
                            qs, cfg, state_idx, noise, env_half, avail))
                else:
                    qs_new, (_, r,
                             (mode, exec_c, off, rs_new, d_dram, d_llc)) = (
                        qlearn.episode_step(qs, cfg, state_idx, k_sel,
                                            env_half, avail))
            else:
                if kind == "fixed":
                    action = fixed_modes[acc]
                else:                       # manual (paper Algorithm 1)
                    action = _manual_select(
                        s, x.footprint, omodes, jnp.sum(ofps), avail)
                r, (mode, exec_c, off, rs_new, d_dram, d_llc) = (
                    env_half(action))
                qs_new = qs

            # ---- bookkeeping: thread slot table + inter-stage warmth +
            # (fast path) this slot's cached demand.
            if demand_cache:
                tbl_new = (
                    tbl_mode.at[x.thread].set(mode),
                    tbl_fp.at[x.thread].set(x.footprint),
                    tbl_tiles.at[x.thread].set(x.tiles),
                    warm.at[x.thread].set(
                        warmth_after(mode, x.footprint, warm_cap)),
                    tbl_dram.at[x.thread].set(d_dram),
                    tbl_llc.at[x.thread].set(d_llc))
            else:
                tbl_new = (
                    tbl_acc.at[x.thread].set(acc),
                    tbl_mode.at[x.thread].set(mode),
                    tbl_fp.at[x.thread].set(x.footprint),
                    tbl_tiles.at[x.thread].set(x.tiles),
                    warm.at[x.thread].set(
                        warmth_after(mode, x.footprint, warm_cap)))

            if gated:
                def keep(new, old):
                    return jnp.where(x.valid, new, old)
                qs_new = jax.tree_util.tree_map(keep, qs_new, qs)
                rs_new = jax.tree_util.tree_map(keep, rs_new, rs)
                tbl_new = jax.tree_util.tree_map(keep, tbl_new, tbl)

            y = (mode, state_idx, exec_c, off, r)
            if presample_noise:
                return (qs_new, rs_new, tbl_new), y
            return (qs_new, rs_new, key, tbl_new), y

        if demand_cache:
            tbl0 = (jnp.full((T,), -1, jnp.int32),
                    jnp.zeros((T,), jnp.float32),
                    jnp.zeros((T, n_tiles), bool),
                    jnp.ones((T,), jnp.float32),
                    jnp.zeros((T,), jnp.float32),
                    jnp.zeros((T,), jnp.float32))
        else:
            tbl0 = (jnp.full((T,), -1, jnp.int32),
                    jnp.full((T,), -1, jnp.int32),
                    jnp.zeros((T,), jnp.float32),
                    jnp.zeros((T, n_tiles), bool),
                    jnp.ones((T,), jnp.float32))
        # Episode randomness is pre-sampled in one batched threefry call —
        # per-step split/categorical inside the scan would dominate the
        # step cost (see qlearn.SelectNoise).  Only the q kind draws.
        n_steps = sched.acc_id.shape[0]
        if presample_noise and kind == "q":
            noise = qlearn.sample_select_noise(
                key, (n_steps,), masks.shape[-1])
        else:
            noise = qlearn.SelectNoise(
                u_explore=jnp.zeros((n_steps,), jnp.float32),
                g_pick=jnp.zeros((n_steps, 0), jnp.float32),
                g_tie=jnp.zeros((n_steps, 0), jnp.float32))
        rs0 = rewards.init_reward_state(n_accs)
        carry = ((qs, rs0, tbl0) if presample_noise
                 else (qs, rs0, key, tbl0))
        carry, ys = jax.lax.scan(step, carry, (sched, noise))
        mode, state_idx, exec_c, off, rew = ys

        # Per-phase wall clock: max over threads of per-thread busy time
        # (threads chain serially; phases are sequential).  Padding rows
        # contribute nothing.
        secs = jnp.where(sched.valid, exec_c, 0.0) * cycle_time
        off_real = jnp.where(sched.valid, off, 0.0)
        per_thread = jnp.zeros((P, T), secs.dtype).at[
            sched.phase_id, sched.thread].add(secs)
        phase_time = jnp.max(per_thread, axis=1)
        phase_off = jnp.zeros((P,), off_real.dtype).at[
            sched.phase_id].add(off_real)
        return carry[0], EpisodeResult(
            phase_time=phase_time, phase_offchip=phase_off, mode=mode,
            state_idx=state_idx, exec_time=exec_c, offchip=off,
            reward=rew)

    return episode


def build_train_fn(n_phases: int, n_threads: int, eval_shape,
                   cycle_time: float, demand_cache: bool = True,
                   gated: bool = False, presample_noise: bool = True):
    """Build ``train_one(params, train_scheds, eval_sched, base, phase_mask,
    cfg, weights, key, q0)``: a scan of training episodes over iterations,
    optionally evaluating the frozen policy each iteration against the
    NON_COH baseline (Fig. 8).  Like :func:`build_episode_fn` it is
    parameterized over :class:`LaneParams` so the stacked environment can
    vmap SoC lanes over it."""
    episode = build_episode_fn("q", n_phases, n_threads, cycle_time,
                               demand_cache, gated, presample_noise)
    eval_episode = (build_episode_fn("q", eval_shape[0], eval_shape[1],
                                     cycle_time, demand_cache, gated,
                                     presample_noise)
                    if eval_shape is not None else None)

    def train_one(params, train_scheds, eval_sched, base, phase_mask, cfg,
                  weights, key, q0):
        dummy_fixed = jnp.zeros((params.pmat.shape[0],), jnp.int32)

        def body(carry, sched_i):
            qs, key = carry
            key, k_train, k_eval = jax.random.split(key, 3)
            qs, _ = episode(params, sched_i, qs, cfg, dummy_fixed, weights,
                            k_train)
            if eval_sched is not None:
                _, er = eval_episode(params, eval_sched, qlearn.freeze(qs),
                                     cfg, dummy_fixed, weights, k_eval)
                out = normalized_metrics(er, base, phase_mask)
            else:
                out = (jnp.float32(0.0), jnp.float32(0.0))
            return (qs, key), out

        (qs, _), hist = jax.lax.scan(body, (q0, key), train_scheds)
        return qs, hist

    return train_one


class VecEnv:
    """Fully-jitted batched SoC environment over one SoC + accelerator set.

    Mirrors :class:`~repro.soc.des.SoCSimulator`'s construction (same
    profile resolution, action masks and timing constants) so the two paths
    are directly comparable; ``VecEnv.from_simulator`` shares an existing
    simulator's resolved profiles.

    ``demand_cache=True`` (the default) runs the carry-cached scan step;
    ``False`` recomputes every slot's demand each step (the pre-cache hot
    path, kept for benchmarking and equivalence tests — results are
    identical, see ``tests/test_vecenv_equivalence.py``).
    ``presample_noise=False`` additionally restores per-step RNG splitting;
    together with ``demand_cache=False`` that is the original (pre-
    optimization) scan step, the "before" of
    ``benchmarks/vecenv_throughput.py``.
    """

    def __init__(self, soc: SoCConfig,
                 profiles: Sequence[AccProfile] | None = None,
                 seed: int = 0, flavor: str = "mixed",
                 cycle_time: float = 1e-8,
                 demand_cache: bool = True,
                 presample_noise: bool = True):
        self.soc = soc
        rng = np.random.default_rng(seed)
        self.profiles = list(profiles) if profiles is not None else (
            resolve_profiles(soc.accelerators, rng, flavor))
        assert len(self.profiles) == soc.n_accs
        self.pmat = jnp.asarray(profile_matrix(self.profiles))
        self.static = SoCStatic.from_config(soc)
        self.geom = soc.geometry
        self.cycle_time = float(cycle_time)
        self.demand_cache = bool(demand_cache)
        self.presample_noise = bool(presample_noise)
        masks = np.ones((soc.n_accs, N_MODES), bool)
        for i in soc.no_private_cache:
            masks[i, CoherenceMode.FULLY_COH] = False
        self.masks = jnp.asarray(masks)
        self.params = LaneParams(pmat=self.pmat, masks=self.masks,
                                 static=self.static)
        self._episode_cache: dict = {}
        self._train_cache: dict = {}

    @classmethod
    def from_simulator(cls, sim: SoCSimulator,
                       cycle_time: float = 1e-8,
                       demand_cache: bool = True,
                       presample_noise: bool = True) -> "VecEnv":
        return cls(sim.soc, profiles=sim.profiles, cycle_time=cycle_time,
                   demand_cache=demand_cache,
                   presample_noise=presample_noise)

    # ------------------------------------------------------------ episode
    def _episode_fn(self, kind: str, n_phases: int, n_threads: int):
        """Build (and cache) the episode closure (params pre-bound)."""
        cache_key = (kind, n_phases, n_threads)
        if cache_key in self._episode_cache:
            return self._episode_cache[cache_key]
        base_fn = build_episode_fn(kind, n_phases, n_threads,
                                   self.cycle_time, self.demand_cache,
                                   presample_noise=self.presample_noise)
        params = self.params

        def episode(sched, qs, cfg, fixed_modes, weights, key):
            return base_fn(params, sched, qs, cfg, fixed_modes, weights, key)

        self._episode_cache[cache_key] = episode
        return episode

    # ----------------------------------------------------- public episodes
    def episode(self, compiled: CompiledApp, *, policy: str = "q",
                qstate: qlearn.QState | None = None,
                cfg: qlearn.QConfig | None = None,
                fixed_modes=None,
                weights: rewards.RewardWeights | None = None,
                key=None) -> tuple[qlearn.QState, EpisodeResult]:
        """Run one episode under jit.  ``policy``:

        * ``'q'`` — the Cohmeleon agent (``qstate`` trains in place unless
          frozen);
        * ``'fixed'`` — per-accelerator mode array (scalar broadcasts), the
          fixed-homogeneous/heterogeneous baselines;
        * ``'manual'`` — paper Algorithm 1.
        """
        cfg = cfg or qlearn.QConfig()
        qstate = qstate if qstate is not None else qlearn.init_qstate(cfg)
        if fixed_modes is None:
            fixed_modes = CoherenceMode.NON_COH_DMA
        fixed_modes = jnp.broadcast_to(
            jnp.asarray(fixed_modes, jnp.int32), (self.soc.n_accs,))
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        key = key if key is not None else jax.random.PRNGKey(0)
        jit_key = ("jit", policy, compiled.n_phases, compiled.n_threads)
        if jit_key not in self._episode_cache:
            self._episode_cache[jit_key] = jax.jit(self._episode_fn(
                policy, compiled.n_phases, compiled.n_threads))
        return self._episode_cache[jit_key](
            compiled.schedule, qstate, cfg, fixed_modes, weights, key)

    def baseline_episode(self, compiled: CompiledApp) -> EpisodeResult:
        """Fixed NON_COH_DMA episode — the paper's normalization baseline."""
        _, res = self.episode(compiled, policy="fixed",
                              fixed_modes=CoherenceMode.NON_COH_DMA)
        return res

    # ------------------------------------------------------------ training
    def _train_fn(self, n_phases: int, n_threads: int, eval_shape):
        cache_key = (n_phases, n_threads, eval_shape)
        if cache_key in self._train_cache:
            return self._train_cache[cache_key]
        base_fn = build_train_fn(n_phases, n_threads, eval_shape,
                                 self.cycle_time, self.demand_cache,
                                 presample_noise=self.presample_noise)
        params = self.params

        def train_one(train_scheds, eval_sched, base, cfg, weights, key, q0):
            return base_fn(params, train_scheds, eval_sched, base, None,
                           cfg, weights, key, q0)

        # Cache the jitted single-agent and vmapped variants so repeated
        # calls (benchmark timing loops, sweeps) hit the jit cache instead
        # of retracing.  ``None`` eval args trace as empty pytrees, so one
        # callable serves both the eval and no-eval protocols.
        batched = jax.vmap(
            train_one,
            in_axes=(None, None, None, None,
                     rewards.RewardWeights(0, 0, 0), 0, 0))
        fns = (jax.jit(train_one), jax.jit(batched))
        self._train_cache[cache_key] = fns
        return fns

    def train(self, train_apps: Sequence[CompiledApp],
              cfg: qlearn.QConfig,
              weights: rewards.RewardWeights | None = None,
              key=None,
              eval_app: CompiledApp | None = None
              ) -> tuple[qlearn.QState, tuple]:
        """Train one agent: scan over per-iteration schedules (each compiled
        with its own tile seed, like the DES's per-iteration run seeds)."""
        scheds = stack_schedules(train_apps)
        weights = weights or rewards.PAPER_DEFAULT_WEIGHTS
        key = key if key is not None else jax.random.PRNGKey(0)
        eval_sched = eval_app.schedule if eval_app is not None else None
        base = self.baseline_episode(eval_app) if eval_app is not None else None
        single, _ = self._train_fn(
            train_apps[0].n_phases, train_apps[0].n_threads,
            None if eval_app is None else
            (eval_app.n_phases, eval_app.n_threads))
        return single(scheds, eval_sched, base, cfg, weights, key,
                      qlearn.init_qstate(cfg))

    def train_batched(self, train_apps: Sequence[CompiledApp],
                      cfg: qlearn.QConfig,
                      weights_batch: rewards.RewardWeights,
                      keys,
                      eval_app: CompiledApp | None = None
                      ) -> tuple[qlearn.QState, tuple]:
        """Train B agents in one call: ``vmap`` over (reward weights, PRNG
        key) pairs.  ``weights_batch`` has (B,) leaves (rewards.stack_weights)
        and ``keys`` is (B, 2).  Returns a batched QState (leaves with
        leading axis B) and, when ``eval_app`` is given, per-iteration
        (norm_time, norm_mem) histories of shape (B, iterations)."""
        scheds = stack_schedules(train_apps)
        eval_sched = eval_app.schedule if eval_app is not None else None
        base = self.baseline_episode(eval_app) if eval_app is not None else None
        _, batched = self._train_fn(
            train_apps[0].n_phases, train_apps[0].n_threads,
            None if eval_app is None else
            (eval_app.n_phases, eval_app.n_threads))
        q0 = qlearn.init_qstate_batch(cfg, keys.shape[0])
        return batched(scheds, eval_sched, base, cfg, weights_batch, keys, q0)

    def evaluate_batched(self, compiled: CompiledApp,
                         qstates: qlearn.QState,
                         cfg: qlearn.QConfig,
                         keys) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Frozen-greedy evaluation of B agents on one app in one call;
        returns (norm_time, norm_mem) of shape (B,) vs the NON_COH base."""
        base = self.baseline_episode(compiled)
        cache_key = ("batched_eval", compiled.n_phases, compiled.n_threads)
        if cache_key not in self._train_cache:
            episode = self._episode_fn("q", compiled.n_phases,
                                       compiled.n_threads)
            dummy_fixed = jnp.zeros((self.soc.n_accs,), jnp.int32)
            # rewards don't steer a frozen agent; any weights do
            w = rewards.PAPER_DEFAULT_WEIGHTS

            def eval_one(sched, base_, cfg_, qs, key):
                _, er = episode(sched, qlearn.freeze(qs), cfg_,
                                dummy_fixed, w, key)
                return normalized_metrics(er, base_)

            self._train_cache[cache_key] = jax.jit(jax.vmap(
                eval_one, in_axes=(None, None, None, 0, 0)))
        return self._train_cache[cache_key](compiled.schedule, base, cfg,
                                            qstates, keys)
