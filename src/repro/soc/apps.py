"""Evaluation applications (paper §5, "Applications").

An application is a set of *phases*, each meant to represent a real
multithreaded program: a phase has N threads, each thread owns a dataset and
runs a chain of accelerators serially over it (output of one is input of the
next), optionally looping.  Instances vary thread counts, workload sizes and
accelerator parameters so that the policies are exercised across operating
conditions.

Workload-size characterization (paper §5): Small (< accelerator L2),
Medium (< one LLC partition), Large (< aggregate LLC), Extra-Large (> LLC).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.soc.config import SoCConfig
from repro.soc.des import Application, Invocation, Phase, Thread

SIZE_CLASSES = ("S", "M", "L", "XL")


def sample_footprint(rng: np.random.Generator, soc: SoCConfig,
                     size_class: str) -> float:
    l2, slice_, llc = soc.l2_bytes, soc.llc_slice_bytes, soc.llc_total_bytes
    lo, hi = {
        "S": (2 * 1024, l2),
        "M": (l2, slice_),
        "L": (slice_, llc),
        "XL": (llc, 4 * llc),
    }[size_class]
    return float(np.exp(rng.uniform(np.log(lo), np.log(hi))))


# Loop counts per size class: small-workload threads iterate more (as in
# the paper's apps, where accelerators are "invoked multiple times in a
# row"), keeping phase contributions comparable across classes.
LOOPS_BY_CLASS = {"S": 6, "M": 4, "L": 2, "XL": 1}


def make_phase(rng: np.random.Generator, soc: SoCConfig, *, name: str,
               n_threads: int, size_classes: Sequence[str],
               chain_len: int = 3, loops: int | None = None) -> Phase:
    """Random phase: each thread chains ``chain_len`` random accelerators.

    Threads start on distinct accelerator instances (a round-robin over a
    random permutation) so parallelism is real; the device-locking in the
    simulator still serializes any residual collisions.
    """
    threads = []
    perm = rng.permutation(soc.n_accs)
    for t in range(n_threads):
        size_class = size_classes[t % len(size_classes)]
        fp = sample_footprint(rng, soc, size_class)
        chain = [
            Invocation(acc_id=int(perm[(t + j) % soc.n_accs]), footprint=fp)
            for j in range(chain_len)
        ]
        threads.append(Thread(
            chain=chain,
            loops=loops if loops is not None else LOOPS_BY_CLASS[size_class]))
    return Phase(name=name, threads=threads)


def make_application(soc: SoCConfig, seed: int = 0, n_phases: int = 8,
                     max_threads: int | None = None) -> Application:
    """Randomly-configured evaluation-application instance (paper §5).

    Phases sweep thread counts and size classes so that several hundred
    invocations cover the operating space; different seeds give the
    train/test instance split used in the paper.
    """
    rng = np.random.default_rng(seed)
    max_threads = max_threads or min(12, soc.n_accs)
    phases = []
    for p in range(n_phases):
        n_threads = int(rng.integers(1, max_threads + 1))
        # Each phase stresses one workload-size class (the paper's phases
        # are "meant to represent a real application"); round-robin over
        # classes guarantees coverage of all operating conditions.
        sizes = [SIZE_CLASSES[p % len(SIZE_CLASSES)]]
        if rng.uniform() < 0.25:    # occasional mixed-size phase
            sizes.append(str(rng.choice(SIZE_CLASSES)))
        phases.append(make_phase(
            rng, soc, name=f"phase{p}({n_threads}t,{'/'.join(sizes)})",
            n_threads=n_threads, size_classes=sizes,
            chain_len=int(rng.integers(2, 5))))
    return Application(name=f"{soc.name}-app-seed{seed}", phases=phases)


def make_fig5_phases(soc: SoCConfig, seed: int = 0) -> Application:
    """Four selected phases varying thread count and workload size (Fig. 5)."""
    rng = np.random.default_rng(seed)
    spec = [
        ("2 threads, S/M", 2, ("S", "M")),
        ("4 threads, M", 4, ("M",)),
        ("8 threads, M/L", 8, ("M", "L")),
        ("12 threads, L/XL", min(12, soc.n_accs), ("L", "XL")),
    ]
    phases = [
        make_phase(rng, soc, name=name, n_threads=n, size_classes=sizes,
                   chain_len=3, loops=2)
        for name, n, sizes in spec
    ]
    return Application(name=f"{soc.name}-fig5", phases=phases)


def make_case_study_app(soc: SoCConfig, seed: int = 0,
                        loops: int = 2) -> Application:
    """Domain-appropriate pipelines for the case-study SoCs (paper §5).

    SoC5 (autonomous vehicles): FFT->Viterbi V2V chains + Conv2D->GEMM CNN
    chains.  SoC6 (computer vision): night-vision -> autoencoder -> MLP
    image pipelines, parallelized across the three copies.  SoC4 (one of
    each): mixed chains across all accelerators.
    """
    rng = np.random.default_rng(seed)
    name_to_ids: dict[str, list[int]] = {}
    for i, n in enumerate(soc.accelerators):
        name_to_ids.setdefault(n, []).append(i)

    def chain_of(names: Sequence[str], copy: int, fp: float) -> Thread:
        chain = [
            Invocation(acc_id=name_to_ids[n][copy % len(name_to_ids[n])],
                       footprint=fp)
            for n in names
        ]
        return Thread(chain=chain, loops=loops)

    phases = []
    if soc.name == "SoC6":
        pipeline = ("nightvision", "autoencoder", "mlp")
        for p, sizes in enumerate((("S",), ("M",), ("L",), ("M", "XL"))):
            threads = [
                chain_of(pipeline, c,
                         sample_footprint(rng, soc, sizes[c % len(sizes)]))
                for c in range(3)
            ]
            phases.append(Phase(name=f"cv-phase{p}", threads=threads))
    elif soc.name == "SoC5":
        v2v = ("fft", "viterbi")
        cnn = ("conv2d", "gemm")
        for p, sizes in enumerate((("S",), ("M",), ("L",), ("XL",))):
            threads = []
            for c in range(2):
                threads.append(chain_of(
                    v2v, c, sample_footprint(rng, soc, sizes[0])))
                threads.append(chain_of(
                    cnn, c, sample_footprint(rng, soc, sizes[0])))
            phases.append(Phase(name=f"av-phase{p}", threads=threads))
    else:  # SoC4 and any generic case
        for p, sizes in enumerate((("S", "M"), ("M",), ("L",), ("M", "XL"))):
            n_threads = min(6, soc.n_accs)
            threads = []
            for t in range(n_threads):
                names = [soc.accelerators[int(rng.integers(0, soc.n_accs))]
                         for _ in range(3)]
                threads.append(chain_of(
                    names, 0,
                    sample_footprint(rng, soc, sizes[t % len(sizes)])))
            phases.append(Phase(name=f"mixed-phase{p}", threads=threads))
    return Application(name=f"{soc.name}-casestudy", phases=phases)
