"""ESP-like SoC substrate: configs, accelerator profiles, timing model,
discrete-event simulator, vectorized RL environment (``vecenv``) and the
stacked multi-SoC batching axis over it (``stacked``).

The package re-exports the policy/episode API surface lazily (PEP 562):
``from repro.soc import PolicySpec, VecEnv, StackedVecEnv, ...`` — lazy
because ``vecenv`` imports ``repro.core.policies`` (which itself imports
``repro.soc.config``), and an eager import here would turn that
diamond into a partially-initialized-module cycle.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # vecenv: the unified PolicySpec episode API
    "PolicySpec": "repro.soc.vecenv",
    "VecEnv": "repro.soc.vecenv",
    "CompiledApp": "repro.soc.vecenv",
    "EpisodeResult": "repro.soc.vecenv",
    "LaneParams": "repro.soc.vecenv",
    "Schedule": "repro.soc.vecenv",
    "compile_app": "repro.soc.vecenv",
    "stack_specs": "repro.soc.vecenv",
    "fixed_policy_spec": "repro.soc.vecenv",
    "manual_policy_spec": "repro.soc.vecenv",
    "learned_policy_spec": "repro.soc.vecenv",
    "precompute_manual_modes": "repro.soc.vecenv",
    "normalized_metrics": "repro.soc.vecenv",
    "TrainCarry": "repro.soc.vecenv",
    "init_train_carry": "repro.soc.vecenv",
    # serving: continuous-traffic loop over the episodic substrate
    "ServeEnv": "repro.soc.vecenv",
    "ServeResult": "repro.soc.vecenv",
    "build_serve_fn": "repro.soc.vecenv",
    # traffic: arrival-process spec + pre-sampled arrival tables
    "TrafficSpec": "repro.soc.traffic",
    "Arrivals": "repro.soc.traffic",
    "poisson": "repro.soc.traffic",
    "bursty": "repro.soc.traffic",
    "sample_arrivals": "repro.soc.traffic",
    "chunk_key": "repro.soc.traffic",
    # faults: in-scan perturbation subsystem
    "FaultSpec": "repro.soc.faults",
    "StepFault": "repro.soc.faults",
    "no_faults": "repro.soc.faults",
    "storm": "repro.soc.faults",
    "fault_row": "repro.soc.faults",
    "sample_fault_arrays": "repro.soc.faults",
    # stacked: the multi-SoC lane axis over the same API
    "StackedApps": "repro.soc.stacked",
    "StackedVecEnv": "repro.soc.stacked",
    "compile_apps_stacked": "repro.soc.stacked",
    "compile_apps_bucketed": "repro.soc.stacked",
    "length_buckets": "repro.soc.stacked",
    "padded_waste": "repro.soc.stacked",
    "reassemble_lanes": "repro.soc.stacked",
    # dse: budgeted generative design-space sampler + bucketed co-search
    "SampledSoC": "repro.soc.dse",
    "sample_socs": "repro.soc.dse",
    "run_sweep": "repro.soc.dse",
    "rank_axes": "repro.soc.dse",
    # fidelity path + configs
    "Application": "repro.soc.des",
    "SoCSimulator": "repro.soc.des",
    "SoCConfig": "repro.soc.config",
    "SOCS": "repro.soc.config",
    "SoCBudget": "repro.soc.config",
    "DEFAULT_BUDGET": "repro.soc.config",
    "soc_area": "repro.soc.config",
    "soc_offchip_bw": "repro.soc.config",
    "budget_report": "repro.soc.config",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
