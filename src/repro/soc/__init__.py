"""ESP-like SoC substrate: configs, accelerator profiles, timing model,
discrete-event simulator and vectorized RL environment."""
