"""ESP-like SoC substrate: configs, accelerator profiles, timing model,
discrete-event simulator, vectorized RL environment (``vecenv``) and the
stacked multi-SoC batching axis over it (``stacked``)."""
