"""Third vmap axis over SoC configurations (paper Fig. 9 in one call).

``soc.vecenv`` batches agents (reward weights x seeds) over one SoC;
this module pads K heterogeneous SoCs — different accelerator counts,
memory-tile counts, thread widths, schedule lengths, phase counts — to a
common shape and ``vmap``s the same episode/training closures over a
leading *lane* axis:

  * :func:`compile_apps_stacked` compiles one application per SoC (the
    DES's rng protocol per lane, so per-lane results are unchanged) and
    pads schedules to a common ``(S_max, T_max, tiles_max)``; padding rows
    carry ``valid=False`` and sit at the tail of each lane, so they leave
    the Q-table, reward extrema and slot table untouched (the ``gated``
    episode variant) and consume no real PRNG stream;
  * :class:`StackedVecEnv` stacks per-SoC :class:`~repro.soc.vecenv.
    LaneParams` (profile matrices, action masks, timing scalars) along
    axis 0 and exposes batched fixed/manual/Q episodes plus
    ``train_batched`` over (SoC lanes x agents) — Fig. 9's seven SoCs
    x seeds x reward weights train and evaluate in single jitted calls.

Per-lane equivalence: a lane of a stacked call reproduces the same
episode the lane's own :class:`VecEnv` runs (padded slots/tiles are
masked everywhere), which in turn matches the DES on single-thread
applications — pinned by ``tests/test_vecenv_stacked.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode
from repro.soc import vecenv as vec
from repro.soc.config import SoCConfig
from repro.soc.des import Application, SoCSimulator
from repro.soc.memsys import SoCStatic


@dataclasses.dataclass(frozen=True)
class StackedApps:
    """K compiled applications padded to a common schedule shape.

    ``schedule`` leaves carry a leading lane axis ``(K, S_max, ...)``;
    ``phase_mask[k, p]`` marks lane ``k``'s real phases and feeds the
    masked per-phase normalization."""

    schedule: vec.Schedule
    n_phases: int                  # padded P_max
    n_threads: int                 # padded T_max
    n_tiles: int                   # padded memory-tile axis
    n_steps: tuple                 # (K,) real invocations per lane
    phase_mask: jnp.ndarray        # (K, P_max) bool
    names: tuple
    phase_names: tuple             # per lane, real phases only
    compiled: tuple                # per-lane unpadded CompiledApp

    @property
    def n_lanes(self) -> int:
        return len(self.compiled)


def _pad_axis(arr: np.ndarray, axis: int, target: int, fill):
    if arr.shape[axis] == target:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, target - arr.shape[axis])
    return np.pad(arr, widths, constant_values=fill)


def pad_compiled(c: vec.CompiledApp, n_steps: int, n_threads: int,
                 n_tiles: int) -> vec.Schedule:
    """Pad one compiled schedule to ``(n_steps, n_threads, n_tiles)``.

    Padding rows are ``valid=False`` no-ops at the tail; padded thread
    slots / memory tiles are never set in any mask, so they contribute
    zeros to every sensed or timed quantity."""
    s = jax.tree_util.tree_map(np.asarray, c.schedule)
    return vec.Schedule(
        acc_id=_pad_axis(s.acc_id, 0, n_steps, 0),
        footprint=_pad_axis(s.footprint, 0, n_steps, 1.0),
        tiles=_pad_axis(_pad_axis(s.tiles, 1, n_tiles, False),
                        0, n_steps, False),
        thread=_pad_axis(s.thread, 0, n_steps, 0),
        phase_id=_pad_axis(s.phase_id, 0, n_steps, 0),
        fresh=_pad_axis(s.fresh, 0, n_steps, True),
        others=_pad_axis(_pad_axis(s.others, 1, n_threads, False),
                         0, n_steps, False),
        valid=_pad_axis(s.valid, 0, n_steps, False),
    )


def compile_apps_stacked(apps: Sequence[Application],
                         socs: Sequence[SoCConfig],
                         seed: int | Sequence[int] = 0) -> StackedApps:
    """Compile one application per SoC and stack to a common shape.

    ``seed`` follows :func:`~repro.soc.vecenv.compile_app`'s tile-striping
    protocol — a scalar is shared by every lane (each lane still draws its
    own rng stream, exactly as its unstacked compile would), a sequence
    gives one seed per lane."""
    if len(apps) != len(socs):
        raise ValueError(f"{len(apps)} apps vs {len(socs)} socs")
    seeds = ([seed] * len(apps) if np.isscalar(seed) else list(seed))
    compiled = [vec.compile_app(a, soc, seed=s)
                for a, soc, s in zip(apps, socs, seeds)]
    n_steps = max(c.n_steps for c in compiled)
    n_threads = max(c.n_threads for c in compiled)
    n_tiles = max(soc.n_mem_tiles for soc in socs)
    n_phases = max(c.n_phases for c in compiled)
    padded = [pad_compiled(c, n_steps, n_threads, n_tiles) for c in compiled]
    schedule = jax.tree_util.tree_map(
        lambda *xs: jnp.asarray(np.stack(xs)), *padded)
    phase_mask = jnp.asarray(np.stack([
        np.arange(n_phases) < c.n_phases for c in compiled]))
    return StackedApps(
        schedule=schedule, n_phases=n_phases, n_threads=n_threads,
        n_tiles=n_tiles, n_steps=tuple(c.n_steps for c in compiled),
        phase_mask=phase_mask, names=tuple(c.name for c in compiled),
        phase_names=tuple(c.phase_names for c in compiled),
        compiled=tuple(compiled))


def _cfg_axes(cfg: qlearn.QConfig):
    """vmap in_axes spec for a QConfig whose leaves may carry a lane axis."""
    return qlearn.QConfig(*[
        0 if (hasattr(v, "ndim") and getattr(v, "ndim", 0) >= 1) else None
        for v in cfg])


class StackedVecEnv:
    """K SoCs as one vmapped environment (always the carry-cached step).

    Build with :meth:`from_simulators` to share DES simulators' resolved
    accelerator profiles (the cross-backend comparison protocol), or
    directly from configs.  All public entry points run every lane in a
    single jitted call.
    """

    def __init__(self, socs: Sequence[SoCConfig], seed: int = 0,
                 flavors: Sequence[str] | str = "mixed",
                 envs: Sequence[vec.VecEnv] | None = None,
                 cycle_time: float = 1e-8):
        if envs is None:
            if isinstance(flavors, str):
                flavors = [flavors] * len(socs)
            envs = [vec.VecEnv(soc, seed=seed, flavor=fl,
                               cycle_time=cycle_time)
                    for soc, fl in zip(socs, flavors)]
        self.envs = list(envs)
        self.socs = [e.soc for e in self.envs]
        self.cycle_time = float(self.envs[0].cycle_time)
        n_accs = max(soc.n_accs for soc in self.socs)
        feat = self.envs[0].pmat.shape[1]
        pmat = np.zeros((len(self.envs), n_accs, feat), np.float32)
        masks = np.ones((len(self.envs), n_accs, self.envs[0].masks.shape[1]),
                        bool)
        for k, env in enumerate(self.envs):
            pmat[k, :env.soc.n_accs] = np.asarray(env.pmat)
            masks[k, :env.soc.n_accs] = np.asarray(env.masks)
        static = SoCStatic(*[
            jnp.asarray([getattr(env.static, f) for env in self.envs],
                        jnp.float32)
            for f in SoCStatic._fields])
        self.n_accs = n_accs
        self.params = vec.LaneParams(pmat=jnp.asarray(pmat),
                                     masks=jnp.asarray(masks),
                                     static=static)
        self._cache: dict = {}

    @classmethod
    def from_simulators(cls, sims: Sequence[SoCSimulator],
                        cycle_time: float = 1e-8) -> "StackedVecEnv":
        envs = [vec.VecEnv.from_simulator(sim, cycle_time=cycle_time)
                for sim in sims]
        return cls([s.soc for s in sims], envs=envs, cycle_time=cycle_time)

    @property
    def n_lanes(self) -> int:
        return len(self.envs)

    def compile(self, apps: Sequence[Application],
                seed: int | Sequence[int] = 0) -> StackedApps:
        return compile_apps_stacked(apps, self.socs, seed)

    # ------------------------------------------------------------ episodes
    def _episode_fn(self, kind: str, n_phases: int, n_threads: int):
        key = (kind, n_phases, n_threads)
        if key not in self._cache:
            self._cache[key] = vec.build_episode_fn(
                kind, n_phases, n_threads, self.cycle_time,
                demand_cache=True, gated=True)
        return self._cache[key]

    def _default_keys(self, *batch) -> jnp.ndarray:
        n = int(np.prod(batch))
        return jax.vmap(jax.random.PRNGKey)(jnp.arange(n)).reshape(
            *batch, 2)

    def episodes_fixed(self, stacked: StackedApps, fixed_modes,
                       keys=None) -> vec.EpisodeResult:
        """Fixed-mode episodes for every (lane, policy) pair in one call.

        ``fixed_modes``: (K, N, A) int32 — N fixed policies per lane (the
        4 homogeneous baselines + any per-lane heterogeneous assignments).
        Returns an EpisodeResult with (K, N, ...) leaves."""
        fixed_modes = jnp.asarray(fixed_modes, jnp.int32)
        K, N = fixed_modes.shape[:2]
        if keys is None:
            keys = self._default_keys(K, N)
        cache_key = ("fixed_jit", stacked.n_phases, stacked.n_threads)
        if cache_key not in self._cache:
            ep = self._episode_fn("fixed", stacked.n_phases,
                                  stacked.n_threads)
            cfg = qlearn.QConfig()
            qs0 = qlearn.init_qstate(cfg)
            w = rewards.PAPER_DEFAULT_WEIGHTS

            def one(params, sched, fm, key):
                _, res = ep(params, sched, qs0, cfg, fm, w, key)
                return res

            self._cache[cache_key] = jax.jit(jax.vmap(
                jax.vmap(one, in_axes=(None, None, 0, 0)),
                in_axes=(0, 0, 0, 0)))
        return self._cache[cache_key](self.params, stacked.schedule,
                                      fixed_modes, keys)

    def episodes_manual(self, stacked: StackedApps,
                        keys=None) -> vec.EpisodeResult:
        """Paper Algorithm 1 on every lane in one call ((K, ...) leaves)."""
        if keys is None:
            keys = self._default_keys(self.n_lanes)
        cache_key = ("manual_jit", stacked.n_phases, stacked.n_threads)
        if cache_key not in self._cache:
            ep = self._episode_fn("manual", stacked.n_phases,
                                  stacked.n_threads)
            cfg = qlearn.QConfig()
            qs0 = qlearn.init_qstate(cfg)
            w = rewards.PAPER_DEFAULT_WEIGHTS
            dummy = jnp.zeros((self.n_accs,), jnp.int32)

            def one(params, sched, key):
                _, res = ep(params, sched, qs0, cfg, dummy, w, key)
                return res

            self._cache[cache_key] = jax.jit(jax.vmap(one,
                                                      in_axes=(0, 0, 0)))
        return self._cache[cache_key](self.params, stacked.schedule, keys)

    def episodes_q(self, stacked: StackedApps, qstates: qlearn.QState,
                   cfg: qlearn.QConfig, keys=None,
                   freeze: bool = True) -> vec.EpisodeResult:
        """Q-policy episodes for every (lane, agent) pair in one call.

        ``qstates`` leaves carry (K, N, ...); returns (K, N, ...) leaves.
        ``freeze=True`` evaluates greedily without updates (the Fig. 9
        protocol for trained agents and the Random policy's untrained
        all-ties table)."""
        K, N = qstates.qtable.shape[:2]
        if keys is None:
            keys = self._default_keys(K, N)
        axes = _cfg_axes(cfg)
        cache_key = ("q_jit", stacked.n_phases, stacked.n_threads,
                     bool(freeze), tuple(axes))
        if cache_key not in self._cache:
            ep = self._episode_fn("q", stacked.n_phases, stacked.n_threads)
            w = rewards.PAPER_DEFAULT_WEIGHTS
            dummy = jnp.zeros((self.n_accs,), jnp.int32)

            def one(params, sched, cfg_, qs, key):
                if freeze:
                    qs = qlearn.freeze(qs)
                _, res = ep(params, sched, qs, cfg_, dummy, w, key)
                return res

            self._cache[cache_key] = jax.jit(jax.vmap(
                jax.vmap(one, in_axes=(None, None, None, 0, 0)),
                in_axes=(0, 0, axes, 0, 0)))
        return self._cache[cache_key](self.params, stacked.schedule, cfg,
                                      qstates, keys)

    def baseline(self, stacked: StackedApps) -> vec.EpisodeResult:
        """Per-lane fixed NON_COH_DMA episode ((K, ...) leaves) — the
        paper's normalization baseline."""
        fm = jnp.full((self.n_lanes, 1, self.n_accs),
                      int(CoherenceMode.NON_COH_DMA), jnp.int32)
        res = self.episodes_fixed(stacked, fm)
        return jax.tree_util.tree_map(lambda x: x[:, 0], res)

    # ------------------------------------------------------------ training
    def train_batched(self, stacked_iters: Sequence[StackedApps],
                      cfg: qlearn.QConfig,
                      weights_batch: rewards.RewardWeights,
                      keys,
                      eval_stacked: StackedApps | None = None
                      ) -> tuple[qlearn.QState, tuple]:
        """Train (K lanes x B agents) in one jitted call.

        ``stacked_iters`` is one StackedApps per training iteration (each
        compiled with its own tile seed, the DES's per-iteration protocol);
        all iterations share one schedule shape.  ``weights_batch`` has
        (B,) leaves, ``keys`` is (K, B, 2).  ``cfg.decay_steps`` may be a
        (K,) array for per-lane decay horizons (lanes differ in
        invocations per iteration).  Returns a QState with (K, B, ...)
        leaves and, when ``eval_stacked`` is given, per-iteration
        (norm_time, norm_mem) histories of shape (K, B, iterations)."""
        first = stacked_iters[0]
        scheds = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=1),
            *[st.schedule for st in stacked_iters])
        eval_shape = (None if eval_stacked is None
                      else (eval_stacked.n_phases, eval_stacked.n_threads))
        if eval_stacked is not None:
            eval_sched = eval_stacked.schedule
            base = self.baseline(eval_stacked)
            pmask = eval_stacked.phase_mask
            eval_axes = (0, 0, 0)
        else:
            eval_sched = base = pmask = None
            eval_axes = (None, None, None)

        B = keys.shape[1]
        q0 = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (self.n_lanes,) + x.shape),
            qlearn.init_qstate_batch(qlearn.QConfig(), B))
        axes = _cfg_axes(cfg)
        cache_key = ("train_jit", first.n_phases, first.n_threads,
                     eval_shape, tuple(axes))
        if cache_key not in self._cache:
            train_one = vec.build_train_fn(
                first.n_phases, first.n_threads, eval_shape,
                self.cycle_time, demand_cache=True, gated=True)
            agents = jax.vmap(train_one,
                              in_axes=(None, None, None, None, None, None,
                                       rewards.RewardWeights(0, 0, 0), 0, 0))
            self._cache[cache_key] = jax.jit(jax.vmap(
                agents, in_axes=(0, 0, *eval_axes, axes, None, 0, 0)))
        return self._cache[cache_key](self.params, scheds, eval_sched, base,
                                      pmask, cfg, weights_batch, keys, q0)

    def evaluate_batched(self, stacked: StackedApps, qstates: qlearn.QState,
                         cfg: qlearn.QConfig, keys=None
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Frozen-greedy evaluation of (K, B) agents vs the per-lane
        NON_COH baseline; returns (norm_time, norm_mem), each (K, B)."""
        base = self.baseline(stacked)
        res = self.episodes_q(stacked, qstates, cfg, keys=keys, freeze=True)
        lanes = jax.vmap(jax.vmap(vec.normalized_metrics,
                                  in_axes=(0, None, None)),
                         in_axes=(0, 0, 0))
        return lanes(res, base, stacked.phase_mask)

    # ----------------------------------------------------------- host side
    def lane_phase_metrics(self, stacked: StackedApps,
                           res: vec.EpisodeResult, lane: int
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Lane ``lane``'s real-phase (wall time, off-chip accesses) from a
        stacked EpisodeResult (any leading policy axes are preserved)."""
        n_ph = stacked.compiled[lane].n_phases
        pt = np.asarray(res.phase_time)[lane][..., :n_ph]
        po = np.asarray(res.phase_offchip)[lane][..., :n_ph]
        return pt, po
