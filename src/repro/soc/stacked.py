"""Third vmap axis over SoC configurations (paper Fig. 9 in one call).

``soc.vecenv`` batches agents (reward weights x seeds) over one SoC;
this module pads K heterogeneous SoCs — different accelerator counts,
memory-tile counts, thread widths, schedule lengths, phase counts — to a
common shape and ``vmap``s the same episode/training closures over a
leading *lane* axis:

  * :func:`compile_apps_stacked` compiles one application per SoC (the
    DES's rng protocol per lane, so per-lane results are unchanged) and
    pads schedules to a common ``(S_max, T_max, tiles_max)``; padding rows
    carry ``valid=False`` and sit at the tail of each lane, so they leave
    the Q-table, reward extrema and slot table untouched (the ``gated``
    episode variant) and consume no real PRNG stream;
  * :class:`StackedVecEnv` stacks per-SoC :class:`~repro.soc.vecenv.
    LaneParams` (profile matrices, action masks, timing scalars) along
    axis 0 and exposes ONE batched episode entry point —
    :meth:`StackedVecEnv.episodes` over a ``(K lanes, N policies)`` batch
    of lowered :class:`~repro.soc.vecenv.PolicySpec`s, heterogeneous
    families welcome — plus ``train_batched`` over (SoC lanes x agents).
    Fig. 9's eight SoCs train in one call and evaluate EVERY policy
    family (fixed suite, manual, random, Cohmeleon) in one more;
  * :func:`length_buckets` / :func:`compile_apps_bucketed` optionally
    split lanes by schedule length (greedy k-way cuts on the sorted
    prefix-waste curve): when lengths diverge, a few tight stacked calls
    beat one call padded to the global max (~15% padded-step waste on
    the Fig. 9 set with two buckets; measured in
    ``benchmarks/vecenv_throughput.py``), and :func:`reassemble_lanes`
    scatters per-bucket results back to original lane order — the
    design-space sweep (:mod:`repro.soc.dse`) runs hundreds of generated
    SoCs this way.

Per-lane equivalence: a lane of a stacked call reproduces the same
episode the lane's own :class:`VecEnv` runs (padded slots/tiles are
masked everywhere), which in turn matches the DES on single-thread
applications — pinned by ``tests/test_vecenv_stacked.py``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode
from repro.core.policies import FixedHomogeneous, Policy
from repro.soc import vecenv as vec
from repro.soc.config import SoCConfig
from repro.soc.des import Application, SoCSimulator
from repro.soc.memsys import SoCStatic


@dataclasses.dataclass(frozen=True)
class StackedApps:
    """K compiled applications padded to a common schedule shape.

    ``schedule`` leaves carry a leading lane axis ``(K, S_max, ...)``;
    ``phase_mask[k, p]`` marks lane ``k``'s real phases and feeds the
    masked per-phase normalization."""

    schedule: vec.Schedule
    n_phases: int                  # padded P_max
    n_threads: int                 # padded T_max
    n_tiles: int                   # padded memory-tile axis
    n_steps: tuple                 # (K,) real invocations per lane
    phase_mask: jnp.ndarray        # (K, P_max) bool
    names: tuple
    phase_names: tuple             # per lane, real phases only
    compiled: tuple                # per-lane unpadded CompiledApp

    @property
    def n_lanes(self) -> int:
        return len(self.compiled)


def _pad_axis(arr: np.ndarray, axis: int, target: int, fill):
    if arr.shape[axis] == target:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, target - arr.shape[axis])
    return np.pad(arr, widths, constant_values=fill)


def pad_compiled(c: vec.CompiledApp, n_steps: int, n_threads: int,
                 n_tiles: int) -> vec.Schedule:
    """Pad one compiled schedule to ``(n_steps, n_threads, n_tiles)``.

    Padding rows are ``valid=False`` no-ops at the tail; padded thread
    slots / memory tiles are never set in any mask, so they contribute
    zeros to every sensed or timed quantity."""
    s = jax.tree_util.tree_map(np.asarray, c.schedule)
    return vec.Schedule(
        acc_id=_pad_axis(s.acc_id, 0, n_steps, 0),
        footprint=_pad_axis(s.footprint, 0, n_steps, 1.0),
        tiles=_pad_axis(_pad_axis(s.tiles, 1, n_tiles, False),
                        0, n_steps, False),
        thread=_pad_axis(s.thread, 0, n_steps, 0),
        phase_id=_pad_axis(s.phase_id, 0, n_steps, 0),
        fresh=_pad_axis(s.fresh, 0, n_steps, True),
        others=_pad_axis(_pad_axis(s.others, 1, n_threads, False),
                         0, n_steps, False),
        valid=_pad_axis(s.valid, 0, n_steps, False),
    )


def _stack_compiled(compiled: Sequence[vec.CompiledApp],
                    socs: Sequence[SoCConfig]) -> StackedApps:
    """Pad pre-compiled lanes to a common shape and stack them."""
    n_steps = max(c.n_steps for c in compiled)
    n_threads = max(c.n_threads for c in compiled)
    n_tiles = max(soc.n_mem_tiles for soc in socs)
    n_phases = max(c.n_phases for c in compiled)
    padded = [pad_compiled(c, n_steps, n_threads, n_tiles) for c in compiled]
    schedule = jax.tree_util.tree_map(
        lambda *xs: jnp.asarray(np.stack(xs)), *padded)
    phase_mask = jnp.asarray(np.stack([
        np.arange(n_phases) < c.n_phases for c in compiled]))
    return StackedApps(
        schedule=schedule, n_phases=n_phases, n_threads=n_threads,
        n_tiles=n_tiles, n_steps=tuple(c.n_steps for c in compiled),
        phase_mask=phase_mask, names=tuple(c.name for c in compiled),
        phase_names=tuple(c.phase_names for c in compiled),
        compiled=tuple(compiled))


def _compile_lanes(apps, socs, seed) -> list[vec.CompiledApp]:
    if len(apps) != len(socs):
        raise ValueError(f"{len(apps)} apps vs {len(socs)} socs")
    if np.isscalar(seed):
        seeds = [seed] * len(apps)
    else:
        seeds = list(seed)
        if len(seeds) != len(apps):
            raise ValueError(
                f"{len(seeds)} per-lane seeds vs {len(apps)} apps — "
                "a seed sequence must give exactly one seed per lane")
    return [vec.compile_app(a, soc, seed=s)
            for a, soc, s in zip(apps, socs, seeds)]


def compile_apps_stacked(apps: Sequence[Application],
                         socs: Sequence[SoCConfig],
                         seed: int | Sequence[int] = 0) -> StackedApps:
    """Compile one application per SoC and stack to a common shape.

    ``seed`` follows :func:`~repro.soc.vecenv.compile_app`'s tile-striping
    protocol — a scalar is shared by every lane (each lane still draws its
    own rng stream, exactly as its unstacked compile would), a sequence
    gives one seed per lane."""
    return _stack_compiled(_compile_lanes(apps, socs, seed), list(socs))


def padded_waste(stacked: StackedApps) -> float:
    """Fraction of the stacked scan's steps that are padding no-ops."""
    k, s_max = stacked.schedule.acc_id.shape[:2]
    return 1.0 - sum(stacked.n_steps) / float(k * s_max)


def length_buckets(lengths: Sequence[int], max_buckets: int = 2,
                   min_gain: float = 0.05) -> list[list[int]]:
    """Partition lane indices by schedule length to cut padded-step waste.

    Every lane of a stacked call pads to the longest schedule in its
    bucket; when lengths diverge, splitting the lanes into up to
    ``max_buckets`` calls — each padded only to its own max — trades
    extra dispatches for fewer wasted scan steps (~15% on the Fig. 9 set
    with 2 buckets; much more on generated design-space samples).

    Cuts are placed greedily on the sorted-length prefix-waste curve:
    each round takes the single cut (anywhere inside any current bucket)
    that removes the most padded volume, and stops when the best cut
    saves less than ``min_gain`` of the single-call scan volume
    (``k * max(lengths)``) — so near-uniform sets still return one
    bucket, and ``max_buckets=2`` reproduces the old single-cut search
    exactly.  Returns index groups in ascending length order, original
    index order inside each group."""
    lens = [int(l) for l in lengths]
    k = len(lens)
    single = [list(range(k))]
    if k < 2 or max_buckets < 2:
        return single
    order = sorted(range(k), key=lambda i: lens[i])
    sl = [lens[i] for i in order]
    volume = float(k * sl[-1])

    def seg_waste(a: int, b: int) -> int:
        """Padded waste of sorted segment [a, b) stacked as one call."""
        return sl[b - 1] * (b - a) - sum(sl[a:b])

    cuts = [0, k]
    while len(cuts) - 1 < max_buckets:
        best_gain, best_cut = 0.0, None
        for a, b in zip(cuts, cuts[1:]):
            base = seg_waste(a, b)
            for c in range(a + 1, b):
                gain = (base - seg_waste(a, c) - seg_waste(c, b)) / volume
                if gain > best_gain:
                    best_gain, best_cut = gain, c
        if best_cut is None or best_gain < min_gain:
            break
        cuts = sorted(cuts + [best_cut])
    if len(cuts) == 2:
        return single
    return [sorted(order[a:b]) for a, b in zip(cuts, cuts[1:])]


def compile_apps_bucketed(
    apps: Sequence[Application], socs: Sequence[SoCConfig],
    seed: int | Sequence[int] = 0, max_buckets: int = 2,
    min_gain: float = 0.05,
) -> list[tuple[list[int], StackedApps]]:
    """:func:`compile_apps_stacked` with length bucketing: returns one
    ``(lane_indices, StackedApps)`` per bucket (at most ``max_buckets``).
    Pair each bucket with :meth:`StackedVecEnv.sublanes` to run it and
    :func:`reassemble_lanes` to put per-bucket results back in lane
    order."""
    compiled = _compile_lanes(apps, socs, seed)
    groups = length_buckets([c.n_steps for c in compiled],
                            max_buckets=max_buckets, min_gain=min_gain)
    return [(g, _stack_compiled([compiled[i] for i in g],
                                [socs[i] for i in g]))
            for g in groups]


def reassemble_lanes(groups: Sequence[Sequence[int]], parts: Sequence):
    """Invert bucketing: scatter per-bucket results back to lane order.

    ``groups`` are the index groups of :func:`length_buckets` /
    :func:`compile_apps_bucketed` (they partition ``range(k)``) and
    ``parts`` one pytree per bucket whose leaves carry that bucket's
    lanes on the leading axis.  Leaves must share trailing shapes across
    buckets — reduce per-lane metrics (e.g. normalized scalars) before
    reassembling, since buckets pad phases/steps to different maxima.
    Returns one pytree with leading axis ``k`` in original lane order."""
    index = np.concatenate([np.asarray(list(g), int) for g in groups])
    if sorted(index.tolist()) != list(range(len(index))):
        raise ValueError(f"groups {list(map(list, groups))} do not "
                         "partition the lane range")
    inv = np.argsort(index, kind="stable")

    def scatter(*leaves):
        return np.concatenate([np.asarray(l) for l in leaves])[inv]

    return jax.tree_util.tree_map(scatter, *parts)


@dataclasses.dataclass(frozen=True)
class _LaneView:
    """One stacked lane behind the vecenv lowering protocol (``.params``
    padded to the stacked shape, ``.profiles`` the lane's real ones)."""

    params: vec.LaneParams
    profiles: list


@dataclasses.dataclass(frozen=True)
class _LaneSchedule:
    """A padded lane schedule behind the ``.schedule`` protocol."""

    schedule: vec.Schedule


def _cfg_axes(cfg: qlearn.QConfig):
    """vmap in_axes spec for a QConfig whose leaves may carry a lane axis."""
    return qlearn.QConfig(*[
        0 if (hasattr(v, "ndim") and getattr(v, "ndim", 0) >= 1) else None
        for v in cfg])


class StackedVecEnv:
    """K SoCs as one vmapped environment (always the carry-cached step).

    Build with :meth:`from_simulators` to share DES simulators' resolved
    accelerator profiles (the cross-backend comparison protocol), or
    directly from configs.  All public entry points run every lane in a
    single jitted call.

    ``fused_step`` follows :class:`~repro.soc.vecenv.VecEnv`: ``None``
    (default) enables the :mod:`repro.kernels.soc_step` episode lowering —
    the stacked path always runs the fast (demand-cached, presampled)
    step, so only equivalence tests pass ``False``.
    """

    def __init__(self, socs: Sequence[SoCConfig], seed: int = 0,
                 flavors: Sequence[str] | str = "mixed",
                 envs: Sequence[vec.VecEnv] | None = None,
                 cycle_time: float = 1e-8,
                 fused_step: bool | None = None):
        if envs is None:
            if isinstance(flavors, str):
                flavors = [flavors] * len(socs)
            envs = [vec.VecEnv(soc, seed=seed, flavor=fl,
                               cycle_time=cycle_time)
                    for soc, fl in zip(socs, flavors)]
        self.envs = list(envs)
        self.socs = [e.soc for e in self.envs]
        self.cycle_time = float(self.envs[0].cycle_time)
        n_accs = max(soc.n_accs for soc in self.socs)
        feat = self.envs[0].pmat.shape[1]
        pmat = np.zeros((len(self.envs), n_accs, feat), np.float32)
        masks = np.ones((len(self.envs), n_accs, self.envs[0].masks.shape[1]),
                        bool)
        for k, env in enumerate(self.envs):
            pmat[k, :env.soc.n_accs] = np.asarray(env.pmat)
            masks[k, :env.soc.n_accs] = np.asarray(env.masks)
        static = SoCStatic(*[
            jnp.asarray([getattr(env.static, f) for env in self.envs],
                        jnp.float32)
            for f in SoCStatic._fields])
        self.n_accs = n_accs
        self.fused_step = bool(True if fused_step is None else fused_step)
        self.params = vec.LaneParams(pmat=jnp.asarray(pmat),
                                     masks=jnp.asarray(masks),
                                     static=static)
        self._cache: dict = {}
        # Jitted-call accounting: fig9's acceptance protocol asserts the
        # whole figure is one train + one eval call in --quick mode.
        self.calls = collections.Counter()

    @classmethod
    def from_simulators(cls, sims: Sequence[SoCSimulator],
                        cycle_time: float = 1e-8) -> "StackedVecEnv":
        envs = [vec.VecEnv.from_simulator(sim, cycle_time=cycle_time)
                for sim in sims]
        return cls([s.soc for s in sims], envs=envs, cycle_time=cycle_time)

    @property
    def n_lanes(self) -> int:
        return len(self.envs)

    def sublanes(self, lanes: Sequence[int]) -> "StackedVecEnv":
        """A stacked environment over a lane subset (shares the per-lane
        VecEnvs) — the execution half of :func:`length_buckets`."""
        return StackedVecEnv([self.socs[i] for i in lanes],
                             envs=[self.envs[i] for i in lanes],
                             cycle_time=self.cycle_time,
                             fused_step=self.fused_step)

    def compile(self, apps: Sequence[Application],
                seed: int | Sequence[int] = 0) -> StackedApps:
        return compile_apps_stacked(apps, self.socs, seed)

    # ------------------------------------------------------------ episodes
    def _episode_fn(self, n_phases: int, n_threads: int):
        key = ("ep", n_phases, n_threads)
        if key not in self._cache:
            self._cache[key] = vec.build_episode_fn(
                n_phases, n_threads, self.cycle_time,
                demand_cache=True, gated=True, fused=self.fused_step)
        return self._cache[key]

    def _default_keys(self, *batch) -> jnp.ndarray:
        n = int(np.prod(batch))
        return jax.vmap(jax.random.PRNGKey)(jnp.arange(n)).reshape(
            *batch, 2)

    def lane_view(self, lane: int):
        """Lane ``lane`` as a vecenv-protocol object (``.params`` padded to
        the stacked shape, ``.profiles``) — what ``Policy.lower`` needs."""
        return _LaneView(
            params=jax.tree_util.tree_map(lambda x: x[lane], self.params),
            profiles=self.envs[lane].profiles)

    def lower(self, stacked: StackedApps,
              policies) -> vec.PolicySpec:
        """Lower policies onto every padded lane: ``(K, N, ...)`` specs.

        ``policies`` is either one sequence of N :class:`Policy` shared by
        all lanes, or K sequences (N each) for per-lane assignments (e.g.
        per-SoC profiled heterogeneous baselines, per-SoC trained agents).
        The result feeds :meth:`episodes` directly."""
        if policies and isinstance(policies[0], Policy):
            policies = [policies] * self.n_lanes
        if len(policies) != self.n_lanes:
            raise ValueError(
                f"{len(policies)} policy rows vs {self.n_lanes} lanes")
        lane_specs = []
        for k, pols in enumerate(policies):
            view = self.lane_view(k)
            lane = _LaneSchedule(schedule=jax.tree_util.tree_map(
                lambda x: x[k], stacked.schedule))
            lane_specs.append(vec.stack_specs(
                [pol.lower(view, lane) for pol in pols]))
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *lane_specs)

    def lower_qstates(self, stacked: StackedApps, qstates: qlearn.QState,
                      freeze: bool = True) -> vec.PolicySpec:
        """Lower a (K, B) batch of trained agents into learned specs
        ((K, B, ...) leaves; ``freeze=True`` is the evaluation protocol)."""
        if freeze:
            # per-agent frozen flags (scalar-freeze would break the vmap)
            qstates = qstates._replace(
                frozen=jnp.ones(qstates.qtable.shape[:2], bool))
        k, b = qstates.qtable.shape[:2]
        s = stacked.schedule.acc_id.shape[-1]
        return vec.PolicySpec(
            modes=jnp.zeros((k, b, s), jnp.int32),
            learned=jnp.ones((k, b), bool),
            qstate=qstates)

    def lower_mlps(self, stacked: StackedApps, mlps,
                   freeze: bool = True) -> vec.PolicySpec:
        """Lower a (K, B) batch of function-approximation agents
        (:class:`repro.soc.nn.MLPQState` with (K, B)-leading leaves) into
        qfun specs ((K, B, ...) leaves) — the MLP analogue of
        :meth:`lower_qstates`.  The tabular slot broadcasts one frozen
        placeholder per (lane, agent)."""
        k, b = mlps.wpack.shape[:2]
        if freeze:
            mlps = mlps._replace(frozen=jnp.ones((k, b), bool))
        s = stacked.schedule.acc_id.shape[-1]
        qstate = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (k, b) + x.shape),
            qlearn.frozen_qstate())
        return vec.PolicySpec(
            modes=jnp.zeros((k, b, s), jnp.int32),
            learned=jnp.zeros((k, b), bool),
            qstate=qstate,
            qfun=jnp.ones((k, b), bool),
            mlp=mlps)

    def episodes(self, stacked: StackedApps, specs: vec.PolicySpec,
                 cfg: qlearn.QConfig | None = None,
                 keys=None, faults=None) -> vec.EpisodeResult:
        """Every (lane, policy) episode of a heterogeneous spec batch in
        ONE jitted call.

        ``specs`` leaves carry a leading ``(K, N)`` (lanes x policies)
        batch — mixed families welcome (:meth:`lower` builds them from
        Policy objects, :meth:`lower_qstates` from trained agents) —
        and the returned EpisodeResult has (K, N, ...) leaves.  This
        replaces the old per-family ``episodes_fixed`` /
        ``episodes_manual`` / ``episodes_q`` triple: the Fig. 9
        evaluation is one call for ALL families across ALL SoCs."""
        self.calls["episodes"] += 1
        cfg = cfg or qlearn.QConfig()
        K, N = specs.learned.shape
        if keys is None:
            keys = self._default_keys(K, N)
        axes = _cfg_axes(cfg)
        cache_key = ("episodes_jit", stacked.n_phases, stacked.n_threads,
                     tuple(axes))
        if cache_key not in self._cache:
            ep = self._episode_fn(stacked.n_phases, stacked.n_threads)
            w = rewards.PAPER_DEFAULT_WEIGHTS

            # One FaultSpec perturbs every (lane, policy) episode
            # identically: in_axes None at both vmap levels.
            def one(params, sched, cfg_, spec, key, f):
                _, res = ep(params, sched, spec, cfg_, w, key, f)
                return res

            self._cache[cache_key] = jax.jit(jax.vmap(
                jax.vmap(one, in_axes=(None, None, None, 0, 0, None)),
                in_axes=(0, 0, axes, 0, 0, None)))
        return self._cache[cache_key](self.params, stacked.schedule, cfg,
                                      specs, keys, faults)

    def baseline(self, stacked: StackedApps,
                 faults=None) -> vec.EpisodeResult:
        """Per-lane fixed NON_COH_DMA episode ((K, ...) leaves) — the
        paper's normalization baseline."""
        specs = self.lower(stacked,
                           [FixedHomogeneous(CoherenceMode.NON_COH_DMA)])
        res = self.episodes(stacked, specs, faults=faults)
        return jax.tree_util.tree_map(lambda x: x[:, 0], res)

    # ------------------------------------------------------------- serving
    def serve(self, stacked: StackedApps, specs: vec.PolicySpec,
              traffic, cfg: qlearn.QConfig | None = None,
              keys=None, faults=None, *, queue_cap: int = 8,
              n_requests: int = 1024):
        """Every (lane, policy) serving chunk of one offered stream in ONE
        jitted call — the serving analogue of :meth:`episodes`.

        ``specs`` leaves carry a leading ``(K, N)`` batch; the
        :class:`~repro.soc.traffic.TrafficSpec` replicates across lanes
        and policies (identical arrival times/tenants everywhere — lanes
        map the shared row *indices* onto their own schedules, sampled
        over each lane's real row count so padding rows are never
        invoked).  Returns ``(carry, qstate, ServeResult)`` with
        ``(K, N, ...)`` leaves."""
        self.calls["serve"] += 1
        cfg = cfg or qlearn.QConfig()
        K, N = specs.learned.shape
        if keys is None:
            keys = self._default_keys(K, N)
        axes = _cfg_axes(cfg)
        cache_key = ("serve_jit", stacked.n_phases, stacked.n_threads,
                     queue_cap, n_requests, tuple(axes))
        if cache_key not in self._cache:
            base = vec.build_serve_fn(n_requests, queue_cap,
                                      fused=self.fused_step)
            w = rewards.PAPER_DEFAULT_WEIGHTS
            t0 = jnp.zeros((), jnp.float32)

            def one(params, sched, n_real, cfg_, spec, tspec, key, f):
                return base(params, sched, spec, cfg_, w, tspec, None,
                            key, t0, f, n_real)

            self._cache[cache_key] = jax.jit(jax.vmap(
                jax.vmap(one, in_axes=(None, None, None, None, 0, None,
                                       0, None)),
                in_axes=(0, 0, 0, axes, 0, None, 0, None)))
        n_real = jnp.asarray(stacked.n_steps, jnp.int32)
        return self._cache[cache_key](self.params, stacked.schedule,
                                      n_real, cfg, specs, traffic, keys,
                                      faults)

    # ------------------------------------------------------------ training
    def train_batched(self, stacked_iters: Sequence[StackedApps],
                      cfg: qlearn.QConfig,
                      weights_batch: rewards.RewardWeights,
                      keys,
                      eval_stacked: StackedApps | None = None,
                      faults=None) -> tuple[qlearn.QState, tuple]:
        """Train (K lanes x B agents) in one jitted call.

        ``stacked_iters`` is one StackedApps per training iteration (each
        compiled with its own tile seed, the DES's per-iteration protocol);
        all iterations share one schedule shape.  ``weights_batch`` has
        (B,) leaves, ``keys`` is (K, B, 2).  ``cfg.decay_steps`` may be a
        (K,) array for per-lane decay horizons (lanes differ in
        invocations per iteration).  Returns a QState with (K, B, ...)
        leaves and, when ``eval_stacked`` is given, per-iteration
        (norm_time, norm_mem) histories of shape (K, B, iterations)."""
        self.calls["train"] += 1
        first = stacked_iters[0]
        scheds = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=1),
            *[st.schedule for st in stacked_iters])
        eval_shape = (None if eval_stacked is None
                      else (eval_stacked.n_phases, eval_stacked.n_threads))
        if eval_stacked is not None:
            eval_sched = eval_stacked.schedule
            base = self.baseline(eval_stacked, faults=faults)
            pmask = eval_stacked.phase_mask
            eval_axes = (0, 0, 0)
        else:
            eval_sched = base = pmask = None
            eval_axes = (None, None, None)

        B = keys.shape[1]
        q0 = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (self.n_lanes,) + x.shape),
            qlearn.init_qstate_batch(qlearn.QConfig(), B))
        axes = _cfg_axes(cfg)
        carry_axes = vec.TrainCarry(key=0, it=None, best=0)
        cache_key = ("train_jit", first.n_phases, first.n_threads,
                     eval_shape, tuple(axes))
        if cache_key not in self._cache:
            train_one = vec.build_train_fn(
                first.n_phases, first.n_threads, eval_shape,
                self.cycle_time, demand_cache=True, gated=True,
                fused=self.fused_step)
            # Carry batches (key, best) per agent / per lane; the
            # iteration counter and the FaultSpec replicate everywhere.
            agents = jax.vmap(train_one,
                              in_axes=(None, None, None, None, None, None,
                                       rewards.RewardWeights(0, 0, 0),
                                       carry_axes, 0, None),
                              out_axes=(0, carry_axes, 0))
            self._cache[cache_key] = jax.jit(jax.vmap(
                agents,
                in_axes=(0, 0, *eval_axes, axes, None, carry_axes, 0, None),
                out_axes=(0, carry_axes, 0)))
        carry0 = vec.TrainCarry(
            key=jnp.asarray(keys), it=jnp.zeros((), jnp.int32),
            best=jnp.full(keys.shape[:2], -jnp.inf, jnp.float32))
        qs, _, hist = self._cache[cache_key](
            self.params, scheds, eval_sched, base, pmask, cfg,
            weights_batch, carry0, q0, faults)
        return qs, hist

    def evaluate_batched(self, stacked: StackedApps, qstates: qlearn.QState,
                         cfg: qlearn.QConfig, keys=None, faults=None
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Frozen-greedy evaluation of (K, B) agents vs the per-lane
        NON_COH baseline; returns (norm_time, norm_mem), each (K, B)."""
        base = self.baseline(stacked, faults=faults)
        res = self.episodes(stacked,
                            self.lower_qstates(stacked, qstates),
                            cfg, keys=keys, faults=faults)
        lanes = jax.vmap(jax.vmap(vec.normalized_metrics,
                                  in_axes=(0, None, None)),
                         in_axes=(0, 0, 0))
        return lanes(res, base, stacked.phase_mask)

    # ----------------------------------------------------------- host side
    def lane_phase_metrics(self, stacked: StackedApps,
                           res: vec.EpisodeResult, lane: int
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Lane ``lane``'s real-phase (wall time, off-chip accesses) from a
        stacked EpisodeResult (any leading policy axes are preserved)."""
        n_ph = stacked.compiled[lane].n_phases
        pt = np.asarray(res.phase_time)[lane][..., :n_ph]
        po = np.asarray(res.phase_offchip)[lane][..., :n_ph]
        return pt, po
