"""Discrete-event simulator of the ESP-like SoC running phased applications.

This is the fidelity path of the reproduction (the scale path is
``soc.vecenv``).  It mirrors the paper's runtime structure:

  * an *application* is a list of phases; a *phase* is a set of software
    threads; a *thread* is a chain of accelerator invocations over one
    dataset (output of one feeds the next), optionally looped (paper §5);
  * at each invocation the runtime senses the Table-3 state, asks the
    policy for a coherence mode, actuates it, and on completion evaluates
    the paper's multi-objective reward from the hardware monitors —
    including the paper's *attributed* (approximate) DRAM counts;
  * invocation timing comes from the jnp memory-system model, evaluated
    against the set of concurrently-active accelerators at start time
    (single-rate approximation, noted in DESIGN.md).

The simulator is deliberately host-Python (heap-based event loop, like a
real driver stack) with all timing math jitted.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rewards, state as cstate
from repro.core.modes import CoherenceMode, N_MODES, flush_kind
from repro.core.policies import DecisionContext, Policy
from repro.soc import faults as fault_mod
from repro.soc.accelerators import AccProfile, profile_matrix, resolve_profiles
from repro.soc.config import SoCConfig
from repro.soc.memsys import SoCStatic, invocation_perf, warmth_after

MAX_SLOTS = 32           # fixed concurrency slots for the jitted model
# Allocation interleaving across memory tiles: ESP partitions the address
# space per memory tile and accelerator data spreads across partitions
# (the paper's ddr(k,m) attribution sums footprint(acc, m) over tiles m,
# and its L workload class "smaller than the AGGREGATE LLC" presumes
# multi-partition residency).  256KB page-set striping reproduces that.
_STRIPE_BYTES = 256 << 10


def stripe_tiles(rng: np.random.Generator, n_tiles: int,
                 footprint: float) -> np.ndarray:
    """Memory-tile mask for one invocation: contiguous 256KB-page-set
    striping from a random start tile.  Shared by the DES and the
    vectorized environment's tracer — one ``rng.integers`` draw per
    invocation is part of the cross-path equivalence contract
    (tests/test_vecenv_equivalence.py)."""
    span = int(min(n_tiles, max(1, int(np.ceil(footprint / _STRIPE_BYTES)))))
    start = int(rng.integers(0, n_tiles))
    mask = np.zeros(n_tiles, bool)
    for k in range(span):
        mask[(start + k) % n_tiles] = True
    return mask


@dataclasses.dataclass(frozen=True)
class Invocation:
    acc_id: int
    footprint: float


@dataclasses.dataclass(frozen=True)
class Thread:
    chain: Sequence[Invocation]
    loops: int = 1


@dataclasses.dataclass(frozen=True)
class Phase:
    name: str
    threads: Sequence[Thread]


@dataclasses.dataclass(frozen=True)
class Application:
    name: str
    phases: Sequence[Phase]


@dataclasses.dataclass
class InvocationRecord:
    acc_id: int
    acc_name: str
    footprint: float
    mode: int
    state_idx: int
    start: float
    end: float
    exec_time: float
    offchip_true: float       # ground-truth line accesses
    offchip_attr: float       # paper-attributed line accesses
    reward: float


@dataclasses.dataclass
class PhaseResult:
    name: str
    wall_time: float
    offchip_accesses: float
    invocations: list[InvocationRecord]


@dataclasses.dataclass
class RunResult:
    policy: str
    phases: list[PhaseResult]
    decide_overhead_s: float   # mean host-side seconds per decision

    @property
    def total_time(self) -> float:
        return sum(p.wall_time for p in self.phases)

    @property
    def total_offchip(self) -> float:
        return sum(p.offchip_accesses for p in self.phases)


class _Active:
    """Bookkeeping for one in-flight invocation."""

    __slots__ = ("acc_id", "mode", "footprint", "tiles", "start", "end",
                 "offchip_per_tile", "meas", "state_idx", "ddr_before")

    def __init__(self, **kw):
        for k, v in kw.items():
            setattr(self, k, v)


def _make_perf_fn(s: SoCStatic) -> Callable:
    @partial(jax.jit, static_argnames=())
    def fn(mode, profile, footprint, my_tiles, other_modes, other_profiles,
           other_footprints, other_tiles, warm_frac, fault=None):
        # ``fault=None`` jits to the exact pre-fault program (None is an
        # empty pytree, so fault-free runs stay bitwise-identical); a
        # StepFault row perturbs this invocation's timing exactly like the
        # vectorized environment's faulted scan step does.
        m, aux = invocation_perf(
            mode, profile, footprint, my_tiles, other_modes, other_profiles,
            other_footprints, other_tiles, warm_frac, s, fault=fault)
        return (m.exec_time, m.comm_cycles, m.total_cycles,
                m.offchip_accesses, aux["offchip_bytes"])
    return fn


class SoCSimulator:
    """Event-driven simulator for one SoC + accelerator set."""

    def __init__(self, soc: SoCConfig, profiles: Sequence[AccProfile] | None = None,
                 seed: int = 0, flavor: str = "mixed"):
        self.soc = soc
        rng = np.random.default_rng(seed)
        self.profiles = list(profiles) if profiles is not None else (
            resolve_profiles(soc.accelerators, rng, flavor))
        assert len(self.profiles) == soc.n_accs
        self.pmat = profile_matrix(self.profiles)
        self.static = SoCStatic.from_config(soc)
        self.perf_fn = _make_perf_fn(self.static)
        self.geom = soc.geometry
        # Per-accelerator action masks (SoC3: some lack a private cache).
        self.masks = np.ones((soc.n_accs, N_MODES), bool)
        for i in soc.no_private_cache:
            self.masks[i, CoherenceMode.FULLY_COH] = False

    # ---------------------------------------------------------------- tiles
    def _tiles_for(self, rng: np.random.Generator, footprint: float) -> np.ndarray:
        return stripe_tiles(rng, self.soc.n_mem_tiles, footprint)

    # ----------------------------------------------------------------- run
    def run(self, app: Application, policy: Policy, seed: int = 0,
            train: bool = True, cycle_time: float = 1e-8,
            weights: rewards.RewardWeights | None = None,
            faults: fault_mod.FaultSpec | None = None) -> RunResult:
        rng = np.random.default_rng(seed)
        n_tiles = self.soc.n_mem_tiles
        reward_state = rewards.init_reward_state(self.soc.n_accs)
        w = weights or rewards.PAPER_DEFAULT_WEIGHTS
        eval_fn = jax.jit(
            lambda rs, k, m: rewards.evaluate(rs, k, m, w)
        )

        # Fault injection mirrors the vectorized environment: one uniform
        # draw from the spec's own key over the app's total invocation
        # count, indexed by a global invocation-start counter.  On
        # single-thread applications start order equals the compiled
        # schedule's row order, so the DES sees the exact per-step fault
        # rows the vecenv scan consumes (the --fidelity cross-check).
        fault_u = None
        if faults is not None:
            n_total = sum(len(th.chain) * th.loops
                          for ph in app.phases for th in ph.threads)
            fault_u = fault_mod.sample_fault_uniforms(faults, n_total)
        inv_counter = 0

        phase_results: list[PhaseResult] = []
        decide_times: list[float] = []

        for phase in app.phases:
            now = 0.0
            active: dict[int, _Active] = {}       # thread_id -> in-flight
            completed_traffic = np.zeros(n_tiles, np.float64)
            records: list[InvocationRecord] = []
            # thread program counters
            progs: list[list[Invocation]] = []
            for th in phase.threads:
                seqs: list[Invocation] = []
                for _ in range(th.loops):
                    seqs.extend(th.chain)
                progs.append(seqs)
            pcs = [0] * len(progs)
            warm: list[float] = [1.0] * len(progs)  # data warm at phase start
            heap: list[tuple[float, int, int]] = []  # (time, seq, thread)
            seq = 0
            for t in range(len(progs)):
                heapq.heappush(heap, (0.0, seq, t)); seq += 1
            pending_start = set(range(len(progs)))
            # Device locking: an accelerator instance is serially shared —
            # the driver queues concurrent requests (paper §1: accelerators
            # are "shared among multiple cores on an as-needed basis").
            busy_until = [0.0] * self.soc.n_accs

            def ddr_counters(at: float) -> np.ndarray:
                """Continuous-counter model: completed + prorated in-flight."""
                out = completed_traffic.copy()
                for a in active.values():
                    frac = 0.0 if a.end <= a.start else np.clip(
                        (at - a.start) / (a.end - a.start), 0.0, 1.0)
                    out += a.offchip_per_tile * frac
                return out

            def footprint_map() -> np.ndarray:
                fp = np.zeros((self.soc.n_accs, n_tiles), np.float64)
                for a in active.values():
                    fp[a.acc_id][a.tiles] += a.footprint / a.tiles.sum()
                return fp

            while heap:
                now, _, tid = heapq.heappop(heap)
                if tid in active and tid not in pending_start:
                    # completion event for thread tid
                    a = active.pop(tid)
                    completed_traffic += a.offchip_per_tile
                    fp_map = footprint_map()
                    fp_map[a.acc_id][a.tiles] += a.footprint / a.tiles.sum()
                    ddr_after = ddr_counters(now)
                    delta = np.maximum(ddr_after - a.ddr_before, 0.0)
                    tot = fp_map.sum(axis=0)
                    share = np.divide(
                        fp_map[a.acc_id], np.maximum(tot, 1e-9))
                    attr = float((delta * share).sum())
                    meas = rewards.Measurement(
                        exec_time=jnp.float32(a.meas["exec_time"]),
                        comm_cycles=jnp.float32(a.meas["comm_cycles"]),
                        total_cycles=jnp.float32(a.meas["total_cycles"]),
                        offchip_accesses=jnp.float32(attr),
                        footprint=jnp.float32(a.footprint),
                    )
                    r, reward_state, _ = eval_fn(
                        reward_state, jnp.int32(a.acc_id), meas)
                    r = float(r)
                    ctx = self._ctx(a.acc_id, a.footprint, a.state_idx,
                                    active, rng)
                    if train:
                        policy.observe_reward(ctx, a.mode, r)
                    records.append(InvocationRecord(
                        acc_id=a.acc_id,
                        acc_name=self.profiles[a.acc_id].name,
                        footprint=a.footprint, mode=a.mode,
                        state_idx=a.state_idx, start=a.start, end=now,
                        exec_time=a.meas["exec_time"],
                        offchip_true=float(a.offchip_per_tile.sum()),
                        offchip_attr=attr, reward=r))
                    # producer mode determines how warm the next stage's
                    # input is (NON_COH leaves data off-chip).
                    warm[tid] = self._warmth_after(a.mode, a.footprint)
                    pending_start.add(tid)
                    heapq.heappush(heap, (now, seq, tid)); seq += 1
                    continue

                # start event for thread tid
                if pcs[tid] >= len(progs[tid]):
                    pending_start.discard(tid)
                    continue
                inv = progs[tid][pcs[tid]]
                if busy_until[inv.acc_id] > now:
                    # instance busy: the driver queues us; retry at release
                    heapq.heappush(heap, (busy_until[inv.acc_id], seq, tid))
                    seq += 1
                    continue
                pending_start.discard(tid)
                pcs[tid] += 1
                tiles = self._tiles_for(rng, inv.footprint)
                state_idx = self._sense(inv, tiles, active)
                ctx = self._ctx(inv.acc_id, inv.footprint, state_idx,
                                active, rng, target_tiles=tiles,
                                warm=warm[tid])
                t0 = time.perf_counter()
                mode = int(policy.decide(ctx))
                decide_times.append(time.perf_counter() - t0)
                if (not self.masks[inv.acc_id][mode]
                        or not np.isfinite(inv.footprint)):
                    mode = int(CoherenceMode.NON_COH_DMA)

                frow = None
                if faults is not None:
                    frow = fault_mod.fault_row(
                        faults, jnp.int32(inv_counter),
                        jnp.int32(inv.acc_id),
                        jnp.asarray(fault_u[inv_counter]))
                inv_counter += 1
                o_modes, o_profiles, o_fps, o_tiles = self._slots(active)
                exec_t, comm_c, tot_c, off_acc, off_bytes = self.perf_fn(
                    jnp.int32(mode), jnp.asarray(self.pmat[inv.acc_id]),
                    jnp.float32(inv.footprint), jnp.asarray(tiles),
                    o_modes, o_profiles, o_fps, o_tiles,
                    jnp.float32(warm[tid]), frow)
                exec_t = float(exec_t)
                per_tile = np.zeros(n_tiles, np.float64)
                per_tile[tiles] = float(off_acc) / tiles.sum()
                active[tid] = _Active(
                    acc_id=inv.acc_id, mode=mode, footprint=inv.footprint,
                    tiles=tiles, start=now, end=now + exec_t * cycle_time,
                    offchip_per_tile=per_tile,
                    meas={"exec_time": exec_t, "comm_cycles": float(comm_c),
                          "total_cycles": float(tot_c)},
                    state_idx=state_idx,
                    ddr_before=ddr_counters(now))
                busy_until[inv.acc_id] = active[tid].end
                heapq.heappush(heap, (active[tid].end, seq, tid)); seq += 1

            offchip = float(completed_traffic.sum())
            phase_results.append(PhaseResult(
                name=phase.name, wall_time=now, offchip_accesses=offchip,
                invocations=records))

        return RunResult(
            policy=policy.name, phases=phase_results,
            decide_overhead_s=float(np.mean(decide_times)) if decide_times else 0.0)

    # ------------------------------------------------------------- helpers
    def _warmth_after(self, mode: int, footprint: float) -> float:
        cap = (self.soc.llc_total_bytes + self.soc.n_cpus * self.soc.l2_bytes)
        return float(warmth_after(mode, footprint, cap))

    def _slots(self, active: dict[int, _Active]):
        n_tiles = self.soc.n_mem_tiles
        o_modes = np.full(MAX_SLOTS, -1, np.int32)
        o_profiles = np.zeros((MAX_SLOTS, self.pmat.shape[1]), np.float32)
        o_fps = np.zeros(MAX_SLOTS, np.float32)
        o_tiles = np.zeros((MAX_SLOTS, n_tiles), bool)
        for i, a in enumerate(list(active.values())[:MAX_SLOTS]):
            o_modes[i] = a.mode
            o_profiles[i] = self.pmat[a.acc_id]
            o_fps[i] = a.footprint
            o_tiles[i] = a.tiles
        return (jnp.asarray(o_modes), jnp.asarray(o_profiles),
                jnp.asarray(o_fps), jnp.asarray(o_tiles))

    def _sense(self, inv: Invocation, tiles: np.ndarray,
               active: dict[int, _Active]) -> int:
        return cstate.observe_host(
            active_modes=[a.mode for a in active.values()],
            active_footprints=[a.footprint for a in active.values()],
            needed_tiles=[a.tiles for a in active.values()],
            target_tiles=tiles,
            target_footprint=inv.footprint,
            geom=self.geom)

    def _ctx(self, acc_id: int, footprint: float, state_idx: int,
             active: dict[int, _Active], rng, *, target_tiles=None,
             warm: float = 1.0, slack: float = 0.0,
             reuse: float = 0.0) -> DecisionContext:
        return DecisionContext(
            acc_id=acc_id,
            acc_name=self.profiles[acc_id].name,
            footprint=footprint,
            state_idx=state_idx,
            active_modes=[a.mode for a in active.values()],
            active_footprint=sum(a.footprint for a in active.values()),
            available=self.masks[acc_id].tolist(),
            soc=self.soc,
            rng=rng,
            active_footprints=[a.footprint for a in active.values()],
            target_tiles=target_tiles,
            profile=self.pmat[acc_id],
            warm=warm, slack=slack, reuse=reuse)

    # ------------------------------------------------------------- serving
    def serve(self, sched, policy: Policy, arrivals, *,
              queue_cap: int = 8, backoff: float = 0.0,
              prio_reserve: float = 0.0, overload_frac: float = 0.0,
              pressure_beta: float = 0.05, max_retries: int = 3,
              train: bool = False,
              weights: rewards.RewardWeights | None = None,
              faults: fault_mod.FaultSpec | None = None,
              seed: int = 0) -> list:
        """Host mirror of the vectorized serving loop (``vecenv.ServeEnv``).

        Consumes a compiled :class:`~repro.soc.vecenv.Schedule` and a
        pre-sampled :class:`~repro.soc.traffic.Arrivals` table — the SAME
        table the vectorized path scans, so both paths see bit-identical
        offered traffic — and replays it request by request through this
        simulator's jitted timing model: bounded per-accelerator
        admission rings of ``queue_cap`` finish times, deadline shedding
        after ``max_retries`` exponentially backed-off attempts
        (``faults.backoff_cycles``), priority-weighted effective
        capacity, and the shed-pressure overload latch forcing NON_COH.

        This *extends the episodic ``run()``'s global invocation counter
        to an open-ended stream*: fault rows index by offered-request
        position (executed or shed), exactly like the vectorized path's
        ``sample_fault_arrays`` over the request stream.  Like the
        serving scan — and unlike the episodic event loop — requests are
        processed in arrival order with the per-accelerator slot table
        carrying each device's *last admitted* invocation, so the two
        paths share one concurrency approximation and the fidelity
        cross-check (``benchmarks/fig11_serving.py --fidelity``) compares
        like with like.

        Returns a list of per-request record dicts (arrival, admission
        outcome, start/finish, exec cycles, reward).
        """
        sched = jax.tree_util.tree_map(np.asarray, sched)
        arr = jax.tree_util.tree_map(np.asarray, arrivals)
        n_accs = self.soc.n_accs
        n_tiles = self.soc.n_mem_tiles
        n = int(arr.t_arr.shape[0])
        w = weights or rewards.PAPER_DEFAULT_WEIGHTS
        reward_state = rewards.init_reward_state(n_accs)
        eval_fn = jax.jit(lambda rs, k, m: rewards.evaluate(rs, k, m, w))
        rng = np.random.default_rng(seed)

        fault_u = None
        if faults is not None:
            fault_u = fault_mod.sample_fault_uniforms(faults, n)

        # Per-accelerator serving state (the ServeCarry, host-side).
        busy = np.zeros(n_accs)
        fin = np.zeros((n_accs, queue_cap))
        head = np.zeros(n_accs, np.int64)
        slot_mode = np.full(n_accs, -1, np.int64)
        slot_fp = np.zeros(n_accs)
        slot_tiles = np.zeros((n_accs, n_tiles), bool)
        pressure, tripped = 0.0, False

        records: list[dict] = []
        for i in range(n):
            row = int(arr.row[i])
            acc = int(sched.acc_id[row])
            t_a = float(arr.t_arr[i])
            dl = float(arr.deadline[i])
            pr = float(arr.priority[i])
            footprint = float(sched.footprint[row])
            tiles = np.asarray(sched.tiles[row], bool)

            # ---- admission: bounded retry-with-backoff ----------------
            cap_eff = queue_cap - prio_reserve * queue_cap * (1.0 - pr)
            executed, attempt, start = False, max_retries + 1, 0.0
            for r in range(max_retries + 1):
                t_r = t_a + backoff * (2.0 ** r - 1.0)
                depth_r = float((fin[acc] > t_r).sum())
                s_r = max(t_r, busy[acc])
                if depth_r < cap_eff and s_r <= dl:
                    executed, attempt, start = True, r, s_r
                    break
            degraded = tripped
            rec = {"t_arr": t_a, "acc_id": acc, "tenant": int(arr.tenant[i]),
                   "executed": executed, "retries": attempt,
                   "depth": float((fin[acc] > t_a).sum()),
                   "degraded": bool(degraded and executed),
                   "mode": -1, "state_idx": -1, "start": 0.0,
                   "finish": 0.0, "exec_time": 0.0, "latency": 0.0,
                   "reward": 0.0}

            if executed:
                # ---- sense against each device's last admitted work ---
                omask = (busy > start)
                omask[acc] = False
                omask &= slot_mode >= 0
                idx = np.nonzero(omask)[0]
                state_idx = cstate.observe_host(
                    active_modes=[int(slot_mode[j]) for j in idx],
                    active_footprints=[float(slot_fp[j]) for j in idx],
                    needed_tiles=[slot_tiles[j] for j in idx],
                    target_tiles=tiles, target_footprint=footprint,
                    geom=self.geom)
                ctx = DecisionContext(
                    acc_id=acc, acc_name=self.profiles[acc].name,
                    footprint=footprint, state_idx=state_idx,
                    active_modes=[int(slot_mode[j]) for j in idx],
                    active_footprint=float(slot_fp[idx].sum()),
                    available=self.masks[acc].tolist(),
                    soc=self.soc, rng=rng,
                    active_footprints=[float(slot_fp[j]) for j in idx],
                    target_tiles=tiles, profile=self.pmat[acc],
                    warm=1.0, slack=dl - t_a,
                    reuse=t_a - float(busy[acc]))
                mode = int(policy.decide(ctx))
                if degraded:
                    # graceful overload degradation (the serve_step rule)
                    mode = int(CoherenceMode.NON_COH_DMA)
                if (not self.masks[acc][mode]
                        or not np.isfinite(footprint)):
                    mode = int(CoherenceMode.NON_COH_DMA)

                frow = None
                if faults is not None:
                    frow = fault_mod.fault_row(
                        faults, jnp.int32(i), jnp.int32(acc),
                        jnp.asarray(fault_u[i]))
                o_modes = np.full(MAX_SLOTS, -1, np.int32)
                o_profiles = np.zeros((MAX_SLOTS, self.pmat.shape[1]),
                                      np.float32)
                o_fps = np.zeros(MAX_SLOTS, np.float32)
                o_tiles = np.zeros((MAX_SLOTS, n_tiles), bool)
                for k, j in enumerate(idx[:MAX_SLOTS]):
                    o_modes[k] = slot_mode[j]
                    o_profiles[k] = self.pmat[j]
                    o_fps[k] = slot_fp[j]
                    o_tiles[k] = slot_tiles[j]
                exec_t, comm_c, tot_c, off_acc, _ = self.perf_fn(
                    jnp.int32(mode), jnp.asarray(self.pmat[acc]),
                    jnp.float32(footprint), jnp.asarray(tiles),
                    jnp.asarray(o_modes), jnp.asarray(o_profiles),
                    jnp.asarray(o_fps), jnp.asarray(o_tiles),
                    jnp.float32(1.0), frow)
                exec_t = float(exec_t)
                finish = start + exec_t
                meas = rewards.Measurement(
                    exec_time=jnp.float32(exec_t),
                    comm_cycles=jnp.float32(float(comm_c)),
                    total_cycles=jnp.float32(float(tot_c)),
                    offchip_accesses=jnp.float32(float(off_acc)),
                    footprint=jnp.float32(footprint))
                r, reward_state, _ = eval_fn(reward_state, jnp.int32(acc),
                                             meas)
                if train:
                    policy.observe_reward(ctx, mode, float(r))
                fin[acc][head[acc]] = finish
                head[acc] = (head[acc] + 1) % queue_cap
                busy[acc] = finish
                slot_mode[acc] = mode
                slot_fp[acc] = footprint
                slot_tiles[acc] = tiles
                rec.update(mode=mode, state_idx=state_idx, start=start,
                           finish=finish, exec_time=exec_t,
                           latency=finish - t_a, reward=float(r))

            # ---- overload watchdog (EMA of the shed indicator) --------
            pressure = ((1.0 - pressure_beta) * pressure
                        + pressure_beta * (0.0 if executed else 1.0))
            if overload_frac > 0.0 and pressure > overload_frac:
                tripped = True
            elif pressure < 0.5 * overload_frac:
                tripped = False
            records.append(rec)
        return records
