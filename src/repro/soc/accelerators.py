"""Accelerator communication profiles and the configurable traffic-generator.

Paper §5: "From the viewpoint of the rest of the SoC, an accelerator can be
characterized by its patterns of communication with the memory hierarchy."
The traffic-generator parameters are exactly the paper's list: access
pattern (streaming / strided / irregular), DMA burst length, compute
duration, data reuse factor, read-to-write ratio, stride length, access
fraction, and in-place storage.

The 12 named profiles model the ESP accelerators of Table 2 at the same
granularity the traffic-generator uses — what matters to the memory system
is the pattern, not the math inside the datapath.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

STREAMING, STRIDED, IRREGULAR = 0, 1, 2
PATTERN_NAMES = ("streaming", "strided", "irregular")


@dataclasses.dataclass(frozen=True)
class AccProfile:
    """Traffic-generator parameter bundle for one accelerator (paper §5)."""

    name: str
    pattern: int = STREAMING
    burst_bytes: float = 256.0    # DMA burst length
    compute_per_byte: float = 2.0  # datapath cycles per byte processed
    reuse: float = 1.0            # times each input byte is re-read
    read_frac: float = 0.75       # read / (read + write) traffic split
    stride_bytes: float = 0.0     # strided pattern stride
    access_frac: float = 1.0      # irregular: fraction of footprint touched
    in_place: bool = False        # output overwrites input region
    engines: int = 1              # internal engines (night-vision has 4)

    def asarray(self) -> np.ndarray:
        """Pack into a flat float32 vector for the jnp timing model."""
        return np.asarray(
            [
                self.pattern,
                self.burst_bytes,
                self.compute_per_byte,
                self.reuse,
                self.read_frac,
                self.stride_bytes,
                self.access_frac,
                1.0 if self.in_place else 0.0,
                self.engines,
            ],
            np.float32,
        )


class ProfileArray(NamedTuple):
    """Column names for the packed profile vector."""

    PATTERN: int = 0
    BURST: int = 1
    COMPUTE: int = 2
    REUSE: int = 3
    READ_FRAC: int = 4
    STRIDE: int = 5
    ACCESS_FRAC: int = 6
    IN_PLACE: int = 7
    ENGINES: int = 8


PF = ProfileArray()
PROFILE_WIDTH = 9

# The ESP accelerator suite (paper Table 2 / §3).  Parameters chosen to
# reproduce the communication behaviour reported in the paper: GEMM / MRI-Q
# are compute-bound with heavy reuse, SPMV is irregular and latency-bound,
# FFT is a multi-pass in-place strided kernel, Sort is a multi-pass
# streaming kernel, etc.
PROFILES = {
    "autoencoder": AccProfile("autoencoder", STREAMING, 512, 0.2, 2.0, 0.80),
    "cholesky": AccProfile("cholesky", STRIDED, 128, 0.8, 3.0, 0.70,
                           stride_bytes=512, in_place=True),
    "conv2d": AccProfile("conv2d", STREAMING, 256, 0.5, 2.0, 0.80),
    "fft": AccProfile("fft", STRIDED, 64, 0.25, 3.0, 0.50,
                      stride_bytes=1024, in_place=True),
    "gemm": AccProfile("gemm", STREAMING, 512, 2.5, 4.0, 0.85),
    "mlp": AccProfile("mlp", STREAMING, 512, 0.5, 1.5, 0.85),
    "mriq": AccProfile("mriq", STREAMING, 256, 5.0, 1.0, 0.90),
    "nvdla": AccProfile("nvdla", STREAMING, 256, 1.2, 3.0, 0.80),
    "nightvision": AccProfile("nightvision", STREAMING, 128, 1.2, 2.0, 0.60,
                              engines=4),
    "sort": AccProfile("sort", STREAMING, 256, 0.15, 4.0, 0.50, in_place=True),
    "spmv": AccProfile("spmv", IRREGULAR, 8, 0.2, 1.2, 0.90, access_frac=0.4),
    "viterbi": AccProfile("viterbi", STRIDED, 64, 0.8, 2.0, 0.75,
                          stride_bytes=256),
}


def sample_traffic_profile(rng: np.random.Generator, name: str) -> AccProfile:
    """Sample a random traffic-generator configuration (paper §5).

    Used for SoC1/2/3 whose accelerators are traffic-generator instances.
    """
    pattern = int(rng.integers(0, 3))
    return AccProfile(
        name=name,
        pattern=pattern,
        burst_bytes=float(rng.choice([8, 16, 64, 128, 256, 512, 1024])),
        compute_per_byte=float(rng.uniform(0.1, 5.0)),
        reuse=float(rng.uniform(1.0, 4.0)),
        read_frac=float(rng.uniform(0.4, 0.95)),
        stride_bytes=float(rng.choice([64, 256, 1024])) if pattern == STRIDED else 0.0,
        access_frac=float(rng.uniform(0.1, 0.6)) if pattern == IRREGULAR else 1.0,
        in_place=bool(rng.uniform() < 0.3),
    )


def sample_streaming_profile(rng: np.random.Generator, name: str) -> AccProfile:
    """Streaming-only traffic-gen set (Fig. 9 'SoC0 streaming')."""
    return dataclasses.replace(
        sample_traffic_profile(rng, name),
        pattern=STREAMING, stride_bytes=0.0, access_frac=1.0,
        burst_bytes=float(rng.choice([256, 512, 1024])),
    )


def sample_irregular_profile(rng: np.random.Generator, name: str) -> AccProfile:
    """Irregular-only traffic-gen set (Fig. 9 'SoC0 irregular')."""
    return dataclasses.replace(
        sample_traffic_profile(rng, name),
        pattern=IRREGULAR, burst_bytes=float(rng.choice([8, 16])),
        access_frac=float(rng.uniform(0.1, 0.6)),
        reuse=float(rng.uniform(1.2, 3.0)),
    )


def resolve_profiles(names, rng: np.random.Generator | None = None,
                     flavor: str = "mixed") -> list[AccProfile]:
    """Map SoC accelerator names to profiles; traffic* names are sampled."""
    rng = rng or np.random.default_rng(0)
    sampler = {
        "mixed": sample_traffic_profile,
        "streaming": sample_streaming_profile,
        "irregular": sample_irregular_profile,
    }[flavor]
    out = []
    for n in names:
        if n.startswith("traffic"):
            out.append(sampler(rng, n))
        else:
            out.append(PROFILES[n])
    return out


def profile_matrix(profiles) -> np.ndarray:
    """(n_accs, PROFILE_WIDTH) float32 matrix for the jnp timing model."""
    return np.stack([p.asarray() for p in profiles]).astype(np.float32)
