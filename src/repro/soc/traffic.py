"""Continuous multi-tenant traffic for the serving path — spec + arrivals.

The episodic environments replay a *closed* world: a compiled schedule of
``n_steps`` invocations, then the world ends.  Serving (``vecenv.ServeEnv``)
opens it: requests arrive over continuous time from a stochastic process,
compete for bounded per-accelerator admission queues, and are shed when
their deadline cannot be met.  This module owns the arrival side of that
loop as a scalar-pytree spec plus one pre-sampled arrival table per chunk —
the ``qlearn.SelectNoise`` / ``faults.StepFault`` pattern:

  * :class:`TrafficSpec` is a pytree of scalar ``jnp`` leaves (plus small
    per-tenant vectors) carrying its OWN threefry key, so traffic streams
    compose with the episode/serving key protocol without perturbing it,
    and sweeping any knob (rate, burstiness, deadlines ...) reuses the
    compiled program — the leaves are traced, never baked in;
  * :func:`sample_arrivals` lowers a spec to an :class:`Arrivals` table for
    one chunk of ``n_requests`` offered requests in one batched draw —
    arrival times, the schedule row each request invokes, tenant, absolute
    deadline and priority.  The table rides the serving scan's xs; no host
    Python ever runs per-request;
  * the DES mirror (``SoCSimulator.serve``) consumes the *same* table via
    ``np.asarray``, so the fidelity cross-check replays bit-identical
    arrivals through the host event loop.

Arrival process: a 2-state Markov-modulated Poisson process (MMPP-2).  The
chain sits in a *calm* state (rate ``rate``) or a *burst* state (rate
``rate * burst_rate``) and flips with per-arrival probabilities
``p_burst`` (calm -> burst) and ``p_calm`` (burst -> calm); exponential
inter-arrival gaps are inverse-CDF transforms of pre-sampled uniforms, so
``burst_rate == 1`` degenerates to a plain Poisson stream regardless of
the chain (the :func:`poisson` constructor).

Tenancy: ``mix`` weights a K-way categorical tenant draw (Gumbel argmax —
one pre-sampled ``(n, K)`` table).  Tenant ``k`` invokes rows from its
contiguous slice of the compiled schedule (``[k*S/K, (k+1)*S/K)``), so a
multi-tenant stream exercises disjoint working sets; per-tenant relative
``deadline`` cycles (``<= 0`` disables — the request never sheds on time)
and ``priority`` in [0, 1] (weights each tenant's share of the admission
queue via ``prio_reserve``) complete the request.

The serving-robustness knobs (``backoff``, ``overload_frac``,
``pressure_beta``, ``prio_reserve``) live on the spec too: they are
properties of the offered traffic contract (how hard to retry, when the
service may degrade), and keeping them here means one pytree configures a
whole serving run.  ``vecenv.build_serve_fn`` threads them into the fused
step's :class:`~repro.kernels.soc_step.ref.ServeParams`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Deadline sentinel: far beyond any reachable simulated-cycle timestamp,
# finite so the admission compare (start <= deadline) stays IEEE-ordinary.
NO_DEADLINE = np.float32(1e30)


class TrafficSpec(NamedTuple):
    """Scalar pytree describing one offered-traffic contract.

    All leaves are traced jnp scalars / small vectors — sweeping any of
    them (offered-load sweeps, deadline sweeps) hits the jit cache.  The
    spec carries its OWN key; chunked serving folds the chunk index into
    it (``chunk_key``) so every chunk draws fresh arrivals while the
    serving loop's main key stream is untouched.

    * ``rate`` — calm-state arrival rate in requests per cycle;
    * ``burst_rate`` — burst-state rate multiplier (1 = plain Poisson);
    * ``p_burst`` / ``p_calm`` — per-arrival MMPP-2 flip probabilities
      (calm -> burst, burst -> calm);
    * ``mix`` — (K,) tenant mix weights (need not be normalized);
    * ``deadline`` — (K,) per-tenant relative deadline in cycles from
      arrival; ``<= 0`` disables deadline shedding for that tenant;
    * ``priority`` — (K,) per-tenant priority in [0, 1]; with
      ``prio_reserve > 0``, low-priority tenants see a smaller effective
      admission queue (``cap * (1 - prio_reserve * (1 - priority))``);
    * ``backoff`` — base retry backoff in cycles (bounded exponential —
      the PR-7 fault-retry math, ``faults.backoff_cycles``);
    * ``overload_frac`` — shed-rate EMA level that trips the overload
      watchdog (forced NON_COH + epsilon reopen); 0 disables;
    * ``pressure_beta`` — EMA coefficient of the shed-pressure monitor;
    * ``key`` — (2,) uint32 threefry key owning all traffic randomness.
    """

    rate: jnp.ndarray           # () f32 requests / cycle (calm)
    burst_rate: jnp.ndarray     # () f32 burst multiplier
    p_burst: jnp.ndarray        # () f32 calm -> burst flip prob
    p_calm: jnp.ndarray         # () f32 burst -> calm flip prob
    mix: jnp.ndarray            # (K,) f32 tenant weights
    deadline: jnp.ndarray       # (K,) f32 relative deadline cycles
    priority: jnp.ndarray       # (K,) f32 in [0, 1]
    backoff: jnp.ndarray        # () f32 retry backoff cycles
    overload_frac: jnp.ndarray  # () f32 watchdog trip level (0 = off)
    pressure_beta: jnp.ndarray  # () f32 shed-EMA coefficient
    prio_reserve: jnp.ndarray   # () f32 queue fraction priority-gated
    key: jnp.ndarray            # (2,) uint32


def poisson(rate, *, deadline=0.0, priority=1.0, backoff=0.0,
            overload_frac=0.0, pressure_beta=0.05, prio_reserve=0.0,
            key=None, seed: int = 0) -> TrafficSpec:
    """Single-tenant Poisson traffic at ``rate`` requests per cycle.

    The degenerate MMPP (``burst_rate=1``): the fidelity-scoped stream the
    DES cross-check runs on.  ``deadline``/``priority`` may be scalars or
    (K,) arrays (scalars become one tenant)."""
    return bursty(rate, burst_rate=1.0, p_burst=0.0, p_calm=1.0,
                  mix=jnp.ones(np.shape(deadline) or (1,), jnp.float32),
                  deadline=deadline, priority=priority, backoff=backoff,
                  overload_frac=overload_frac, pressure_beta=pressure_beta,
                  prio_reserve=prio_reserve, key=key, seed=seed)


def bursty(rate, *, burst_rate=4.0, p_burst=0.05, p_calm=0.25,
           mix=(1.0,), deadline=0.0, priority=1.0, backoff=0.0,
           overload_frac=0.0, pressure_beta=0.05, prio_reserve=0.0,
           key=None, seed: int = 0) -> TrafficSpec:
    """MMPP-2 bursty multi-tenant traffic.

    ``mix`` fixes K; scalar ``deadline``/``priority`` broadcast across
    tenants.  Defaults flip into ~4x bursts lasting ~4 arrivals every ~20
    arrivals."""
    f32 = jnp.float32
    mix = jnp.atleast_1d(jnp.asarray(mix, f32))
    k = mix.shape[0]
    return TrafficSpec(
        rate=jnp.asarray(rate, f32),
        burst_rate=jnp.asarray(burst_rate, f32),
        p_burst=jnp.asarray(p_burst, f32),
        p_calm=jnp.asarray(p_calm, f32),
        mix=mix,
        deadline=jnp.broadcast_to(jnp.asarray(deadline, f32), (k,)),
        priority=jnp.broadcast_to(jnp.asarray(priority, f32), (k,)),
        backoff=jnp.asarray(backoff, f32),
        overload_frac=jnp.asarray(overload_frac, f32),
        pressure_beta=jnp.asarray(pressure_beta, f32),
        prio_reserve=jnp.asarray(prio_reserve, f32),
        key=key if key is not None else jax.random.PRNGKey(seed))


def chunk_key(spec: TrafficSpec, chunk: int) -> TrafficSpec:
    """The spec for chunk ``chunk`` of a long-lived stream: same contract,
    chunk-folded key — every chunk draws fresh arrivals deterministically
    (``fold_in``, the FaultSpec per-iteration protocol)."""
    return spec._replace(key=jax.random.fold_in(spec.key, chunk))


class Arrivals(NamedTuple):
    """One chunk's pre-sampled arrival table ((n_requests,) leaves).

    Rides the serving scan's xs; ``np.asarray`` of the same table drives
    the DES mirror, so both paths see bit-identical offered traffic.

    * ``t_arr`` — absolute arrival time in cycles (monotone increasing,
      continuing from ``t0``);
    * ``row`` — compiled-schedule row this request invokes (the request's
      accelerator, footprint and tile stripe are that row's);
    * ``tenant`` — tenant index in [0, K);
    * ``deadline`` — absolute latest admissible *start* time
      (:data:`NO_DEADLINE` when the tenant's deadline is disabled);
    * ``priority`` — the tenant's priority, clipped to [0, 1];
    * ``burst`` — the MMPP state that timed this arrival (diagnostics).
    """

    t_arr: jnp.ndarray     # (n,) f32 absolute cycles
    row: jnp.ndarray       # (n,) i32 schedule row
    tenant: jnp.ndarray    # (n,) i32
    deadline: jnp.ndarray  # (n,) f32 absolute cycles
    priority: jnp.ndarray  # (n,) f32 in [0, 1]
    burst: jnp.ndarray     # (n,) bool


def sample_arrivals(spec: TrafficSpec, n_requests: int, n_rows: int,
                    t0=0.0) -> Arrivals:
    """Draw one chunk of ``n_requests`` arrivals over ``n_rows`` schedule
    rows, starting the clock at ``t0``.

    Everything is pre-sampled in one batched draw from the spec's own key
    (4-way split: MMPP flips, gaps, row picks, tenant Gumbels); the only
    sequential piece is the K-independent 2-state chain — a scalar-carry
    ``lax.scan`` over pre-drawn uniforms, the same shape as
    ``qlearn``'s noise protocol.  ``n_requests`` and ``n_rows`` are
    static (shapes); every spec leaf is traced, so offered-load sweeps
    never retrace."""
    f32 = jnp.float32
    k_state, k_gap, k_row, k_ten = jax.random.split(spec.key, 4)
    u_state = jax.random.uniform(k_state, (n_requests,), f32)
    u_gap = jax.random.uniform(k_gap, (n_requests,), f32)
    u_row = jax.random.uniform(k_row, (n_requests,), f32)
    g_ten = jax.random.gumbel(k_ten, (n_requests, spec.mix.shape[0]), f32)

    # MMPP-2 state chain: the state in force for arrival i is the state
    # *after* applying flip i (a calm-started chunk's first arrival can
    # already be bursty).  burst_rate == 1 makes the chain timing-inert.
    def flip(high, u):
        high = jnp.where(high, u >= spec.p_calm, u < spec.p_burst)
        return high, high

    _, burst = jax.lax.scan(flip, jnp.zeros((), bool), u_state)
    rate_t = spec.rate * jnp.where(burst, spec.burst_rate, 1.0)
    # Inverse-CDF exponential gaps; log1p keeps u -> 0 exact and the rate
    # floor keeps a zero-rate spec finite (gaps become huge, not inf/nan).
    gaps = -jnp.log1p(-u_gap * np.float32(1 - 1e-7))
    gaps = gaps / jnp.maximum(rate_t, np.float32(1e-12))
    t_arr = jnp.asarray(t0, f32) + jnp.cumsum(gaps)

    # Tenant draw (Gumbel argmax == categorical(mix)) and the tenant's
    # contiguous schedule-row slice.  Slice bounds use static n_rows/K
    # host arithmetic per tenant via integer jnp ops on the traced index.
    kk = spec.mix.shape[0]
    logits = jnp.log(jnp.maximum(spec.mix, np.float32(1e-12)))
    tenant = jnp.argmax(logits[None, :] + g_ten, axis=-1).astype(jnp.int32)
    lo = (tenant * n_rows) // kk
    hi = ((tenant + 1) * n_rows) // kk
    span = jnp.maximum(hi - lo, 1)
    row = lo + jnp.floor(u_row * span.astype(f32)).astype(jnp.int32)
    row = jnp.clip(row, 0, n_rows - 1)

    dl_rel = spec.deadline[tenant]
    deadline = t_arr + jnp.where(dl_rel <= 0.0, NO_DEADLINE, dl_rel)
    priority = jnp.clip(spec.priority[tenant], 0.0, 1.0)
    return Arrivals(t_arr=t_arr, row=row, tenant=tenant, deadline=deadline,
                    priority=priority, burst=burst)
