"""SoC configurations (paper Table 4) and memory-system timing constants.

The seven evaluation SoCs vary accelerator count, NoC size, CPU count, DRAM
controllers, LLC partitioning and L2 size — we reproduce the table exactly.
Timing constants approximate the ESP FPGA prototypes (LEON3 @ soft-core
clock, 32-bit NoC planes, one memory link of 32 bits/cycle per memory tile,
paper §4.3/§5); absolute values only set the scale, every paper figure is
normalized to the Fixed non-coherent-DMA policy.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.state import CacheGeometry

KB = 1024
MB = 1024 * KB


@dataclasses.dataclass(frozen=True)
class MemTimings:
    """Cycle-level constants of the memory system model (memsys.py)."""

    line_bytes: int = 64            # coherence / DMA-beat granularity
    dram_lat: float = 120.0         # DRAM access latency (cycles)
    dram_bw: float = 4.0            # bytes/cycle per controller (32 bits/cy)
    llc_hit_lat: float = 24.0       # NoC + LLC pipeline (cycles)
    llc_bw: float = 8.0             # bytes/cycle LLC slice service rate
    l2_hit_lat: float = 4.0         # accelerator-private L2 hit (cycles)
    l2_bw: float = 16.0             # bytes/cycle private-cache fill path
    noc_hop_lat: float = 1.0        # per-router latency (cycles)
    noc_bw: float = 4.0             # bytes/cycle per NoC plane link
    driver_base: float = 5000.0     # device-driver invocation overhead
    tlb_per_page: float = 12.0      # TLB preload per 2 MB page (paper §5)
    page_bytes: int = 2 * MB
    flush_base: float = 2000.0      # fixed flush-instruction overhead
    flush_bw: float = 8.0           # bytes/cycle writeback drain
    dir_lookup: float = 8.0         # directory action per line (coh modes)
    recall_lat: float = 40.0        # LLC->L2 recall round trip per line
    mshr_per_tile: int = 4          # outstanding line transactions per bridge
                                    # (ESP's DMA-to-cache bridge splits bursts
                                    # into line requests with few MSHRs, the
                                    # key reason long-burst NON_COH DMA wins
                                    # for big streaming workloads, paper §3)


@dataclasses.dataclass(frozen=True)
class SoCConfig:
    """One row of paper Table 4 (or a generated design point, soc.dse).

    Construction validates the structural invariants every consumer
    assumes — a buggy sampler fails here with a named config, not as a
    shape error three jit layers deep."""

    name: str
    n_accs: int
    noc_rows: int
    noc_cols: int
    n_cpus: int
    n_mem_tiles: int                # DDR controllers == LLC partitions
    llc_slice_bytes: int
    l2_bytes: int
    accelerators: Sequence[str]     # profile names, len == n_accs
    # SoC3: five accelerators lack a private cache (FPGA resource limits),
    # so FULLY_COH is unavailable for them (action masking).
    no_private_cache: Sequence[int] = ()
    timings: MemTimings = MemTimings()

    def __post_init__(self):
        problems = []
        if self.n_accs < 1:
            problems.append(f"n_accs={self.n_accs} < 1")
        if self.n_cpus < 1:
            problems.append(f"n_cpus={self.n_cpus} < 1")
        if self.n_mem_tiles < 1:
            problems.append(f"n_mem_tiles={self.n_mem_tiles} < 1")
        if len(self.accelerators) != self.n_accs:
            problems.append(f"{len(self.accelerators)} accelerator names "
                            f"vs n_accs={self.n_accs}")
        bad = [i for i in self.no_private_cache
               if not 0 <= int(i) < self.n_accs]
        if bad:
            problems.append(f"no_private_cache indices {bad} outside "
                            f"[0, {self.n_accs})")
        tiles = self.noc_rows * self.noc_cols
        need = self.n_accs + self.n_cpus + self.n_mem_tiles
        if tiles < need:
            problems.append(f"{self.noc_rows}x{self.noc_cols} NoC has "
                            f"{tiles} tiles < {need} occupants "
                            f"(accs+cpus+mem)")
        if self.llc_slice_bytes <= 0:
            problems.append(f"llc_slice_bytes={self.llc_slice_bytes} <= 0")
        if self.l2_bytes <= 0:
            problems.append(f"l2_bytes={self.l2_bytes} <= 0")
        if problems:
            raise ValueError(
                f"invalid SoCConfig {self.name!r}: " + "; ".join(problems))

    @property
    def llc_total_bytes(self) -> int:
        return self.llc_slice_bytes * self.n_mem_tiles

    @property
    def geometry(self) -> CacheGeometry:
        return CacheGeometry(
            l2_bytes=self.l2_bytes,
            llc_slice_bytes=self.llc_slice_bytes,
            n_mem_tiles=self.n_mem_tiles,
        )


def _repeat(names: Sequence[str], copies: int) -> tuple[str, ...]:
    return tuple(n for n in names for _ in range(copies))


# The 11 ESP accelerators (+ NVDLA) of paper Table 2 / §3.
ALL_ACCS = (
    "autoencoder", "cholesky", "conv2d", "fft", "gemm", "mlp",
    "mriq", "nvdla", "nightvision", "sort", "spmv", "viterbi",
)

SOC0 = SoCConfig(  # traffic-generator SoC (Table 4: "SoCs w/ Traffic Gen")
    name="SoC0", n_accs=12, noc_rows=5, noc_cols=5, n_cpus=4, n_mem_tiles=4,
    llc_slice_bytes=512 * KB, l2_bytes=64 * KB,
    accelerators=tuple(f"traffic{i}" for i in range(12)),
)
SOC1 = SoCConfig(
    name="SoC1", n_accs=7, noc_rows=4, noc_cols=4, n_cpus=2, n_mem_tiles=4,
    llc_slice_bytes=256 * KB, l2_bytes=32 * KB,
    accelerators=("traffic0", "traffic1", "traffic2", "traffic3",
                  "traffic4", "traffic5", "traffic6"),
)
SOC2 = SoCConfig(
    name="SoC2", n_accs=9, noc_rows=4, noc_cols=4, n_cpus=4, n_mem_tiles=2,
    llc_slice_bytes=512 * KB, l2_bytes=32 * KB,
    accelerators=tuple(f"traffic{i}" for i in range(9)),
)
SOC3 = SoCConfig(
    name="SoC3", n_accs=16, noc_rows=5, noc_cols=5, n_cpus=4, n_mem_tiles=4,
    llc_slice_bytes=256 * KB, l2_bytes=64 * KB,
    accelerators=tuple(f"traffic{i}" for i in range(16)),
    no_private_cache=(3, 6, 9, 12, 15),
)
SOC4 = SoCConfig(  # case study: one of each accelerator
    name="SoC4", n_accs=11, noc_rows=5, noc_cols=4, n_cpus=2, n_mem_tiles=4,
    llc_slice_bytes=256 * KB, l2_bytes=32 * KB,
    accelerators=tuple(a for a in ALL_ACCS if a != "nvdla"),
)
SOC5 = SoCConfig(  # collaborative autonomous vehicles
    name="SoC5", n_accs=8, noc_rows=4, noc_cols=4, n_cpus=1, n_mem_tiles=4,
    llc_slice_bytes=256 * KB, l2_bytes=32 * KB,
    accelerators=_repeat(("fft", "viterbi", "conv2d", "gemm"), 2),
)
SOC6 = SoCConfig(  # computer vision: 3x image-classification pipeline
    name="SoC6", n_accs=9, noc_rows=4, noc_cols=4, n_cpus=1, n_mem_tiles=2,
    llc_slice_bytes=256 * KB, l2_bytes=32 * KB,
    accelerators=_repeat(("nightvision", "autoencoder", "mlp"), 3),
)

# §3 motivation SoCs: "Each processor and accelerator has its own 32KB
# private cache. The 1MB LLC is split in two units" — used for Fig. 2
# (one accelerator of each type, isolation) and Fig. 3 (12 accelerators:
# 3x FFT, night-vision, sort, SPMV, concurrent).
SOC_MOTIV_ISO = SoCConfig(
    name="SoC-motiv-iso", n_accs=12, noc_rows=4, noc_cols=5, n_cpus=2,
    n_mem_tiles=2, llc_slice_bytes=512 * KB, l2_bytes=32 * KB,
    accelerators=ALL_ACCS,
)
SOC_MOTIV_PAR = SoCConfig(
    name="SoC-motiv-par", n_accs=12, noc_rows=4, noc_cols=5, n_cpus=2,
    n_mem_tiles=2, llc_slice_bytes=512 * KB, l2_bytes=32 * KB,
    accelerators=_repeat(("fft", "nightvision", "sort", "spmv"), 3),
)

SOCS = {s.name: s for s in (SOC0, SOC1, SOC2, SOC3, SOC4, SOC5, SOC6,
                            SOC_MOTIV_ISO, SOC_MOTIV_PAR)}

# Paper §3 / Fig. 2 workload buckets, and §5's S/M/L/XL characterization.
WORKLOAD_SMALL = 16 * KB
WORKLOAD_MEDIUM = 256 * KB
WORKLOAD_LARGE = 4 * MB


# --------------------------------------------------------------- budget model
@dataclasses.dataclass(frozen=True)
class SoCBudget:
    """Area / off-chip-bandwidth envelope for generated SoCs (soc.dse).

    A lumos-style abstract budget: every tile occupant costs area in the
    same arbitrary unit (one accelerator datapath == 1.0), SRAM costs
    area per MB, and the off-chip bandwidth budget caps how many DDR
    controllers a design may instantiate (each contributes
    ``timings.dram_bw`` bytes/cycle).  The defaults envelope paper
    Table 4: every hand-written SoC fits (pinned in tests), so the
    generated design space is "SoCs buildable on the paper's FPGA".
    Accelerators listed in ``no_private_cache`` pay no L2 area — the
    same resource trade the paper's SoC3 makes."""

    max_area: float = 48.0          # abstract tile-area units
    max_offchip_bw: float = 16.0    # bytes/cycle aggregate DDR
    cpu_area: float = 2.0           # CPU tile (core + its private cache)
    acc_area: float = 1.0           # accelerator datapath tile
    mem_tile_area: float = 1.5      # DDR controller + LLC slice control
    router_area: float = 0.25       # per NoC router
    cache_area_per_mb: float = 4.0  # SRAM (private L2s + LLC slices)


DEFAULT_BUDGET = SoCBudget()


def soc_cache_bytes(soc: SoCConfig) -> int:
    """Total on-chip SRAM: one private L2 per CPU and per accelerator that
    has one, plus the LLC slices."""
    n_l2 = soc.n_cpus + soc.n_accs - len(soc.no_private_cache)
    return n_l2 * soc.l2_bytes + soc.n_mem_tiles * soc.llc_slice_bytes


def soc_area(soc: SoCConfig, budget: SoCBudget = DEFAULT_BUDGET) -> float:
    """Area of ``soc`` under ``budget``'s cost model (budget-relative
    only through the per-component cost constants)."""
    return (soc.n_cpus * budget.cpu_area
            + soc.n_accs * budget.acc_area
            + soc.n_mem_tiles * budget.mem_tile_area
            + soc.noc_rows * soc.noc_cols * budget.router_area
            + soc_cache_bytes(soc) / MB * budget.cache_area_per_mb)


def soc_offchip_bw(soc: SoCConfig) -> float:
    """Aggregate off-chip bandwidth (bytes/cycle across DDR channels)."""
    return soc.n_mem_tiles * soc.timings.dram_bw


def budget_report(soc: SoCConfig,
                  budget: SoCBudget = DEFAULT_BUDGET) -> dict:
    """Area/bandwidth numbers and whether ``soc`` fits ``budget``."""
    area = soc_area(soc, budget)
    bw = soc_offchip_bw(soc)
    return {
        "area": area,
        "area_frac": area / budget.max_area,
        "offchip_bw": bw,
        "bw_frac": bw / budget.max_offchip_bw,
        "within_budget": bool(area <= budget.max_area
                              and bw <= budget.max_offchip_bw),
    }
