"""Function-approximation policy subsystem — a tiny MLP Q-network.

The tabular agent (:mod:`repro.core.qlearn`) can only serve the 243
Table-3 buckets it has visited: an unseen application or a freshly
sampled SoC (``soc.dse``) lands in optimistic all-tie rows and degrades
toward the Random policy.  This module replaces the table with a small
packed MLP over normalized *sense features* — footprint, tile count,
active-accelerator/DDR/LLC pressure, plus HyDRA-style deadline-slack and
reuse-distance signals from the serving path — trained with the paper's
contextual-bandit semi-gradient TD update ``delta = Q(s, a) - R``.

Design constraints (all load-bearing):

  * **One packed weight array.**  :class:`MLPQState` carries every layer
    in a single ``(rows, cols)`` float32 ``wpack`` (per layer: ``nin``
    weight rows then one bias row, columns padded to the widest layer).
    A single rectangular leaf rides the fused-step scan carry, the
    Pallas kernel's VMEM scratch and checkpoints without pytree surgery.
  * **Pallas-safe arithmetic.**  :func:`forward_packed`,
    :func:`td_update_packed` and :func:`step_features` are called from
    inside the fused kernel body (:mod:`repro.kernels.soc_step.ref`), so
    they use static slices, 2-D ``broadcasted_iota`` and elementwise
    broadcast-sums (no ``jnp.dot`` — the layers are far below MXU tile
    sizes) and never capture device arrays.
  * **Static architecture.**  :class:`MLPConfig` is registered as a
    static pytree node, so it rides *inside* :class:`MLPQState` (and
    therefore inside ``PolicySpec``) as part of the treedef — jit keys
    on it, ``vmap``/``tree_map`` skip it, and stacking specs with
    mismatched configs fails loudly at the treedef level.
  * **Bitwise dead branch.**  A :func:`frozen_mlp_qstate` placeholder
    attached to a table spec (``qfun=False``) must leave both the
    Q-table and the placeholder weights bitwise untouched; every update
    here is a ``jnp.where`` whose gate is exactly False on that branch.
  * **Degradation for free.**  The MLP's Q-row feeds the same
    ``qlearn.row_select_presampled`` as the table row, so non-finite
    weights (fault storms, PR 7) hit its existing non-finite-row
    fallback and the step serves NON_COH without new machinery.

The portfolio trainer (:func:`train_portfolio`) trains ONE shared
network across (apps x SoCs) pairs with per-iteration federated
averaging of the packed weights; ``benchmarks/fig13_generalize.py``
evaluates it against the shared tabular agent on held-out apps and
held-out DSE-sampled SoCs.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn
from repro.core.modes import N_MODES
from repro.core.policies import Policy
from repro.core.state import N_STATES
from repro.soc.accelerators import IRREGULAR, PF, PROFILE_WIDTH

# Number of normalized sense features (the "sense" embedding).  Order is
# part of the spec — the DES mirror, the unfused step and the fused
# kernel all call :func:`step_features` so they cannot drift.
N_SENSE_FEATURES = 14


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    """Static network architecture (part of the pytree *structure*).

    ``features`` picks the input embedding: ``"sense"`` is the
    14-feature normalized snapshot; ``"onehot"`` embeds the Table-3
    state index as a one-hot vector (243 wide) — with ``hidden=()`` that
    is an exact linear re-parameterization of a Q-table, which is what
    the spec-lowering equivalence tests distill into.  ``lr`` is only
    the default :func:`init_mlp_qstate` bakes into the state's traced
    ``lr`` leaf."""

    features: str = "sense"
    hidden: tuple = (16, 16)
    lr: float = 0.05

    def __post_init__(self):
        object.__setattr__(self, "hidden", tuple(int(h) for h in self.hidden))
        if self.features not in ("sense", "onehot"):
            raise ValueError(f"unknown feature embedding {self.features!r}")


# Static registration: MLPConfig becomes treedef, not leaves — jit keys
# on it and vmap/tree_map pass it through untouched.
try:
    jax.tree_util.register_static(MLPConfig)
except AttributeError:  # older jax: empty-children node with aux=self
    jax.tree_util.register_pytree_node(
        MLPConfig, lambda c: ((), c), lambda aux, _: aux)


class MLPQState(NamedTuple):
    """The function-approximation agent — drop-in for ``qlearn.QState``.

    ``wpack`` is the packed weight stack (:func:`pack_shape`); ``lr``
    the traced learning-rate scale (the effective step size is
    ``alpha_t * lr`` with ``alpha_t`` the paper's decayed alpha, so the
    MLP follows the exact tabular decay protocol); ``step``/``frozen``
    mirror the tabular counters and drive the shared
    ``qlearn.decay_arrays`` schedule."""

    wpack: jnp.ndarray   # (R, C) float32 packed weights
    lr: jnp.ndarray      # () float32 learning-rate scale
    step: jnp.ndarray    # () int32 training invocations so far
    frozen: jnp.ndarray  # () bool
    cfg: MLPConfig       # static (treedef) architecture


def mlp_dims(cfg: MLPConfig) -> tuple:
    """Layer widths ``(n_in, *hidden, n_actions)`` for ``cfg``."""
    n_in = N_SENSE_FEATURES if cfg.features == "sense" else N_STATES
    return (n_in, *cfg.hidden, N_MODES)


def pack_shape(dims: Sequence[int]) -> tuple:
    """(rows, cols) of the packed weight array for ``dims``.

    Layer ``l`` occupies ``dims[l]`` weight rows followed by one bias
    row; columns pad to the widest output so one rectangle holds all."""
    return sum(d + 1 for d in dims[:-1]), max(dims[1:])


def _iota1d(n: int) -> jnp.ndarray:
    # TPU requires >= 2D iota; squeeze back to the 1-D index vector.
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).squeeze(-1)


def forward_packed(wpack, x, dims) -> jnp.ndarray:
    """Q-row for feature vector ``x``: ReLU MLP over the packed weights.

    The matmul is an elementwise broadcast-sum (``sum(W * x[:, None])``)
    — exact for one-hot inputs (the off rows contribute signed zeros),
    VPU-friendly at these tiny widths, and Pallas-safe."""
    h = x
    off = 0
    last = len(dims) - 2
    for l in range(len(dims) - 1):
        nin, nout = dims[l], dims[l + 1]
        w = wpack[off:off + nin, :nout]
        z = jnp.sum(w * h[:, None], axis=0) + wpack[off + nin, :nout]
        h = z if l == last else jnp.maximum(z, 0.0)
        off += nin + 1
    return h


def td_update_packed(wpack, x, action, reward, lr_eff, dims, gate):
    """One semi-gradient TD step on the packed weights.

    Contextual-bandit target (the paper's update has no bootstrap):
    ``delta = Q(s, a) - R``, hand-backpropagated over the packed layout
    (static Python loop — the architecture is static).  The update is a
    single ``jnp.where``: it fires only when ``gate`` holds (the spec's
    ``qfun`` flag, AND the row-validity gate on padded/shed steps), the
    effective step size is positive (frozen or fully-decayed agents are
    exact no-ops) and ``delta`` is finite — non-finite weights, features
    or rewards can never poison the pack (``0 * NaN`` is NaN, so gating
    multiplicatively would not be safe; selecting is)."""
    # Forward, keeping per-layer activations for the backward pass.
    hs = [x]
    off = 0
    offs = []
    last = len(dims) - 2
    for l in range(len(dims) - 1):
        nin, nout = dims[l], dims[l + 1]
        offs.append(off)
        w = wpack[off:off + nin, :nout]
        z = jnp.sum(w * hs[-1][:, None], axis=0) + wpack[off + nin, :nout]
        hs.append(z if l == last else jnp.maximum(z, 0.0))
        off += nin + 1

    f32 = jnp.float32
    n_act = dims[-1]
    hot = (_iota1d(n_act) == action).astype(f32)
    q_a = jnp.sum(hs[-1] * hot)
    delta = q_a - reward

    cols = wpack.shape[-1]
    g = hot * delta                      # dL/dz of the output layer
    rows = [None] * (len(dims) - 1)
    for l in range(len(dims) - 2, -1, -1):
        nin, nout = dims[l], dims[l + 1]
        dw = hs[l][:, None] * g[None, :]                   # (nin, nout)
        db = g[None, :]                                    # (1, nout)
        blk = jnp.concatenate([dw, db], axis=0)
        if cols > nout:
            blk = jnp.concatenate(
                [blk, jnp.zeros((nin + 1, cols - nout), f32)], axis=1)
        rows[l] = blk
        if l > 0:
            w = wpack[offs[l]:offs[l] + nin, :nout]
            g = jnp.sum(w * g[None, :], axis=1) * (hs[l] > 0.0).astype(f32)
    grad = jnp.concatenate(rows, axis=0)

    ok = gate & jnp.isfinite(delta) & (lr_eff > 0.0)
    return jnp.where(ok, wpack - lr_eff * grad, wpack)


def step_features(feats: str, s, state_idx, *, footprint, tiles, omask,
                  omodes, ofps, odram, warm_t, profile, slack, reuse):
    """The per-invocation input embedding, shared by every engine.

    ``feats="onehot"`` embeds the sensed Table-3 index (the exact-table
    re-parameterization); ``"sense"`` builds the 14 normalized features
    below from quantities the fused step already has in hand.  The
    unfused step, the serving step and the DES mirror call this with
    bitwise-identical inputs, so the embeddings (and hence selections)
    cannot drift between engines.

    Sense features (all roughly [0, 1]; squashes are odd and bounded):
    log/capacity-relative footprint (vs L2 and total LLC), needed-tile
    fraction, counts of active / LLC-routed / non-coherent concurrent
    accelerators, aggregate LLC footprint pressure, aggregate DDR
    bandwidth demand pressure, inter-stage warmth, the irregular-access
    profile flag, log compute-per-byte, and the HyDRA-style
    deadline-slack and reuse-distance squashes (zero on the episodic
    path; the serving step feeds real values)."""
    f32 = jnp.float32
    if feats == "onehot":
        return (_iota1d(N_STATES) == state_idx).astype(f32)
    llc_total = s.llc_slice_bytes * s.n_mem_tiles
    n_tiles = tiles.shape[-1]
    fp = footprint.astype(f32) if hasattr(footprint, "astype") else f32(footprint)
    omask_f = omask.astype(f32)
    cached = omask & (omodes > 0)          # routes through the LLC
    non_coh = omask & (omodes == 0)
    sl = slack * np.float32(1e-6)
    ru = reuse * np.float32(1e-6)
    return jnp.stack([
        jnp.log2(1.0 + fp) * np.float32(1.0 / 32.0),
        jnp.clip(fp / s.l2_bytes, 0.0, 4.0) * np.float32(0.25),
        jnp.clip(fp / llc_total, 0.0, 4.0) * np.float32(0.25),
        jnp.sum(tiles.astype(f32)) / np.float32(n_tiles),
        jnp.sum(omask_f) * np.float32(0.125),
        jnp.sum(cached.astype(f32)) * np.float32(0.125),
        jnp.sum(non_coh.astype(f32)) * np.float32(0.125),
        jnp.clip(jnp.sum(ofps) / llc_total, 0.0, 4.0) * np.float32(0.25),
        jnp.clip(jnp.sum(odram) / s.dram_bw, 0.0, 4.0) * np.float32(0.25),
        warm_t,
        (profile[PF.PATTERN] == np.float32(IRREGULAR)).astype(f32),
        jnp.log2(1.0 + profile[PF.COMPUTE]) * np.float32(0.125),
        sl / (1.0 + jnp.abs(sl)),
        ru / (1.0 + jnp.abs(ru)),
    ])


# --------------------------------------------------------------------------
# State constructors
# --------------------------------------------------------------------------

def init_mlp_qstate(key, cfg: MLPConfig = MLPConfig(),
                    q_init: float = 1.0) -> MLPQState:
    """A fresh trainable network.

    Hidden layers draw He-scaled Gaussians; the output layer starts at
    exactly ``W=0, b=q_init`` so every state's Q-row is an all-tie at
    the tabular optimistic init — the untrained MLP equals the Random
    policy under randomized argmax, preserving the paper's "iteration 0
    == Random" property just like ``qlearn.init_qstate``."""
    dims = mlp_dims(cfg)
    rows, cols = pack_shape(dims)
    wpack = jnp.zeros((rows, cols), jnp.float32)
    off = 0
    last = len(dims) - 2
    for l in range(len(dims) - 1):
        nin, nout = dims[l], dims[l + 1]
        if l == last:
            wpack = wpack.at[off + nin, :nout].set(jnp.float32(q_init))
        else:
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (nin, nout), jnp.float32)
            wpack = wpack.at[off:off + nin, :nout].set(
                w * np.float32(np.sqrt(2.0 / nin)))
        off += nin + 1
    return MLPQState(wpack=wpack, lr=jnp.asarray(cfg.lr, jnp.float32),
                     step=jnp.zeros((), jnp.int32),
                     frozen=jnp.zeros((), bool), cfg=cfg)


def frozen_mlp_qstate(cfg: MLPConfig = MLPConfig(),
                      q_init: float = 1.0) -> MLPQState:
    """The inert placeholder a non-``qfun`` PolicySpec carries — the MLP
    analogue of ``qlearn.frozen_qstate``.  Deterministic (no PRNG) and
    frozen: the fused step's update gate is exactly False on it, so
    attaching it to a table spec is a bitwise no-op (pinned by the
    dead-branch tests)."""
    dims = mlp_dims(cfg)
    rows, cols = pack_shape(dims)
    nin, nout = dims[-2], dims[-1]
    wpack = jnp.zeros((rows, cols), jnp.float32).at[
        rows - 1, :nout].set(jnp.float32(q_init))
    return MLPQState(wpack=wpack, lr=jnp.zeros((), jnp.float32),
                     step=jnp.zeros((), jnp.int32),
                     frozen=jnp.ones((), bool), cfg=cfg)


def freeze(mlp: MLPQState) -> MLPQState:
    """Disable further updates (evaluate the converged network)."""
    return mlp._replace(frozen=jnp.ones((), bool))


def mlp_from_qtable(qtable, lr: float = 0.0) -> MLPQState:
    """Distill a Q-table into an exactly-equivalent linear MLP.

    One-hot state embedding, no hidden layers, weights = the table,
    biases = 0: the forward broadcast-sum reduces to the gathered table
    row plus signed zeros, so epsilon-greedy selection over the MLP's
    Q-row picks *identical* modes to the table spec (the spec-lowering
    equivalence contract in ``tests/test_policy_spec.py``)."""
    qtable = jnp.asarray(qtable, jnp.float32)
    n_states, n_actions = qtable.shape
    cfg = MLPConfig(features="onehot", hidden=(), lr=float(lr))
    rows, cols = pack_shape(mlp_dims(cfg))
    assert (rows, cols) == (n_states + 1, n_actions)
    wpack = jnp.zeros((rows, cols), jnp.float32).at[:n_states, :].set(qtable)
    return MLPQState(wpack=wpack, lr=jnp.asarray(lr, jnp.float32),
                     step=jnp.zeros((), jnp.int32),
                     frozen=jnp.zeros((), bool), cfg=cfg)


# --------------------------------------------------------------------------
# DES host mirror
# --------------------------------------------------------------------------

@jax.jit
def _forward_jit(wpack, feats_vec, cfg: MLPConfig):
    return forward_packed(wpack, feats_vec, mlp_dims(cfg))


class MLPQPolicy(Policy):
    """DES host mirror of the function-approximation agent.

    ``decide`` rebuilds the same feature vector the vectorized engines
    feed :func:`step_features` (the fidelity cross-check pins phase-time
    agreement on single-thread apps, where the concurrent-set features
    are trivially equal) and greedily argmaxes the network's Q-row over
    the available modes.  ``lower`` emits the ``qfun`` PolicySpec, so
    the one-line table->MLP swap in the examples is literally swapping
    this class for ``QPolicy``."""

    name = "cohmeleon-mlp"

    def __init__(self, mlp: MLPQState | None = None,
                 cfg: MLPConfig = MLPConfig(), seed: int = 0):
        self.mlp = (mlp if mlp is not None
                    else init_mlp_qstate(jax.random.PRNGKey(seed), cfg))

    def decide(self, ctx) -> int:
        from repro.soc.memsys import SoCStatic
        s = SoCStatic.from_config(ctx.soc)
        n_accs = ctx.soc.n_accs
        omodes = np.full((n_accs,), -1, np.int32)
        ofps = np.zeros((n_accs,), np.float32)
        afps = (ctx.active_footprints if ctx.active_footprints is not None
                else [0.0] * len(ctx.active_modes))
        for i, (m, fp) in enumerate(zip(ctx.active_modes, afps)):
            if i >= n_accs:
                break
            omodes[i] = m
            ofps[i] = fp
        omask = omodes >= 0
        tiles = (np.asarray(ctx.target_tiles, bool)
                 if ctx.target_tiles is not None
                 else np.zeros((ctx.soc.n_mem_tiles,), bool))
        profile = (np.asarray(ctx.profile, np.float32)
                   if ctx.profile is not None
                   else np.zeros((PROFILE_WIDTH,), np.float32))
        feats = step_features(
            self.mlp.cfg.features, s, jnp.asarray(ctx.state_idx, jnp.int32),
            footprint=jnp.asarray(ctx.footprint, jnp.float32),
            tiles=jnp.asarray(tiles), omask=jnp.asarray(omask),
            omodes=jnp.asarray(omodes), ofps=jnp.asarray(ofps),
            odram=jnp.zeros((n_accs,), jnp.float32),
            warm_t=jnp.asarray(ctx.warm, jnp.float32),
            profile=jnp.asarray(profile),
            slack=jnp.asarray(ctx.slack, jnp.float32),
            reuse=jnp.asarray(ctx.reuse, jnp.float32))
        row = np.asarray(_forward_jit(self.mlp.wpack, feats, self.mlp.cfg))
        masked = np.where(np.asarray(ctx.available, bool), row, -np.inf)
        if not np.all(np.isfinite(row)):
            return 0  # NON_COH fallback, mirroring row_select_presampled
        return int(np.argmax(masked))

    def lower(self, env, compiled):
        from repro.soc import vecenv as vec
        return vec.mlp_policy_spec(self.mlp, compiled.schedule)


# --------------------------------------------------------------------------
# Portfolio training: one shared network across (apps x SoCs)
# --------------------------------------------------------------------------

def _portfolio_call(env, compiled):
    """(cached) jitted B-seed training call for one (env, app) pair."""
    cache_key = ("mlp_portfolio", compiled.n_phases, compiled.n_threads)
    if cache_key not in env._train_cache:
        ep = env._episode_fn(compiled.n_phases, compiled.n_threads)

        def one(sched, spec, cfg, w, key):
            (_, mlp_f), res = ep(sched, spec, cfg, w, key, None)
            valid = sched.valid.astype(jnp.float32)
            mean_r = (jnp.sum(jnp.where(sched.valid, res.reward, 0.0))
                      / jnp.maximum(jnp.sum(valid), 1.0))
            return mlp_f, mean_r

        env._train_cache[cache_key] = jax.jit(
            jax.vmap(one, in_axes=(None, None, None, None, 0)))
    return env._train_cache[cache_key]


def train_portfolio(items, cfg, *, iterations: int = 6, batch: int = 2,
                    mcfg: MLPConfig = MLPConfig(), key=None,
                    weights=None, mlp: MLPQState | None = None,
                    manager=None):
    """Train ONE shared MLP across a portfolio of (env, apps) pairs.

    ``items`` is a sequence of ``(VecEnv, [CompiledApp, ...])`` pairs —
    one per (SoC, application); ``cfg`` is the tabular ``QConfig`` whose
    epsilon/alpha decay protocol the MLP follows exactly (``decay_steps``
    counts *total* invocations across the portfolio).  Each iteration
    runs one batched training episode per pair (``batch`` seeds vmapped
    in one jitted call) with the *current* shared weights, then
    federated-averages the resulting packs across every (pair x seed)
    lane — simple FedAvg, exact for the 1-lane case.  The shared step
    counter advances by the mean per-lane increment so the decay
    schedule tracks a single agent's.

    ``manager`` (a ``checkpoint.CheckpointManager``) makes the loop
    crash-resumable: the ``(MLPQState, iteration)`` snapshot is saved
    after every iteration and restored on entry, so an interrupted +
    resumed run ends bitwise-equal to an uninterrupted one (the
    per-iteration keys are derived by ``fold_in``, never carried).

    Returns ``(mlp, history)`` with ``history`` the (iterations,) mean
    training reward across the portfolio."""
    from repro.core import rewards
    if key is None:
        key = jax.random.PRNGKey(0)
    weights = weights if weights is not None else rewards.PAPER_DEFAULT_WEIGHTS
    if mlp is None:
        key, sub = jax.random.split(key)
        mlp = init_mlp_qstate(sub, mcfg)
    done = 0
    hist = np.zeros((iterations,), np.float32)
    if manager is not None and manager.latest_step() is not None:
        state = manager.restore({
            "mlp": mlp._replace(cfg=None), "hist": jnp.asarray(hist),
            "done": jnp.zeros((), jnp.int32)})
        mlp = state["mlp"]._replace(cfg=mlp.cfg)
        hist = np.array(state["hist"], np.float32)   # writable copy
        done = int(state["done"])

    from repro.soc import vecenv as vec
    for it in range(done, iterations):
        wpacks, steps, rs = [], [], []
        for j, (env, comps) in enumerate(items):
            comp = comps[it % len(comps)]
            spec = vec.mlp_policy_spec(mlp, comp.schedule)
            k = jax.random.fold_in(key, it * len(items) + j)
            ks = jax.random.split(k, batch)
            mlp_f, mean_r = _portfolio_call(env, comp)(
                comp.schedule, spec, cfg, weights, ks)
            wpacks.append(mlp_f.wpack)       # (batch, R, C)
            steps.append(mlp_f.step)         # (batch,)
            rs.append(mean_r)
        wall = jnp.concatenate(wpacks, axis=0)
        mlp = mlp._replace(
            wpack=jnp.mean(wall, axis=0),
            step=jnp.mean(jnp.concatenate(steps).astype(jnp.float32)
                          ).astype(jnp.int32))
        hist[it] = float(jnp.mean(jnp.concatenate(rs)))
        if manager is not None:
            manager.save(it + 1, {
                "mlp": mlp._replace(cfg=None), "hist": jnp.asarray(hist),
                "done": jnp.asarray(it + 1, jnp.int32)})
    if manager is not None:
        manager.wait()
    return mlp, jnp.asarray(hist)
