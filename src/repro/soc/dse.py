"""Generative SoC design space: budgeted sampling + bucketed co-search.

The paper evaluates Cohmeleon on eight hand-written SoCs (Table 4); its
core claim — the best coherence mode depends on accelerator, workload
AND architecture — begs the design-space question this module answers:
*which architectures make learned coherence win biggest?*

Two halves:

  * :func:`sample_socs` draws design points (accelerator counts and
    pattern mixes, cache sizes, DDR channels, CPU counts, NoC dims,
    ``no_private_cache`` masks) under a lumos-style area/bandwidth
    :class:`~repro.soc.config.SoCBudget`.  Over-budget draws are
    repaired deterministically (shrink LLC, shrink L2, drop
    accelerators, ...) so every emitted :class:`SoCConfig` validates and
    fits the envelope, and each design point carries its own
    deterministic seed (apps, tile striping, episode keys derive from
    it, so every per-SoC input is independent of sample count and of
    how the sweep is bucketed; deterministic-family metrics are bitwise
    bucketing-invariant, while keyed families redraw their pre-sampled
    noise when a bucket's padded scan length changes — jax's threefry
    pairs counter halves by total draw length).
  * :func:`run_sweep` pushes hundreds of generated SoCs through k-way
    :func:`~repro.soc.stacked.compile_apps_bucketed`, trains one
    Cohmeleon agent per SoC with ONE
    :meth:`~repro.soc.stacked.StackedVecEnv.train_batched` call per
    bucket, evaluates the whole policy suite (fixed modes, random,
    manual Algorithm 1, the trained agents) with ONE
    :meth:`~repro.soc.stacked.StackedVecEnv.episodes` call per bucket,
    reassembles per-lane metrics back to sample order
    (:func:`~repro.soc.stacked.reassemble_lanes`), and regresses the
    learned-policy win margins (speedup and off-chip reduction vs the
    NON_COH baseline) against the sampler axes.

``benchmarks/fig12_dse.py`` is the figure driver; the committed report
ranks architectures and sampler axes by learned-coherence margin.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn
from repro.core.modes import CoherenceMode
from repro.core.policies import FixedHomogeneous, ManualPolicy, RandomPolicy
from repro.core.rewards import PAPER_DEFAULT_WEIGHTS, stack_weights
from repro.soc import vecenv as vec
from repro.soc.accelerators import (PATTERN_NAMES, PROFILES)
from repro.soc.config import (DEFAULT_BUDGET, KB, MemTimings, SoCBudget,
                              SoCConfig, budget_report, soc_offchip_bw)
from repro.soc.stacked import (StackedVecEnv, _compile_lanes,
                               _stack_compiled, length_buckets,
                               reassemble_lanes)

# Accelerators grouped by access pattern (streaming / strided /
# irregular) — the sampler draws a pattern mix first so the mix axes
# vary widely instead of concentrating at the suite's 8/3/1 split.
_BY_PATTERN = tuple(
    tuple(n for n, p in PROFILES.items() if p.pattern == pat)
    for pat in range(len(PATTERN_NAMES)))

L2_CHOICES = (16 * KB, 32 * KB, 64 * KB, 128 * KB)
LLC_CHOICES = (128 * KB, 256 * KB, 512 * KB, 1024 * KB)

# Sampler axes regressed against the learned-policy margin.  NoC dims
# are excluded: the grid is the smallest that fits the occupants, so
# its size is collinear with the count axes (and only costs area).
FEATURE_AXES = (
    "n_accs", "n_cpus", "n_mem_tiles", "l2_kb", "llc_slice_kb",
    "no_l2_frac", "frac_streaming", "frac_strided", "frac_irregular",
    "mean_compute_per_byte", "mean_reuse", "mean_burst",
    "area_frac", "bw_per_acc",
)

EVAL_FAMILIES = tuple(FixedHomogeneous(m).name for m in CoherenceMode) + (
    "random", "manual", "cohmeleon")
_BASE_IDX = 0            # NON_COH_DMA row == the normalization baseline
_N_FIXED = len(CoherenceMode)


@dataclasses.dataclass(frozen=True)
class SampledSoC:
    """One generated design point: validated config + deterministic seed
    + the raw sampler-axis values (the regression features)."""

    config: SoCConfig
    seed: int            # per-config seed (apps, tile striping, keys)
    axes: dict


def config_seed(key: int, i: int) -> int:
    """Deterministic per-config seed — depends only on (key, i), never on
    the sample count or bucket layout."""
    return int(np.random.SeedSequence([key, i]).generate_state(1)[0]
               % np.uint32(2 ** 31 - 1))


def _noc_dims(occupants: int) -> tuple[int, int]:
    """Smallest near-square grid with at least ``occupants`` tiles."""
    rows = int(math.ceil(math.sqrt(occupants)))
    cols = int(math.ceil(occupants / rows))
    return rows, cols


def _build(name: str, d: dict) -> SoCConfig:
    rows, cols = _noc_dims(d["n_accs"] + d["n_cpus"] + d["n_mem_tiles"])
    return SoCConfig(
        name=name, n_accs=d["n_accs"], noc_rows=rows, noc_cols=cols,
        n_cpus=d["n_cpus"], n_mem_tiles=d["n_mem_tiles"],
        llc_slice_bytes=d["llc_slice"], l2_bytes=d["l2"],
        accelerators=tuple(d["accs"][:d["n_accs"]]),
        no_private_cache=tuple(i for i in d["no_l2"] if i < d["n_accs"]))


def _sample_one(rng: np.random.Generator, name: str, budget: SoCBudget,
                min_accs: int, max_accs: int) -> tuple[SoCConfig, dict]:
    """Draw one design point, then repair it deterministically until it
    fits the budget (shrink LLC -> shrink L2 -> drop accelerators ->
    drop DDR channels -> drop CPUs, cheapest-first)."""
    n_accs = int(rng.integers(min_accs, max_accs + 1))
    mix = rng.dirichlet(np.ones(len(PATTERN_NAMES)))
    patterns = rng.choice(len(PATTERN_NAMES), size=n_accs, p=mix)
    accs = [str(rng.choice(_BY_PATTERN[p])) for p in patterns]
    no_l2_frac = float(rng.uniform(0.0, 0.4))
    d = {
        "n_accs": n_accs,
        "accs": accs,
        "n_cpus": int(rng.choice([1, 2, 4])),
        "n_mem_tiles": int(rng.choice([1, 2, 4])),
        "l2": int(rng.choice(L2_CHOICES)),
        "llc_slice": int(rng.choice(LLC_CHOICES)),
        "no_l2": sorted(int(i) for i in np.nonzero(
            rng.random(n_accs) < no_l2_frac)[0]),
    }
    # Bandwidth budget first: each DDR channel costs dram_bw bytes/cycle.
    dram_bw = MemTimings().dram_bw
    while (d["n_mem_tiles"] > 1
           and d["n_mem_tiles"] * dram_bw > budget.max_offchip_bw):
        d["n_mem_tiles"] //= 2
    # Area budget: shrink until the report says it fits.
    while True:
        cfg = _build(name, d)
        rep = budget_report(cfg, budget)
        if rep["within_budget"]:
            break
        if d["llc_slice"] > LLC_CHOICES[0]:
            d["llc_slice"] //= 2
        elif d["l2"] > L2_CHOICES[0]:
            d["l2"] //= 2
        elif d["n_accs"] > max(2, min(min_accs, 2)):
            d["n_accs"] -= 1
        elif d["n_mem_tiles"] > 1:
            d["n_mem_tiles"] -= 1
        elif d["n_cpus"] > 1:
            d["n_cpus"] -= 1
        else:
            raise ValueError(f"budget {budget} too tight for any design")

    profs = [PROFILES[n] for n in cfg.accelerators]
    pat = np.asarray([p.pattern for p in profs])
    axes = {
        "n_accs": cfg.n_accs,
        "n_cpus": cfg.n_cpus,
        "n_mem_tiles": cfg.n_mem_tiles,
        "noc_tiles": cfg.noc_rows * cfg.noc_cols,
        "l2_kb": cfg.l2_bytes // KB,
        "llc_slice_kb": cfg.llc_slice_bytes // KB,
        "no_l2_frac": len(cfg.no_private_cache) / cfg.n_accs,
        "frac_streaming": float(np.mean(pat == 0)),
        "frac_strided": float(np.mean(pat == 1)),
        "frac_irregular": float(np.mean(pat == 2)),
        "mean_compute_per_byte": float(np.mean(
            [p.compute_per_byte for p in profs])),
        "mean_reuse": float(np.mean([p.reuse for p in profs])),
        "mean_burst": float(np.mean([p.burst_bytes for p in profs])),
        "area": rep["area"],
        "area_frac": rep["area_frac"],
        "offchip_bw": rep["offchip_bw"],
        "bw_per_acc": soc_offchip_bw(cfg) / cfg.n_accs,
    }
    return cfg, axes


def sample_socs(key: int, n: int, budget: SoCBudget | None = None, *,
                min_accs: int = 4, max_accs: int = 16
                ) -> list[SampledSoC]:
    """Draw ``n`` validated, budget-fitting design points.

    Each point is sampled from its own ``SeedSequence([key, i])`` stream
    and carries :func:`config_seed`'s deterministic per-config seed —
    sample ``i`` is identical no matter how many points are drawn."""
    budget = budget or DEFAULT_BUDGET
    out = []
    for i in range(n):
        rng = np.random.default_rng(np.random.SeedSequence([key, i]))
        cfg, axes = _sample_one(rng, f"dse{key}-{i}", budget,
                                min_accs, max_accs)
        out.append(SampledSoC(config=cfg, seed=config_seed(key, i),
                              axes=axes))
    return out


# ------------------------------------------------------------------ sweep
def _eval_keys(seeds: np.ndarray, n_policies: int) -> jnp.ndarray:
    """(K, N, 2) evaluation keys derived from per-config seeds — bucket-
    and sample-count-invariant, so deterministic-family metrics from
    bucketed runs reassemble bitwise against a single stacked call."""
    flat = (seeds[:, None].astype(np.int64) * 131 + np.arange(n_policies)
            ) % (2 ** 31 - 1)
    return jax.vmap(jax.random.PRNGKey)(
        jnp.asarray(flat.ravel(), jnp.uint32)).reshape(
            len(seeds), n_policies, 2)


def _bucket_norms(sub: StackedVecEnv, st_iters, st_eval,
                  seeds_g: np.ndarray, iters: int, sharded: bool = False
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Train one agent per lane, then evaluate the whole suite in one
    episodes call; returns (norm_time, norm_mem), each (K_g, N).

    ``sharded`` routes the training call through
    :func:`repro.soc.shard.sharded_train_batched_stacked`, splitting the
    agent axis across every visible device; on a single device the
    wrapper falls back to the plain vmap call bitwise-identically."""
    cfg = qlearn.QConfig(decay_steps=jnp.asarray(
        [s * iters for s in st_iters[0].n_steps], jnp.int32))
    tkeys = jax.vmap(jax.random.PRNGKey)(
        jnp.asarray(seeds_g, jnp.uint32)).reshape(len(seeds_g), 1, 2)
    if sharded:
        from repro.soc import shard
        qs, _ = shard.sharded_train_batched_stacked(
            sub, st_iters, cfg, stack_weights([PAPER_DEFAULT_WEIGHTS]),
            tkeys)
    else:
        qs, _ = sub.train_batched(
            st_iters, cfg, stack_weights([PAPER_DEFAULT_WEIGHTS]), tkeys)

    suite = [FixedHomogeneous(m) for m in CoherenceMode]
    suite += [RandomPolicy(), ManualPolicy()]
    det = sub.lower(st_eval, suite)
    learned = sub.lower_qstates(st_eval, qs)
    specs = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=1), det, learned)
    keys = _eval_keys(seeds_g, len(EVAL_FAMILIES))
    res = sub.episodes(st_eval, specs, cfg, keys=keys)
    base = jax.tree_util.tree_map(lambda x: x[:, _BASE_IDX], res)
    nt, nm = jax.vmap(jax.vmap(vec.normalized_metrics,
                               in_axes=(0, None, None)),
                      in_axes=(0, 0, 0))(res, base, st_eval.phase_mask)
    return np.asarray(nt), np.asarray(nm)


def rank_axes(samples: Sequence[SampledSoC],
              targets: dict[str, np.ndarray]) -> dict:
    """Standardized least-squares regression of each target (e.g. the
    learned speedup margin) on :data:`FEATURE_AXES`; axes ranked by
    coefficient magnitude.  Constant axes get coefficient 0."""
    X = np.asarray([[s.axes[a] for a in FEATURE_AXES] for s in samples],
                   np.float64)
    mu, sd = X.mean(axis=0), X.std(axis=0)
    keep = sd > 1e-12
    Z = np.zeros_like(X)
    Z[:, keep] = (X[:, keep] - mu[keep]) / sd[keep]
    A = np.concatenate([np.ones((len(X), 1)), Z], axis=1)
    out = {}
    for name, y in targets.items():
        y = np.asarray(y, np.float64)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        pred = A @ coef
        ss_res = float(np.sum((y - pred) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2))
        ranked = sorted(zip(FEATURE_AXES, coef[1:].tolist()),
                        key=lambda kv: -abs(kv[1]))
        out[name] = {
            "ranked_coefficients": [[a, c] for a, c in ranked],
            "r2": 1.0 - ss_res / max(ss_tot, 1e-30),
        }
    return out


def run_sweep(samples: Sequence[SampledSoC], *, iters: int = 3,
              n_phases: int = 3, max_buckets: int = 4,
              min_gain: float = 0.02, sharded: bool = False) -> dict:
    """Train + evaluate every sampled SoC in at most ``max_buckets``
    batched (train, eval) call pairs and reduce to per-architecture win
    margins.

    Per bucket: ONE :meth:`StackedVecEnv.train_batched` call (one agent
    per lane, per-lane decay horizons) and ONE
    :meth:`StackedVecEnv.episodes` call evaluating the full suite —
    fixed modes, random, manual, and the freshly trained agents — with
    the NON_COH row of the same call as the normalization baseline.
    Per-config seeds drive app generation, tile striping and episode
    keys, so every per-SoC input — and every deterministic-family
    metric — is independent of bucketing; keyed families (random,
    cohmeleon) consume noise pre-sampled at the bucket's padded scan
    length, so their draws differ across bucket layouts.

    ``sharded=True`` splits each bucket's training call across every
    visible device (:mod:`repro.soc.shard`); with one device it falls
    back to the plain call, bitwise-identical by construction."""
    from repro.soc.apps import make_application

    socs = [s.config for s in samples]
    seeds = np.asarray([s.seed for s in samples], np.int64)
    env = StackedVecEnv(socs)

    t0 = time.perf_counter()
    train_apps = [make_application(c, seed=s.seed, n_phases=n_phases)
                  for c, s in zip(socs, samples)]
    eval_apps = [make_application(c, seed=s.seed + 1, n_phases=n_phases)
                 for c, s in zip(socs, samples)]
    compiled_iters = [
        _compile_lanes(train_apps, socs, [int(s) + it for s in seeds])
        for it in range(iters)]
    compiled_eval = _compile_lanes(eval_apps, socs,
                                   [int(s) + 7919 for s in seeds])
    lengths = [c.n_steps for c in compiled_iters[0]]
    groups = length_buckets(lengths, max_buckets=max_buckets,
                            min_gain=min_gain)
    t_compile = time.perf_counter() - t0

    def volume(lens, gs):
        return sum(len(g) * max(lens[i] for i in g) for g in gs)

    eval_lengths = [c.n_steps for c in compiled_eval]
    vol_single = (iters * volume(lengths, [list(range(len(socs)))])
                  + volume(eval_lengths, [list(range(len(socs)))]))
    vol_bucketed = (iters * volume(lengths, groups)
                    + volume(eval_lengths, groups))
    real = iters * sum(lengths) + sum(eval_lengths)

    parts, subs = [], []
    t0 = time.perf_counter()
    for g in groups:
        sub = env.sublanes(g)
        subs.append(sub)
        socs_g = [socs[i] for i in g]
        st_iters = [_stack_compiled([compiled_iters[it][i] for i in g],
                                    socs_g) for it in range(iters)]
        st_eval = _stack_compiled([compiled_eval[i] for i in g], socs_g)
        parts.append(_bucket_norms(sub, st_iters, st_eval,
                                   seeds[list(g)], iters, sharded))
    nt = reassemble_lanes(groups, [p[0] for p in parts])
    nm = reassemble_lanes(groups, [p[1] for p in parts])
    t_run = time.perf_counter() - t0

    fixed_t, fixed_m = nt[:, :_N_FIXED], nm[:, :_N_FIXED]
    coh_t, coh_m = nt[:, -1], nm[:, -1]
    margins = {
        "speedup_vs_noncoh": 1.0 - coh_t,
        "offchip_reduction_vs_noncoh": 1.0 - coh_m,
        "speedup_vs_fixed_mean":
            (fixed_t.mean(axis=1) - coh_t) / fixed_t.mean(axis=1),
        "offchip_reduction_vs_fixed_mean":
            (fixed_m.mean(axis=1) - coh_m) / fixed_m.mean(axis=1),
        "speedup_vs_best_fixed":
            (fixed_t.min(axis=1) - coh_t) / fixed_t.min(axis=1),
    }
    train_calls = sum(s.calls["train"] for s in subs)
    eval_calls = sum(s.calls["episodes"] for s in subs)
    return {
        "n_socs": len(samples),
        "families": list(EVAL_FAMILIES),
        "norm_time": nt,
        "norm_mem": nm,
        "margins": margins,
        "groups": [list(g) for g in groups],
        "calls": {"train": int(train_calls), "eval": int(eval_calls),
                  "n_buckets": len(groups), "max_buckets": max_buckets},
        "waste": {
            "padded_volume_single_call": int(vol_single),
            "padded_volume_bucketed": int(vol_bucketed),
            "real_invocations": int(real),
            "padded_waste_single_call": 1.0 - real / vol_single,
            "padded_waste_bucketed": 1.0 - real / vol_bucketed,
            "waste_reduction": (vol_single - vol_bucketed) / vol_single,
        },
        "timing": {
            "compile_s": t_compile,
            "train_eval_s": t_run,
            "padded_steps_per_s": vol_bucketed / max(t_run, 1e-9),
            "real_invocations_per_s": real / max(t_run, 1e-9),
        },
        "axis_ranking": rank_axes(samples, {
            "speedup_vs_noncoh": margins["speedup_vs_noncoh"],
            "offchip_reduction_vs_noncoh":
                margins["offchip_reduction_vs_noncoh"],
        }),
    }
