"""Cohmeleon-JAX: learning-based orchestration of memory-interaction modes
(MICRO 2021 reproduction) + a multi-pod JAX training/serving framework for
the ten assigned architectures.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
