"""Host data pipeline: background prefetch + device put.

A real-cluster input pipeline in miniature: a producer thread keeps a small
queue of ready host-batches (overlapping data generation with the train
step), and ``device_put`` targets the batch's sharding so each host only
feeds its addressable shard.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax


class PrefetchIterator:
    """Wrap a host-batch iterator with a daemon prefetch thread."""

    def __init__(self, it: Iterator[dict], depth: int = 2,
                 sharding: Optional[object] = None):
        self._it = it
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for batch in self._it:
                if self._sharding is not None:
                    batch = jax.device_put(batch, self._sharding)
                self._q.put(batch)
        except Exception as e:  # surfaced on next()
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if item is None:
            raise (self._err or StopIteration)
        return item
