"""Data pipeline: synthetic deterministic corpus + host prefetch."""
from repro.data.synthetic import DataConfig, batch_iterator, host_batch
from repro.data.pipeline import PrefetchIterator

__all__ = ["DataConfig", "batch_iterator", "host_batch", "PrefetchIterator"]
