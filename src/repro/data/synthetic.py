"""Deterministic synthetic token pipeline.

Serves the role of the tokenized-corpus loader in a real deployment: each
host generates only its shard of the global batch (derived from
(step, host_id) with a counter-based PRNG, so restarts are reproducible and
no host ever materializes the global batch), with Zipf-ish token marginals
so compression/embedding paths see realistic frequency skew.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2     # token frequency skew


def _rng_for(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def host_batch(arch: ArchConfig, cfg: DataConfig, step: int,
               host: int = 0, n_hosts: int = 1) -> dict:
    """This host's shard of the global batch for ``step``."""
    assert cfg.global_batch % n_hosts == 0
    b = cfg.global_batch // n_hosts
    rng = _rng_for(cfg, step, host)
    s = cfg.seq_len

    def tokens(shape):
        # Zipf-distributed ids clipped into the vocab.
        raw = rng.zipf(cfg.zipf_a, size=shape)
        return np.minimum(raw - 1, arch.vocab - 1).astype(np.int32)

    if arch.n_codebooks:
        toks = tokens((b, arch.n_codebooks, s + 1))
        batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    else:
        toks = tokens((b, s + 1))
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    if arch.family == "vlm":
        batch["vision_embeds"] = rng.normal(
            size=(b, arch.vision_tokens, arch.vision_dim)).astype(np.float32)
        # M-RoPE positions: vision patches get a (t, h, w) grid, text is
        # linear after the grid (stub geometry: square-ish patch grid).
        side = max(int(np.sqrt(arch.vision_tokens)), 1)
        t_pos = np.zeros(arch.vision_tokens, np.int32)
        h_pos = (np.arange(arch.vision_tokens) // side).astype(np.int32)
        w_pos = (np.arange(arch.vision_tokens) % side).astype(np.int32)
        text = np.arange(s - arch.vision_tokens, dtype=np.int32) + side
        mrope = np.stack([
            np.concatenate([t_pos, text]),
            np.concatenate([h_pos, text]),
            np.concatenate([w_pos, text]),
        ])                                                   # (3, S)
        batch["mrope_positions"] = np.tile(mrope[:, None, :], (1, b, 1))
    return batch


def apply_delay_pattern(tokens: np.ndarray, pad_id: int = 0) -> np.ndarray:
    """MusicGen delay pattern: codebook k is shifted right by k steps so the
    model predicts codebooks autoregressively across the K dimension."""
    b, k, s = tokens.shape
    out = np.full_like(tokens, pad_id)
    for ki in range(k):
        out[:, ki, ki:] = tokens[:, ki, :s - ki]
    return out


def batch_iterator(arch: ArchConfig, cfg: DataConfig, host: int = 0,
                   n_hosts: int = 1, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        batch = host_batch(arch, cfg, step, host, n_hosts)
        if arch.n_codebooks:
            batch["tokens"] = apply_delay_pattern(batch["tokens"])
        yield batch
        step += 1
