"""Fault tolerance & straggler mitigation for long multi-pod runs.

On a real cluster these hooks sit around the train loop; here the failure
and straggler *injection* is simulated (CPU container) while the detection
/ recovery machinery is real and unit-tested:

  * HeartbeatMonitor — workers post heartbeats; a worker silent for
    ``timeout`` is declared failed.  On failure the runner restores the
    latest checkpoint and re-meshes onto the surviving device set
    (elastic re-mesh: checkpoint stores full arrays; restore re-shards,
    see checkpoint.ckpt).
  * StragglerDetector — per-step duration tracking; a worker slower than
    ``threshold`` x median over a window is flagged for re-dispatch
    (TPU pods can't re-route a partitioned step, so mitigation = swap the
    slow host's data shard feeding and alert the scheduler; both hooks are
    invoked here).
  * ElasticRunner — drives step/checkpoint/heartbeat and performs the
    restore-and-remesh dance when a failure is injected.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class HeartbeatMonitor:
    n_workers: int
    timeout: float = 30.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, now: Optional[float] = None) -> None:
        self._last[worker] = time.monotonic() if now is None else now

    def failed_workers(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        out = []
        for w in range(self.n_workers):
            last = self._last.get(w)
            if last is None or now - last > self.timeout:
                out.append(w)
        return out


@dataclasses.dataclass
class StragglerDetector:
    threshold: float = 1.5       # x median
    window: int = 20
    _durations: dict = dataclasses.field(default_factory=dict)

    def record(self, worker: int, duration: float) -> None:
        self._durations.setdefault(worker, []).append(duration)
        if len(self._durations[worker]) > self.window:
            self._durations[worker].pop(0)

    def stragglers(self) -> list[int]:
        if not self._durations:
            return []
        medians = {w: float(np.median(d))
                   for w, d in self._durations.items() if d}
        overall = float(np.median(list(medians.values())))
        return [w for w, m in medians.items()
                if m > self.threshold * overall]


class ElasticRunner:
    """Step driver with checkpoint/restart + elastic re-mesh on failure.

    ``build(devices) -> (step_fn, state_shardings)`` reconstructs the
    compiled step and shardings for the current device set; on failure the
    runner rebuilds with the survivors and restores state resharded.
    """

    def __init__(self, build: Callable, manager, ckpt_every: int = 50):
        self.build = build
        self.manager = manager
        self.ckpt_every = ckpt_every
        self.recoveries = 0

    def run(self, state, n_steps: int, devices,
            inject_failure_at: Optional[int] = None,
            surviving_devices=None):
        step_fn, shardings = self.build(devices)
        import jax
        state = jax.device_put(state, shardings)
        step = 0
        while step < n_steps:
            if inject_failure_at is not None and step == inject_failure_at:
                # --- simulated node loss: re-mesh onto survivors ---------
                self.manager.wait()
                latest = self.manager.latest_step()
                devices = surviving_devices
                step_fn, shardings = self.build(devices)
                state = self.manager.restore(
                    jax.eval_shape(lambda s: s, state), step=latest,
                    shardings=shardings)
                step = latest if latest is not None else 0
                self.recoveries += 1
                inject_failure_at = None
                continue
            state = step_fn(state)
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                self.manager.save(step, state)
        self.manager.wait()
        return state, step
