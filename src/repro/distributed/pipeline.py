"""Pipeline parallelism: GPipe-style microbatch schedule on a "pipe" mesh
axis via shard_map + lax.ppermute.

Stage parameters are stacked on a leading (n_stages) axis sharded over the
pipe axis; inside the shard_map each device group holds one stage.  The
static tick loop runs M + S - 1 ticks: stage 0 injects a fresh microbatch
per tick, every stage applies its layer stack, activations hop one stage
per tick via collective_permute.  The last stage accumulates outputs.

Opt-in (1000+-node scaling feature, DESIGN.md §5): the assigned production
mesh uses DP x TP, so the baseline dry-runs don't engage this module; it is
exercised by tests/test_pipeline.py on 8 host devices and composes with the
mesh as an extra leading axis.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(
    stage_fn: Callable,         # (stage_params, x) -> y   (same shape)
    stage_params,               # pytree, leaves (n_stages, ...)
    microbatches: jax.Array,    # (M, mb, ...) input activations
    mesh: Mesh,
    axis_name: str = "pipe",
):
    """Run the GPipe schedule. Returns (M, mb, ...) outputs (last stage)."""
    n_stages = mesh.shape[axis_name]
    m = microbatches.shape[0]
    assert m >= n_stages, (m, n_stages)

    param_specs = jax.tree_util.tree_map(
        lambda l: P(axis_name, *([None] * (l.ndim - 1))), stage_params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(param_specs, P()),       # microbatches replicated
        out_specs=P(),
        check_rep=False)
    def run(params, mbs):
        params = jax.tree_util.tree_map(lambda l: l[0], params)
        idx = jax.lax.axis_index(axis_name)
        is_first = idx == 0
        is_last = idx == n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        x_shape = mbs.shape[1:]
        carry = jnp.zeros(x_shape, mbs.dtype)
        outputs = jnp.zeros(mbs.shape, mbs.dtype)

        def tick(t, state):
            carry, outputs = state
            inject_idx = jnp.minimum(t, m - 1)
            x_in = jnp.where(is_first, mbs[inject_idx], carry)
            y = stage_fn(params, x_in)
            # Collect finished microbatch at the last stage.
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            take = jnp.logical_and(is_last, t >= n_stages - 1)
            outputs = jax.lax.cond(
                take,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0),
                lambda o: o,
                outputs)
            carry = jax.lax.ppermute(y, axis_name, perm)
            return carry, outputs

        _, outputs = jax.lax.fori_loop(
            0, m + n_stages - 1, tick, (carry, outputs))
        # Broadcast the last stage's outputs to every stage (so out_specs
        # P() — replicated — is truthful).
        outputs = jax.lax.psum(
            jnp.where(is_last, outputs, jnp.zeros_like(outputs)), axis_name)
        return outputs

    return run(stage_params, microbatches)


def make_pipe_mesh(n_stages: int) -> Mesh:
    devs = jax.devices()[:n_stages]
    import numpy as np
    return Mesh(np.asarray(devs).reshape(n_stages), ("pipe",))
