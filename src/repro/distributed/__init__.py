"""Distribution substrate: sharding rules, pipeline parallelism, fault
tolerance, collective helpers."""
