"""Sharding rules: logical parameter/activation axes -> mesh axes.

Scheme (DESIGN.md §5, MaxText-style 2-D):

  * batch           -> ("pod", "data")        pure DP across pods + hosts
  * d_model (embed) -> "data"                 FSDP: params, grads and
                                              optimizer state shard over
                                              the data axis (ZeRO-3 via
                                              GSPMD all-gather on use)
  * heads / d_ff / vocab / experts -> "model" TP / EP
  * seq             -> None (SP optional: "model" for long-context prefill)

Rules are keyed by regex on the parameter tree path, so new modules get
sensible shardings without touching this file (longest-match wins).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, PartitionSpec builder) — matched in order, first hit wins.
# Specs written for the logical (data, model) axes; the pod axis is folded
# into the data axis via _expand (params are replicated across pods, batch
# is split across pods).
_PARAM_RULES: list[tuple[str, P]] = [
    # embeddings / heads: vocab on model, d_model REPLICATED — sharding D
    # over "data" here makes the head matmul contract a data-sharded dim
    # against data-sharded batch, which GSPMD resolves by all-reducing the
    # full fp32 logits (measured 40 GB/step/device on qwen2-vl; §Perf
    # iteration 2).  vocab-on-model keeps logits sharded with zero forward
    # collectives and a tiny dE all-reduce in backward.
    (r"(^|\.)embed$", P("model", None)),
    (r"codebook", P(None, "model", None)),
    (r"lm_head$", P(None, "model")),
    (r"vision_proj$", P(None, "data")),
    # attention projections (stacked: leading layer axis)
    (r"\bwq$", P(None, "data", "model", None)),
    (r"\bwk$", P(None, "data", "model", None)),
    (r"\bwv$", P(None, "data", "model", None)),
    (r"\bwo$", P(None, "model", None, "data")),
    # MoE: experts on model, d_model on data
    (r"moe\.router$", P(None, "data", None)),
    (r"moe\.w_(gate|up)$", P(None, "model", "data", None)),
    (r"moe\.w_down$", P(None, "model", None, "data")),
    # dense FFN: d_ff on model, d_model on data
    (r"mlp\.w_(gate|up)$", P(None, "data", "model")),
    (r"mlp\.w_down$", P(None, "model", "data")),
    # rwkv time/channel mix square matrices: shard both dims
    (r"(tm|cm)\.w[rkvgo]$", P(None, "data", "model")),
    (r"(tm|cm)\.wk$", P(None, "data", "model")),
    (r"cm\.wv$", P(None, "model", "data")),
    # rg-lru
    (r"rg\.w_(in|gate)$", P(None, "data", "model")),
    (r"rg\.w_out$", P(None, "model", "data")),
    (r"rg\.w[ax]$", P(None, "data", "model")),
    # everything small (norms, biases, decays, loras): replicated
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return ".".join(parts)


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Keep a spec axis when GSPMD's implicit padding stays efficient.

    Sharding dim d over an axis of size n pads to ceil(d/n)*n; we keep the
    sharding when utilization d / (ceil(d/n)*n) >= 0.5 — e.g. 12 heads over
    16 (util 0.75, each device gets 1 possibly-padded head) beats 16x
    replicated attention compute; 2 kv-heads over 16 (util 0.125) is
    dropped and replicated instead."""
    sizes = _mesh_axis_sizes(mesh)
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes[a] for a in axes]))
        dim = shape[i]
        # jit input shardings must divide exactly; indivisible dims fall
        # back to replicated params + activation constraints (below).
        if dim >= total and dim % total == 0:
            out.append(ax)
        else:
            out.append(None)
    while len(out) < len(shape):
        out.append(None)
    return P(*out[:len(shape)])


def activation_spec(mesh: Mesh, shape, *, batch_dim: int = 0,
                    head_dim: int | None = None) -> P:
    """PartitionSpec for an activation constraint: batch over (pod, data),
    heads over model when padding utilization >= 0.5 (constraints, unlike
    input shardings, tolerate uneven dims via GSPMD padding)."""
    sizes = _mesh_axis_sizes(mesh)
    spec: list = [None] * len(shape)
    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    total_b = int(np.prod([sizes[a] for a in batch_axes]))
    if shape[batch_dim] % total_b == 0 or shape[batch_dim] >= total_b:
        spec[batch_dim] = batch_axes if len(batch_axes) > 1 else "data"
    if head_dim is not None and "model" in sizes:
        n = sizes["model"]
        d = shape[head_dim]
        padded = -(-d // n) * n
        if d / padded >= 0.5:
            spec[head_dim] = "model"
    return P(*spec)


_ACTIVE_MESH: list = []   # set by launch drivers around tracing


class activation_mesh:
    """Context manager registering the mesh used by activation constraints
    (the legacy `with mesh:` context isn't visible to tracing code)."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __enter__(self):
        _ACTIVE_MESH.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH.pop()


def constrain(x, *, batch_dim: int = 0, head_dim: int | None = None):
    """with_sharding_constraint against the registered mesh (no-op outside
    an activation_mesh context, so tests/examples on 1 device are
    unaffected)."""
    if not _ACTIVE_MESH:
        return x
    mesh = _ACTIVE_MESH[-1]
    if not {"data", "model"} <= set(mesh.axis_names):
        return x
    spec = activation_spec(mesh, x.shape, batch_dim=batch_dim,
                           head_dim=head_dim)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _expand_pod(spec: P, mesh: Mesh, batch_axes: bool = False) -> P:
    """Fold the pod axis: batch dims shard over ("pod","data"); params
    replicate over pod (pure DP between pods)."""
    if "pod" not in mesh.axis_names:
        return spec
    out = []
    for ax in spec:
        if batch_axes and ax == "data":
            out.append(("pod", "data"))
        else:
            out.append(ax)
    return P(*out)


def param_shardings(mesh: Mesh, params_shape) -> dict:
    """NamedShardings for a (possibly abstract) param pytree."""
    def leaf(path, leaf):
        key = _path_str(path)
        # Codebook (musicgen) variants carry a leading K axis.
        if key.endswith("embed") and len(leaf.shape) == 3:
            spec = P(None, "model", None)
        elif key.endswith("lm_head") and len(leaf.shape) == 3:
            spec = P(None, None, "model")
        else:
            spec = None
            for pat, rule_spec in _PARAM_RULES:
                if re.search(pat, key):
                    spec = rule_spec
                    break
        if spec is None:
            return NamedSharding(mesh, P())    # replicated
        fitted = _fit_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, _expand_pod(fitted, mesh))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def batch_shardings(mesh: Mesh, batch_shape) -> dict:
    """Input batches: leading batch dim over (pod, data); mrope positions
    have batch second (3, B, S)."""
    def leaf(path, x):
        key = _path_str(path)
        if "mrope" in key:
            spec = P(None, "data")
        else:
            spec = P("data")
        fitted = _fit_spec(spec, x.shape, mesh)
        return NamedSharding(mesh, _expand_pod(fitted, mesh, batch_axes=True))

    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def cache_shardings(mesh: Mesh, cache_shape) -> dict:
    """KV caches: (B, S, K, hd) -> batch over (pod, data), kv heads over
    model; recurrent states: batch over (pod, data)."""
    def leaf(path, x):
        nd = len(x.shape)
        # Leaves under "blocks" are stacked with a leading layer axis.
        stacked = "blocks" in _path_str(path)
        batch_dim = 1 if stacked else 0
        spec = [None] * nd
        if nd > batch_dim:
            spec[batch_dim] = "data"
        # KV caches (B, S, K, hd): shard the kv-head dim over model.
        if nd - (1 if stacked else 0) == 4:
            spec[batch_dim + 2] = "model"
        fitted = _fit_spec(P(*spec), x.shape, mesh)
        return NamedSharding(mesh, _expand_pod(fitted, mesh, batch_axes=True))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def lane_mesh(devices=None) -> Mesh:
    """1-D mesh over independent batch lanes (every device by default).

    The SoC trainer's scale-out axis (:mod:`repro.soc.shard`) is pure data
    parallelism — (SoC lane, reward weight, seed) tuples never communicate —
    so a single flat axis is the whole sharding story there, in contrast to
    the 2-D (data, model) scheme above."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), ("lanes",))
