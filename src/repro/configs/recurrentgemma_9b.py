"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288, vocab=256000 — RG-LRU + local attention, 1 attn per 3 blocks
[arXiv:2402.19427]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    sliding_window=2048,
    rg_pattern=3,
    lru_width=4096,
    conv1d_width=4,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
)
