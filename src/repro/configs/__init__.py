"""Architecture registry: ``--arch <id>`` resolution for every assigned
architecture, plus reduced smoke-test variants for CPU."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs import shapes  # noqa: F401  (re-export)
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.qwen3_8b import CONFIG as QWEN3_8B
from repro.configs.gemma2_27b import CONFIG as GEMMA2_27B
from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.yi_34b import CONFIG as YI_34B
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.qwen2_vl_2b import CONFIG as QWEN2_VL_2B
from repro.configs.recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from repro.configs.musicgen_large import CONFIG as MUSICGEN_LARGE

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        GRANITE_MOE_3B, ARCTIC_480B, QWEN3_8B, GEMMA2_27B, GEMMA2_9B,
        YI_34B, RWKV6_3B, QWEN2_VL_2B, RECURRENTGEMMA_9B, MUSICGEN_LARGE,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small width/depth,
    few experts, tiny vocab — structure (pattern, features) preserved."""
    cfg = get_arch(name)
    pattern_span = max(cfg.global_every, cfg.rg_pattern, 1)
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=2 * pattern_span,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=96 if not cfg.n_experts else 32,
        vocab=128,
        param_dtype="float32",
        compute_dtype="float32",
        remat=cfg.remat,
    )
    if cfg.n_experts:
        updates.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.family == "ssm":
        updates.update(rwkv_head_dim=16, n_heads=4, n_kv_heads=4)
    if cfg.family == "hybrid":
        updates.update(lru_width=64, sliding_window=8, n_kv_heads=1)
    if cfg.sliding_window and cfg.family != "hybrid":
        updates.update(sliding_window=8)
    if cfg.family == "vlm":
        updates.update(vision_tokens=4, vision_dim=32,
                       mrope_sections=(4, 2, 2))
    if cfg.n_codebooks:
        updates.update(n_codebooks=2)
    return dataclasses.replace(cfg, **updates)
