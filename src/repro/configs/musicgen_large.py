"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32, full MHA)
d_ff=8192, vocab=2048 — decoder-only over EnCodec tokens (4 codebooks,
delay pattern in the data pipeline), sinusoidal positions
[arXiv:2306.05284].  The EnCodec frontend is a stub: inputs are codebook
token ids."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    n_codebooks=4,
    pos_emb="sinusoidal",
    act="gelu",
)
