"""Architecture configuration schema.

One frozen dataclass covers all six assigned families (dense / moe / ssm /
vlm / hybrid / audio); family-specific fields default to "off".  Configs are
pure data — model code lives in ``repro.models``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | vlm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- attention features -------------------------------------------------
    qk_norm: bool = False          # qwen3: per-head RMSNorm on q and k
    attn_softcap: float = 0.0      # gemma2: tanh logit soft-capping
    final_softcap: float = 0.0     # gemma2: final-logit soft-capping
    sliding_window: int = 0        # window size for local-attention layers
    global_every: int = 0          # gemma2: 1 global layer per N (pattern
                                   # [local]*(N-1)+[global]); 0 = all global
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) split
    pos_emb: str = "rope"          # rope | sinusoidal | none
    post_norms: bool = False       # gemma2: post-attn/post-ffn RMSNorms
    embed_scale: bool = False      # gemma2: scale embeddings by sqrt(d)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False   # arctic: parallel dense FFN
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25      # expert-capacity multiple (drops above)
    expert_pad_to: int = 0             # pad experts to a mesh multiple so
                                       # EP shards cleanly (router masks the
                                       # dead experts); 0 = no padding

    @property
    def padded_experts(self) -> int:
        return max(self.expert_pad_to, self.n_experts) if self.n_experts else 0

    # --- hybrid / ssm -------------------------------------------------------
    rg_pattern: int = 0            # recurrentgemma: 1 attn block per N
    lru_width: int = 0             # RG-LRU state width (0 -> d_model)
    conv1d_width: int = 4
    rwkv_head_dim: int = 64

    # --- vlm ----------------------------------------------------------------
    vision_tokens: int = 0         # stub frontend: #patch embeddings
    vision_dim: int = 0            # stub frontend: raw patch-embedding dim

    # --- audio --------------------------------------------------------------
    n_codebooks: int = 0           # musicgen: EnCodec codebooks

    # --- misc ---------------------------------------------------------------
    act: str = "silu"              # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- numerics / training ------------------------------------------------
    param_dtype: str = "float32"   # bf16 for the 480B-class config
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""       # "int8" quantizes the KV cache with a
                                   # per-(pos, head) scale — halves decode
                                   # HBM traffic (§Perf Cell C lever)
    optimizer: str = "adamw"       # adamw | adafactor
    remat: str = "none"            # none | full | dots (activation ckpt)
    scan_layers: bool = True       # lax.scan over superblocks (False:
                                   # unrolled python loop — used by the
                                   # dry-run's exact cost accounting)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------------- sizes
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in §Roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

        def ffn(width):  # gated MLP: w_gate, w_up, w_down
            return 3 * d * width

        per_layer = 0
        if self.family == "ssm":
            # rwkv6 time-mix (r,k,v,g,w,out ~ 6 d^2 incl. lora) + channel mix
            per_layer = 6 * d * d + 2 * d * f + d * f
        elif self.family == "hybrid":
            n_attn = self.n_layers // max(self.rg_pattern, 1)
            n_rg = self.n_layers - n_attn
            lru = self.lru_width or d
            rg_block = 2 * d * lru + lru * d + lru * self.conv1d_width
            per_layer = 0  # accumulated below
            total = (n_attn * (attn + ffn(f)) + n_rg * (rg_block + ffn(f)))
            emb = v * d * (1 if self.tie_embeddings else 2)
            return total + emb + d
        else:
            per_layer = attn
            if self.n_experts:
                per_layer += self.n_experts * ffn(f) + d * self.n_experts
                if self.moe_dense_residual:
                    per_layer += ffn(f)
            else:
                per_layer += ffn(f)

        emb_mult = 1 if self.tie_embeddings else 2
        emb = v * d * emb_mult
        if self.n_codebooks:
            emb = v * d * self.n_codebooks * emb_mult
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * f * self.n_layers
        return self.param_count() - inactive

    def embed_param_count(self) -> int:
        mult = 1 if self.tie_embeddings else 2
        per = self.vocab * self.d_model
        if self.n_codebooks:
            per *= self.n_codebooks
        return per * mult

    def active_nonembed_param_count(self) -> int:
        """Active params excluding embedding tables (flop-bearing only —
        the Kaplan 6ND convention)."""
        return self.active_param_count() - self.embed_param_count()
