"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336,
vocab=256000 — local/global alternating, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    global_every=2,
    act="gelu",
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
)
