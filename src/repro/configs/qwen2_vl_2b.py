"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960,
vocab=151936 — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision frontend is a STUB per the task spec: input_specs() provides
precomputed patch embeddings (vision_dim-wide), projected and spliced into
the first ``vision_tokens`` sequence positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    mrope_sections=(16, 24, 24),
    vision_tokens=256,
    vision_dim=1280,
    rope_theta=1000000.0,
)
