"""Assigned input-shape sets (seq_len x global_batch per the task spec).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len); ``train_*`` lower ``train_step``; ``prefill_*`` lower the
prefill function.  ``long_500k`` requires sub-quadratic attention and only
applies to ssm/hybrid archs (skips recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}

#: Families for which 524k-token decode is tractable (sub-quadratic mixing).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def applicable_shapes(family: str):
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if family in LONG_CONTEXT_FAMILIES:
        out.append(LONG_500K)
    return out
