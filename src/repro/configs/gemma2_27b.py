"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864,
vocab=256000 — local(4096)/global alternating, logit softcaps, GeGLU,
pre+post norms, scaled tied embeddings [arXiv:2408.00118]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    global_every=2,
    act="gelu",
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    remat="dots",
    rope_theta=10000.0,
)
