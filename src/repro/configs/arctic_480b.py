"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864,
vocab=32000, MoE 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

480B-class: bf16 params + Adafactor (factored second moment) keep the
per-chip footprint within a v5e's 16 GB HBM at 256 chips (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    rope_theta=10000.0,
)
