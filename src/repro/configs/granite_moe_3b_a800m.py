"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512,
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite family].

Note: the task spec's primary line says "MoE 40e top-8" while its bracketed
hf pointer names the 1b-a400m sibling (32 experts); we follow the primary
spec (40 experts), matching the 3b-a800m variant.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    n_experts=40,
    top_k=8,
    expert_pad_to=48,   # EP shards over the 16-wide model axis (3/chip)
    tie_embeddings=True,
    rope_theta=10000.0,
)
