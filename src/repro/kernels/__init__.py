"""Pallas TPU kernels for the perf-critical compute of the assigned archs.

The paper itself contributes no kernel (it is an orchestration-layer
paper — noted in DESIGN.md §6); these kernels serve the framework's
performance deliverables.  Each subpackage is kernel.py (pl.pallas_call +
BlockSpec) + ops.py (jit wrapper) + ref.py (pure-jnp oracle):

  flash_attention/  blockwise online-softmax attention (causal, sliding
                    window, softcap, GQA via K/V index_map)
  rwkv6_scan/       chunk-parallel RWKV-6 recurrence, VMEM state carry
  rglru_scan/       RG-LRU diagonal recurrence, sequential-chunk scan
  moe_gmm/          ragged grouped expert matmul with scalar-prefetched
                    group sizes (skips empty row tiles)
  soc_step/         fused Cohmeleon episode step: the whole sense/select/
                    time/reward/learn cycle over a sequential (S,) grid
                    with the Q-table + slot table in VMEM scratch (the
                    vecenv ``fused_step=`` scale path; lowers to a pure-
                    XLA scan of the same step on CPU)
"""
