"""Fused SoC episode step — pure-jnp reference semantics.

One step of the vectorized Cohmeleon environment
(:mod:`repro.soc.vecenv`), reformulated so the whole
sense -> select -> time -> reward -> learn cycle is a single pass over
the packed ``(T, 6 + n_tiles)`` slot table and ONE Q-table row:

  * the Q-row for the sensed state is gathered once and shared between
    epsilon-greedy selection and the blend/write-back update (the unfused
    step gathers it twice);
  * the (epsilon, alpha) decay schedule and the step-counter increments
    are precomputed per step *outside* the scan
    (:func:`repro.core.qlearn.decay_arrays`), so the carry holds only the
    Q-table — visits/step diagnostics are reconstructed from the episode
    trace afterwards (:func:`repro.core.qlearn.replay_visits`);
  * each slot's normalized footprint-per-tile (``fp / |tiles|``) is cached
    in the slot table next to the (dram, llc) demand cache and invalidated
    only on slot writes, feeding both the Table-3 sense reductions and the
    per-tile DDR attribution without per-step divisions;
  * everything per-slot lives in ONE ``(T, 6 + n_tiles)`` float32 table
    (:data:`TBL_MODE` .. tile columns), so the per-step bookkeeping is a
    single masked read and a single row write-back instead of seven
    scatter/gather pairs — and the per-step inputs are packed into one
    float row + one int row (:func:`pack_inputs`), so the scan slices two
    arrays per step instead of fifteen.

Every reformulation is value-preserving and almost all are bitwise: the
shared row feeds identical floats to both consumers, integer visit counts
commute, the tile masks are exact {0, 1} factors whether stored as bool
or float32, and the slot-mode column compares identically as float (modes
are small exact integers).  The fused-vs-unfused equivalence tests pin
bitwise equality on CPU.

:func:`episode_ref` scans :func:`fused_step` over a whole episode — it is
both the oracle ``tests/test_kernels.py`` checks the Pallas kernel
against and the fast XLA lowering :mod:`repro.kernels.soc_step.ops`
dispatches to on CPU backends.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import qlearn, rewards, state as cstate
from repro.core.modes import CoherenceMode
from repro.core.state import CacheGeometry
from repro.soc.faults import StepFault
from repro.soc.memsys import SoCStatic, invocation_perf_cached, warmth_after

# Packed slot-table column layout: one (T, N_TBL_COLS + n_tiles) float32
# array is the whole per-thread carry (mode compares exactly as float;
# tile columns are {0, 1} factors, which every consumer casts or
# multiplies — bitwise-identical to the unfused bool/int arrays).
TBL_MODE, TBL_FP, TBL_WARM, TBL_DRAM, TBL_LLC, TBL_FPT = range(6)
N_TBL_COLS = 6

# Column order of the packed per-step trace row (int columns are exact
# small integers in f32; unpack_ys restores their dtypes).
YCOLS = ("mode", "state_idx", "action", "exec_time", "offchip", "reward")

# Column order of the packed int input row (see pack_inputs).
ICOLS = ("acc_id", "thread", "fresh", "valid", "pre_mode")


def tbl_width(n_tiles: int) -> int:
    return N_TBL_COLS + n_tiles


def init_slot_table(n_threads: int, n_tiles: int) -> jnp.ndarray:
    """Fresh packed slot table: mode=-1 (never used), warmth=1, rest 0."""
    tbl = jnp.zeros((n_threads, tbl_width(n_tiles)), jnp.float32)
    return tbl.at[:, TBL_MODE].set(-1.0).at[:, TBL_WARM].set(1.0)


def _neutral_row(n_tiles: int) -> jnp.ndarray:
    """What an inactive slot reads as: mode=-1, every contribution 0."""
    return jnp.zeros((tbl_width(n_tiles),), jnp.float32).at[TBL_MODE].set(
        -1.0)


class StepInputs(NamedTuple):
    """Per-step xs of the fused episode.

    A schedule row, the lowered policy's precomputed mode, the pregathered
    per-accelerator rows (``pmat[acc_id]`` / ``masks[acc_id]`` — hoisting
    the gathers out of the scan is value-identical), the precomputed decay
    schedule and the pre-sampled select noise.  Leaves carry a leading
    (S,) axis when fed to :func:`episode_ref` / :func:`pack_inputs`."""

    acc_id: jnp.ndarray      # () int32
    footprint: jnp.ndarray   # () float32 bytes
    tiles: jnp.ndarray       # (n_tiles,) bool
    thread: jnp.ndarray      # () int32
    fresh: jnp.ndarray       # () bool
    others: jnp.ndarray      # (T,) bool
    valid: jnp.ndarray       # () bool
    pre_mode: jnp.ndarray    # () int32 — the PolicySpec mode table row
    profile: jnp.ndarray     # (F,) float32 — pmat[acc_id]
    avail: jnp.ndarray       # (A,) bool — masks[acc_id]
    eps: jnp.ndarray         # () float32 precomputed epsilon
    alpha: jnp.ndarray       # () float32 precomputed alpha
    u_explore: jnp.ndarray   # () float32
    g_pick: jnp.ndarray      # (A,) float32 gumbel
    g_tie: jnp.ndarray       # (A,) float32 gumbel
    # Optional pre-sampled fault rows (repro.soc.faults.StepFault columns).
    # None (the default) keeps the healthy program: None fields are empty
    # pytree nodes, so they scan/pack away to nothing at trace time.
    f_exec: jnp.ndarray | None = None   # () float32 compute-cost multiplier
    f_ddr: jnp.ndarray | None = None    # () float32 dram_bw multiplier
    f_llc: jnp.ndarray | None = None    # () float32 extra LLC load
    f_retry: jnp.ndarray | None = None  # () float32 retry backoff cycles


def pack_inputs(xs: StepInputs) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack an (S,)-leading :class:`StepInputs` into ``(xf, xi)``.

    ``xf`` is ``(S, 4 + n_tiles + T + F + 3A [+ 4])`` float32 —
    ``[footprint, eps, alpha, u_explore, tiles, others, profile, avail,
    g_pick, g_tie]`` plus, when the episode is fault-injected, the four
    :class:`~repro.soc.faults.StepFault` columns — and ``xi`` is ``(S,
    5)`` int32 (:data:`ICOLS`).  This is the Pallas kernel's input
    layout: one float row + one int row per grid step instead of fifteen
    blocked operands; boolean masks ride as exact {0, 1} floats.  (The
    XLA ``lax.scan`` lowering feeds the leaves directly — per-step row
    unpacking costs more than it saves there.)"""
    f32, i32 = jnp.float32, jnp.int32
    cols = [
        jnp.stack([xs.footprint.astype(f32), xs.eps.astype(f32),
                   xs.alpha.astype(f32), xs.u_explore.astype(f32)],
                  axis=-1),
        xs.tiles.astype(f32), xs.others.astype(f32),
        xs.profile.astype(f32), xs.avail.astype(f32),
        xs.g_pick.astype(f32), xs.g_tie.astype(f32)]
    if xs.f_exec is not None:
        cols.append(jnp.stack([xs.f_exec.astype(f32), xs.f_ddr.astype(f32),
                               xs.f_llc.astype(f32),
                               xs.f_retry.astype(f32)], axis=-1))
    xf = jnp.concatenate(cols, axis=-1)
    xi = jnp.stack([xs.acc_id.astype(i32), xs.thread.astype(i32),
                    xs.fresh.astype(i32), xs.valid.astype(i32),
                    xs.pre_mode.astype(i32)], axis=-1)
    return xf, xi


def unpack_inputs(xf: jnp.ndarray, xi: jnp.ndarray, *, n_tiles: int,
                  n_threads: int, n_actions: int,
                  faulted: bool = False) -> StepInputs:
    """Invert :func:`pack_inputs` for ONE step row (no leading axis).

    Static slices of the packed rows fuse into their consumers; bool
    fields are restored with exact ``!= 0`` compares.  ``faulted`` (a
    static flag, mirroring whether ``pack_inputs`` saw fault columns)
    recovers the trailing :class:`~repro.soc.faults.StepFault` columns."""
    o = 4
    tiles = xf[o:o + n_tiles] != 0.0
    o += n_tiles
    others = xf[o:o + n_threads] != 0.0
    o += n_threads
    n_feat = xf.shape[-1] - o - 3 * n_actions - (4 if faulted else 0)
    profile = xf[o:o + n_feat]
    o += n_feat
    avail = xf[o:o + n_actions] != 0.0
    o += n_actions
    g_pick = xf[o:o + n_actions]
    o += n_actions
    g_tie = xf[o:o + n_actions]
    o += n_actions
    fault = {}
    if faulted:
        fault = dict(f_exec=xf[o], f_ddr=xf[o + 1], f_llc=xf[o + 2],
                     f_retry=xf[o + 3])
    return StepInputs(
        acc_id=xi[0], thread=xi[1], fresh=xi[2] != 0, valid=xi[3] != 0,
        pre_mode=xi[4], footprint=xf[0], eps=xf[1], alpha=xf[2],
        u_explore=xf[3], tiles=tiles, others=others, profile=profile,
        avail=avail, g_pick=g_pick, g_tie=g_tie, **fault)


def unpack_ys(y: jnp.ndarray) -> tuple:
    """Split the stacked ``(S, 6)`` trace (:data:`YCOLS`) back into typed
    per-step arrays."""
    i32 = jnp.int32
    return (y[:, 0].astype(i32), y[:, 1].astype(i32), y[:, 2].astype(i32),
            y[:, 3], y[:, 4], y[:, 5])


def fused_step(s: SoCStatic, geom: CacheGeometry, warm_cap, learned,
               weights, qtable, rs, tbl, x: StepInputs, *,
               ddr_attribution: bool = False, gated: bool = False):
    """One fused sense->select->time->reward->learn step.

    Pure values in, pure values out — the Pallas kernel body loads its
    scratch, calls this, and stores the results, so kernel and reference
    cannot drift.  ``tbl`` is the packed ``(T, 6 + n_tiles)`` slot table;
    returns ``(qtable, rs, tbl, y)`` with ``y`` the stacked ``(6,)``
    :data:`YCOLS` trace row.
    """
    n_tiles = tbl.shape[-1] - N_TBL_COLS
    omask = x.others & (tbl[:, TBL_MODE] >= 0.0)
    # ONE masked read serves sense, timing and DDR attribution: inactive
    # slots become the neutral row (mode -1, zero contributions).
    otbl = jnp.where(omask[:, None], tbl, _neutral_row(n_tiles))
    omodes = otbl[:, TBL_MODE]
    ofps = otbl[:, TBL_FP]
    odram = otbl[:, TBL_DRAM]
    ollc = otbl[:, TBL_LLC]
    ofpt = otbl[:, TBL_FPT]
    otiles = otbl[:, N_TBL_COLS:]
    state_idx = cstate.observe(
        active_modes=omodes, active_footprints=ofps, needed_tiles=otiles,
        target_tiles=x.tiles, target_footprint=x.footprint, geom=geom,
        active_fp_per_tile=ofpt)

    self_row = tbl[x.thread]
    warm_t = jnp.where(x.fresh, 1.0, self_row[TBL_WARM])

    # One shared Q-row gather: selection and update read identical floats.
    row = qtable[state_idx]
    q_action = qlearn.row_select_presampled(
        row, x.eps, qlearn.SelectNoise(
            u_explore=x.u_explore, g_pick=x.g_pick, g_tie=x.g_tie),
        x.avail)
    action = jax.lax.select(learned, q_action, x.pre_mode)

    # Degradation safety: a non-finite sense feature (a fault-corrupted
    # footprint) forces the always-available non-coherent mode, like an
    # unavailable action.  ``& True`` on the healthy path is bitwise-free.
    mode = jnp.where(x.avail[action] & jnp.isfinite(x.footprint), action,
                     int(CoherenceMode.NON_COH_DMA)).astype(jnp.int32)
    fault = None
    if x.f_exec is not None:
        fault = StepFault(exec_scale=x.f_exec, ddr_scale=x.f_ddr,
                          llc_extra=x.f_llc, retry_cycles=x.f_retry)
    m, aux = invocation_perf_cached(
        mode, x.profile, x.footprint, x.tiles, omodes, odram, ollc,
        ofps, otiles, warm_t, s, fault=fault)
    off_reward = m.offchip_accesses
    if ddr_attribution:
        # Prorated per-tile DDR attribution (paper §4.1(4)); the cached
        # fpt replaces the per-step ``ofps / o_nt`` division.
        myt = x.tiles.astype(jnp.float32)
        n_my = jnp.maximum(jnp.sum(myt), 1.0)
        o_nt = jnp.maximum(jnp.sum(otiles, -1), 1.0)
        my_fp_t = (x.footprint / n_my) * myt
        o_fp_t = jnp.sum(ofpt[:, None] * otiles, 0)
        share = my_fp_t / jnp.maximum(my_fp_t + o_fp_t, 1e-9)
        my_bpt = (m.offchip_accesses * s.line / n_my) * myt
        o_bpt = jnp.sum(((odram * m.exec_time) / o_nt)[:, None] * otiles, 0)
        off_reward = jnp.sum(share * (my_bpt + o_bpt)) / s.line
    meas = rewards.Measurement(
        exec_time=m.exec_time, comm_cycles=m.comm_cycles,
        total_cycles=m.total_cycles, offchip_accesses=off_reward,
        footprint=x.footprint)
    r, rs_new, _ = rewards.evaluate(rs, x.acc_id, meas, weights)

    new_qrow = qlearn.row_update(row, x.alpha, action, r)
    new_slot = jnp.concatenate([
        jnp.stack([mode.astype(jnp.float32), x.footprint,
                   warmth_after(mode, x.footprint, warm_cap),
                   aux["demand_dram"], aux["demand_llc"],
                   x.footprint / jnp.maximum(jnp.sum(x.tiles), 1)]),
        x.tiles.astype(jnp.float32)])
    if gated:
        # Row-level gating is bitwise-equal to the unfused full-pytree
        # where(valid): only the written rows differ between new and old.
        new_qrow = jnp.where(x.valid, new_qrow, row)
        new_slot = jnp.where(x.valid, new_slot, self_row)
        rs_new = jax.tree_util.tree_map(
            lambda n, o: jnp.where(x.valid, n, o), rs_new, rs)
    qtable_new = qtable.at[state_idx].set(new_qrow)
    tbl_new = tbl.at[x.thread].set(new_slot)

    y = jnp.stack([mode.astype(jnp.float32), state_idx.astype(jnp.float32),
                   action.astype(jnp.float32), m.exec_time,
                   m.offchip_accesses, r])
    return qtable_new, rs_new, tbl_new, y


def derive_geom(s: SoCStatic) -> tuple[CacheGeometry, jnp.ndarray]:
    """(cache geometry, warmth capacity) from the static scalar bundle."""
    geom = CacheGeometry(l2_bytes=s.l2_bytes,
                         llc_slice_bytes=s.llc_slice_bytes,
                         n_mem_tiles=s.n_mem_tiles)
    warm_cap = s.llc_slice_bytes * s.n_mem_tiles + s.n_cpus * s.l2_bytes
    return geom, warm_cap


def episode_ref(s: SoCStatic, learned, weights, qtable0, extrema0,
                xs: StepInputs, *, ddr_attribution: bool = False,
                gated: bool = False):
    """Scan :func:`fused_step` over a whole episode (pure XLA).

    ``xs`` leaves carry a leading (S,) axis; ``extrema0`` is the initial
    reward-extrema table ((4, n_accs), from ``rewards.init_reward_state``).
    Returns ``(qtable_final, ys)`` with ``ys`` the per-step
    ``(mode, state_idx, action, exec_cycles, offchip, reward)`` arrays.
    """
    geom, warm_cap = derive_geom(s)
    n_threads = xs.others.shape[-1]
    n_tiles = xs.tiles.shape[-1]

    def step(carry, x):
        qtable, rs, tbl = carry
        qtable, rs, tbl, y = fused_step(
            s, geom, warm_cap, learned, weights, qtable, rs, tbl, x,
            ddr_attribution=ddr_attribution, gated=gated)
        return (qtable, rs, tbl), y

    carry0 = (qtable0, rewards.RewardState(extrema=extrema0),
              init_slot_table(n_threads, n_tiles))
    (qtable, _, _), y = jax.lax.scan(step, carry0, xs)
    return qtable, unpack_ys(y)
