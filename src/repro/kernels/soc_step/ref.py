"""Fused SoC episode step — pure-jnp reference semantics.

One step of the vectorized Cohmeleon environment
(:mod:`repro.soc.vecenv`), reformulated so the whole
sense -> select -> time -> reward -> learn cycle is a single pass over
the packed ``(T, 6 + n_tiles)`` slot table and ONE Q-table row:

  * the Q-row for the sensed state is gathered once and shared between
    epsilon-greedy selection and the blend/write-back update (the unfused
    step gathers it twice);
  * the (epsilon, alpha) decay schedule and the step-counter increments
    are precomputed per step *outside* the scan
    (:func:`repro.core.qlearn.decay_arrays`), so the carry holds only the
    Q-table — visits/step diagnostics are reconstructed from the episode
    trace afterwards (:func:`repro.core.qlearn.replay_visits`);
  * each slot's normalized footprint-per-tile (``fp / |tiles|``) is cached
    in the slot table next to the (dram, llc) demand cache and invalidated
    only on slot writes, feeding both the Table-3 sense reductions and the
    per-tile DDR attribution without per-step divisions;
  * everything per-slot lives in ONE ``(T, 6 + n_tiles)`` float32 table
    (:data:`TBL_MODE` .. tile columns), so the per-step bookkeeping is a
    single masked read and a single row write-back instead of seven
    scatter/gather pairs — and the per-step inputs are packed into one
    float row + one int row (:func:`pack_inputs`), so the scan slices two
    arrays per step instead of fifteen.

Every reformulation is value-preserving and almost all are bitwise: the
shared row feeds identical floats to both consumers, integer visit counts
commute, the tile masks are exact {0, 1} factors whether stored as bool
or float32, and the slot-mode column compares identically as float (modes
are small exact integers).  The fused-vs-unfused equivalence tests pin
bitwise equality on CPU.

:func:`episode_ref` scans :func:`fused_step` over a whole episode — it is
both the oracle ``tests/test_kernels.py`` checks the Pallas kernel
against and the fast XLA lowering :mod:`repro.kernels.soc_step.ops`
dispatches to on CPU backends.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn, rewards, state as cstate
from repro.core.modes import CoherenceMode
from repro.core.state import CacheGeometry
from repro.soc import nn as socnn
from repro.soc.faults import StepFault
from repro.soc.memsys import SoCStatic, invocation_perf_cached, warmth_after

# Packed slot-table column layout: one (T, N_TBL_COLS + n_tiles) float32
# array is the whole per-thread carry (mode compares exactly as float;
# tile columns are {0, 1} factors, which every consumer casts or
# multiplies — bitwise-identical to the unfused bool/int arrays).
TBL_MODE, TBL_FP, TBL_WARM, TBL_DRAM, TBL_LLC, TBL_FPT = range(6)
N_TBL_COLS = 6

# Column order of the packed per-step trace row (int columns are exact
# small integers in f32; unpack_ys restores their dtypes).
YCOLS = ("mode", "state_idx", "action", "exec_time", "offchip", "reward")

# Column order of the packed int input row (see pack_inputs).
ICOLS = ("acc_id", "thread", "fresh", "valid", "pre_mode")


def tbl_width(n_tiles: int) -> int:
    return N_TBL_COLS + n_tiles


def init_slot_table(n_threads: int, n_tiles: int) -> jnp.ndarray:
    """Fresh packed slot table: mode=-1 (never used), warmth=1, rest 0."""
    tbl = jnp.zeros((n_threads, tbl_width(n_tiles)), jnp.float32)
    return tbl.at[:, TBL_MODE].set(-1.0).at[:, TBL_WARM].set(1.0)


def _neutral_row(n_tiles: int) -> jnp.ndarray:
    """What an inactive slot reads as: mode=-1, every contribution 0."""
    return jnp.zeros((tbl_width(n_tiles),), jnp.float32).at[TBL_MODE].set(
        -1.0)


class StepInputs(NamedTuple):
    """Per-step xs of the fused episode.

    A schedule row, the lowered policy's precomputed mode, the pregathered
    per-accelerator rows (``pmat[acc_id]`` / ``masks[acc_id]`` — hoisting
    the gathers out of the scan is value-identical), the precomputed decay
    schedule and the pre-sampled select noise.  Leaves carry a leading
    (S,) axis when fed to :func:`episode_ref` / :func:`pack_inputs`."""

    acc_id: jnp.ndarray      # () int32
    footprint: jnp.ndarray   # () float32 bytes
    tiles: jnp.ndarray       # (n_tiles,) bool
    thread: jnp.ndarray      # () int32
    fresh: jnp.ndarray       # () bool
    others: jnp.ndarray      # (T,) bool
    valid: jnp.ndarray       # () bool
    pre_mode: jnp.ndarray    # () int32 — the PolicySpec mode table row
    profile: jnp.ndarray     # (F,) float32 — pmat[acc_id]
    avail: jnp.ndarray       # (A,) bool — masks[acc_id]
    eps: jnp.ndarray         # () float32 precomputed epsilon
    alpha: jnp.ndarray       # () float32 precomputed alpha
    u_explore: jnp.ndarray   # () float32
    g_pick: jnp.ndarray      # (A,) float32 gumbel
    g_tie: jnp.ndarray       # (A,) float32 gumbel
    # Optional pre-sampled fault rows (repro.soc.faults.StepFault columns).
    # None (the default) keeps the healthy program: None fields are empty
    # pytree nodes, so they scan/pack away to nothing at trace time.
    f_exec: jnp.ndarray | None = None   # () float32 compute-cost multiplier
    f_ddr: jnp.ndarray | None = None    # () float32 dram_bw multiplier
    f_llc: jnp.ndarray | None = None    # () float32 extra LLC load
    f_retry: jnp.ndarray | None = None  # () float32 retry backoff cycles


def pack_inputs(xs: StepInputs) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack an (S,)-leading :class:`StepInputs` into ``(xf, xi)``.

    ``xf`` is ``(S, 4 + n_tiles + T + F + 3A [+ 4])`` float32 —
    ``[footprint, eps, alpha, u_explore, tiles, others, profile, avail,
    g_pick, g_tie]`` plus, when the episode is fault-injected, the four
    :class:`~repro.soc.faults.StepFault` columns — and ``xi`` is ``(S,
    5)`` int32 (:data:`ICOLS`).  This is the Pallas kernel's input
    layout: one float row + one int row per grid step instead of fifteen
    blocked operands; boolean masks ride as exact {0, 1} floats.  (The
    XLA ``lax.scan`` lowering feeds the leaves directly — per-step row
    unpacking costs more than it saves there.)"""
    f32, i32 = jnp.float32, jnp.int32
    cols = [
        jnp.stack([xs.footprint.astype(f32), xs.eps.astype(f32),
                   xs.alpha.astype(f32), xs.u_explore.astype(f32)],
                  axis=-1),
        xs.tiles.astype(f32), xs.others.astype(f32),
        xs.profile.astype(f32), xs.avail.astype(f32),
        xs.g_pick.astype(f32), xs.g_tie.astype(f32)]
    if xs.f_exec is not None:
        cols.append(jnp.stack([xs.f_exec.astype(f32), xs.f_ddr.astype(f32),
                               xs.f_llc.astype(f32),
                               xs.f_retry.astype(f32)], axis=-1))
    xf = jnp.concatenate(cols, axis=-1)
    xi = jnp.stack([xs.acc_id.astype(i32), xs.thread.astype(i32),
                    xs.fresh.astype(i32), xs.valid.astype(i32),
                    xs.pre_mode.astype(i32)], axis=-1)
    return xf, xi


def unpack_inputs(xf: jnp.ndarray, xi: jnp.ndarray, *, n_tiles: int,
                  n_threads: int, n_actions: int,
                  faulted: bool = False) -> StepInputs:
    """Invert :func:`pack_inputs` for ONE step row (no leading axis).

    Static slices of the packed rows fuse into their consumers; bool
    fields are restored with exact ``!= 0`` compares.  ``faulted`` (a
    static flag, mirroring whether ``pack_inputs`` saw fault columns)
    recovers the trailing :class:`~repro.soc.faults.StepFault` columns."""
    o = 4
    tiles = xf[o:o + n_tiles] != 0.0
    o += n_tiles
    others = xf[o:o + n_threads] != 0.0
    o += n_threads
    n_feat = xf.shape[-1] - o - 3 * n_actions - (4 if faulted else 0)
    profile = xf[o:o + n_feat]
    o += n_feat
    avail = xf[o:o + n_actions] != 0.0
    o += n_actions
    g_pick = xf[o:o + n_actions]
    o += n_actions
    g_tie = xf[o:o + n_actions]
    o += n_actions
    fault = {}
    if faulted:
        fault = dict(f_exec=xf[o], f_ddr=xf[o + 1], f_llc=xf[o + 2],
                     f_retry=xf[o + 3])
    return StepInputs(
        acc_id=xi[0], thread=xi[1], fresh=xi[2] != 0, valid=xi[3] != 0,
        pre_mode=xi[4], footprint=xf[0], eps=xf[1], alpha=xf[2],
        u_explore=xf[3], tiles=tiles, others=others, profile=profile,
        avail=avail, g_pick=g_pick, g_tie=g_tie, **fault)


def unpack_ys(y: jnp.ndarray) -> tuple:
    """Split the stacked ``(S, 6)`` trace (:data:`YCOLS`) back into typed
    per-step arrays."""
    i32 = jnp.int32
    return (y[:, 0].astype(i32), y[:, 1].astype(i32), y[:, 2].astype(i32),
            y[:, 3], y[:, 4], y[:, 5])


def fused_step(s: SoCStatic, geom: CacheGeometry, warm_cap, learned,
               weights, qtable, rs, tbl, x: StepInputs, *,
               ddr_attribution: bool = False, gated: bool = False,
               wpack=None, qfun=None, mlp_lr=None, mlp_dims=None,
               mlp_feats: str = "sense", slack=None, reuse=None):
    """One fused sense->select->time->reward->learn step.

    Pure values in, pure values out — the Pallas kernel body loads its
    scratch, calls this, and stores the results, so kernel and reference
    cannot drift.  ``tbl`` is the packed ``(T, 6 + n_tiles)`` slot table;
    returns ``(qtable, rs, tbl, y)`` with ``y`` the stacked ``(6,)``
    :data:`YCOLS` trace row.

    ``wpack=None`` (the default) is the exact tabular program.  With a
    packed MLP (:mod:`repro.soc.nn`) the step additionally runs the
    network forward over the sense features and its semi-gradient TD
    update, returning ``(qtable, rs, tbl, wpack, y)``; the traced
    ``qfun`` flag selects which Q-row (table or network) drives
    selection and which agent learns, so mixed table/MLP spec batches
    share one program.  ``slack``/``reuse`` are the serving path's
    HyDRA-style features (episodes default them to 0).
    """
    n_tiles = tbl.shape[-1] - N_TBL_COLS
    omask = x.others & (tbl[:, TBL_MODE] >= 0.0)
    # ONE masked read serves sense, timing and DDR attribution: inactive
    # slots become the neutral row (mode -1, zero contributions).
    otbl = jnp.where(omask[:, None], tbl, _neutral_row(n_tiles))
    omodes = otbl[:, TBL_MODE]
    ofps = otbl[:, TBL_FP]
    odram = otbl[:, TBL_DRAM]
    ollc = otbl[:, TBL_LLC]
    ofpt = otbl[:, TBL_FPT]
    otiles = otbl[:, N_TBL_COLS:]
    state_idx = cstate.observe(
        active_modes=omodes, active_footprints=ofps, needed_tiles=otiles,
        target_tiles=x.tiles, target_footprint=x.footprint, geom=geom,
        active_fp_per_tile=ofpt)

    self_row = tbl[x.thread]
    warm_t = jnp.where(x.fresh, 1.0, self_row[TBL_WARM])

    # One shared Q-row gather: selection and update read identical floats.
    row = qtable[state_idx]
    if wpack is None:
        row_sel = row
        learned_eff = learned
    else:
        # Function-approximation branch (repro.soc.nn): for qfun specs
        # the network's Q-row replaces the table row.  Routing it through
        # the SAME row_select_presampled keeps PR-7's non-finite-row ->
        # NON_COH degradation fallback for free: fault-poisoned weights
        # produce a non-finite row and the step serves non-coherently.
        feats = socnn.step_features(
            mlp_feats, s, state_idx, footprint=x.footprint, tiles=x.tiles,
            omask=omask, omodes=omodes, ofps=ofps, odram=odram,
            warm_t=warm_t, profile=x.profile,
            slack=jnp.float32(0.0) if slack is None else slack,
            reuse=jnp.float32(0.0) if reuse is None else reuse)
        row_mlp = socnn.forward_packed(wpack, feats, mlp_dims)
        row_sel = jnp.where(qfun, row_mlp, row)
        learned_eff = learned | qfun
    q_action = qlearn.row_select_presampled(
        row_sel, x.eps, qlearn.SelectNoise(
            u_explore=x.u_explore, g_pick=x.g_pick, g_tie=x.g_tie),
        x.avail)
    action = jax.lax.select(learned_eff, q_action, x.pre_mode)

    # Degradation safety: a non-finite sense feature (a fault-corrupted
    # footprint) forces the always-available non-coherent mode, like an
    # unavailable action.  ``& True`` on the healthy path is bitwise-free.
    mode = jnp.where(x.avail[action] & jnp.isfinite(x.footprint), action,
                     int(CoherenceMode.NON_COH_DMA)).astype(jnp.int32)
    fault = None
    if x.f_exec is not None:
        fault = StepFault(exec_scale=x.f_exec, ddr_scale=x.f_ddr,
                          llc_extra=x.f_llc, retry_cycles=x.f_retry)
    m, aux = invocation_perf_cached(
        mode, x.profile, x.footprint, x.tiles, omodes, odram, ollc,
        ofps, otiles, warm_t, s, fault=fault)
    off_reward = m.offchip_accesses
    if ddr_attribution:
        # Prorated per-tile DDR attribution (paper §4.1(4)); the cached
        # fpt replaces the per-step ``ofps / o_nt`` division.
        myt = x.tiles.astype(jnp.float32)
        n_my = jnp.maximum(jnp.sum(myt), 1.0)
        o_nt = jnp.maximum(jnp.sum(otiles, -1), 1.0)
        my_fp_t = (x.footprint / n_my) * myt
        o_fp_t = jnp.sum(ofpt[:, None] * otiles, 0)
        share = my_fp_t / jnp.maximum(my_fp_t + o_fp_t, 1e-9)
        my_bpt = (m.offchip_accesses * s.line / n_my) * myt
        o_bpt = jnp.sum(((odram * m.exec_time) / o_nt)[:, None] * otiles, 0)
        off_reward = jnp.sum(share * (my_bpt + o_bpt)) / s.line
    meas = rewards.Measurement(
        exec_time=m.exec_time, comm_cycles=m.comm_cycles,
        total_cycles=m.total_cycles, offchip_accesses=off_reward,
        footprint=x.footprint)
    r, rs_new, _ = rewards.evaluate(rs, x.acc_id, meas, weights)

    new_qrow = qlearn.row_update(row, x.alpha, action, r)
    if wpack is not None:
        # qfun specs leave the (placeholder) table bitwise untouched —
        # x.alpha follows the MLP's decay schedule there, so the blend
        # must be overridden, not merely zero-alpha'd.
        new_qrow = jnp.where(qfun, row, new_qrow)
        upd_gate = (qfun & x.valid) if gated else qfun
        wpack_new = socnn.td_update_packed(
            wpack, feats, action, r, x.alpha * mlp_lr, mlp_dims, upd_gate)
    new_slot = jnp.concatenate([
        jnp.stack([mode.astype(jnp.float32), x.footprint,
                   warmth_after(mode, x.footprint, warm_cap),
                   aux["demand_dram"], aux["demand_llc"],
                   x.footprint / jnp.maximum(jnp.sum(x.tiles), 1)]),
        x.tiles.astype(jnp.float32)])
    if gated:
        # Row-level gating is bitwise-equal to the unfused full-pytree
        # where(valid): only the written rows differ between new and old.
        new_qrow = jnp.where(x.valid, new_qrow, row)
        new_slot = jnp.where(x.valid, new_slot, self_row)
        rs_new = jax.tree_util.tree_map(
            lambda n, o: jnp.where(x.valid, n, o), rs_new, rs)
    qtable_new = qtable.at[state_idx].set(new_qrow)
    tbl_new = tbl.at[x.thread].set(new_slot)

    y = jnp.stack([mode.astype(jnp.float32), state_idx.astype(jnp.float32),
                   action.astype(jnp.float32), m.exec_time,
                   m.offchip_accesses, r])
    if wpack is not None:
        return qtable_new, rs_new, tbl_new, wpack_new, y
    return qtable_new, rs_new, tbl_new, y


# --------------------------------------------------------------------------
# Serving mode: the same fused step driven by an open-ended arrival stream
# (repro.soc.traffic) instead of a fixed schedule.  One scan step == one
# OFFERED request in arrival order; the carry additionally holds the
# per-accelerator admission state (bounded finish-time ring buffers), the
# overload-pressure EMA and the in-carry decay counter (the overload
# watchdog may rewind it mid-stream, so it cannot be precomputed outside
# the scan the way ``qlearn.decay_arrays`` does for episodes).
# --------------------------------------------------------------------------

# Per-request serving trace columns, appended after YCOLS.  ``executed``
# gates every other column (a shed request contributes zeros); ``retries``
# is the admitted attempt index (0 = admitted on arrival) or
# FAULT_MAX_RETRIES + 1 when every backoff attempt was shed; ``depth`` is
# the victim accelerator's queue depth at arrival (pre-admission).
SERVE_YCOLS = YCOLS + ("executed", "latency", "retries", "depth",
                       "degraded", "start", "finish")

# Retry budget shared with the fault model (soc.faults.FAULT_MAX_RETRIES;
# a literal here so this module stays import-light for the kernel).
_SERVE_MAX_RETRIES = 3
_SHED_RETRIES = np.float32(_SERVE_MAX_RETRIES + 1)


class ServeParams(NamedTuple):
    """Scalar serving knobs threaded into every serve step (all traced —
    sweeping any of them reuses the compiled program).

    The decay schedule scalars live here rather than precomputing
    ``(eps_t, alpha_t)`` arrays because the overload watchdog rewinds the
    in-carry step counter mid-stream — the schedule must be evaluated
    against the carried counter, with the same float formula as
    :func:`repro.core.qlearn.schedule`."""

    eps0: jnp.ndarray           # () f32 — cfg.epsilon0
    alpha0: jnp.ndarray         # () f32 — cfg.alpha0
    decay_steps: jnp.ndarray    # () f32 — cfg.decay_steps
    reopen_frac: jnp.ndarray    # () f32 — cfg.reopen_frac (overload rewind)
    frozen: jnp.ndarray         # () f32 {0,1} — qstate.frozen
    backoff: jnp.ndarray        # () f32 — admission retry backoff cycles
    overload_frac: jnp.ndarray  # () f32 — shed-EMA trip level (0 disables)
    pressure_beta: jnp.ndarray  # () f32 — shed-EMA coefficient
    prio_reserve: jnp.ndarray   # () f32 — queue fraction reserved by prio


class ServeCarry(NamedTuple):
    """The long-lived serving state (crosses scan chunks and checkpoints).

    ``fin`` is the per-accelerator ring of admitted-request finish times
    (static ``queue_cap`` slots; the queue depth at time t is the count of
    entries > t — exact because admission itself bounds the number
    outstanding), ``busy`` the finish time of the last admitted request
    (devices serve FIFO, so it is the earliest feasible start), ``head``
    the ring write cursor.  ``pressure`` is the shed-rate EMA the overload
    watchdog trips on; ``tripped`` ({0,1} f32) its hysteresis latch;
    ``step`` the in-carry decay counter (see :class:`ServeParams`)."""

    qtable: jnp.ndarray    # (S, A) f32
    extrema: jnp.ndarray   # (4, n_accs) f32 reward extrema
    tbl: jnp.ndarray       # (n_accs, 6 + n_tiles) f32 slot table
    busy: jnp.ndarray      # (n_accs,) f32
    fin: jnp.ndarray       # (n_accs, queue_cap) f32
    head: jnp.ndarray      # (n_accs,) i32
    pressure: jnp.ndarray  # () f32
    tripped: jnp.ndarray   # () f32 {0,1}
    step: jnp.ndarray      # () i32
    # Packed MLP weights when an nn-policy spec is serving (None — an
    # empty pytree slot — for tabular serving, so every existing carry,
    # checkpoint and cross-chunk round trip is structurally unchanged).
    wpack: jnp.ndarray | None = None


def init_serve_carry(qtable0, extrema0, n_accs: int, n_tiles: int,
                     queue_cap: int, step0, wpack0=None) -> ServeCarry:
    """A fresh serving state: idle devices, empty rings, no pressure.

    One slot per accelerator (serving concurrency is between accelerators,
    not application threads), so the slot table has ``n_accs`` rows."""
    return ServeCarry(
        qtable=jnp.asarray(qtable0, jnp.float32),
        extrema=jnp.asarray(extrema0, jnp.float32),
        tbl=init_slot_table(n_accs, n_tiles),
        busy=jnp.zeros((n_accs,), jnp.float32),
        fin=jnp.zeros((n_accs, queue_cap), jnp.float32),
        head=jnp.zeros((n_accs,), jnp.int32),
        pressure=jnp.zeros((), jnp.float32),
        tripped=jnp.zeros((), jnp.float32),
        step=jnp.asarray(step0, jnp.int32),
        wpack=wpack0,
    )


def _iota1d(n: int) -> jnp.ndarray:
    # TPU requires >= 2D iota; squeeze back to the 1-D index vector.
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).squeeze(-1)


def _backoff_cycles(backoff, retries: int):
    # soc.faults.backoff_cycles with a static retry count (np scalar so it
    # inlines as a literal under Pallas tracing); exp2 of a small integer
    # is exact, retries == 0 contributes exactly +0.0.
    return backoff * np.float32(2.0 ** retries - 1.0)


def serve_step(s: SoCStatic, geom: CacheGeometry, warm_cap, learned,
               weights, sp: ServeParams, carry: ServeCarry, x: StepInputs,
               t_arr, deadline, priority, *,
               ddr_attribution: bool = False, qfun=None, mlp_lr=None,
               mlp_dims=None, mlp_feats: str = "sense"):
    """One offered request: admit-or-shed, then the fused episode step.

    Admission tries ``_SERVE_MAX_RETRIES + 1`` statically-unrolled
    candidates (arrival, then exponentially backed-off retries — the
    PR-7 retry math, :func:`repro.soc.faults.backoff_cycles`); a
    candidate is admissible when the victim accelerator's queue depth at
    that time is under its (priority-weighted) capacity AND the request
    would start before its deadline.  Shed requests leave every carried
    state untouched (the fused step is row-gated on ``executed``).
    Retried requests keep their arrival-order scan slot — an admitted
    retry executes at its backed-off start time, but later arrivals in
    the stream are still processed after it (a documented approximation;
    exact for the zero-retry fast path).

    Sustained shedding raises the ``pressure`` EMA; crossing
    ``overload_frac`` forces NON_COH fallback (graceful degradation: the
    cheapest, always-available mode under overload) and — on the rising
    edge — rewinds the decay counter to the epsilon-reopen point
    (:func:`repro.core.qlearn.reopen_step` arithmetic), so a long-lived
    agent re-explores once the regime shifts instead of serving a stale
    table.  The latch clears at half the trip level (hysteresis).

    ``x`` is a :class:`StepInputs` row whose ``thread``/``fresh``/
    ``others``/``valid``/``eps``/``alpha`` fields are placeholders — the
    serving loop owns those (slot = accelerator, every request fresh,
    concurrency sensed from ``busy``, validity = admitted, schedule from
    the carried counter).  Returns ``(carry, y)`` with ``y`` the stacked
    ``(len(SERVE_YCOLS),)`` trace row.
    """
    f32 = jnp.float32
    acc = x.acc_id
    n_accs = carry.busy.shape[0]
    queue_cap = carry.fin.shape[-1]
    busy_a = carry.busy[acc]
    frow = carry.fin[acc]
    degraded = carry.tripped != 0.0
    live = sp.frozen == 0.0

    # ---- admission control with bounded retry-with-backoff ------------
    cap_eff = (np.float32(queue_cap)
               - sp.prio_reserve * np.float32(queue_cap) * (1.0 - priority))
    oks, starts = [], []
    for r in range(_SERVE_MAX_RETRIES + 1):
        t_r = t_arr + _backoff_cycles(sp.backoff, r)
        depth_r = jnp.sum((frow > t_r).astype(f32))
        start_r = jnp.maximum(t_r, busy_a)
        oks.append((depth_r < cap_eff) & (start_r <= deadline))
        starts.append(start_r)
    ok = jnp.stack(oks)
    executed = jnp.any(ok)
    attempt = jnp.argmax(ok).astype(jnp.int32)
    start = jnp.stack(starts)[attempt]
    retries = jnp.where(executed, attempt.astype(f32), _SHED_RETRIES)
    depth0 = jnp.sum((frow > t_arr).astype(f32))

    # ---- decay schedule from the carried counter (qlearn.schedule) ----
    frac = jnp.clip(1.0 - carry.step.astype(f32) / sp.decay_steps,
                    0.0, 1.0)
    eps = jnp.where(live, sp.eps0 * frac, 0.0)
    alpha = jnp.where(live, sp.alpha0 * frac, 0.0)

    # ---- the fused sense->select->time->reward->learn step ------------
    # Forced NON_COH under overload: learned routes through the pre_mode
    # branch, and the Q update stays on-policy (the observed action IS
    # NON_COH while degraded).
    others = (carry.busy > start) & (_iota1d(n_accs) != acc)
    si = x._replace(
        thread=acc, fresh=jnp.ones((), bool), others=others,
        valid=executed, eps=eps, alpha=alpha,
        pre_mode=jnp.where(degraded, int(CoherenceMode.NON_COH_DMA),
                           x.pre_mode).astype(jnp.int32))
    wpack_new = None
    if carry.wpack is None:
        qtable, rs, tbl, y = fused_step(
            s, geom, warm_cap, learned & ~degraded, weights, carry.qtable,
            rewards.RewardState(extrema=carry.extrema), carry.tbl, si,
            ddr_attribution=ddr_attribution, gated=True)
    else:
        # nn-policy serving: overload degradation gates the network
        # exactly like the table (qfun & ~degraded routes through the
        # forced-NON_COH pre_mode), and the HyDRA-style features are live
        # here — slack is time-to-deadline at arrival, reuse the idle gap
        # since this accelerator's last admitted work.
        qtable, rs, tbl, wpack_new, y = fused_step(
            s, geom, warm_cap, learned & ~degraded, weights, carry.qtable,
            rewards.RewardState(extrema=carry.extrema), carry.tbl, si,
            ddr_attribution=ddr_attribution, gated=True,
            wpack=carry.wpack, qfun=qfun & ~degraded, mlp_lr=mlp_lr,
            mlp_dims=mlp_dims, mlp_feats=mlp_feats,
            slack=deadline - t_arr, reuse=t_arr - busy_a)

    # ---- queue/ring bookkeeping ---------------------------------------
    ex_f = executed.astype(f32)
    exec_time = y[3]
    finish = start + exec_time
    slot_hot = (_iota1d(queue_cap) == carry.head[acc]) & executed
    fin = carry.fin.at[acc].set(jnp.where(slot_hot, finish, frow))
    nxt = carry.head[acc] + 1
    head = carry.head.at[acc].set(jnp.where(
        executed, jnp.where(nxt >= queue_cap, 0, nxt), carry.head[acc]))
    busy = carry.busy.at[acc].set(jnp.where(executed, finish, busy_a))

    # ---- overload watchdog --------------------------------------------
    pressure = ((1.0 - sp.pressure_beta) * carry.pressure
                + sp.pressure_beta * (1.0 - ex_f))
    wd_on = sp.overload_frac > 0.0
    over = wd_on & (pressure > sp.overload_frac)
    rising = over & (carry.tripped == 0.0)
    reopened = jnp.minimum(
        carry.step,
        (sp.decay_steps * (1.0 - sp.reopen_frac)).astype(jnp.int32))
    step = jnp.where(rising & live, reopened, carry.step)
    step = step + jnp.where(executed & live, 1, 0).astype(jnp.int32)
    tripped = jnp.where(
        over, 1.0,
        jnp.where(pressure >= 0.5 * sp.overload_frac, carry.tripped, 0.0))

    y_serve = jnp.stack([
        jnp.where(executed, y[0], -1.0),          # mode
        jnp.where(executed, y[1], -1.0),          # state_idx
        jnp.where(executed, y[2], -1.0),          # action
        y[3] * ex_f,                              # exec_time
        y[4] * ex_f,                              # offchip
        y[5] * ex_f,                              # reward
        ex_f,                                     # executed
        (finish - t_arr) * ex_f,                  # latency
        retries,                                  # retries (shed = R + 1)
        depth0,                                   # queue depth at arrival
        degraded.astype(f32),                     # degraded this step
        start * ex_f,                             # admitted start time
        finish * ex_f,                            # admitted finish time
    ])
    new_carry = ServeCarry(
        qtable=qtable, extrema=rs.extrema, tbl=tbl, busy=busy, fin=fin,
        head=head, pressure=pressure, tripped=tripped, step=step,
        wpack=wpack_new)
    return new_carry, y_serve


def serve_episode_ref(s: SoCStatic, learned, weights, sp: ServeParams,
                      carry0: ServeCarry, xs: StepInputs, t_arr, deadline,
                      priority, *, ddr_attribution: bool = False,
                      qfun=None, mlp_lr=None, mlp_dims=None,
                      mlp_feats: str = "sense"):
    """Scan :func:`serve_step` over an arrival-stream chunk (pure XLA).

    ``xs`` leaves and the three serving columns carry a leading
    (n_requests,) axis.  Returns ``(carry_final, ys (n_requests,
    len(SERVE_YCOLS)))`` — the carry round-trips into the next chunk (and
    through checkpoints) unchanged.  A carry holding packed MLP weights
    (``carry0.wpack``) serves the nn policy; the weights ride the carry.
    """
    geom, warm_cap = derive_geom(s)

    def step(carry, xv):
        x, t_a, dl, pr = xv
        return serve_step(s, geom, warm_cap, learned, weights, sp, carry,
                          x, t_a, dl, pr, ddr_attribution=ddr_attribution,
                          qfun=qfun, mlp_lr=mlp_lr, mlp_dims=mlp_dims,
                          mlp_feats=mlp_feats)

    return jax.lax.scan(step, carry0, (xs, t_arr, deadline, priority))


def derive_geom(s: SoCStatic) -> tuple[CacheGeometry, jnp.ndarray]:
    """(cache geometry, warmth capacity) from the static scalar bundle."""
    geom = CacheGeometry(l2_bytes=s.l2_bytes,
                         llc_slice_bytes=s.llc_slice_bytes,
                         n_mem_tiles=s.n_mem_tiles)
    warm_cap = s.llc_slice_bytes * s.n_mem_tiles + s.n_cpus * s.l2_bytes
    return geom, warm_cap


def episode_ref(s: SoCStatic, learned, weights, qtable0, extrema0,
                xs: StepInputs, *, ddr_attribution: bool = False,
                gated: bool = False, wpack0=None, qfun=None, mlp_lr=None,
                mlp_dims=None, mlp_feats: str = "sense"):
    """Scan :func:`fused_step` over a whole episode (pure XLA).

    ``xs`` leaves carry a leading (S,) axis; ``extrema0`` is the initial
    reward-extrema table ((4, n_accs), from ``rewards.init_reward_state``).
    Returns ``(qtable_final, ys)`` with ``ys`` the per-step
    ``(mode, state_idx, action, exec_cycles, offchip, reward)`` arrays.

    With a packed MLP (``wpack0`` + the traced ``qfun`` flag,
    :mod:`repro.soc.nn`) the weights ride the scan carry next to the
    Q-table and the return becomes ``(qtable_final, wpack_final, ys)``.
    """
    geom, warm_cap = derive_geom(s)
    n_threads = xs.others.shape[-1]
    n_tiles = xs.tiles.shape[-1]
    rs0 = rewards.RewardState(extrema=extrema0)
    tbl0 = init_slot_table(n_threads, n_tiles)

    if wpack0 is None:
        def step(carry, x):
            qtable, rs, tbl = carry
            qtable, rs, tbl, y = fused_step(
                s, geom, warm_cap, learned, weights, qtable, rs, tbl, x,
                ddr_attribution=ddr_attribution, gated=gated)
            return (qtable, rs, tbl), y

        (qtable, _, _), y = jax.lax.scan(step, (qtable0, rs0, tbl0), xs)
        return qtable, unpack_ys(y)

    def step_mlp(carry, x):
        qtable, rs, tbl, wpack = carry
        qtable, rs, tbl, wpack, y = fused_step(
            s, geom, warm_cap, learned, weights, qtable, rs, tbl, x,
            ddr_attribution=ddr_attribution, gated=gated, wpack=wpack,
            qfun=qfun, mlp_lr=mlp_lr, mlp_dims=mlp_dims,
            mlp_feats=mlp_feats)
        return (qtable, rs, tbl, wpack), y

    (qtable, _, _, wpack), y = jax.lax.scan(
        step_mlp, (qtable0, rs0, tbl0, wpack0), xs)
    return qtable, wpack, unpack_ys(y)
