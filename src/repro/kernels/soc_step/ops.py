"""Public entry point for the fused SoC episode step.

:func:`fused_episode` is what :mod:`repro.soc.vecenv` calls when built
with ``fused_step=True``: it takes the precomputed :class:`~repro.kernels.
soc_step.ref.StepInputs` trace of an episode plus the initial Q-table /
reward extrema and returns the trained table and the per-step trace.

Dispatch follows the suite's ``interpret=None -> cpu`` auto-detection
convention (see ``flash_attention.ops``), with one extra knob because
this kernel's sequential grid only pays off where VMEM scratch is real:

  * ``kernel=None`` (default) lowers through the Pallas kernel on
    accelerator backends and through the pure-XLA
    :func:`~repro.kernels.soc_step.ref.episode_ref` scan on CPU — the
    same fused formulation, compiled the way each backend runs it best
    (the interpreted Pallas body is a correctness tool, not a fast path);
  * ``kernel=True`` forces the Pallas kernel; ``interpret=None`` then
    auto-enables the interpreter on CPU, which is how the kernel-vs-ref
    tests execute the kernel body without a TPU.

Both lowerings share :func:`~repro.kernels.soc_step.ref.fused_step` and
the :func:`~repro.kernels.soc_step.ref.pack_inputs` row layout, so they
agree to float tolerance by construction (bitwise on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.soc.memsys import SoCStatic
from repro.kernels.soc_step import kernel as _kernel
from repro.kernels.soc_step.ref import (StepInputs, episode_ref,
                                        pack_inputs, unpack_ys)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def fused_episode(s: SoCStatic, learned, weights, qtable0, extrema0,
                  xs: StepInputs, *, ddr_attribution: bool = False,
                  gated: bool = False, kernel: bool | None = None,
                  interpret: bool | None = None, qfun=None, mlp=None):
    """Run one fused episode; returns ``(qtable_final, ys)``.

    ``xs`` leaves carry a leading (S,) axis (see :class:`StepInputs`);
    ``ys`` is the per-step ``(mode, state_idx, action, exec_cycles,
    offchip, reward)`` tuple with integer columns as int32.

    With a function-approximation agent (``mlp`` — a
    :class:`repro.soc.nn.MLPQState` — plus the spec's traced ``qfun``
    flag) the packed weights ride the episode next to the Q-table and
    the return becomes ``(qtable_final, wpack_final, ys)``.  Both
    lowerings support it: the XLA scan scans the weights in the carry;
    the Pallas kernel adds a VMEM-resident weights operand and appends
    ``[qfun, mlp_lr]`` to the consts row.
    """
    mlp_dims = None
    if mlp is not None:
        from repro.soc import nn as socnn
        mlp_dims = socnn.mlp_dims(mlp.cfg)
    if kernel is None:
        kernel = not _on_cpu()
    if not kernel:
        if mlp is None:
            qtable, ys = episode_ref(
                s, learned, weights, qtable0, extrema0, xs,
                ddr_attribution=ddr_attribution, gated=gated)
            return qtable, ys
        return episode_ref(
            s, learned, weights, qtable0, extrema0, xs,
            ddr_attribution=ddr_attribution, gated=gated,
            wpack0=mlp.wpack, qfun=qfun, mlp_lr=mlp.lr,
            mlp_dims=mlp_dims, mlp_feats=mlp.cfg.features)
    if interpret is None:
        interpret = _on_cpu()

    f32 = jnp.float32
    xf, xi = pack_inputs(xs)
    consts_parts = [
        jnp.stack([jnp.asarray(getattr(s, f), f32)
                   for f in SoCStatic._fields]),
        jnp.stack([jnp.asarray(learned, f32),
                   jnp.asarray(weights.x, f32),
                   jnp.asarray(weights.y, f32),
                   jnp.asarray(weights.z, f32)]),
    ]
    if mlp is not None:
        consts_parts.append(jnp.stack([jnp.asarray(qfun, f32),
                                       jnp.asarray(mlp.lr, f32)]))
    consts = jnp.concatenate(consts_parts)
    out = _kernel.soc_step_episode(
        xf, xi, consts, qtable0.astype(f32), extrema0.astype(f32),
        mlp.wpack if mlp is not None else None,
        n_threads=xs.others.shape[-1], n_tiles=xs.tiles.shape[-1],
        n_actions=xs.avail.shape[-1],
        ddr_attribution=ddr_attribution, gated=gated,
        faulted=xs.f_exec is not None,
        interpret=interpret, mlp_dims=mlp_dims,
        mlp_feats=mlp.cfg.features if mlp is not None else "sense")
    if mlp is None:
        qtable, y = out
        return qtable, unpack_ys(y)
    qtable, wpack, y = out
    return qtable, wpack, unpack_ys(y)


def fused_serve_episode(s: SoCStatic, learned, weights, serve_params,
                        carry0, xs: StepInputs, t_arr, deadline, priority,
                        *, ddr_attribution: bool = False,
                        kernel: bool | None = None,
                        interpret: bool | None = None,
                        qfun=None, mlp=None):
    """Run one arrival-stream chunk through the fused serving step.

    Dispatch mirrors :func:`fused_episode`: the Pallas serve kernel on
    accelerator backends, the ``serve_episode_ref`` scan on CPU, and
    ``kernel=True, interpret=None`` for the interpreted kernel-vs-ref
    test path.  ``xs`` is a (n_requests,)-leading :class:`StepInputs`
    whose ``thread``/``fresh``/``others``/``valid``/``eps``/``alpha``
    columns are placeholders (the serve step owns them — see
    :func:`~repro.kernels.soc_step.ref.serve_step`); ``carry0`` is a
    :class:`~repro.kernels.soc_step.ref.ServeCarry`.  Returns
    ``(carry_final, ys (n_requests, len(SERVE_YCOLS)))``.
    """
    from repro.kernels.soc_step.ref import serve_episode_ref

    if mlp is not None:
        # nn-policy serving always takes the XLA scan: the serve kernel
        # does not carry the weight pack (serving is admission-bound and
        # CPU CI must never compile the kernel), and the MLP weights ride
        # ``carry0.wpack`` so chunking/checkpointing work unchanged.
        from repro.soc import nn as socnn
        return serve_episode_ref(
            s, learned, weights, serve_params, carry0, xs, t_arr, deadline,
            priority, ddr_attribution=ddr_attribution, qfun=qfun,
            mlp_lr=mlp.lr, mlp_dims=socnn.mlp_dims(mlp.cfg),
            mlp_feats=mlp.cfg.features)
    if kernel is None:
        kernel = not _on_cpu()
    if not kernel:
        return serve_episode_ref(
            s, learned, weights, serve_params, carry0, xs, t_arr, deadline,
            priority, ddr_attribution=ddr_attribution)
    if interpret is None:
        interpret = _on_cpu()

    f32 = jnp.float32
    xf, xi = pack_inputs(xs)
    xv = jnp.stack([jnp.asarray(t_arr, f32), jnp.asarray(deadline, f32),
                    jnp.asarray(priority, f32)], axis=-1)
    consts = jnp.concatenate([
        jnp.stack([jnp.asarray(getattr(s, f), f32)
                   for f in SoCStatic._fields]),
        jnp.stack([jnp.asarray(learned, f32),
                   jnp.asarray(weights.x, f32),
                   jnp.asarray(weights.y, f32),
                   jnp.asarray(weights.z, f32)]),
        jnp.stack([jnp.asarray(getattr(serve_params, f), f32)
                   for f in type(serve_params)._fields]),
    ])
    return _kernel.soc_step_serve(
        xf, xi, xv, consts, carry0,
        n_tiles=xs.tiles.shape[-1], n_actions=xs.avail.shape[-1],
        ddr_attribution=ddr_attribution, faulted=xs.f_exec is not None,
        interpret=interpret)
