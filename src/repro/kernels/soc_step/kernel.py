"""Pallas kernel: a whole fused-step SoC episode as ONE kernel launch.

The grid is ``(S,)`` — one sequential grid step per invocation — and the
episode state (Q-table, reward extrema, packed thread-slot table) lives
in VMEM scratch, which persists across the sequential grid axis.  Each
grid step loads its scratch, runs
:func:`repro.kernels.soc_step.ref.fused_step` on the values (kernel and
reference share one step implementation, so they cannot drift), stores
the updated state back, and emits one packed trace row; the final
Q-table is written on the last grid step.

Compared to the ``lax.scan`` lowering, every per-step quantity the step
needs arrives as a ``(1, ...)`` block of one packed float input row and
one packed int input row (:func:`repro.kernels.soc_step.ref.pack_inputs`
owns the layout), so observe's per-tile masked reductions and the Q-row
gather/blend/write-back run over VMEM-resident state with no HBM round
trip per step.

``interpret=True`` executes the body with the Pallas interpreter — the
CPU test path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rewards
from repro.kernels.soc_step.ref import (SERVE_YCOLS, YCOLS, ServeCarry,
                                        ServeParams, derive_geom,
                                        fused_step, init_slot_table,
                                        serve_step, tbl_width,
                                        unpack_inputs)
from repro.soc.memsys import SoCStatic

N_STATIC = len(SoCStatic._fields)
# consts vector layout: the SoCStatic scalars, then learned, then (x, y, z).
N_CONSTS = N_STATIC + 4
# serving consts: the episode consts plus the ServeParams scalars.
N_SERVE_CONSTS = N_CONSTS + len(ServeParams._fields)


def _episode_kernel(*refs, n_steps: int, n_tiles: int, n_threads: int,
                    n_actions: int, ddr_attribution: bool, gated: bool,
                    faulted: bool, mlp_dims, mlp_feats: str):
    # ``mlp_dims`` (static) selects the ref layout: the MLP variant adds
    # a packed-weights input, output and VMEM scratch (the weights
    # persist across the sequential grid exactly like the Q-table).
    if mlp_dims is None:
        (xf, xi, consts, qt0, ex0, y_out, qt_out, qt, ex, tbl) = refs
        wp0 = wp_out = wp = None
    else:
        (xf, xi, consts, qt0, ex0, wp0,
         y_out, qt_out, wp_out, qt, ex, tbl, wp) = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        qt[...] = qt0[...]
        ex[...] = ex0[...]
        tbl[...] = init_slot_table(n_threads, n_tiles)
        if wp is not None:
            wp[...] = wp0[...]

    c = consts[...]
    s = SoCStatic(*[c[j] for j in range(N_STATIC)])
    learned = c[N_STATIC] != 0.0
    weights = rewards.RewardWeights(
        x=c[N_STATIC + 1], y=c[N_STATIC + 2], z=c[N_STATIC + 3])
    geom, warm_cap = derive_geom(s)

    x = unpack_inputs(xf[...][0], xi[...][0], n_tiles=n_tiles,
                      n_threads=n_threads, n_actions=n_actions,
                      faulted=faulted)

    if mlp_dims is None:
        qtable_new, rs_new, tbl_new, y = fused_step(
            s, geom, warm_cap, learned, weights, qt[...],
            rewards.RewardState(extrema=ex[...]), tbl[...], x,
            ddr_attribution=ddr_attribution, gated=gated)
        wp_new = None
    else:
        qfun = c[N_CONSTS] != 0.0
        mlp_lr = c[N_CONSTS + 1]
        qtable_new, rs_new, tbl_new, wp_new, y = fused_step(
            s, geom, warm_cap, learned, weights, qt[...],
            rewards.RewardState(extrema=ex[...]), tbl[...], x,
            ddr_attribution=ddr_attribution, gated=gated, wpack=wp[...],
            qfun=qfun, mlp_lr=mlp_lr, mlp_dims=mlp_dims,
            mlp_feats=mlp_feats)
        wp[...] = wp_new

    qt[...] = qtable_new
    ex[...] = rs_new.extrema
    tbl[...] = tbl_new
    y_out[...] = y[None, :]

    @pl.when(i == n_steps - 1)
    def _finish():
        qt_out[...] = qtable_new
        if wp_out is not None:
            wp_out[...] = wp_new


@functools.partial(
    jax.jit,
    static_argnames=("n_threads", "n_tiles", "n_actions",
                     "ddr_attribution", "gated", "faulted", "interpret",
                     "mlp_dims", "mlp_feats"))
def soc_step_episode(xf, xi, consts, qtable0, extrema0, wpack0=None, *,
                     n_threads: int, n_tiles: int, n_actions: int,
                     ddr_attribution: bool = False, gated: bool = False,
                     faulted: bool = False, interpret: bool = False,
                     mlp_dims=None, mlp_feats: str = "sense"):
    """Run the packed episode through the Pallas kernel.

    ``xf (S, NF)`` f32 / ``xi (S, 5)`` i32 are the packed per-step input
    rows from :func:`~repro.kernels.soc_step.ref.pack_inputs`; ``consts
    (N_CONSTS,)`` f32 is the SoCStatic scalars + learned + reward
    weights.  ``faulted`` says whether ``xf`` carries the four trailing
    fault columns (the row width flows through ``xf.shape`` either way).
    Returns ``(qtable_final, y (S, 6))`` with ``y`` columns
    :data:`~repro.kernels.soc_step.ref.YCOLS`.

    The function-approximation variant (``wpack0`` + static ``mlp_dims``
    tuple / ``mlp_feats`` embedding name, :mod:`repro.soc.nn`) appends
    ``[qfun, mlp_lr]`` to ``consts`` (width ``N_CONSTS + 2``), keeps the
    packed weights VMEM-resident across the grid like the Q-table, and
    returns ``(qtable_final, wpack_final, y)``.
    """
    n_steps, n_f = xf.shape
    n_i = xi.shape[1]
    n_states, _ = qtable0.shape
    n_accs = extrema0.shape[1]
    n_consts = consts.shape[0]

    row = lambda width: pl.BlockSpec((1, width), lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    in_specs = [
        row(n_f), row(n_i), full((n_consts,)),
        full((n_states, n_actions)), full((4, n_accs)),
    ]
    operands = [xf, xi, consts, qtable0, extrema0]
    out_specs = [row(len(YCOLS)), full((n_states, n_actions))]
    out_shape = [
        jax.ShapeDtypeStruct((n_steps, len(YCOLS)), jnp.float32),
        jax.ShapeDtypeStruct((n_states, n_actions), jnp.float32),
    ]
    scratch_shapes = [
        pltpu.VMEM((n_states, n_actions), jnp.float32),       # Q-table
        pltpu.VMEM((4, n_accs), jnp.float32),                 # extrema
        pltpu.VMEM((n_threads, tbl_width(n_tiles)), jnp.float32),
    ]
    if mlp_dims is not None:
        wshape = wpack0.shape
        in_specs.append(full(wshape))
        operands.append(wpack0.astype(jnp.float32))
        out_specs.append(full(wshape))
        out_shape.append(jax.ShapeDtypeStruct(wshape, jnp.float32))
        scratch_shapes.append(pltpu.VMEM(wshape, jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_episode_kernel, n_steps=n_steps,
                          n_tiles=n_tiles, n_threads=n_threads,
                          n_actions=n_actions,
                          ddr_attribution=ddr_attribution, gated=gated,
                          faulted=faulted, mlp_dims=mlp_dims,
                          mlp_feats=mlp_feats),
        grid=(n_steps,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*operands)
    if mlp_dims is None:
        y, qtable = outs
        return qtable, y
    y, qtable, wpack = outs
    return qtable, wpack, y


def _serve_kernel(xf, xi, xv, consts, qt0, ex0, tbl0, busy0, fin0, head0,
                  misc0, st0,
                  y_out, qt_out, ex_out, tbl_out, busy_out, fin_out,
                  head_out, misc_out, st_out,
                  qt, ex, tbl, busy, fin, head, misc, sti,
                  *, n_steps: int, n_tiles: int, n_accs: int,
                  n_actions: int, ddr_attribution: bool, faulted: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        qt[...] = qt0[...]
        ex[...] = ex0[...]
        tbl[...] = tbl0[...]
        busy[...] = busy0[...]
        fin[...] = fin0[...]
        head[...] = head0[...]
        misc[...] = misc0[...]
        sti[...] = st0[...]

    c = consts[...]
    s = SoCStatic(*[c[j] for j in range(N_STATIC)])
    learned = c[N_STATIC] != 0.0
    weights = rewards.RewardWeights(
        x=c[N_STATIC + 1], y=c[N_STATIC + 2], z=c[N_STATIC + 3])
    sp = ServeParams(*[c[N_CONSTS + j]
                       for j in range(len(ServeParams._fields))])
    geom, warm_cap = derive_geom(s)

    # Serving slots are accelerators, so the packed row's placeholder
    # others column has width n_accs (serve_step overwrites it anyway).
    x = unpack_inputs(xf[...][0], xi[...][0], n_tiles=n_tiles,
                      n_threads=n_accs, n_actions=n_actions,
                      faulted=faulted)
    v = xv[...][0]

    carry = ServeCarry(
        qtable=qt[...], extrema=ex[...], tbl=tbl[...], busy=busy[...][0],
        fin=fin[...], head=head[...][0], pressure=misc[...][0, 0],
        tripped=misc[...][0, 1], step=sti[...][0, 0])
    carry, y = serve_step(s, geom, warm_cap, learned, weights, sp, carry,
                          x, v[0], v[1], v[2],
                          ddr_attribution=ddr_attribution)

    qt[...] = carry.qtable
    ex[...] = carry.extrema
    tbl[...] = carry.tbl
    busy[...] = carry.busy[None, :]
    fin[...] = carry.fin
    head[...] = carry.head[None, :]
    misc[...] = jnp.stack([carry.pressure, carry.tripped]).reshape(1, 2)
    sti[...] = carry.step.reshape(1, 1)
    y_out[...] = y[None, :]

    @pl.when(i == n_steps - 1)
    def _finish():
        qt_out[...] = carry.qtable
        ex_out[...] = carry.extrema
        tbl_out[...] = carry.tbl
        busy_out[...] = carry.busy[None, :]
        fin_out[...] = carry.fin
        head_out[...] = carry.head[None, :]
        misc_out[...] = jnp.stack([carry.pressure,
                                   carry.tripped]).reshape(1, 2)
        st_out[...] = carry.step.reshape(1, 1)


@functools.partial(
    jax.jit,
    static_argnames=("n_tiles", "n_actions", "ddr_attribution", "faulted",
                     "interpret"))
def soc_step_serve(xf, xi, xv, consts, carry0: ServeCarry, *,
                   n_tiles: int, n_actions: int,
                   ddr_attribution: bool = False, faulted: bool = False,
                   interpret: bool = False):
    """Run a packed arrival-stream chunk through the Pallas serve kernel.

    Same launch shape as :func:`soc_step_episode` — grid ``(S,)``, one
    sequential step per offered request, all serving state VMEM-resident —
    but the whole :class:`~repro.kernels.soc_step.ref.ServeCarry` rides
    as kernel inputs/outputs so chunks (and checkpoint restores) chain
    bitwise.  ``xv (S, 3)`` f32 carries ``[t_arr, deadline, priority]``;
    ``consts (N_SERVE_CONSTS,)`` appends the ServeParams scalars to the
    episode consts.  Returns ``(carry_final, y (S, len(SERVE_YCOLS)))``.
    """
    n_steps, n_f = xf.shape
    n_i = xi.shape[1]
    n_states, _ = qt_shape = carry0.qtable.shape
    n_accs = carry0.busy.shape[0]
    queue_cap = carry0.fin.shape[-1]
    n_actions_q = qt_shape[1]

    row = lambda width: pl.BlockSpec((1, width), lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    carry_specs = [
        full(qt_shape), full((4, n_accs)),
        full((n_accs, tbl_width(n_tiles))), full((1, n_accs)),
        full((n_accs, queue_cap)), full((1, n_accs)), full((1, 2)),
        full((1, 1)),
    ]
    carry_shapes = [
        jax.ShapeDtypeStruct(qt_shape, jnp.float32),
        jax.ShapeDtypeStruct((4, n_accs), jnp.float32),
        jax.ShapeDtypeStruct((n_accs, tbl_width(n_tiles)), jnp.float32),
        jax.ShapeDtypeStruct((1, n_accs), jnp.float32),
        jax.ShapeDtypeStruct((n_accs, queue_cap), jnp.float32),
        jax.ShapeDtypeStruct((1, n_accs), jnp.int32),
        jax.ShapeDtypeStruct((1, 2), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    ]
    outs = pl.pallas_call(
        functools.partial(_serve_kernel, n_steps=n_steps, n_tiles=n_tiles,
                          n_accs=n_accs, n_actions=n_actions,
                          ddr_attribution=ddr_attribution,
                          faulted=faulted),
        grid=(n_steps,),
        in_specs=[row(n_f), row(n_i), row(3), full((N_SERVE_CONSTS,))]
        + carry_specs,
        out_specs=[row(len(SERVE_YCOLS))] + carry_specs,
        out_shape=[jax.ShapeDtypeStruct((n_steps, len(SERVE_YCOLS)),
                                        jnp.float32)] + carry_shapes,
        scratch_shapes=[
            pltpu.VMEM(qt_shape, jnp.float32),
            pltpu.VMEM((4, n_accs), jnp.float32),
            pltpu.VMEM((n_accs, tbl_width(n_tiles)), jnp.float32),
            pltpu.VMEM((1, n_accs), jnp.float32),
            pltpu.VMEM((n_accs, queue_cap), jnp.float32),
            pltpu.VMEM((1, n_accs), jnp.int32),
            pltpu.VMEM((1, 2), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xf, xi, xv, consts, carry0.qtable, carry0.extrema, carry0.tbl,
      carry0.busy.reshape(1, n_accs), carry0.fin,
      carry0.head.reshape(1, n_accs),
      jnp.stack([carry0.pressure, carry0.tripped]).reshape(1, 2),
      carry0.step.reshape(1, 1))
    y, qt, ex, tbl, busy, fin, head, misc, st = outs
    carry = ServeCarry(
        qtable=qt, extrema=ex, tbl=tbl, busy=busy.reshape(n_accs),
        fin=fin, head=head.reshape(n_accs), pressure=misc[0, 0],
        tripped=misc[0, 1], step=st[0, 0])
    return carry, y
