"""Pallas kernel: a whole fused-step SoC episode as ONE kernel launch.

The grid is ``(S,)`` — one sequential grid step per invocation — and the
episode state (Q-table, reward extrema, packed thread-slot table) lives
in VMEM scratch, which persists across the sequential grid axis.  Each
grid step loads its scratch, runs
:func:`repro.kernels.soc_step.ref.fused_step` on the values (kernel and
reference share one step implementation, so they cannot drift), stores
the updated state back, and emits one packed trace row; the final
Q-table is written on the last grid step.

Compared to the ``lax.scan`` lowering, every per-step quantity the step
needs arrives as a ``(1, ...)`` block of one packed float input row and
one packed int input row (:func:`repro.kernels.soc_step.ref.pack_inputs`
owns the layout), so observe's per-tile masked reductions and the Q-row
gather/blend/write-back run over VMEM-resident state with no HBM round
trip per step.

``interpret=True`` executes the body with the Pallas interpreter — the
CPU test path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rewards
from repro.soc.memsys import SoCStatic
from repro.kernels.soc_step.ref import (YCOLS, derive_geom, fused_step,
                                        init_slot_table, tbl_width,
                                        unpack_inputs)

N_STATIC = len(SoCStatic._fields)
# consts vector layout: the SoCStatic scalars, then learned, then (x, y, z).
N_CONSTS = N_STATIC + 4


def _episode_kernel(xf, xi, consts, qt0, ex0,
                    y_out, qt_out,
                    qt, ex, tbl,
                    *, n_steps: int, n_tiles: int, n_threads: int,
                    n_actions: int, ddr_attribution: bool, gated: bool,
                    faulted: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        qt[...] = qt0[...]
        ex[...] = ex0[...]
        tbl[...] = init_slot_table(n_threads, n_tiles)

    c = consts[...]
    s = SoCStatic(*[c[j] for j in range(N_STATIC)])
    learned = c[N_STATIC] != 0.0
    weights = rewards.RewardWeights(
        x=c[N_STATIC + 1], y=c[N_STATIC + 2], z=c[N_STATIC + 3])
    geom, warm_cap = derive_geom(s)

    x = unpack_inputs(xf[...][0], xi[...][0], n_tiles=n_tiles,
                      n_threads=n_threads, n_actions=n_actions,
                      faulted=faulted)

    qtable_new, rs_new, tbl_new, y = fused_step(
        s, geom, warm_cap, learned, weights, qt[...],
        rewards.RewardState(extrema=ex[...]), tbl[...], x,
        ddr_attribution=ddr_attribution, gated=gated)

    qt[...] = qtable_new
    ex[...] = rs_new.extrema
    tbl[...] = tbl_new
    y_out[...] = y[None, :]

    @pl.when(i == n_steps - 1)
    def _finish():
        qt_out[...] = qtable_new


@functools.partial(
    jax.jit,
    static_argnames=("n_threads", "n_tiles", "n_actions",
                     "ddr_attribution", "gated", "faulted", "interpret"))
def soc_step_episode(xf, xi, consts, qtable0, extrema0, *, n_threads: int,
                     n_tiles: int, n_actions: int,
                     ddr_attribution: bool = False, gated: bool = False,
                     faulted: bool = False, interpret: bool = False):
    """Run the packed episode through the Pallas kernel.

    ``xf (S, NF)`` f32 / ``xi (S, 5)`` i32 are the packed per-step input
    rows from :func:`~repro.kernels.soc_step.ref.pack_inputs`; ``consts
    (N_CONSTS,)`` f32 is the SoCStatic scalars + learned + reward
    weights.  ``faulted`` says whether ``xf`` carries the four trailing
    fault columns (the row width flows through ``xf.shape`` either way).
    Returns ``(qtable_final, y (S, 6))`` with ``y`` columns
    :data:`~repro.kernels.soc_step.ref.YCOLS`.
    """
    n_steps, n_f = xf.shape
    n_i = xi.shape[1]
    n_states, _ = qtable0.shape
    n_accs = extrema0.shape[1]

    row = lambda width: pl.BlockSpec((1, width), lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    y, qtable = pl.pallas_call(
        functools.partial(_episode_kernel, n_steps=n_steps,
                          n_tiles=n_tiles, n_threads=n_threads,
                          n_actions=n_actions,
                          ddr_attribution=ddr_attribution, gated=gated,
                          faulted=faulted),
        grid=(n_steps,),
        in_specs=[
            row(n_f), row(n_i), full((N_CONSTS,)),
            full((n_states, n_actions)), full((4, n_accs)),
        ],
        out_specs=[
            row(len(YCOLS)), full((n_states, n_actions)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_steps, len(YCOLS)), jnp.float32),
            jax.ShapeDtypeStruct((n_states, n_actions), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_states, n_actions), jnp.float32),       # Q-table
            pltpu.VMEM((4, n_accs), jnp.float32),                 # extrema
            pltpu.VMEM((n_threads, tbl_width(n_tiles)), jnp.float32),
        ],
        interpret=interpret,
    )(xf, xi, consts, qtable0, extrema0)
    return qtable, y
