"""Pure-jnp oracle for the RWKV-6 recurrence: the step-by-step scan.

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = S_{t-1}^T r_t + (r_t . (u . k_t)) v_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, logw, u, s0):
    """r/k/v/logw: (B, H, T, K); u: (H, K); s0: (B, H, K, V).

    Returns (y (B,H,T,V), s_final (B,H,K,V)); all fp32.
    """
    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s) + \
            jnp.einsum("bhk,bhk,bhv->bhv", r_t, u[None] * k_t, v_t)
        s = jnp.exp(lw_t)[..., None] * s + k_t[..., None] * v_t[..., None, :]
        return s, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 2, 0)
               for a in (r, k, v, logw))
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 2), s_fin
