"""Chunk-parallel RWKV-6 recurrence — Pallas TPU kernel.

Grid (B*H, n_chunks); chunks execute sequentially on TPU so the (K, V)
recurrence state persists in VMEM scratch across chunk iterations.  Within
a chunk the kernel is fully parallel (MXU matmuls): the intra-chunk part is
an attention-like (chunk x chunk) matmul against decay-weighted keys, the
inter-chunk part applies the carried state; both use only *bounded*
exponentials (pairwise cumsum differences — see models.rwkv6).

Chunk length 16 with per-step log-decay clamped at -4 bounds exp factors by
e^64 (fp32-safe).  The clamp is applied by the caller (ops.py / the model).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, sfin_ref, s_ref,
                 *, chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)       # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)       # (1, K) broadcast row

    cum = jnp.cumsum(lw, axis=0)           # inclusive (C, K)
    cum_prev = cum - lw                    # exclusive
    cum_end = cum[-1:, :]                  # (1, K)

    q_t = r * jnp.exp(cum_prev)            # bounded by |r|
    k_in = k * jnp.exp(-cum)               # bounded by e^{C*|LOGW_MIN|}
    k_end = k * jnp.exp(cum_end - cum)     # bounded by |k|

    a = jax.lax.dot_general(q_t, k_in, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, C)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(col < row, a, 0.0)       # strictly causal
    bonus = jnp.sum(r * (u * k), axis=1)   # (C,)

    y_intra = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_intra = y_intra + bonus[:, None] * v
    y_inter = jax.lax.dot_general(q_t, s_ref[...], (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: S <- diag(e^{cum_end}) S + k_end^T v
    s_ref[...] = (jnp.exp(cum_end[0])[:, None] * s_ref[...]
                  + jax.lax.dot_general(k_end, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(ic == n_chunks - 1)
    def _finish():
        sfin_ref[0] = s_ref[...]


def rwkv6_scan_kernel(r, k, v, logw, u, *, chunk: int = 16,
                      interpret: bool = False):
    """r/k/v/logw: (BH, T, K); u: (BH, K). Returns (y (BH,T,K), s (BH,K,K)).

    T must be a multiple of ``chunk``; state starts at zero (callers fold a
    nonzero initial state by prepending a pseudo-chunk if needed).
    """
    bh, t, kdim = r.shape
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    kernel = functools.partial(_rwkv_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (bh, n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, kdim), lambda b, ic: (b, ic, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, kdim), lambda b, ic: (b, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, kdim, kdim), lambda b, ic: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, kdim), jnp.float32),
            jax.ShapeDtypeStruct((bh, kdim, kdim), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kdim, kdim), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
