"""Jit'd wrapper: model-layout RWKV-6 scan via the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_kernel

LOGW_MIN = -4.0


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, logw, u, *, chunk: int = 16,
               interpret: bool | None = None):
    """r/k/v/logw: (B, H, T, K); u: (H, K). Returns (y, s_final).

    Matches ``models.rwkv6.wkv_chunked`` with zero initial state.  The
    per-step log decay is clamped at LOGW_MIN (same clamp as the model).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, h, t, kdim = r.shape
    logw = jnp.maximum(logw, LOGW_MIN)

    def flat(x):
        return x.reshape(b * h, t, kdim).astype(jnp.float32)

    u_bh = jnp.broadcast_to(u[None], (b, h, kdim)).reshape(b * h, kdim)
    y, s = rwkv6_scan_kernel(
        flat(r), flat(k), flat(v), flat(logw), u_bh.astype(jnp.float32),
        chunk=chunk, interpret=interpret)
    return (y.reshape(b, h, t, kdim),
            s.reshape(b, h, kdim, kdim))
