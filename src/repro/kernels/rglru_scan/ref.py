"""Pure-jnp oracle for the RG-LRU diagonal recurrence.

    h_t = a_t . h_{t-1} + b_t,   a_t = exp(log_a_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a, b, h0):
    """log_a/b: (B, T, W); h0: (B, W). Returns (h (B,T,W), h_final)."""
    def step(h, inp):
        la_t, b_t = inp
        h = jnp.exp(la_t) * h + b_t
        return h, h

    xs = (jnp.moveaxis(log_a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0))
    h_fin, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1), h_fin
