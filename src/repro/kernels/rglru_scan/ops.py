"""Jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_scan(log_a, b, h0=None, *, chunk: int = 128,
               interpret: bool | None = None):
    """log_a/b: (B, T, W); optional h0 folded into the first step."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if h0 is not None:
        b = b.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * h0)
    return rglru_scan_kernel(
        log_a.astype(jnp.float32), b.astype(jnp.float32),
        chunk=chunk, interpret=interpret)
