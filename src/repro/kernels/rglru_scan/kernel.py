"""RG-LRU diagonal linear recurrence — Pallas TPU kernel.

Grid (B, n_chunks) with sequential chunk execution; the hidden state lives
in VMEM scratch.  Within a chunk the recurrence is evaluated time-step by
time-step over W-wide vectors (VPU element-wise work, no MXU): the
recurrence is diagonal, so each step is a fused multiply-add over the full
lane dimension — at W = 4096 lanes this keeps the VPU saturated while the
next chunk's (log_a, b) block streams into VMEM.

The step-by-step form avoids the exp(-cumsum) blow-up a closed-form
within-chunk parallelization would need (RG-LRU decays can be ~e^{-8} per
step), trading MXU idle time for exactness — acceptable because this
kernel's use case is the decode/state-carry path where T is modest.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(la_ref, b_ref, y_ref, hfin_ref, h_ref, *,
                  chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = la_ref[0].astype(jnp.float32)     # (C, W)
    b = b_ref[0].astype(jnp.float32)       # (C, W)

    def step(t, h):
        h = jnp.exp(la[t]) * h + b[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hfin_ref[0] = h_ref[...]


def rglru_scan_kernel(log_a, b, *, chunk: int = 128,
                      interpret: bool = False):
    """log_a/b: (B, T, W). Zero initial state. Returns (h (B,T,W), h_fin)."""
    bsz, t, w = log_a.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    kernel = functools.partial(_rglru_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, w), lambda b_, ic: (b_, ic, 0))
    return pl.pallas_call(
        kernel,
        grid=(bsz, n_chunks),
        in_specs=[seq_spec, seq_spec],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, w), lambda b_, ic: (b_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, w), jnp.float32),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
