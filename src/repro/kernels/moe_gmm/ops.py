"""Jit'd wrapper for the grouped expert matmul."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm.kernel import moe_gmm_kernel


@functools.partial(jax.jit, static_argnames=(
    "block_c", "block_f", "block_d", "interpret"))
def moe_gmm(x, w, group_sizes, *, block_c: int = 128, block_f: int = 128,
            block_d: int = 512, interpret: bool | None = None):
    """Grouped matmul out[e] = x[e] @ w[e] with ragged row validity."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return moe_gmm_kernel(x, w, group_sizes, block_c=block_c,
                          block_f=block_f, block_d=block_d,
                          interpret=interpret)
