"""Grouped (ragged) expert matmul — Pallas TPU kernel.

Computes out[e] = x[e] @ w[e] for every expert, skipping row tiles beyond
the expert's actual group size (scalar-prefetched), which is where the win
over a dense bmm comes from: with a capacity factor of 1.25 and imbalanced
routing, a large fraction of row tiles are empty.

Grid (E, C/bc, F/bf, D/bd): the contraction dim is innermost and TPU grids
run sequentially, so the (bc, bf) fp32 accumulator persists in VMEM scratch
across the D tiles.  Block sizes default to MXU-aligned 128x128x512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(sizes_ref, x_ref, w_ref, o_ref, acc_ref, *,
                block_c: int, n_d: int):
    ie = pl.program_id(0)
    ic = pl.program_id(1)
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    size_e = sizes_ref[ie]
    row0 = ic * block_c

    @pl.when(row0 < size_e)
    def _compute():
        x = x_ref[0].astype(jnp.float32)      # (bc, bd)
        w = w_ref[0].astype(jnp.float32)      # (bd, bf)
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(idd == n_d - 1)
    def _finish():
        rows = row0 + jax.lax.broadcasted_iota(
            jnp.int32, acc_ref.shape, 0)
        valid = rows < size_e
        o_ref[0] = jnp.where(valid, acc_ref[...], 0.0).astype(o_ref.dtype)


def moe_gmm_kernel(x, w, group_sizes, *, block_c: int = 128,
                   block_f: int = 128, block_d: int = 512,
                   interpret: bool = False):
    """x: (E, C, D); w: (E, D, F); group_sizes: (E,) int32 -> (E, C, F)."""
    e, c, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert c % block_c == 0 and f % block_f == 0 and d % block_d == 0
    n_d = d // block_d

    kernel = functools.partial(_gmm_kernel, block_c=block_c, n_d=n_d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(e, c // block_c, f // block_f, n_d),
        in_specs=[
            # index_maps receive the scalar-prefetch ref as a trailing arg.
            pl.BlockSpec((1, block_c, block_d),
                         lambda ie, ic, if_, idd, sizes: (ie, ic, idd)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda ie, ic, if_, idd, sizes: (ie, idd, if_)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ie, ic, if_, idd, sizes: (ie, ic, if_)),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, w)
