"""Pure-jnp oracle for the grouped expert matmul.

Row blocks beyond an expert's group size must contribute zeros — the ragged
semantics the kernel exploits to skip work.
"""
from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w, group_sizes):
    """x: (E, C, D); w: (E, D, F); group_sizes: (E,) valid rows per expert.

    Returns (E, C, F) with rows >= group_size zeroed.
    """
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    c = x.shape[1]
    valid = jnp.arange(c)[None, :] < group_sizes[:, None]   # (E, C)
    return jnp.where(valid[..., None], out, 0.0).astype(x.dtype)
