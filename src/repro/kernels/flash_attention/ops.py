"""Jit'd public wrapper for the flash-attention kernel.

On CPU (this container) the kernel runs with interpret=True; on TPU it
compiles through Mosaic.  The wrapper keeps the models' (B, S, H, hd)
layout and transposes to the kernel's (B, H, S, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_kv: int = 128, interpret: bool | None = None):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, H, hd)."""
    if interpret is None:
        interpret = _on_cpu()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
