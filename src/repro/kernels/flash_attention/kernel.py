"""Blockwise online-softmax (flash) attention — Pallas TPU kernel.

Grid (B, H, n_q_blocks, n_kv_blocks); the kv axis is innermost and TPU
grids execute sequentially, so the running (max, denom, accumulator) for a
q block persists in VMEM scratch across kv iterations.  BlockSpecs tile
(block_q x head_dim) of Q and (block_kv x head_dim) of K/V into VMEM; GQA
is handled by the K/V index_map (query head h reads kv head h // group) so
kv tensors are never materialized per-query-head in HBM.

Features (same semantics as ref.py / models.attention): causal mask,
sliding window, tanh soft-capping.  Fully-masked kv blocks are skipped via
pl.when on block indices — on real hardware this prunes ~half the work for
causal attention; under interpret=True it is a correctness no-op.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 block_q: int, block_kv: int, n_kv: int, q_offset: int):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # When Sq < Skv the query block's absolute positions are end-aligned
    # with the keys (prefill-with-prefix convention, same as ref.py).
    q_start = iq * block_q + q_offset
    kv_start = ikv * block_kv

    # Static-shape block skip conditions (evaluated on dynamic indices).
    diag_ok = jnp.logical_or(
        jnp.logical_not(causal), kv_start <= q_start + block_q - 1)
    win_ok = jnp.logical_or(
        window <= 0, kv_start + block_kv - 1 > q_start - window)

    @pl.when(jnp.logical_and(diag_ok, win_ok))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)               # (bkv, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bkv)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, block_q: int = 128,
                           block_kv: int = 128, interpret: bool = False):
    """q: (B, H, Sq, hd); k/v: (B, Hkv, Skv, hd). Returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    n_q = sq // block_q
    n_kv = skv // block_kv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv, n_kv=n_kv,
        q_offset=skv - sq)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h_, iq, ikv: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h_, iq, ikv: (b_, h_ // group, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h_, iq, ikv: (b_, h_ // group, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h_, iq, ikv: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
