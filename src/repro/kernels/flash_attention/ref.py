"""Pure-jnp oracle for the flash-attention kernel.

Supports the assigned archs' full feature set: causal masking, sliding
window, gemma2 tanh logit soft-capping, GQA (kv heads broadcast).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: (B, H, Sq, hd); k/v: (B, Hkv, Skv, hd), H a multiple of Hkv.

    Returns (B, H, Sq, hd) in q.dtype; softmax in fp32.
    """
    b, h, sq, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)

    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if softcap and softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)

    skv = k.shape[2]
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)   # align ends (prefill)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
