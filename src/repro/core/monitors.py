"""Hardware-monitor model (paper §4.1(4) / §4.3 Evaluate).

The real system exposes memory-mapped counters per tile: accelerator active
cycles, accelerator communication cycles, and per-memory-tile DRAM access
counts.  Software reads the DRAM counters before/after each invocation and
— because per-accelerator DRAM attribution would need extra hardware —
approximates each accelerator's share proportionally to its active
footprint (the paper's ``ddr(k, m)`` equation):

    ddr(k,m) = ddr_total(m) * footprint(k,m) / sum_acc footprint(acc,m)

Cohmeleon consumes the *attributed* value, not ground truth; we model both
so tests can quantify the approximation error.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attribute_ddr(
    ddr_total,        # (n_tiles,) observed access delta per memory tile
    footprints,       # (n_accs, n_tiles) bytes of each acc's data per tile
):
    """Paper's proportional attribution.  Returns (n_accs, n_tiles)."""
    ddr_total = jnp.asarray(ddr_total, jnp.float32)
    footprints = jnp.asarray(footprints, jnp.float32)
    total_fp = jnp.maximum(jnp.sum(footprints, axis=0, keepdims=True), 1e-9)
    return ddr_total[None, :] * footprints / total_fp


class MonitorBank:
    """Host-side counter bank used by the discrete-event simulator.

    Mirrors the paper's implementation: counters are cumulative and
    wrap-free here (overflow handling is a driver detail); software samples
    them around each invocation and diffs.
    """

    def __init__(self, n_accs: int, n_tiles: int):
        self.n_accs = n_accs
        self.n_tiles = n_tiles
        self.ddr_accesses = np.zeros(n_tiles, np.float64)     # per mem tile
        self.acc_cycles = np.zeros(n_accs, np.float64)        # active cycles
        self.comm_cycles = np.zeros(n_accs, np.float64)       # comm cycles

    def snapshot_ddr(self) -> np.ndarray:
        return self.ddr_accesses.copy()

    def record_invocation(self, acc_id: int, total_cycles: float,
                          comm_cycles: float,
                          offchip_per_tile: np.ndarray) -> None:
        self.acc_cycles[acc_id] += total_cycles
        self.comm_cycles[acc_id] += comm_cycles
        self.ddr_accesses += offchip_per_tile

    def attributed_accesses(
        self,
        before: np.ndarray,
        after: np.ndarray,
        acc_id: int,
        footprints: np.ndarray,   # (n_accs, n_tiles) active footprint map
    ) -> float:
        """Software-visible off-chip count for ``acc_id`` over a window."""
        delta = np.maximum(after - before, 0.0)
        shares = np.asarray(attribute_ddr(delta, footprints))
        return float(shares[acc_id].sum())
