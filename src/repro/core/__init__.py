"""Cohmeleon core: learning-based orchestration of memory-interaction modes.

The paper's contribution as a composable module: coherence modes, the
Table-3 state space, the multi-objective reward, the tabular Q-learning
agent, baseline policies (incl. the paper's manually-tuned Algorithm 1),
hardware-monitor modelling, and the experiment drivers.  ``autotune``
carries the beyond-paper TPU adaptation (memory-mode orchestration of
train/serve steps).
"""
from repro.core.modes import CoherenceMode, MODE_NAMES, N_MODES
from repro.core.qlearn import QConfig, QState, init_qstate
from repro.core.rewards import (Measurement, RewardState, RewardWeights,
                                PAPER_DEFAULT_WEIGHTS)
from repro.core.state import N_STATES, CacheGeometry

__all__ = [
    "CoherenceMode", "MODE_NAMES", "N_MODES", "QConfig", "QState",
    "init_qstate", "Measurement", "RewardState", "RewardWeights",
    "PAPER_DEFAULT_WEIGHTS", "N_STATES", "CacheGeometry",
]
