"""Beyond-paper adaptation: Cohmeleon's Q-learning orchestrates the
*memory mode* of train/serve steps on TPU (DESIGN.md §2b).

The analogy to the paper, mode-for-mode:

  paper (SoC)                          this module (TPU pod)
  -----------------------------------  --------------------------------
  coherence mode per LCA invocation    remat/microbatch mode per step
  NON_COH_DMA (bypass caches)          remat="full"  (recompute, min HBM)
  LLC_COH_DMA                          remat="dots"  (checkpoint matmuls)
  COH_DMA                              remat="none"  (keep activations)
  FULLY_COH (private cache)            remat="none" + 2x microbatch
  hardware monitors                    wall-clock + cost_analysis bytes
  Table-3 state (footprint/load)       (batch bucket, seq bucket,
                                        live-HBM headroom bucket,
                                        host load bucket)
  multi-objective reward (R_exec,      same functional forms over
  R_comm, R_mem)                       (step time, bytes, peak memory)

Each mode is a *precompiled* step variant; the Q-agent senses the
discretized state, picks a variant per invocation, measures, and updates
the same 243x4-style table (here |S| = 3^4, |A| = #variants).  Decision
overhead is a dict lookup + argmax — the paper's "negligible overhead"
property carries over (measured in benchmarks/overhead.py).
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn
from repro.core.rewards import (Measurement, RewardWeights,
                                PAPER_DEFAULT_WEIGHTS, evaluate,
                                init_reward_state)
from repro.launch import steps as steps_lib

MODES = ("remat_none", "remat_dots", "remat_full", "microbatch2")


def _bucket(x, edges) -> int:
    return int(np.searchsorted(np.asarray(edges, np.float64), x))


class MemoryModeOrchestrator:
    """Per-invocation memory-mode selection for the train step."""

    def __init__(self, cfg, spec, mesh, seed: int = 0,
                 weights: RewardWeights = PAPER_DEFAULT_WEIGHTS,
                 total_steps: int = 1000, decay_frac: float = 0.5):
        self.cfg = cfg
        self.spec = spec
        self.mesh = mesh
        self.weights = weights
        self._variants: dict[str, Callable] = {}
        for mode in MODES:
            self._variants[mode] = self._build(mode, total_steps)
        self.qcfg = qlearn.QConfig(
            n_states=3 ** 4, n_actions=len(MODES),
            decay_steps=max(int(total_steps * decay_frac), 1))
        self.qs = qlearn.init_qstate(self.qcfg)
        self.rstate = init_reward_state(1)
        self._key = jax.random.PRNGKey(seed)
        self._counts = {m: 0 for m in MODES}
        self._decide_s: list[float] = []
        # decision path must be negligible: jit select/update once
        self._select = jax.jit(
            lambda qs, s, k: qlearn.select(qs, self.qcfg, s, k))
        self._update = jax.jit(
            lambda qs, s, a, r: qlearn.update(qs, self.qcfg, s, a, r))
        self._eval = jax.jit(
            lambda rs, m: evaluate(rs, jnp.int32(0), m, self.weights))
        self._live_cache = 0.0
        self._step_no = 0

    # ------------------------------------------------------------- build
    def _build(self, mode: str, total_steps: int):
        cfg = self.cfg
        if mode == "remat_none":
            cfg = cfg.replace(remat="none")
        elif mode == "remat_dots":
            cfg = cfg.replace(remat="dots")
        elif mode == "remat_full":
            cfg = cfg.replace(remat="full")
        elif mode == "microbatch2":
            cfg = cfg.replace(remat="none")

        base = steps_lib.make_train_step(cfg, total_steps=total_steps)
        if mode != "microbatch2":
            return jax.jit(base, donate_argnums=(0,))

        def micro2(state, batch):
            half = jax.tree_util.tree_map(
                lambda x: x[: x.shape[0] // 2], batch)
            half2 = jax.tree_util.tree_map(
                lambda x: x[x.shape[0] // 2:], batch)
            state, m1 = base(state, half)
            state, m2 = base(state, half2)
            return state, jax.tree_util.tree_map(
                lambda a, b: (a + b) / 2.0, m1, m2)

        return jax.jit(micro2, donate_argnums=(0,))

    # ------------------------------------------------------------- sense
    def _sense(self, batch) -> int:
        tokens = batch["tokens"]
        b = tokens.shape[0]
        s = tokens.shape[-1]
        footprint = float(b * s)
        # live-arrays headroom as the HBM-pressure proxy; refreshed every
        # 16 invocations (the scan is O(#arrays), too slow per step)
        if self._step_no % 16 == 0:
            try:
                self._live_cache = sum(
                    x.nbytes for x in jax.live_arrays())
            except Exception:
                self._live_cache = 0.0
        live = self._live_cache
        attrs = [
            _bucket(b, [8, 64]),
            _bucket(s, [512, 8192]),
            _bucket(live / 1e9, [1.0, 8.0]),
            _bucket(footprint / 1e6, [0.25, 4.0]),
        ]
        idx = 0
        for a in attrs:
            idx = idx * 3 + min(a, 2)
        return idx

    # -------------------------------------------------------------- step
    def step(self, state, batch):
        t0 = time.perf_counter()
        self._step_no += 1
        s_idx = self._sense(batch)
        self._key, sub = jax.random.split(self._key)
        action = int(self._select(self.qs, jnp.int32(s_idx), sub))
        mode = MODES[action]
        self._decide_s.append(time.perf_counter() - t0)

        t1 = time.perf_counter()
        new_state, metrics = self._variants[mode](state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t1

        tokens = float(np.prod(batch["tokens"].shape))
        m = Measurement(
            exec_time=jnp.float32(dt),
            comm_cycles=jnp.float32(dt),     # no comm counter on CPU: 1.0
            total_cycles=jnp.float32(dt),
            offchip_accesses=jnp.float32(self._bytes_proxy(mode)),
            footprint=jnp.float32(tokens),
        )
        reward, self.rstate, _ = self._eval(self.rstate, m)
        self.qs = self._update(self.qs, jnp.int32(s_idx),
                               jnp.int32(action), reward)
        self._counts[mode] += 1
        return new_state, metrics

    def _bytes_proxy(self, mode: str) -> float:
        # remat trades bytes for flops: proxy HBM traffic ordering.
        return {"remat_none": 3.0, "remat_dots": 2.0, "remat_full": 1.0,
                "microbatch2": 1.5}[mode]

    # --------------------------------------------------------------- api
    def decision_counts(self) -> dict:
        return dict(self._counts)

    def decide_overhead_s(self) -> float:
        return float(np.mean(self._decide_s)) if self._decide_s else 0.0

    def freeze(self):
        self.qs = qlearn.freeze(self.qs)
