"""Cohmeleon state space (paper Table 3).

A state is a 5-tuple of discretized attributes, each taking one of three
values, so |S| = 3^5 = 243.  The attributes capture a compact snapshot of
the SoC at invocation time:

  0. fully_coh_acc      — number of active fully-coherent accelerators
                          {0, 1, 2+}
  1. non_coh_per_tile   — avg number of non-coherent accelerators per memory
                          partition needed by this invocation {0, 1, 2+}
  2. to_llc_per_tile    — avg number of accelerators per LLC partition needed
                          by this invocation {0, 1, 2+}
  3. tile_footprint     — avg utilization of each needed cache-hierarchy
                          partition {<=L2, <=LLC slice, >LLC slice}
  4. acc_footprint      — memory footprint of this invocation
                          {<=L2, <=LLC slice, >LLC slice}

Everything here is pure-jnp and jit/vmap friendly: states are encoded as a
single int32 index into the Q-table.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.modes import CoherenceMode

N_ATTRS = 5
N_LEVELS = 3
N_STATES = N_LEVELS**N_ATTRS  # 243

ATTR_NAMES = (
    "fully_coh_acc",
    "non_coh_per_tile",
    "to_llc_per_tile",
    "tile_footprint",
    "acc_footprint",
)


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    """Capacities needed to discretize footprints (bytes)."""

    l2_bytes: int
    llc_slice_bytes: int
    n_mem_tiles: int


def _bucket_count(x):
    """{0, 1, 2+} bucket for a count (works on scalars or arrays)."""
    return jnp.clip(jnp.asarray(x, jnp.int32), 0, 2)


def _bucket_footprint(bytes_, geom: CacheGeometry):
    """{<=L2, <=LLC slice, >LLC slice} bucket for a byte footprint."""
    b = jnp.asarray(bytes_, jnp.float64 if jnp.asarray(bytes_).dtype == jnp.float64 else jnp.float32)
    return jnp.where(
        b <= geom.l2_bytes,
        0,
        jnp.where(b <= geom.llc_slice_bytes, 1, 2),
    ).astype(jnp.int32)


def encode_attrs(attrs) -> jnp.ndarray:
    """Pack a length-5 attribute vector (each in [0,3)) into a state index."""
    attrs = jnp.asarray(attrs, jnp.int32)
    # Unrolled weighted sum: scalar literals only, so the encoding traces
    # without array constants (Pallas kernel bodies reject captured
    # device-array constants).
    out = attrs[..., 0]
    for i in range(1, N_ATTRS):
        out = out + attrs[..., i] * (N_LEVELS**i)
    return out


def decode_state(idx: int) -> tuple[int, ...]:
    """Inverse of :func:`encode_attrs` (host-side helper)."""
    out = []
    for _ in range(N_ATTRS):
        out.append(int(idx % N_LEVELS))
        idx //= N_LEVELS
    return tuple(out)


def observe(
    *,
    active_modes: jnp.ndarray,      # (max_accs,) int32 CoherenceMode, -1 = inactive
    active_footprints: jnp.ndarray,  # (max_accs,) float32 bytes, 0 = inactive
    needed_tiles: jnp.ndarray,       # (max_accs, n_tiles) bool — tiles each acc touches
    target_tiles: jnp.ndarray,       # (n_tiles,) bool — tiles this invocation needs
    target_footprint,                # scalar bytes of this invocation
    geom: CacheGeometry,
    active_fp_per_tile: jnp.ndarray | None = None,  # (max_accs,) bytes/tile
) -> jnp.ndarray:
    """Sense the SoC and return the encoded state index (paper §4.1 Sense).

    All inputs are fixed-size arrays so this function can live inside
    ``lax.scan``/``vmap`` in the vectorized environment.

    ``active_fp_per_tile`` optionally supplies each active slot's
    ``footprint / |needed tiles|`` precomputed (zero for inactive slots).
    A slot's value changes exactly when that slot issues a new invocation,
    so the vectorized environment caches it in its scan carry next to the
    (dram, llc) demand cache and skips the per-step row division here.
    Because the tile masks are exact {0, 1} factors, supplying the cached
    quantity is bitwise-identical to the recompute path.
    """
    active = active_modes >= 0

    fully_coh = jnp.sum(
        jnp.where(active & (active_modes == int(CoherenceMode.FULLY_COH)), 1, 0)
    )

    n_target_tiles = jnp.maximum(jnp.sum(target_tiles.astype(jnp.int32)), 1)

    # Per needed tile: how many active non-coherent accelerators touch it.
    non_coh_mask = active & (active_modes == int(CoherenceMode.NON_COH_DMA))
    per_tile_non_coh = jnp.sum(
        needed_tiles.astype(jnp.int32) * non_coh_mask[:, None].astype(jnp.int32),
        axis=0,
    )
    avg_non_coh = (
        jnp.sum(jnp.where(target_tiles, per_tile_non_coh, 0)) / n_target_tiles
    )

    # Per needed tile: how many active accelerators route through its LLC
    # slice (all modes except non-coherent DMA).
    llc_mask = active & (active_modes != int(CoherenceMode.NON_COH_DMA))
    per_tile_llc = jnp.sum(
        needed_tiles.astype(jnp.int32) * llc_mask[:, None].astype(jnp.int32),
        axis=0,
    )
    avg_llc = jnp.sum(jnp.where(target_tiles, per_tile_llc, 0)) / n_target_tiles

    # Average utilization (bytes of active data) of each needed partition.
    if active_fp_per_tile is None:
        active_fp_per_tile = (
            jnp.where(active, active_footprints, 0.0)
            / jnp.maximum(jnp.sum(needed_tiles, axis=-1), 1))
    per_tile_bytes = jnp.sum(
        needed_tiles.astype(jnp.float32) * active_fp_per_tile[:, None],
        axis=0,
    )
    avg_tile_bytes = (
        jnp.sum(jnp.where(target_tiles, per_tile_bytes, 0.0)) / n_target_tiles
    )

    attrs = jnp.stack(
        [
            _bucket_count(fully_coh),
            _bucket_count(jnp.round(avg_non_coh).astype(jnp.int32)),
            _bucket_count(jnp.round(avg_llc).astype(jnp.int32)),
            _bucket_footprint(avg_tile_bytes, geom),
            _bucket_footprint(target_footprint, geom),
        ]
    )
    return encode_attrs(attrs)


def observe_host(
    *,
    active_modes: Sequence[int],
    active_footprints: Sequence[float],
    needed_tiles: Sequence[Sequence[bool]],
    target_tiles: Sequence[bool],
    target_footprint: float,
    geom: CacheGeometry,
) -> int:
    """Host-side (numpy) convenience wrapper used by the discrete-event sim."""
    n_tiles = len(target_tiles)
    if len(active_modes) == 0:
        modes = np.full((1,), -1, np.int32)
        fps = np.zeros((1,), np.float32)
        tiles = np.zeros((1, n_tiles), bool)
    else:
        modes = np.asarray(active_modes, np.int32)
        fps = np.asarray(active_footprints, np.float32)
        tiles = np.asarray(needed_tiles, bool).reshape(len(active_modes), n_tiles)
    return int(
        observe(
            active_modes=jnp.asarray(modes),
            active_footprints=jnp.asarray(fps),
            needed_tiles=jnp.asarray(tiles),
            target_tiles=jnp.asarray(np.asarray(target_tiles, bool)),
            target_footprint=float(target_footprint),
            geom=geom,
        )
    )
