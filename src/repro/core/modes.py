"""Accelerator cache-coherence modes (paper §2).

The four modes are defined independently of the specific coherence protocol.
Each mode differs in (a) where accelerator memory requests are routed and
(b) which software flushes the device driver must issue before launch.

These integer codes index the action dimension of the Q-table and every
per-mode lookup table in the SoC timing model, so their values are part of
the on-disk checkpoint format — do not reorder.
"""
from __future__ import annotations

import enum


class CoherenceMode(enum.IntEnum):
    """Paper §2 coherence modes, in the paper's presentation order."""

    NON_COH_DMA = 0   # bypass caches, DMA straight to DRAM; full flush first
    LLC_COH_DMA = 1   # DMA to the LLC; private (L2) caches flushed first
    COH_DMA = 2       # DMA to the LLC; LLC recalls/invalidates L2 lines
    FULLY_COH = 3     # private cache on the accelerator, full MESI coherence


N_MODES = len(CoherenceMode)

#: Modes whose driver path must flush the *entire* cache hierarchy before
#: the accelerator may run (paper §2, Non-Coherent DMA).
FULL_FLUSH_MODES = (CoherenceMode.NON_COH_DMA,)

#: Modes whose driver path must flush only the processors' private caches.
PRIVATE_FLUSH_MODES = (CoherenceMode.LLC_COH_DMA,)

#: Modes that route requests through the LLC (and therefore contend for it).
LLC_MODES = (
    CoherenceMode.LLC_COH_DMA,
    CoherenceMode.COH_DMA,
    CoherenceMode.FULLY_COH,
)

#: Modes with no private cache on the accelerator side (DMA modes).
DMA_MODES = (
    CoherenceMode.NON_COH_DMA,
    CoherenceMode.LLC_COH_DMA,
    CoherenceMode.COH_DMA,
)

MODE_NAMES = tuple(m.name.lower().replace("_", "-") for m in CoherenceMode)


def flush_kind(mode: CoherenceMode) -> str:
    """Which software flush the driver issues for ``mode``.

    Returns one of ``"full"`` (whole hierarchy), ``"private"`` (L2s only) or
    ``"none"`` — paper §2 / §4.3 Actuate.
    """
    if mode in FULL_FLUSH_MODES:
        return "full"
    if mode in PRIVATE_FLUSH_MODES:
        return "private"
    return "none"
