"""Baseline coherence-selection policies (paper §4.3 Decide).

  * Random — uniform over available modes.
  * FixedHomogeneous — one mode for every accelerator (design-time choice,
    mimics nearly all prior work; five variants, one per mode, plus the
    profiled heterogeneous variant below).
  * FixedHeterogeneous — per-accelerator mode chosen by profiling each
    accelerator across footprints and picking the best-on-average mode
    (stand-in for design-time approaches such as Bhardwaj et al.).
  * Manual — the paper's expert heuristic (Algorithm 1), hand-tuned for the
    ESP implementation of the modes.
  * QPolicy — the Cohmeleon agent (qlearn.py) behind the same interface.

Every policy implements ``decide(ctx) -> CoherenceMode`` where ``ctx`` is a
:class:`DecisionContext`; the DES calls that per invocation.  For the
vectorized environments every policy additionally implements
``lower(env, compiled) -> repro.soc.vecenv.PolicySpec`` — the single
episode currency of the scale path: fixed and manual lower into a
precomputed per-(phase, thread, step) mode table, Random and Q into a
(frozen) Q-table behind the spec's ``learned`` flag.  One jitted episode
consumes any spec, and stacked specs evaluate heterogeneous policy
batches in one call.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn
from repro.core.modes import CoherenceMode, N_MODES
from repro.soc.config import SoCConfig

# Paper Alg. 1 threshold: "extra small" invocations always go fully
# coherent (their data lives comfortably in the private cache).
EXTRA_SMALL_THRESHOLD = 4 * 1024


@dataclasses.dataclass
class DecisionContext:
    """Everything a policy may look at when an invocation is about to start."""

    acc_id: int
    acc_name: str
    footprint: float
    state_idx: int                       # encoded Table-3 state
    active_modes: Sequence[int]          # modes of currently-active accs
    active_footprint: float              # sum of active accs' footprints
    available: Sequence[bool]            # len-4 action mask
    soc: SoCConfig
    rng: np.random.Generator
    # Optional richer sensing for function-approximation policies
    # (repro.soc.nn) — the tabular/fixed families never read these, so
    # the DES fills them best-effort and older call sites stay valid.
    active_footprints: Sequence[float] | None = None  # per-active footprints
    target_tiles: Sequence[bool] | None = None        # this invocation's tiles
    profile: Sequence[float] | None = None            # packed AccProfile row
    warm: float = 1.0                                 # inter-stage warmth
    slack: float = 0.0                                # deadline - arrival
    reuse: float = 0.0                                # arrival - last finish

    def count(self, mode: CoherenceMode) -> int:
        return int(sum(1 for m in self.active_modes if m == mode))


class Policy:
    name = "policy"
    trainable = False

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        raise NotImplementedError

    def observe_reward(self, ctx: DecisionContext, action: int,
                       reward: float) -> None:
        """Hook for learning policies; no-op for baselines."""

    def lower(self, env, compiled):
        """Lower this policy into a :class:`repro.soc.vecenv.PolicySpec`
        for the unified jitted episode.

        ``env`` is anything exposing the vecenv protocol (``.params`` —
        a ``LaneParams`` — and ``.profiles``): a ``VecEnv`` or a stacked
        lane view.  ``compiled`` is anything with a ``.schedule``
        (``CompiledApp``, or a padded lane of a ``StackedApps``).
        Subclasses override; the base class has no vecenv semantics."""
        raise NotImplementedError(
            f"policy {self.name!r} has no vecenv lowering; "
            "use backend='des'")


class RandomPolicy(Policy):
    name = "random"

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        opts = [i for i in range(N_MODES) if ctx.available[i]]
        return CoherenceMode(int(ctx.rng.choice(opts)))

    def lower(self, env, compiled):
        # A frozen untrained table is all ties -> uniform over available
        # modes (qlearn.select's randomized argmax), i.e. this policy.
        from repro.soc import vecenv as vec
        return vec.learned_policy_spec(qlearn.frozen_qstate(),
                                       compiled.schedule)


class FixedHomogeneous(Policy):
    def __init__(self, mode: CoherenceMode):
        self.mode = CoherenceMode(mode)
        self.name = f"fixed-{self.mode.name.lower().replace('_', '-')}"

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        if ctx.available[self.mode]:
            return self.mode
        return CoherenceMode.NON_COH_DMA  # always available fallback

    def lower(self, env, compiled):
        from repro.soc import vecenv as vec
        return vec.fixed_policy_spec(env.params, compiled.schedule,
                                     int(self.mode))


class FixedHeterogeneous(Policy):
    """Design-time per-accelerator assignment from an offline profile."""

    name = "fixed-heterogeneous"

    def __init__(self, assignment: Mapping[str, CoherenceMode]):
        self.assignment = dict(assignment)

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        mode = self.assignment.get(ctx.acc_name, CoherenceMode.NON_COH_DMA)
        if ctx.available[mode]:
            return mode
        return CoherenceMode.NON_COH_DMA

    def lower(self, env, compiled):
        from repro.soc import vecenv as vec
        modes = [int(self.assignment.get(p.name, CoherenceMode.NON_COH_DMA))
                 for p in env.profiles]
        # padded stacked lanes carry more accelerator rows than profiles
        modes += [int(CoherenceMode.NON_COH_DMA)] * (
            env.params.masks.shape[0] - len(modes))
        return vec.fixed_policy_spec(
            env.params, compiled.schedule, jnp.asarray(modes, jnp.int32))


class ManualPolicy(Policy):
    """Paper Algorithm 1 — the ESP-tuned expert heuristic, verbatim."""

    name = "manual"

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        footprint = ctx.footprint
        l2 = ctx.soc.l2_bytes
        llc = ctx.soc.llc_total_bytes
        active_coh_dma = ctx.count(CoherenceMode.COH_DMA)
        active_fully_coh = ctx.count(CoherenceMode.FULLY_COH)
        active_non_coh = ctx.count(CoherenceMode.NON_COH_DMA)

        if footprint <= EXTRA_SMALL_THRESHOLD:
            mode = CoherenceMode.FULLY_COH
        elif footprint <= l2:
            if active_coh_dma > active_fully_coh:
                mode = CoherenceMode.FULLY_COH
            else:
                mode = CoherenceMode.COH_DMA
        elif footprint + ctx.active_footprint > llc:
            mode = CoherenceMode.NON_COH_DMA
        else:
            if active_non_coh >= 2:
                mode = CoherenceMode.LLC_COH_DMA
            else:
                mode = CoherenceMode.COH_DMA

        if not ctx.available[mode]:
            return CoherenceMode.NON_COH_DMA
        return mode

    def lower(self, env, compiled):
        # Deterministic recursion over the static schedule: the whole
        # Algorithm-1 mode table precomputes off the hot path.
        from repro.soc import vecenv as vec
        return vec.manual_policy_spec(env.params, compiled.schedule)


class QPolicy(Policy):
    """Cohmeleon: the Q-learning agent behind the shared Policy interface."""

    name = "cohmeleon"
    trainable = True

    def __init__(self, cfg: qlearn.QConfig | None = None, seed: int = 0):
        self.cfg = cfg or qlearn.QConfig()
        self.qs = qlearn.init_qstate(self.cfg)
        self._key = jax.random.PRNGKey(seed)
        self._select = jax.jit(
            lambda qs, s, k, m: qlearn.select(qs, self.cfg, s, k, m)
        )
        self._update = jax.jit(
            lambda qs, s, a, r: qlearn.update(qs, self.cfg, s, a, r)
        )
        self._pending: dict[int, tuple[int, int]] = {}

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        self._key, sub = jax.random.split(self._key)
        action = int(
            self._select(
                self.qs,
                jnp.int32(ctx.state_idx),
                sub,
                jnp.asarray(ctx.available, bool),
            )
        )
        self._pending[ctx.acc_id] = (ctx.state_idx, action)
        return CoherenceMode(action)

    def observe_reward(self, ctx: DecisionContext, action: int,
                       reward: float) -> None:
        state_idx, chosen = self._pending.pop(ctx.acc_id, (ctx.state_idx, action))
        self.qs = self._update(
            self.qs, jnp.int32(state_idx), jnp.int32(chosen), jnp.float32(reward)
        )

    def freeze(self) -> None:
        self.qs = qlearn.freeze(self.qs)

    def lower(self, env, compiled):
        """Frozen-greedy lowering (the evaluation protocol): the learned
        table drops into the unified episode unchanged."""
        from repro.soc import vecenv as vec
        return vec.learned_policy_spec(qlearn.freeze(self.qs),
                                       compiled.schedule)


def all_fixed_policies() -> list[Policy]:
    return [FixedHomogeneous(m) for m in CoherenceMode]
