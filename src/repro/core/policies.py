"""Baseline coherence-selection policies (paper §4.3 Decide).

  * Random — uniform over available modes.
  * FixedHomogeneous — one mode for every accelerator (design-time choice,
    mimics nearly all prior work; five variants, one per mode, plus the
    profiled heterogeneous variant below).
  * FixedHeterogeneous — per-accelerator mode chosen by profiling each
    accelerator across footprints and picking the best-on-average mode
    (stand-in for design-time approaches such as Bhardwaj et al.).
  * Manual — the paper's expert heuristic (Algorithm 1), hand-tuned for the
    ESP implementation of the modes.
  * QPolicy — the Cohmeleon agent (qlearn.py) behind the same interface.

Every policy implements ``decide(ctx) -> CoherenceMode`` where ``ctx`` is a
:class:`DecisionContext`; the DES and the vectorized env share these.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn
from repro.core.modes import CoherenceMode, N_MODES
from repro.soc.config import SoCConfig

# Paper Alg. 1 threshold: "extra small" invocations always go fully
# coherent (their data lives comfortably in the private cache).
EXTRA_SMALL_THRESHOLD = 4 * 1024


@dataclasses.dataclass
class DecisionContext:
    """Everything a policy may look at when an invocation is about to start."""

    acc_id: int
    acc_name: str
    footprint: float
    state_idx: int                       # encoded Table-3 state
    active_modes: Sequence[int]          # modes of currently-active accs
    active_footprint: float              # sum of active accs' footprints
    available: Sequence[bool]            # len-4 action mask
    soc: SoCConfig
    rng: np.random.Generator

    def count(self, mode: CoherenceMode) -> int:
        return int(sum(1 for m in self.active_modes if m == mode))


class Policy:
    name = "policy"
    trainable = False

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        raise NotImplementedError

    def observe_reward(self, ctx: DecisionContext, action: int,
                       reward: float) -> None:
        """Hook for learning policies; no-op for baselines."""


class RandomPolicy(Policy):
    name = "random"

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        opts = [i for i in range(N_MODES) if ctx.available[i]]
        return CoherenceMode(int(ctx.rng.choice(opts)))


class FixedHomogeneous(Policy):
    def __init__(self, mode: CoherenceMode):
        self.mode = CoherenceMode(mode)
        self.name = f"fixed-{self.mode.name.lower().replace('_', '-')}"

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        if ctx.available[self.mode]:
            return self.mode
        return CoherenceMode.NON_COH_DMA  # always available fallback


class FixedHeterogeneous(Policy):
    """Design-time per-accelerator assignment from an offline profile."""

    name = "fixed-heterogeneous"

    def __init__(self, assignment: Mapping[str, CoherenceMode]):
        self.assignment = dict(assignment)

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        mode = self.assignment.get(ctx.acc_name, CoherenceMode.NON_COH_DMA)
        if ctx.available[mode]:
            return mode
        return CoherenceMode.NON_COH_DMA


class ManualPolicy(Policy):
    """Paper Algorithm 1 — the ESP-tuned expert heuristic, verbatim."""

    name = "manual"

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        footprint = ctx.footprint
        l2 = ctx.soc.l2_bytes
        llc = ctx.soc.llc_total_bytes
        active_coh_dma = ctx.count(CoherenceMode.COH_DMA)
        active_fully_coh = ctx.count(CoherenceMode.FULLY_COH)
        active_non_coh = ctx.count(CoherenceMode.NON_COH_DMA)

        if footprint <= EXTRA_SMALL_THRESHOLD:
            mode = CoherenceMode.FULLY_COH
        elif footprint <= l2:
            if active_coh_dma > active_fully_coh:
                mode = CoherenceMode.FULLY_COH
            else:
                mode = CoherenceMode.COH_DMA
        elif footprint + ctx.active_footprint > llc:
            mode = CoherenceMode.NON_COH_DMA
        else:
            if active_non_coh >= 2:
                mode = CoherenceMode.LLC_COH_DMA
            else:
                mode = CoherenceMode.COH_DMA

        if not ctx.available[mode]:
            return CoherenceMode.NON_COH_DMA
        return mode


class QPolicy(Policy):
    """Cohmeleon: the Q-learning agent behind the shared Policy interface."""

    name = "cohmeleon"
    trainable = True

    def __init__(self, cfg: qlearn.QConfig | None = None, seed: int = 0):
        self.cfg = cfg or qlearn.QConfig()
        self.qs = qlearn.init_qstate(self.cfg)
        self._key = jax.random.PRNGKey(seed)
        self._select = jax.jit(
            lambda qs, s, k, m: qlearn.select(qs, self.cfg, s, k, m)
        )
        self._update = jax.jit(
            lambda qs, s, a, r: qlearn.update(qs, self.cfg, s, a, r)
        )
        self._pending: dict[int, tuple[int, int]] = {}

    def decide(self, ctx: DecisionContext) -> CoherenceMode:
        self._key, sub = jax.random.split(self._key)
        action = int(
            self._select(
                self.qs,
                jnp.int32(ctx.state_idx),
                sub,
                jnp.asarray(ctx.available, bool),
            )
        )
        self._pending[ctx.acc_id] = (ctx.state_idx, action)
        return CoherenceMode(action)

    def observe_reward(self, ctx: DecisionContext, action: int,
                       reward: float) -> None:
        state_idx, chosen = self._pending.pop(ctx.acc_id, (ctx.state_idx, action))
        self.qs = self._update(
            self.qs, jnp.int32(state_idx), jnp.int32(chosen), jnp.float32(reward)
        )

    def freeze(self) -> None:
        self.qs = qlearn.freeze(self.qs)


def all_fixed_policies() -> list[Policy]:
    return [FixedHomogeneous(m) for m in CoherenceMode]
