"""Tabular Q-learning agent (paper §4.2).

Faithful to the paper's formulation:

  * Q-table of |S| x |A| = 243 x 4 = 972 entries, zero-initialized.
  * epsilon-greedy action selection (explore with prob. epsilon, otherwise
    argmax over the Q-row for the sensed state).
  * Update rule ``Q(s,a) <- (1-alpha) Q(s,a) + alpha R(s,a)`` — note the
    paper uses the immediate multi-objective reward with no bootstrapped
    ``max_a' Q(s',a')`` term (a contextual-bandit-style update), which we
    keep exactly.
  * epsilon (init 0.5) and alpha (init 0.25) decay **linearly to zero** over
    a configured number of training iterations (paper §5 Experimental
    Setup); after convergence updates are disabled and the greedy policy is
    evaluated.

Everything is a pure function over a :class:`QState` pytree, so training can
run under ``jit``/``lax.scan`` and thousands of agents can be trained in
parallel with ``vmap`` (used by the Fig. 6 reward-DSE benchmark).

Action masking: per the paper, "COHMELEON does not necessarily require
support for all four coherence modes; it makes the selection based on the
options that are available" — ``select`` takes an ``action_mask``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modes import CoherenceMode, N_MODES
from repro.core.state import N_STATES

# numpy so it inlines as a literal under Pallas tracing
_NEG = np.float32(-3.4e38)
# The degradation-safe fallback action: always available by construction.
_FALLBACK = int(CoherenceMode.NON_COH_DMA)


class QConfig(NamedTuple):
    n_states: int = N_STATES
    n_actions: int = N_MODES
    epsilon0: float = 0.5     # paper initialization
    alpha0: float = 0.25      # paper initialization
    decay_steps: int = 3000   # invocations until eps/alpha hit zero
    # Beyond-paper robustness fix (EXPERIMENTS.md §Paper-validation): the
    # paper zero-initializes Q; with a noisy multi-objective reward an
    # epsilon-greedy agent can freeze a bad arm off 2-3 early samples
    # (alpha decays) and self-reinforce.  Optimistic init at the reward
    # upper bound makes every arm get pulled while alpha is still large.
    # An untrained table is all-ties -> uniform random, preserving the
    # paper's "iteration 0 == Random policy" property (Fig. 8).
    q_init: float = 1.0
    # Reward-collapse watchdog (fault robustness, :func:`reward_watchdog`):
    # if an episode's mean reward drops below ``collapse_frac`` of the best
    # episode seen so far while still training, the decay counter is wound
    # back so epsilon/alpha re-open to ``reopen_frac`` of their initial
    # values — a degraded SoC invalidates the learned table and the agent
    # must re-explore.  ``collapse_frac = 0`` disables the watchdog (the
    # default; the training scan is then bitwise-identical to the
    # watchdog-free program).
    collapse_frac: float = 0.0
    reopen_frac: float = 0.5


class QState(NamedTuple):
    qtable: jnp.ndarray   # (S, A) float32
    visits: jnp.ndarray   # (S, A) int32 — diagnostics / breakdown plots
    step: jnp.ndarray     # () int32, training invocations so far
    frozen: jnp.ndarray   # () bool — True once training is disabled


def init_qstate(cfg: QConfig = QConfig()) -> QState:
    return QState(
        qtable=jnp.full((cfg.n_states, cfg.n_actions), cfg.q_init,
                        jnp.float32),
        visits=jnp.zeros((cfg.n_states, cfg.n_actions), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        frozen=jnp.zeros((), bool),
    )


def schedule(cfg: QConfig, step):
    """Linearly decayed (epsilon, alpha) at ``step``."""
    frac = jnp.clip(1.0 - step.astype(jnp.float32) / cfg.decay_steps, 0.0, 1.0)
    return cfg.epsilon0 * frac, cfg.alpha0 * frac


def select(
    qs: QState,
    cfg: QConfig,
    state_idx,
    key,
    action_mask=None,
):
    """Epsilon-greedy action for ``state_idx``. Returns int32 action."""
    if action_mask is None:
        action_mask = jnp.ones((cfg.n_actions,), bool)
    eps, _ = schedule(cfg, qs.step)
    eps = jnp.where(qs.frozen, 0.0, eps)

    k_explore, k_pick, k_tie = jax.random.split(key, 3)
    raw = qs.qtable[state_idx]
    row = jnp.where(action_mask, raw, _NEG)
    # Randomized argmax: ties (e.g. the all-zero row of an unvisited
    # state) break uniformly, so an untrained table == the Random policy
    # (paper Fig. 8, "iteration 0") instead of defaulting to action 0.
    is_max = row >= jnp.max(row) - 1e-9
    tie_logits = jnp.where(is_max & action_mask, 0.0, _NEG)
    greedy = jax.random.categorical(k_tie, tie_logits).astype(jnp.int32)

    logits = jnp.where(action_mask, 0.0, _NEG)
    random_action = jax.random.categorical(k_pick, logits).astype(jnp.int32)

    explore = jax.random.uniform(k_explore) < eps
    choice = jnp.where(explore, random_action, greedy)
    # Degradation safety: a corrupted (non-finite) Q-row falls back to the
    # always-available non-coherent mode instead of argmaxing over NaNs.
    return jnp.where(jnp.all(jnp.isfinite(raw)), choice, _FALLBACK)


class SelectNoise(NamedTuple):
    """Pre-sampled randomness for :func:`select_presampled`.

    ``select`` draws three independent variates per call (explore uniform,
    random-action gumbels, tie-break gumbels).  Inside a ``lax.scan`` the
    per-step ``split`` + ``categorical`` threefry calls dominate the step
    cost; pre-sampling the whole episode's noise in one batched call
    (:func:`sample_select_noise`) and feeding rows through the scan xs
    keeps the per-step work at two argmaxes and a compare."""

    u_explore: jnp.ndarray   # (..., ) uniform [0, 1)
    g_pick: jnp.ndarray      # (..., A) gumbel — uniform-random action draw
    g_tie: jnp.ndarray       # (..., A) gumbel — randomized-argmax tie-break


def sample_select_noise(key, shape_prefix: tuple,
                        n_actions: int = N_MODES) -> SelectNoise:
    """One batched threefry call's worth of select noise for ``shape_prefix``
    steps (e.g. ``(S,)`` for an episode of S invocations)."""
    k_explore, k_pick, k_tie = jax.random.split(key, 3)
    return SelectNoise(
        u_explore=jax.random.uniform(k_explore, shape_prefix),
        g_pick=jax.random.gumbel(k_pick, (*shape_prefix, n_actions)),
        g_tie=jax.random.gumbel(k_tie, (*shape_prefix, n_actions)),
    )


def select_presampled(
    qs: QState,
    cfg: QConfig,
    state_idx,
    noise: SelectNoise,
    action_mask=None,
):
    """:func:`select` with the randomness supplied as one :class:`SelectNoise`
    row.  Identical distribution — ``categorical(key, logits)`` is
    ``argmax(logits + gumbel)``, which is what this computes — but with no
    per-call threefry, so it is the hot-path variant used inside the
    vectorized environment's scan step."""
    if action_mask is None:
        action_mask = jnp.ones((cfg.n_actions,), bool)
    eps, _ = schedule(cfg, qs.step)
    eps = jnp.where(qs.frozen, 0.0, eps)

    raw = qs.qtable[state_idx]
    row = jnp.where(action_mask, raw, _NEG)
    is_max = row >= jnp.max(row) - 1e-9
    tie_logits = jnp.where(is_max & action_mask, 0.0, _NEG)
    greedy = jnp.argmax(tie_logits + noise.g_tie, axis=-1).astype(jnp.int32)

    logits = jnp.where(action_mask, 0.0, _NEG)
    random_action = jnp.argmax(logits + noise.g_pick,
                               axis=-1).astype(jnp.int32)

    explore = noise.u_explore < eps
    choice = jnp.where(explore, random_action, greedy)
    # Same non-finite-row fallback as `select`/`row_select_presampled`.
    return jnp.where(jnp.all(jnp.isfinite(raw)), choice, _FALLBACK)


def row_select_presampled(row, eps, noise: SelectNoise, action_mask):
    """:func:`select_presampled` on a pre-gathered Q-row with a precomputed
    epsilon.

    The fused episode step gathers ``qtable[state_idx]`` once and feeds the
    same row to selection and to :func:`row_update`, and precomputes the
    whole episode's (epsilon, alpha) decay outside the scan
    (:func:`decay_arrays`) — this is the selection half.  Identical floats
    to ``select_presampled`` (same masked row, same gumbel argmaxes)."""
    mrow = jnp.where(action_mask, row, _NEG)
    is_max = mrow >= jnp.max(mrow) - 1e-9
    tie_logits = jnp.where(is_max & action_mask, 0.0, _NEG)
    greedy = jnp.argmax(tie_logits + noise.g_tie, axis=-1).astype(jnp.int32)
    logits = jnp.where(action_mask, 0.0, _NEG)
    random_action = jnp.argmax(logits + noise.g_pick,
                               axis=-1).astype(jnp.int32)
    choice = jnp.where(noise.u_explore < eps, random_action, greedy)
    # Same non-finite-row fallback as `select`/`select_presampled`; on a
    # finite row the select is bitwise-free (where(True, choice, _) on
    # exact integers).
    return jnp.where(jnp.all(jnp.isfinite(row)), choice, _FALLBACK)


def row_update(row, alpha, action, reward):
    """The paper update on a pre-gathered Q-row: the blended row to write
    back with ``qtable.at[state_idx].set``.  ``alpha == 0`` (frozen, or a
    decayed-to-zero schedule) leaves the row bitwise unchanged.

    Degradation safety: a non-finite reward (a fault-corrupted timing
    model, a poisoned extrema table) must never reach the blend — both
    alpha and the reward are zeroed (zeroing alpha alone still leaks
    ``0 * NaN == NaN`` into the row) so the row stays intact.  On finite
    rewards the guards are ``where(True, x, 0)``, exact no-ops."""
    ok = jnp.isfinite(reward)
    alpha = jnp.where(ok, alpha, 0.0)
    reward = jnp.where(ok, reward, 0.0)
    hot = jnp.arange(row.shape[-1], dtype=jnp.int32) == action
    return jnp.where(hot, (1.0 - alpha) * row + alpha * reward, row)


def decay_arrays(cfg: QConfig, step0, frozen, inc):
    """Per-step ``(eps_t, alpha_t)`` for an episode, precomputed outside the
    scan.

    ``inc`` is the (S,) int32 per-step counter increment the in-scan update
    would apply (``valid & ~frozen`` — zero on frozen agents and on stacked
    padding rows), so step ``i`` sees the counter value
    ``step0 + sum(inc[:i])`` — exactly the carried ``qs.step`` the unfused
    step reads.  Same float formula as :func:`schedule`, so the values are
    bitwise-identical to the in-scan ones."""
    inc = inc.astype(jnp.int32)
    step_t = step0 + jnp.cumsum(inc) - inc          # counter BEFORE step i
    frac = jnp.clip(1.0 - step_t.astype(jnp.float32) / cfg.decay_steps,
                    0.0, 1.0)
    eps_t = jnp.where(frozen, 0.0, cfg.epsilon0 * frac)
    alpha_t = jnp.where(frozen, 0.0, cfg.alpha0 * frac)
    return eps_t, alpha_t


def replay_visits(qs0: QState, qtable, state_idx, action, inc) -> QState:
    """Rebuild the post-episode :class:`QState` from the trained table plus
    the episode trace, reconstructing ``visits``/``step`` with one batched
    scatter-add.

    In-scan accumulation adds ``inc`` at ``(state_idx, action)`` every step;
    integer addition commutes, so a single
    ``visits.at[state_idx, action].add(inc)`` over the whole trace is
    bitwise-equal — and it takes the (S, A) visits table out of the scan
    carry entirely (the fused step carries only the Q-table)."""
    inc = inc.astype(jnp.int32)
    return QState(
        qtable=qtable,
        visits=qs0.visits.at[state_idx, action].add(inc),
        step=qs0.step + jnp.sum(inc),
        frozen=qs0.frozen,
    )


def update(qs: QState, cfg: QConfig, state_idx, action, reward,
           debug_finite: bool = False) -> QState:
    """Paper update: Q(s,a) <- (1-alpha) Q(s,a) + alpha R(s,a).

    Written as row gather -> one-hot blend -> row write-back rather than a
    ``.at[state_idx, action]`` scatter: XLA keeps a single-dynamic-index
    row update in place inside ``lax.scan``, while the two-dynamic-index
    scatter falls off the in-place path and dominates the whole training
    step (measured ~20x slower in the vectorized environment's scan).
    The arithmetic on the updated element is unchanged.

    A non-finite reward is dropped (:func:`row_update`'s guard): the table
    stays intact and only the visit/step counters advance.  With
    ``debug_finite=True`` the step additionally host-checks the incoming
    reward and the written row (:func:`debug_finite_check`) — a debugging
    aid, off by default so the hot path carries no callback."""
    _, alpha = schedule(cfg, qs.step)
    alpha = jnp.where(qs.frozen, 0.0, alpha)
    row = qs.qtable[state_idx]
    new_row = row_update(row, alpha, action, reward)
    if debug_finite:
        debug_finite_check("qlearn.update", reward=reward, qrow=new_row)
    hot = jnp.arange(row.shape[-1], dtype=jnp.int32) == action
    inc = jnp.where(qs.frozen, 0, 1).astype(jnp.int32)
    new_vrow = qs.visits[state_idx] + hot.astype(jnp.int32) * inc
    return QState(
        qtable=qs.qtable.at[state_idx].set(new_row),
        visits=qs.visits.at[state_idx].set(new_vrow),
        step=qs.step + inc,
        frozen=qs.frozen,
    )


def episode_step(
    qs: QState,
    cfg: QConfig,
    state_idx,
    key,
    reward_fn,
    action_mask=None,
):
    """One sense->select->act->evaluate->update cycle as a pure function.

    ``reward_fn(action) -> (reward, aux)`` is the environment half of the
    step (timing model + reward evaluation); everything nests under
    ``jit``/``lax.scan``/``vmap``.  A frozen ``qs`` makes the update a
    no-op, so the same step serves training and greedy evaluation.  This is
    the episode-step used by the vectorized environment (``soc.vecenv``).

    Returns ``(new_qs, (action, reward, aux))``.
    """
    action = select(qs, cfg, state_idx, key, action_mask)
    reward, aux = reward_fn(action)
    new_qs = update(qs, cfg, state_idx, action, reward)
    return new_qs, (action, reward, aux)


def episode_step_presampled(
    qs: QState,
    cfg: QConfig,
    state_idx,
    noise: SelectNoise,
    reward_fn,
    action_mask=None,
):
    """:func:`episode_step` with pre-sampled select noise (the variant the
    vectorized environment scans with — see :class:`SelectNoise`)."""
    action = select_presampled(qs, cfg, state_idx, noise, action_mask)
    reward, aux = reward_fn(action)
    new_qs = update(qs, cfg, state_idx, action, reward)
    return new_qs, (action, reward, aux)


def init_qstate_batch(cfg: QConfig, batch: int) -> QState:
    """``batch`` independent agents as one stacked QState pytree (vmap axis 0)."""
    return jax.vmap(lambda _: init_qstate(cfg))(jnp.arange(batch))


def freeze(qs: QState) -> QState:
    """Disable further updates (paper: evaluate the converged model)."""
    return qs._replace(frozen=jnp.ones((), bool))


def frozen_qstate(cfg: QConfig = QConfig()) -> QState:
    """A frozen, untrained table.

    Two distinct uses share this shape: the Random policy's lowering (an
    all-ties table under randomized argmax picks uniformly over available
    modes) and the inert placeholder agent a non-learned
    :class:`~repro.soc.vecenv.PolicySpec` carries — frozen means the
    unified episode's update is a bitwise no-op, so fixed/manual specs need
    no Q-branch of their own."""
    return freeze(init_qstate(cfg))


def greedy_policy(qs: QState) -> jnp.ndarray:
    """(S,) argmax table — the learned coherence-selection policy."""
    return jnp.argmax(qs.qtable, axis=-1).astype(jnp.int32)


def reopen_step(cfg: QConfig, step):
    """The decay-counter value that re-opens epsilon/alpha to
    ``cfg.reopen_frac`` of their initial values — never advancing the
    counter (a step already below the reopen point stays put).

    Shared by :func:`reward_watchdog` (reward-collapse rewind between
    training episodes) and the serving path's overload watchdog
    (``soc.vecenv.ServeEnv``: sustained queue-full pressure re-opens
    exploration in-stream, same arithmetic)."""
    return jnp.minimum(
        step,
        (jnp.asarray(cfg.decay_steps, jnp.float32)
         * (1.0 - cfg.reopen_frac)).astype(jnp.int32))


def reward_watchdog(cfg: QConfig, qs: QState, ep_reward, best):
    """Reward-collapse watchdog: re-open exploration when an episode's
    reward collapses relative to the best episode seen so far.

    ``ep_reward`` is the (masked-mean) reward of the episode just
    finished, ``best`` the running best (carry ``-inf`` initially).  When
    ``ep_reward < cfg.collapse_frac * best`` on a still-training agent,
    the decay counter is wound back to ``decay_steps * (1 -
    reopen_frac)`` so epsilon/alpha re-open to ``reopen_frac`` of their
    initial values — the fault-degraded SoC no longer matches the learned
    table, and a near-zero epsilon would lock the stale policy in.  The
    running best also resets to the collapsed value so a *persistently*
    degraded plateau doesn't re-trigger every episode.

    With ``cfg.collapse_frac == 0`` (the default) every lane of this is
    ``where(False, _, x)`` — the returned state is bitwise ``qs``, which
    keeps healthy training runs identical to the watchdog-free program.

    Returns ``(new_qs, new_best)``.
    """
    ep_reward = jnp.asarray(ep_reward, jnp.float32)
    enabled = jnp.asarray(cfg.collapse_frac, jnp.float32) > 0.0
    collapsed = (enabled & ~qs.frozen & (best > 0.0)
                 & (ep_reward < cfg.collapse_frac * best))
    reopened = reopen_step(cfg, qs.step)
    new_qs = qs._replace(step=jnp.where(collapsed, reopened, qs.step))
    new_best = jnp.where(collapsed, ep_reward, jnp.maximum(best, ep_reward))
    return new_qs, new_best


# ---------------------------------------------------------------------------
# debug_finite: host-side finiteness tripwires (off by default everywhere).
# ---------------------------------------------------------------------------
# Violations are also recorded here because an exception raised inside a
# jax.debug.callback only surfaces (as jaxlib's CpuCallback XlaRuntimeError)
# when the result is materialized — tests and post-mortems read the log for
# a deterministic account of WHAT went non-finite and WHERE.
_finite_violations: list[str] = []


def finite_violations() -> list[str]:
    """Snapshot of the recorded finiteness violations (newest last)."""
    return list(_finite_violations)


def clear_finite_violations() -> None:
    _finite_violations.clear()


def _host_assert_finite(tag: str, **arrays) -> None:
    bad = sorted(k for k, v in arrays.items()
                 if not np.all(np.isfinite(np.asarray(v, np.float64))))
    if bad:
        msg = f"{tag}: non-finite {', '.join(bad)}"
        _finite_violations.append(msg)
        raise FloatingPointError(msg)


def debug_finite_check(tag: str, **arrays) -> None:
    """Insert a host callback asserting every named array is finite.

    Works under jit/vmap/scan via ``jax.debug.callback``; a violation is
    appended to :func:`finite_violations` and raised as
    ``FloatingPointError`` (surfacing as an ``XlaRuntimeError`` at the
    blocking site when traced).  Do not call on hot paths — that is why
    every ``debug_finite=`` flag defaults to False."""
    jax.debug.callback(functools.partial(_host_assert_finite, tag), **arrays)
