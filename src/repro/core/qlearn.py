"""Tabular Q-learning agent (paper §4.2).

Faithful to the paper's formulation:

  * Q-table of |S| x |A| = 243 x 4 = 972 entries, zero-initialized.
  * epsilon-greedy action selection (explore with prob. epsilon, otherwise
    argmax over the Q-row for the sensed state).
  * Update rule ``Q(s,a) <- (1-alpha) Q(s,a) + alpha R(s,a)`` — note the
    paper uses the immediate multi-objective reward with no bootstrapped
    ``max_a' Q(s',a')`` term (a contextual-bandit-style update), which we
    keep exactly.
  * epsilon (init 0.5) and alpha (init 0.25) decay **linearly to zero** over
    a configured number of training iterations (paper §5 Experimental
    Setup); after convergence updates are disabled and the greedy policy is
    evaluated.

Everything is a pure function over a :class:`QState` pytree, so training can
run under ``jit``/``lax.scan`` and thousands of agents can be trained in
parallel with ``vmap`` (used by the Fig. 6 reward-DSE benchmark).

Action masking: per the paper, "COHMELEON does not necessarily require
support for all four coherence modes; it makes the selection based on the
options that are available" — ``select`` takes an ``action_mask``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modes import N_MODES
from repro.core.state import N_STATES

# numpy so it inlines as a literal under Pallas tracing
_NEG = np.float32(-3.4e38)


class QConfig(NamedTuple):
    n_states: int = N_STATES
    n_actions: int = N_MODES
    epsilon0: float = 0.5     # paper initialization
    alpha0: float = 0.25      # paper initialization
    decay_steps: int = 3000   # invocations until eps/alpha hit zero
    # Beyond-paper robustness fix (EXPERIMENTS.md §Paper-validation): the
    # paper zero-initializes Q; with a noisy multi-objective reward an
    # epsilon-greedy agent can freeze a bad arm off 2-3 early samples
    # (alpha decays) and self-reinforce.  Optimistic init at the reward
    # upper bound makes every arm get pulled while alpha is still large.
    # An untrained table is all-ties -> uniform random, preserving the
    # paper's "iteration 0 == Random policy" property (Fig. 8).
    q_init: float = 1.0


class QState(NamedTuple):
    qtable: jnp.ndarray   # (S, A) float32
    visits: jnp.ndarray   # (S, A) int32 — diagnostics / breakdown plots
    step: jnp.ndarray     # () int32, training invocations so far
    frozen: jnp.ndarray   # () bool — True once training is disabled


def init_qstate(cfg: QConfig = QConfig()) -> QState:
    return QState(
        qtable=jnp.full((cfg.n_states, cfg.n_actions), cfg.q_init,
                        jnp.float32),
        visits=jnp.zeros((cfg.n_states, cfg.n_actions), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        frozen=jnp.zeros((), bool),
    )


def schedule(cfg: QConfig, step):
    """Linearly decayed (epsilon, alpha) at ``step``."""
    frac = jnp.clip(1.0 - step.astype(jnp.float32) / cfg.decay_steps, 0.0, 1.0)
    return cfg.epsilon0 * frac, cfg.alpha0 * frac


def select(
    qs: QState,
    cfg: QConfig,
    state_idx,
    key,
    action_mask=None,
):
    """Epsilon-greedy action for ``state_idx``. Returns int32 action."""
    if action_mask is None:
        action_mask = jnp.ones((cfg.n_actions,), bool)
    eps, _ = schedule(cfg, qs.step)
    eps = jnp.where(qs.frozen, 0.0, eps)

    k_explore, k_pick, k_tie = jax.random.split(key, 3)
    row = jnp.where(action_mask, qs.qtable[state_idx], _NEG)
    # Randomized argmax: ties (e.g. the all-zero row of an unvisited
    # state) break uniformly, so an untrained table == the Random policy
    # (paper Fig. 8, "iteration 0") instead of defaulting to action 0.
    is_max = row >= jnp.max(row) - 1e-9
    tie_logits = jnp.where(is_max & action_mask, 0.0, _NEG)
    greedy = jax.random.categorical(k_tie, tie_logits).astype(jnp.int32)

    logits = jnp.where(action_mask, 0.0, _NEG)
    random_action = jax.random.categorical(k_pick, logits).astype(jnp.int32)

    explore = jax.random.uniform(k_explore) < eps
    return jnp.where(explore, random_action, greedy)


class SelectNoise(NamedTuple):
    """Pre-sampled randomness for :func:`select_presampled`.

    ``select`` draws three independent variates per call (explore uniform,
    random-action gumbels, tie-break gumbels).  Inside a ``lax.scan`` the
    per-step ``split`` + ``categorical`` threefry calls dominate the step
    cost; pre-sampling the whole episode's noise in one batched call
    (:func:`sample_select_noise`) and feeding rows through the scan xs
    keeps the per-step work at two argmaxes and a compare."""

    u_explore: jnp.ndarray   # (..., ) uniform [0, 1)
    g_pick: jnp.ndarray      # (..., A) gumbel — uniform-random action draw
    g_tie: jnp.ndarray       # (..., A) gumbel — randomized-argmax tie-break


def sample_select_noise(key, shape_prefix: tuple,
                        n_actions: int = N_MODES) -> SelectNoise:
    """One batched threefry call's worth of select noise for ``shape_prefix``
    steps (e.g. ``(S,)`` for an episode of S invocations)."""
    k_explore, k_pick, k_tie = jax.random.split(key, 3)
    return SelectNoise(
        u_explore=jax.random.uniform(k_explore, shape_prefix),
        g_pick=jax.random.gumbel(k_pick, (*shape_prefix, n_actions)),
        g_tie=jax.random.gumbel(k_tie, (*shape_prefix, n_actions)),
    )


def select_presampled(
    qs: QState,
    cfg: QConfig,
    state_idx,
    noise: SelectNoise,
    action_mask=None,
):
    """:func:`select` with the randomness supplied as one :class:`SelectNoise`
    row.  Identical distribution — ``categorical(key, logits)`` is
    ``argmax(logits + gumbel)``, which is what this computes — but with no
    per-call threefry, so it is the hot-path variant used inside the
    vectorized environment's scan step."""
    if action_mask is None:
        action_mask = jnp.ones((cfg.n_actions,), bool)
    eps, _ = schedule(cfg, qs.step)
    eps = jnp.where(qs.frozen, 0.0, eps)

    row = jnp.where(action_mask, qs.qtable[state_idx], _NEG)
    is_max = row >= jnp.max(row) - 1e-9
    tie_logits = jnp.where(is_max & action_mask, 0.0, _NEG)
    greedy = jnp.argmax(tie_logits + noise.g_tie, axis=-1).astype(jnp.int32)

    logits = jnp.where(action_mask, 0.0, _NEG)
    random_action = jnp.argmax(logits + noise.g_pick,
                               axis=-1).astype(jnp.int32)

    explore = noise.u_explore < eps
    return jnp.where(explore, random_action, greedy)


def row_select_presampled(row, eps, noise: SelectNoise, action_mask):
    """:func:`select_presampled` on a pre-gathered Q-row with a precomputed
    epsilon.

    The fused episode step gathers ``qtable[state_idx]`` once and feeds the
    same row to selection and to :func:`row_update`, and precomputes the
    whole episode's (epsilon, alpha) decay outside the scan
    (:func:`decay_arrays`) — this is the selection half.  Identical floats
    to ``select_presampled`` (same masked row, same gumbel argmaxes)."""
    mrow = jnp.where(action_mask, row, _NEG)
    is_max = mrow >= jnp.max(mrow) - 1e-9
    tie_logits = jnp.where(is_max & action_mask, 0.0, _NEG)
    greedy = jnp.argmax(tie_logits + noise.g_tie, axis=-1).astype(jnp.int32)
    logits = jnp.where(action_mask, 0.0, _NEG)
    random_action = jnp.argmax(logits + noise.g_pick,
                               axis=-1).astype(jnp.int32)
    return jnp.where(noise.u_explore < eps, random_action, greedy)


def row_update(row, alpha, action, reward):
    """The paper update on a pre-gathered Q-row: the blended row to write
    back with ``qtable.at[state_idx].set``.  ``alpha == 0`` (frozen, or a
    decayed-to-zero schedule) leaves the row bitwise unchanged."""
    hot = jnp.arange(row.shape[-1], dtype=jnp.int32) == action
    return jnp.where(hot, (1.0 - alpha) * row + alpha * reward, row)


def decay_arrays(cfg: QConfig, step0, frozen, inc):
    """Per-step ``(eps_t, alpha_t)`` for an episode, precomputed outside the
    scan.

    ``inc`` is the (S,) int32 per-step counter increment the in-scan update
    would apply (``valid & ~frozen`` — zero on frozen agents and on stacked
    padding rows), so step ``i`` sees the counter value
    ``step0 + sum(inc[:i])`` — exactly the carried ``qs.step`` the unfused
    step reads.  Same float formula as :func:`schedule`, so the values are
    bitwise-identical to the in-scan ones."""
    inc = inc.astype(jnp.int32)
    step_t = step0 + jnp.cumsum(inc) - inc          # counter BEFORE step i
    frac = jnp.clip(1.0 - step_t.astype(jnp.float32) / cfg.decay_steps,
                    0.0, 1.0)
    eps_t = jnp.where(frozen, 0.0, cfg.epsilon0 * frac)
    alpha_t = jnp.where(frozen, 0.0, cfg.alpha0 * frac)
    return eps_t, alpha_t


def replay_visits(qs0: QState, qtable, state_idx, action, inc) -> QState:
    """Rebuild the post-episode :class:`QState` from the trained table plus
    the episode trace, reconstructing ``visits``/``step`` with one batched
    scatter-add.

    In-scan accumulation adds ``inc`` at ``(state_idx, action)`` every step;
    integer addition commutes, so a single
    ``visits.at[state_idx, action].add(inc)`` over the whole trace is
    bitwise-equal — and it takes the (S, A) visits table out of the scan
    carry entirely (the fused step carries only the Q-table)."""
    inc = inc.astype(jnp.int32)
    return QState(
        qtable=qtable,
        visits=qs0.visits.at[state_idx, action].add(inc),
        step=qs0.step + jnp.sum(inc),
        frozen=qs0.frozen,
    )


def update(qs: QState, cfg: QConfig, state_idx, action, reward) -> QState:
    """Paper update: Q(s,a) <- (1-alpha) Q(s,a) + alpha R(s,a).

    Written as row gather -> one-hot blend -> row write-back rather than a
    ``.at[state_idx, action]`` scatter: XLA keeps a single-dynamic-index
    row update in place inside ``lax.scan``, while the two-dynamic-index
    scatter falls off the in-place path and dominates the whole training
    step (measured ~20x slower in the vectorized environment's scan).
    The arithmetic on the updated element is unchanged."""
    _, alpha = schedule(cfg, qs.step)
    alpha = jnp.where(qs.frozen, 0.0, alpha)
    row = qs.qtable[state_idx]
    hot = jnp.arange(row.shape[-1], dtype=jnp.int32) == action
    new_row = jnp.where(hot, (1.0 - alpha) * row + alpha * reward, row)
    inc = jnp.where(qs.frozen, 0, 1).astype(jnp.int32)
    new_vrow = qs.visits[state_idx] + hot.astype(jnp.int32) * inc
    return QState(
        qtable=qs.qtable.at[state_idx].set(new_row),
        visits=qs.visits.at[state_idx].set(new_vrow),
        step=qs.step + inc,
        frozen=qs.frozen,
    )


def episode_step(
    qs: QState,
    cfg: QConfig,
    state_idx,
    key,
    reward_fn,
    action_mask=None,
):
    """One sense->select->act->evaluate->update cycle as a pure function.

    ``reward_fn(action) -> (reward, aux)`` is the environment half of the
    step (timing model + reward evaluation); everything nests under
    ``jit``/``lax.scan``/``vmap``.  A frozen ``qs`` makes the update a
    no-op, so the same step serves training and greedy evaluation.  This is
    the episode-step used by the vectorized environment (``soc.vecenv``).

    Returns ``(new_qs, (action, reward, aux))``.
    """
    action = select(qs, cfg, state_idx, key, action_mask)
    reward, aux = reward_fn(action)
    new_qs = update(qs, cfg, state_idx, action, reward)
    return new_qs, (action, reward, aux)


def episode_step_presampled(
    qs: QState,
    cfg: QConfig,
    state_idx,
    noise: SelectNoise,
    reward_fn,
    action_mask=None,
):
    """:func:`episode_step` with pre-sampled select noise (the variant the
    vectorized environment scans with — see :class:`SelectNoise`)."""
    action = select_presampled(qs, cfg, state_idx, noise, action_mask)
    reward, aux = reward_fn(action)
    new_qs = update(qs, cfg, state_idx, action, reward)
    return new_qs, (action, reward, aux)


def init_qstate_batch(cfg: QConfig, batch: int) -> QState:
    """``batch`` independent agents as one stacked QState pytree (vmap axis 0)."""
    return jax.vmap(lambda _: init_qstate(cfg))(jnp.arange(batch))


def freeze(qs: QState) -> QState:
    """Disable further updates (paper: evaluate the converged model)."""
    return qs._replace(frozen=jnp.ones((), bool))


def frozen_qstate(cfg: QConfig = QConfig()) -> QState:
    """A frozen, untrained table.

    Two distinct uses share this shape: the Random policy's lowering (an
    all-ties table under randomized argmax picks uniformly over available
    modes) and the inert placeholder agent a non-learned
    :class:`~repro.soc.vecenv.PolicySpec` carries — frozen means the
    unified episode's update is a bitwise no-op, so fixed/manual specs need
    no Q-branch of their own."""
    return freeze(init_qstate(cfg))


def greedy_policy(qs: QState) -> jnp.ndarray:
    """(S,) argmax table — the learned coherence-selection policy."""
    return jnp.argmax(qs.qtable, axis=-1).astype(jnp.int32)
