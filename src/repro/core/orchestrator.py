"""Runtime orchestration glue + experiment drivers (paper §4.1, §5, §6).

``sense -> decide -> actuate -> evaluate`` is implemented inside the
simulators' invocation paths (soc.des is the fidelity path, soc.vecenv the
scale path); this module provides the experiment-level drivers used by
benchmarks and tests:

  * profiling-based Fixed-Heterogeneous assignment (design-time baseline),
  * Cohmeleon online training — serial DES (:func:`train_cohmeleon`) and
    vmap-parallel batched over (reward weights x seeds)
    (:func:`train_cohmeleon_batched`), per the paper's Experimental Setup,
  * policy comparison harness producing per-phase metrics normalized to
    Fixed non-coherent DMA (the paper's normalization), routable through
    either simulation backend.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlearn, rewards
from repro.core.modes import CoherenceMode, MODE_NAMES, N_MODES
from repro.core.policies import (FixedHeterogeneous, FixedHomogeneous,
                                 ManualPolicy, Policy, QPolicy, RandomPolicy)
from repro.core.rewards import RewardWeights
from repro.soc import vecenv as vec
from repro.soc.apps import make_application
from repro.soc.config import (SoCConfig, WORKLOAD_LARGE, WORKLOAD_MEDIUM,
                              WORKLOAD_SMALL)
from repro.soc.des import (Application, Invocation, InvocationRecord, Phase,
                           PhaseResult, RunResult, SoCSimulator, Thread)


def _isolated_app(acc_id: int, footprint: float) -> Application:
    return Application(
        name="isolated",
        phases=[Phase(name="only",
                      threads=[Thread(chain=[Invocation(acc_id, footprint)])])])


def _vecenv_for(sim: SoCSimulator, env: vec.VecEnv | None = None
                ) -> vec.VecEnv:
    """The simulator's memoized scale-path twin (shared jit caches across
    compare_policies / profiling / batched training on the same sim)."""
    if env is not None:
        return env
    env = getattr(sim, "_vecenv", None)
    if env is None:
        env = vec.VecEnv.from_simulator(sim)
        sim._vecenv = env
    return env


def run_isolated(sim: SoCSimulator, acc_id: int, mode: CoherenceMode,
                 footprint: float, seed: int = 0) -> RunResult:
    """One accelerator alone, one invocation (paper Fig. 2 cell)."""
    return sim.run(_isolated_app(acc_id, footprint), FixedHomogeneous(mode),
                   seed=seed, train=False)


def profile_fixed_heterogeneous(
    sim: SoCSimulator,
    footprints: Sequence[float] = (WORKLOAD_SMALL, WORKLOAD_MEDIUM,
                                   WORKLOAD_LARGE),
    seed: int = 0,
    backend: str = "des",
    env: vec.VecEnv | None = None,
) -> FixedHeterogeneous:
    """Design-time per-accelerator profiling (paper §4.3 Decide).

    Sweeps each accelerator in isolation over workload footprints in every
    mode and assigns the mode with the best mean normalized execution time —
    the stand-in for prior design-time approaches.  ``backend='vecenv'``
    times the same single-invocation applications through the jitted
    environment (identical results — single-thread apps are exact across
    paths — at a fraction of the host cost)."""
    if backend == "vecenv":
        env = _vecenv_for(sim, env)
        compiled_cache: dict = {}    # compilation is mode-independent

        def total_time(acc_id, mode, fp):
            if (acc_id, fp) not in compiled_cache:
                compiled_cache[acc_id, fp] = vec.compile_app(
                    _isolated_app(acc_id, fp), sim.soc, seed=seed)
            _, res = env.episode(compiled_cache[acc_id, fp], policy="fixed",
                                 fixed_modes=int(mode))
            return float(res.total_time)
    elif backend == "des":
        def total_time(acc_id, mode, fp):
            return run_isolated(sim, acc_id, mode, fp, seed=seed).total_time
    else:
        raise ValueError(f"unknown backend {backend!r}")

    assignment = {}
    for acc_id, prof in enumerate(sim.profiles):
        if prof.name in assignment:
            continue
        # One NON_COH_DMA baseline per footprint, shared by every mode's
        # normalization (it does not depend on the mode under test).
        base_times = [
            total_time(acc_id, CoherenceMode.NON_COH_DMA, fp)
            for fp in footprints
        ]
        scores = np.zeros(N_MODES)
        for mode in CoherenceMode:
            if not sim.masks[acc_id][mode]:
                scores[mode] = np.inf
                continue
            times = [
                total_time(acc_id, mode, fp) / max(base, 1e-30)
                for fp, base in zip(footprints, base_times)
            ]
            scores[mode] = float(np.mean(times))
        assignment[prof.name] = CoherenceMode(int(np.argmin(scores)))
    return FixedHeterogeneous(assignment)


@dataclasses.dataclass
class TrainHistory:
    iteration: list[int]
    exec_time: list[float]
    offchip: list[float]


def train_cohmeleon(
    sim: SoCSimulator,
    iterations: int = 10,
    seed: int = 0,
    weights: RewardWeights | None = None,
    eval_each_iteration: bool = False,
    n_phases: int = 8,
) -> tuple[QPolicy, TrainHistory]:
    """Online training per the paper's Experimental Setup.

    Train on a randomly-configured application instance; epsilon/alpha decay
    linearly to zero over the configured number of iterations.  Optionally
    evaluate (frozen) after every iteration on a *different* instance
    (Fig. 8 protocol).
    """
    train_app = make_application(sim.soc, seed=seed, n_phases=n_phases)
    test_app = make_application(sim.soc, seed=seed + 1000, n_phases=n_phases)
    invocations_per_iter = sum(
        len(th.chain) * th.loops for ph in train_app.phases
        for th in ph.threads)
    cfg = qlearn.QConfig(decay_steps=max(invocations_per_iter * iterations, 1))
    policy = QPolicy(cfg, seed=seed)

    hist = TrainHistory(iteration=[], exec_time=[], offchip=[])
    base = None
    for it in range(iterations):
        sim.run(train_app, policy, seed=seed + it, train=True,
                weights=weights)
        if eval_each_iteration:
            if base is None:
                base = sim.run(test_app, FixedHomogeneous(
                    CoherenceMode.NON_COH_DMA), seed=77, train=False)
            frozen = QPolicy(cfg, seed=123)
            frozen.qs = qlearn.freeze(policy.qs)
            res = sim.run(test_app, frozen, seed=77, train=False)
            hist.iteration.append(it + 1)
            hist.exec_time.append(_geomean_ratio(res, base, "time"))
            hist.offchip.append(_geomean_ratio(res, base, "mem"))
    policy.freeze()
    return policy, hist


def _geomean_ratio(res: RunResult, base: RunResult, what: str) -> float:
    vals = []
    for p, b in zip(res.phases, base.phases):
        if what == "time":
            vals.append(p.wall_time / max(b.wall_time, 1e-30))
        else:
            vals.append((p.offchip_accesses + 1.0)
                        / max(b.offchip_accesses + 1.0, 1e-30))
    return float(np.exp(np.mean(np.log(np.maximum(vals, 1e-12)))))


@dataclasses.dataclass
class BatchedTrainResult:
    """Output of one vmap-parallel training call over B = |weights| x seeds
    agents.  ``qstates`` is a single QState pytree whose leaves carry the
    batch axis; agent ``i`` trained with ``weights[i // n_seeds]``."""

    env: vec.VecEnv
    cfg: qlearn.QConfig
    qstates: qlearn.QState
    weights: list[RewardWeights]
    n_seeds: int
    hist_time: np.ndarray | None    # (B, iterations) or None
    hist_mem: np.ndarray | None
    train_app: Application
    test_app: Application

    @property
    def n_agents(self) -> int:
        return len(self.weights) * self.n_seeds

    def qpolicy(self, i: int) -> QPolicy:
        """Agent ``i`` as a frozen QPolicy (drops into the DES for
        cross-backend checks and Fig. 7 mode-breakdown plots)."""
        pol = QPolicy(self.cfg, seed=i)
        pol.qs = qlearn.freeze(
            jax.tree_util.tree_map(lambda x: x[i], self.qstates))
        return pol

    def evaluate(self, app: Application | None = None, seed: int = 5,
                 key_seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
        """Frozen-greedy batched evaluation on ``app`` (default: the held-out
        test instance); returns (norm_time, norm_mem) of shape (B,)."""
        compiled = vec.compile_app(app or self.test_app, self.env.soc,
                                   seed=seed)
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.arange(self.n_agents) + key_seed)
        nt, nm = self.env.evaluate_batched(compiled, self.qstates, self.cfg,
                                           keys)
        return np.asarray(nt), np.asarray(nm)

    def per_weight(self, values: np.ndarray) -> np.ndarray:
        """Reduce a (B,) metric to (|weights|,) by averaging over seeds."""
        return np.asarray(values).reshape(len(self.weights),
                                          self.n_seeds).mean(axis=1)


def train_cohmeleon_batched(
    soc: SoCConfig | SoCSimulator,
    iterations: int = 10,
    seed: int = 0,
    weights: Sequence | None = None,
    n_seeds: int = 1,
    n_phases: int = 8,
    eval_each_iteration: bool = False,
    env: vec.VecEnv | None = None,
) -> BatchedTrainResult:
    """The scale-path counterpart of :func:`train_cohmeleon`.

    Same experimental protocol — train on one randomly-configured instance,
    per-iteration tile seeds, evaluate frozen on a different instance — but
    every (reward weighting x agent seed) pair trains in parallel inside a
    single jitted ``vmap(scan(...))`` call.  This is what makes the Fig. 6
    reward-DSE (15 weightings) and Fig. 8 curves one batched call instead of
    N sequential DES runs.
    """
    if isinstance(soc, SoCSimulator):
        env = _vecenv_for(soc, env)
        soc = soc.soc
    else:
        env = env or vec.VecEnv(soc)
    train_app = make_application(soc, seed=seed, n_phases=n_phases)
    test_app = make_application(soc, seed=seed + 1000, n_phases=n_phases)
    train_compiled = [
        vec.compile_app(train_app, soc, seed=seed + it)
        for it in range(iterations)
    ]
    test_compiled = vec.compile_app(test_app, soc, seed=77)
    cfg = qlearn.QConfig(
        decay_steps=max(train_compiled[0].n_steps * iterations, 1))

    wlist = [rewards.as_weights(w) for w in
             (weights if weights is not None
              else [rewards.PAPER_DEFAULT_WEIGHTS])]
    grid = [(w, s) for w in wlist for s in range(n_seeds)]
    wb = rewards.stack_weights([w for w, _ in grid])
    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(
        [seed + 100003 * s for _, s in grid], jnp.uint32))

    qs, hist = env.train_batched(
        train_compiled, cfg, wb, keys,
        eval_app=test_compiled if eval_each_iteration else None)
    ht, hm = ((np.asarray(hist[0]), np.asarray(hist[1]))
              if eval_each_iteration else (None, None))
    return BatchedTrainResult(
        env=env, cfg=cfg, qstates=qs, weights=wlist, n_seeds=n_seeds,
        hist_time=ht, hist_mem=hm, train_app=train_app, test_app=test_app)


@dataclasses.dataclass
class Comparison:
    """Per-policy, per-phase metrics normalized to fixed non-coherent DMA."""

    policies: list[str]
    norm_time: dict[str, list[float]]
    norm_mem: dict[str, list[float]]
    raw: dict[str, RunResult]

    def geomean(self, policy: str) -> tuple[float, float]:
        t = np.exp(np.mean(np.log(np.maximum(self.norm_time[policy], 1e-12))))
        m = np.exp(np.mean(np.log(np.maximum(self.norm_mem[policy], 1e-12))))
        return float(t), float(m)


def episode_to_runresult(env: vec.VecEnv, compiled: vec.CompiledApp,
                         res: vec.EpisodeResult, policy_name: str
                         ) -> RunResult:
    """Lift a vecenv episode trace into the DES's RunResult shape so every
    downstream consumer (mode_breakdown, benchmark reports) works unchanged.
    Decide overhead is 0: vecenv decisions happen inside the jitted step."""
    acc_id = np.asarray(compiled.schedule.acc_id)
    footprint = np.asarray(compiled.schedule.footprint)
    thread = np.asarray(compiled.schedule.thread)
    phase_id = np.asarray(compiled.schedule.phase_id)
    mode = np.asarray(res.mode)
    state_idx = np.asarray(res.state_idx)
    exec_c = np.asarray(res.exec_time, np.float64)
    off = np.asarray(res.offchip, np.float64)
    rew = np.asarray(res.reward, np.float64)
    phase_time = np.asarray(res.phase_time, np.float64)
    phase_off = np.asarray(res.phase_offchip, np.float64)

    cursor = np.zeros((compiled.n_phases, compiled.n_threads))
    phases: list[PhaseResult] = [
        PhaseResult(name=compiled.phase_names[p], wall_time=phase_time[p],
                    offchip_accesses=phase_off[p], invocations=[])
        for p in range(compiled.n_phases)
    ]
    for i in range(len(acc_id)):
        p, t = int(phase_id[i]), int(thread[i])
        start = cursor[p, t]
        end = start + exec_c[i] * env.cycle_time
        cursor[p, t] = end
        phases[p].invocations.append(InvocationRecord(
            acc_id=int(acc_id[i]),
            acc_name=env.profiles[int(acc_id[i])].name,
            footprint=float(footprint[i]), mode=int(mode[i]),
            state_idx=int(state_idx[i]), start=start, end=end,
            exec_time=float(exec_c[i]), offchip_true=float(off[i]),
            offchip_attr=float(off[i]), reward=float(rew[i])))
    return RunResult(policy=policy_name, phases=phases,
                     decide_overhead_s=0.0)


def compare_policies(sim: SoCSimulator, app: Application,
                     policies: Sequence[Policy], seed: int = 0,
                     backend: str = "des",
                     env: vec.VecEnv | None = None) -> Comparison:
    """Run each policy on ``app`` and normalize per phase to NON_COH fixed.

    ``backend='des'`` replays through the event-driven simulator (fidelity
    path), one policy at a time.  ``backend='vecenv'`` lowers every policy
    (``Policy.lower``) into a :class:`~repro.soc.vecenv.PolicySpec`,
    stacks the specs — heterogeneous families included — and replays the
    WHOLE suite plus the NON_COH baseline as ONE jitted batched call;
    same Comparison shape either way.  The VecEnv is memoized on the
    simulator so repeated comparisons reuse its compiled episode
    functions; pass ``env`` to share an external one.
    """
    base_policy = FixedHomogeneous(CoherenceMode.NON_COH_DMA)
    all_pols = [base_policy] + list(policies)
    if backend == "des":
        runs = [sim.run(app, pol, seed=seed, train=False)
                for pol in all_pols]
    elif backend == "vecenv":
        env = _vecenv_for(sim, env)
        compiled = vec.compile_app(app, sim.soc, seed=seed)
        specs = vec.stack_specs([pol.lower(env, compiled)
                                 for pol in all_pols])
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.arange(len(all_pols)) + seed)
        res = env.episodes(compiled, specs, keys=keys)
        runs = [episode_to_runresult(
                    env, compiled,
                    jax.tree_util.tree_map(lambda x, i=i: x[i], res),
                    pol.name)
                for i, pol in enumerate(all_pols)]
    else:
        raise ValueError(f"unknown backend {backend!r}")

    base = runs[0]
    out = Comparison(policies=[], norm_time={}, norm_mem={}, raw={})
    out.raw[base_policy.name] = base
    for pol, res in zip(policies, runs[1:]):
        nt, nm = [], []
        for p, b in zip(res.phases, base.phases):
            nt.append(p.wall_time / max(b.wall_time, 1e-30))
            nm.append((p.offchip_accesses + 1.0)
                      / max(b.offchip_accesses + 1.0, 1e-30))
        out.policies.append(pol.name)
        out.norm_time[pol.name] = nt
        out.norm_mem[pol.name] = nm
        out.raw[pol.name] = res
    return out


def standard_policy_suite(sim: SoCSimulator,
                          include_profiled: bool = True,
                          backend: str = "des") -> list[Policy]:
    """The paper's comparison set: 4 fixed-homogeneous + heterogeneous +
    random + manual (Cohmeleon is trained separately).  ``backend``
    selects the simulation path for the design-time profiling sweep."""
    suite: list[Policy] = [FixedHomogeneous(m) for m in CoherenceMode]
    if include_profiled:
        suite.append(profile_fixed_heterogeneous(sim, backend=backend))
    suite.append(RandomPolicy())
    suite.append(ManualPolicy())
    return suite


def mode_breakdown(res: RunResult, soc) -> dict[str, np.ndarray]:
    """Fraction of invocations per mode, total and per size class (Fig. 7)."""
    def size_class(fp: float) -> str:
        if fp <= soc.l2_bytes:
            return "S"
        if fp <= soc.llc_slice_bytes:
            return "M"
        if fp <= soc.llc_total_bytes:
            return "L"
        return "XL"

    buckets: dict[str, np.ndarray] = {
        k: np.zeros(N_MODES) for k in ("total", "S", "M", "L", "XL")}
    for ph in res.phases:
        for r in ph.invocations:
            buckets["total"][r.mode] += 1
            buckets[size_class(r.footprint)][r.mode] += 1
    for k, v in buckets.items():
        s = v.sum()
        if s > 0:
            buckets[k] = v / s
    return buckets
