"""Runtime orchestration glue + experiment drivers (paper §4.1, §5, §6).

``sense -> decide -> actuate -> evaluate`` is implemented inside the
simulator's invocation path (soc.des); this module provides the
experiment-level drivers used by benchmarks and tests:

  * profiling-based Fixed-Heterogeneous assignment (design-time baseline),
  * Cohmeleon online training (train on one application instance, test on
    another, per the paper's Experimental Setup),
  * policy comparison harness producing per-phase metrics normalized to
    Fixed non-coherent DMA (the paper's normalization).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import qlearn
from repro.core.modes import CoherenceMode, MODE_NAMES, N_MODES
from repro.core.policies import (FixedHeterogeneous, FixedHomogeneous,
                                 ManualPolicy, Policy, QPolicy, RandomPolicy)
from repro.core.rewards import RewardWeights
from repro.soc.apps import make_application
from repro.soc.config import (WORKLOAD_LARGE, WORKLOAD_MEDIUM, WORKLOAD_SMALL)
from repro.soc.des import (Application, Invocation, Phase, RunResult,
                           SoCSimulator, Thread)


def run_isolated(sim: SoCSimulator, acc_id: int, mode: CoherenceMode,
                 footprint: float, seed: int = 0) -> RunResult:
    """One accelerator alone, one invocation (paper Fig. 2 cell)."""
    app = Application(
        name="isolated",
        phases=[Phase(name="only",
                      threads=[Thread(chain=[Invocation(acc_id, footprint)])])])
    return sim.run(app, FixedHomogeneous(mode), seed=seed, train=False)


def profile_fixed_heterogeneous(
    sim: SoCSimulator,
    footprints: Sequence[float] = (WORKLOAD_SMALL, WORKLOAD_MEDIUM,
                                   WORKLOAD_LARGE),
    seed: int = 0,
) -> FixedHeterogeneous:
    """Design-time per-accelerator profiling (paper §4.3 Decide).

    Sweeps each accelerator in isolation over workload footprints in every
    mode and assigns the mode with the best mean normalized execution time —
    the stand-in for prior design-time approaches.
    """
    assignment = {}
    for acc_id, prof in enumerate(sim.profiles):
        if prof.name in assignment:
            continue
        scores = np.zeros(N_MODES)
        for mode in CoherenceMode:
            if not sim.masks[acc_id][mode]:
                scores[mode] = np.inf
                continue
            times = []
            for fp in footprints:
                res = run_isolated(sim, acc_id, mode, fp, seed=seed)
                base = run_isolated(sim, acc_id, CoherenceMode.NON_COH_DMA,
                                    fp, seed=seed)
                times.append(res.total_time / max(base.total_time, 1e-30))
            scores[mode] = float(np.mean(times))
        assignment[prof.name] = CoherenceMode(int(np.argmin(scores)))
    return FixedHeterogeneous(assignment)


@dataclasses.dataclass
class TrainHistory:
    iteration: list[int]
    exec_time: list[float]
    offchip: list[float]


def train_cohmeleon(
    sim: SoCSimulator,
    iterations: int = 10,
    seed: int = 0,
    weights: RewardWeights | None = None,
    eval_each_iteration: bool = False,
    n_phases: int = 8,
) -> tuple[QPolicy, TrainHistory]:
    """Online training per the paper's Experimental Setup.

    Train on a randomly-configured application instance; epsilon/alpha decay
    linearly to zero over the configured number of iterations.  Optionally
    evaluate (frozen) after every iteration on a *different* instance
    (Fig. 8 protocol).
    """
    train_app = make_application(sim.soc, seed=seed, n_phases=n_phases)
    test_app = make_application(sim.soc, seed=seed + 1000, n_phases=n_phases)
    invocations_per_iter = sum(
        len(th.chain) * th.loops for ph in train_app.phases
        for th in ph.threads)
    cfg = qlearn.QConfig(decay_steps=max(invocations_per_iter * iterations, 1))
    policy = QPolicy(cfg, seed=seed)

    hist = TrainHistory(iteration=[], exec_time=[], offchip=[])
    base = None
    for it in range(iterations):
        sim.run(train_app, policy, seed=seed + it, train=True,
                weights=weights)
        if eval_each_iteration:
            if base is None:
                base = sim.run(test_app, FixedHomogeneous(
                    CoherenceMode.NON_COH_DMA), seed=77, train=False)
            frozen = QPolicy(cfg, seed=123)
            frozen.qs = qlearn.freeze(policy.qs)
            res = sim.run(test_app, frozen, seed=77, train=False)
            hist.iteration.append(it + 1)
            hist.exec_time.append(_geomean_ratio(res, base, "time"))
            hist.offchip.append(_geomean_ratio(res, base, "mem"))
    policy.freeze()
    return policy, hist


def _geomean_ratio(res: RunResult, base: RunResult, what: str) -> float:
    vals = []
    for p, b in zip(res.phases, base.phases):
        if what == "time":
            vals.append(p.wall_time / max(b.wall_time, 1e-30))
        else:
            vals.append((p.offchip_accesses + 1.0)
                        / max(b.offchip_accesses + 1.0, 1e-30))
    return float(np.exp(np.mean(np.log(np.maximum(vals, 1e-12)))))


@dataclasses.dataclass
class Comparison:
    """Per-policy, per-phase metrics normalized to fixed non-coherent DMA."""

    policies: list[str]
    norm_time: dict[str, list[float]]
    norm_mem: dict[str, list[float]]
    raw: dict[str, RunResult]

    def geomean(self, policy: str) -> tuple[float, float]:
        t = np.exp(np.mean(np.log(np.maximum(self.norm_time[policy], 1e-12))))
        m = np.exp(np.mean(np.log(np.maximum(self.norm_mem[policy], 1e-12))))
        return float(t), float(m)


def compare_policies(sim: SoCSimulator, app: Application,
                     policies: Sequence[Policy], seed: int = 0) -> Comparison:
    """Run each policy on ``app`` and normalize per phase to NON_COH fixed."""
    base_policy = FixedHomogeneous(CoherenceMode.NON_COH_DMA)
    base = sim.run(app, base_policy, seed=seed, train=False)
    out = Comparison(policies=[], norm_time={}, norm_mem={}, raw={})
    out.raw[base_policy.name] = base
    for pol in policies:
        res = sim.run(app, pol, seed=seed, train=False)
        nt, nm = [], []
        for p, b in zip(res.phases, base.phases):
            nt.append(p.wall_time / max(b.wall_time, 1e-30))
            nm.append((p.offchip_accesses + 1.0)
                      / max(b.offchip_accesses + 1.0, 1e-30))
        out.policies.append(pol.name)
        out.norm_time[pol.name] = nt
        out.norm_mem[pol.name] = nm
        out.raw[pol.name] = res
    return out


def standard_policy_suite(sim: SoCSimulator,
                          include_profiled: bool = True) -> list[Policy]:
    """The paper's comparison set: 4 fixed-homogeneous + heterogeneous +
    random + manual (Cohmeleon is trained separately)."""
    suite: list[Policy] = [FixedHomogeneous(m) for m in CoherenceMode]
    if include_profiled:
        suite.append(profile_fixed_heterogeneous(sim))
    suite.append(RandomPolicy())
    suite.append(ManualPolicy())
    return suite


def mode_breakdown(res: RunResult, soc) -> dict[str, np.ndarray]:
    """Fraction of invocations per mode, total and per size class (Fig. 7)."""
    def size_class(fp: float) -> str:
        if fp <= soc.l2_bytes:
            return "S"
        if fp <= soc.llc_slice_bytes:
            return "M"
        if fp <= soc.llc_total_bytes:
            return "L"
        return "XL"

    buckets: dict[str, np.ndarray] = {
        k: np.zeros(N_MODES) for k in ("total", "S", "M", "L", "XL")}
    for ph in res.phases:
        for r in ph.invocations:
            buckets["total"][r.mode] += 1
            buckets[size_class(r.footprint)][r.mode] += 1
    for k, v in buckets.items():
        s = v.sum()
        if s > 0:
            buckets[k] = v / s
    return buckets
