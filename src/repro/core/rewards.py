"""Cohmeleon reward function (paper §4.2, "Rewards").

For the i-th invocation of accelerator k the paper defines three scaled
measurements::

    exec(k,i) = execution_time / footprint          (scaled execution time)
    comm(k,i) = comm_cycles / total_cycles          (communication ratio)
    mem(k,i)  = offchip_accesses / footprint        (scaled access count)

and three normalized components, each against the per-accelerator
historical extrema::

    R_exec = min_j exec(k,j) / exec(k,i)
    R_comm = min_j comm(k,j) / comm(k,i)
    R_mem  = 1 - (mem(k,i) - min_j mem) / (max_j mem - min_j mem)

The total reward is the tunable convex mix ``x*R_exec + y*R_comm + z*R_mem``.

The running extrema are carried in a :class:`RewardState` pytree so the whole
evaluate step is pure and can run under ``jit``/``vmap``/``lax.scan``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# numpy so they inline as literals under Pallas tracing
_BIG = np.float32(3.4e38)
_EPS = np.float32(1e-12)


class RewardWeights(NamedTuple):
    """(x, y, z) weights for (exec, comm, mem).

    The paper's default operating point (used for the cross-SoC sweep,
    §6 "Additional SoCs") is 67.5 / 7.5 / 25 percent.
    """

    x: float = 0.675
    y: float = 0.075
    z: float = 0.25


PAPER_DEFAULT_WEIGHTS = RewardWeights()


def as_weights(w) -> RewardWeights:
    """Coerce an (x, y, z) tuple / RewardWeights into a RewardWeights."""
    if isinstance(w, RewardWeights):
        return w
    x, y, z = w
    return RewardWeights(float(x), float(y), float(z))


def stack_weights(weights) -> RewardWeights:
    """Stack a sequence of weightings into one RewardWeights with (B,) leaves.

    The result is a pytree whose leaves carry a batch axis, so it can be fed
    straight to ``vmap(..., in_axes=(RewardWeights(0, 0, 0), ...))`` — the
    reward-DSE sweep trains one agent per weighting in a single batched call.
    """
    ws = [as_weights(w) for w in weights]
    return RewardWeights(
        x=jnp.asarray([w.x for w in ws], jnp.float32),
        y=jnp.asarray([w.y for w in ws], jnp.float32),
        z=jnp.asarray([w.z for w in ws], jnp.float32),
    )


class RewardState(NamedTuple):
    """Per-accelerator running extrema of the scaled measurements.

    The four extrema live in ONE fused ``(4, n_accs)`` array — row order
    (exec_min, comm_min, mem_min, mem_max), mirrored by ``_IS_MIN_ROW`` —
    so the per-invocation update inside a ``lax.scan`` is a single column
    gather + min/max blend + single dynamic-update-slice instead of four
    independent gather/scatter pairs (the scan-step profile flagged the
    split arrays as the next hot-path candidate after the Q-row update
    got the same treatment)."""

    extrema: jnp.ndarray   # (4, n_accs) float32


def _is_min_row():
    # Rows 0..2 track minima, row 3 (mem_max) tracks a maximum.  Built from
    # an iota so tracing embeds no array constant (Pallas kernel bodies
    # reject captured device-array constants).
    return jnp.arange(4, dtype=jnp.int32) != 3


def init_reward_state(n_accs: int) -> RewardState:
    return RewardState(extrema=jnp.stack([
        jnp.full((n_accs,), _BIG),
        jnp.full((n_accs,), _BIG),
        jnp.full((n_accs,), _BIG),
        jnp.full((n_accs,), 0.0, jnp.float32),
    ]))


class Measurement(NamedTuple):
    """Raw monitor readings for one completed invocation (paper §4.1 (4))."""

    exec_time: jnp.ndarray       # seconds (or cycles), includes driver+flush
    comm_cycles: jnp.ndarray     # cycles the accelerator spent on memory
    total_cycles: jnp.ndarray    # cycles the accelerator was active
    offchip_accesses: jnp.ndarray  # attributed DRAM accesses (monitors.py)
    footprint: jnp.ndarray       # bytes touched by the invocation


def scaled_measurements(m: Measurement):
    fp = jnp.maximum(m.footprint, 1.0)
    exec_s = m.exec_time / fp
    comm_s = m.comm_cycles / jnp.maximum(m.total_cycles, 1.0)
    mem_s = m.offchip_accesses / fp
    return exec_s, comm_s, mem_s


def evaluate(
    state: RewardState,
    acc_id,
    m: Measurement,
    weights: RewardWeights = PAPER_DEFAULT_WEIGHTS,
):
    """Compute R(s,a;k,i) and the updated running extrema.

    Returns ``(reward, new_state, components)`` where ``components`` is the
    (R_exec, R_comm, R_mem) triple for logging / the reward-DSE benchmark.
    """
    exec_s, comm_s, mem_s = scaled_measurements(m)

    # Update extrema *including* this invocation (min_{j <= i} in the paper):
    # one column gather, a fused min/max blend, one column write-back.
    col = state.extrema[:, acc_id]
    vals = jnp.stack([exec_s, comm_s, mem_s, mem_s])
    new_col = jnp.where(_is_min_row(), jnp.minimum(col, vals),
                        jnp.maximum(col, vals))
    # Degradation safety: a non-finite measurement (fault-corrupted timing)
    # must not poison the running extrema — every later reward normalizes
    # against them.  The invocation's own reward may still come out
    # non-finite; qlearn's update guard drops it at the blend.  On finite
    # measurements this is where(True, x, _), an exact no-op.
    new_col = jnp.where(jnp.isfinite(new_col), new_col, col)

    r_exec = new_col[0] / jnp.maximum(exec_s, _EPS)
    r_comm = new_col[1] / jnp.maximum(comm_s, _EPS)

    span = new_col[3] - new_col[2]
    # When max == min (first invocation, or zero-access regime) the paper's
    # fraction is 0/0; every observation is simultaneously best and worst, so
    # we award the full component.
    r_mem = jnp.where(
        span > _EPS,
        1.0 - (mem_s - new_col[2]) / jnp.maximum(span, _EPS),
        1.0,
    )

    reward = weights.x * r_exec + weights.y * r_comm + weights.z * r_mem
    new_state = RewardState(extrema=state.extrema.at[:, acc_id].set(new_col))
    return reward, new_state, (r_exec, r_comm, r_mem)
