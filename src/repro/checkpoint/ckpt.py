"""Sharding-aware pytree checkpointing (no external deps).

Format: a directory per step containing one ``.npy`` file per leaf (keyed
by its tree path) plus a ``manifest.json`` with the flattened structure.
Leaves are fetched shard-by-shard off device (``jax.device_get``) and can
be restored under *any* mesh/sharding — the basis of elastic re-meshing:
save under mesh A, ``restore(..., shardings=B)`` lands them resharded.

bfloat16 leaves are bit-cast to uint16 on disk (npy has no bf16 dtype).
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts) or "root"


def _fname(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"


def save(path: str, tree) -> None:
    """Atomically write ``tree`` to directory ``path``."""
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".ckpt-tmp-")
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    manifest = {"leaves": []}
    for p, leaf in leaves:
        key = _path_str(p)
        arr = np.asarray(jax.device_get(leaf))
        entry = {"key": key, "file": _fname(key), "dtype": str(arr.dtype)}
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            entry["dtype"] = "bfloat16"
        np.save(os.path.join(tmp, entry["file"]), arr, allow_pickle=False)
        manifest["leaves"].append(entry)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        backup = path + ".old"
        os.replace(path, backup)
        os.replace(tmp, path)
        import shutil
        shutil.rmtree(backup, ignore_errors=True)
    else:
        os.replace(tmp, path)


def restore(path: str, target, shardings=None):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    ``jax.sharding.Sharding`` to place leaves onto (elastic re-mesh)."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    leaves_with_path = jax.tree_util.tree_leaves_with_path(target)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_path))
    out = []
    for (p, leaf), shd in zip(leaves_with_path, shard_leaves):
        key = _path_str(p)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        entry = by_key[key]
        arr = np.load(os.path.join(path, entry["file"]), allow_pickle=False)
        if entry["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        expected = getattr(leaf, "shape", None)
        if expected is not None and tuple(arr.shape) != tuple(expected):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {expected}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out)
