"""Checkpoint manager: async writes, retention, crash-restart discovery.

Fault-tolerance contract (exercised by tests/test_fault_tolerance.py):

  * ``save(step, tree)`` returns immediately; a writer thread serializes
    the on-device state it was handed (device_get happens in the caller
    thread via jax.device_get inside ckpt.save — for true async on a real
    cluster, swap in a donated host copy; the step still overlaps the
    *disk* write, the dominant cost).
  * at most ``keep`` newest checkpoints are retained;
  * ``latest_step()`` scans the directory, so a restarted job (new process,
    possibly a different mesh) resumes from the newest complete checkpoint
    — partial writes are invisible because ckpt.save is atomic (tmp-dir +
    rename).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Optional

from repro.checkpoint import ckpt

_STEP_RE = re.compile(r"^step_(\d+)$")

# Exceptions a damaged / concurrently-deleted checkpoint can surface as:
# the directory or a leaf file vanished between listdir and open (retention
# pruning in another process), a torn manifest from a crashed writer whose
# tmp-dir rename never happened, or a manifest referencing leaves that
# don't match the target tree.
_DAMAGE = (FileNotFoundError, NotADirectoryError, json.JSONDecodeError,
           KeyError, ValueError)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None
        # A writer that died mid-ckpt.save leaves an orphaned tmp dir (the
        # atomic rename never ran).  Sweep them on construction — a
        # restarted job must not accrete them forever.
        for name in os.listdir(directory):
            if name.startswith(".ckpt-tmp-"):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        try:
            names = os.listdir(self.directory)
        except OSError:
            return out
        for name in names:
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree) -> None:
        self.wait()   # one outstanding write at a time

        def write():
            with self._lock:
                ckpt.save(self._step_dir(step), tree)
                self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def restore(self, target, step: Optional[int] = None, shardings=None):
        """Restore ``step`` (explicit) or the newest restorable checkpoint.

        With ``step=None`` the discovery race is handled here: a step that
        ``all_steps()`` listed can be deleted (retention pruning by a
        concurrent writer) or turn out damaged by the time its leaves are
        read, so restore walks newest-to-oldest and falls back past any
        checkpoint that fails to load.  An explicit ``step`` never falls
        back — a damaged pinned checkpoint is an error the caller asked
        to see."""
        if step is not None:
            return ckpt.restore(self._step_dir(step), target, shardings)
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        err: Exception | None = None
        for s in reversed(steps):
            try:
                return ckpt.restore(self._step_dir(s), target, shardings)
            except _DAMAGE as e:
                err = e
        raise FileNotFoundError(
            f"no restorable checkpoint in {self.directory} "
            f"(newest failure: {err!r})")

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
