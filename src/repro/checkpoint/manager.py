"""Checkpoint manager: async writes, retention, crash-restart discovery.

Fault-tolerance contract (exercised by tests/test_fault_tolerance.py):

  * ``save(step, tree)`` returns immediately; a writer thread serializes
    the on-device state it was handed (device_get happens in the caller
    thread via jax.device_get inside ckpt.save — for true async on a real
    cluster, swap in a donated host copy; the step still overlaps the
    *disk* write, the dominant cost).
  * at most ``keep`` newest checkpoints are retained;
  * ``latest_step()`` scans the directory, so a restarted job (new process,
    possibly a different mesh) resumes from the newest complete checkpoint
    — partial writes are invisible because ckpt.save is atomic (tmp-dir +
    rename).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Optional

from repro.checkpoint import ckpt

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree) -> None:
        self.wait()   # one outstanding write at a time

        def write():
            with self._lock:
                ckpt.save(self._step_dir(step), tree)
                self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def restore(self, target, step: Optional[int] = None, shardings=None):
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return ckpt.restore(self._step_dir(step), target, shardings)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
