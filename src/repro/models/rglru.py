"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit is a diagonal linear recurrence with
input-dependent gates:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * r_t * softplus(Lambda)     (c = 8)
    h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . x_t)

wrapped in Griffin's recurrent block: two parallel branches from the
residual stream (conv1d -> RG-LRU, and a GeLU gate) multiplied and
projected back.  Training uses ``lax.associative_scan`` (log-depth); decode
is a single step.  The Pallas kernel (kernels/rglru_scan) implements the
sequential-chunk variant.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common

_C = 8.0


class RGLRUParams(NamedTuple):
    w_in: jax.Array       # (D, W)  branch-1 input proj
    w_gate: jax.Array     # (D, W)  branch-2 (gelu gate) proj
    conv_w: jax.Array     # (4, W)  causal conv1d taps
    conv_b: jax.Array     # (W,)
    wa: jax.Array         # (W, W)  recurrence-gate proj
    ba: jax.Array         # (W,)
    wx: jax.Array         # (W, W)  input-gate proj
    bx: jax.Array         # (W,)
    lam: jax.Array        # (W,)    Lambda (decay parameter)
    w_out: jax.Array      # (W, D)


class RGLRUState(NamedTuple):
    conv: jax.Array       # (B, K-1, W) last conv inputs
    h: jax.Array          # (B, W) recurrence state


def init_rglru(cfg: ArchConfig, key) -> RGLRUParams:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return RGLRUParams(
        w_in=common.dense_init(ks[0], (d, w)),
        w_gate=common.dense_init(ks[1], (d, w)),
        conv_w=common.dense_init(ks[2], (cfg.conv1d_width, w), in_axis=0),
        conv_b=jnp.zeros((w,), jnp.float32),
        wa=common.dense_init(ks[3], (w, w)),
        ba=jnp.zeros((w,), jnp.float32),
        wx=common.dense_init(ks[4], (w, w)),
        bx=jnp.zeros((w,), jnp.float32),
        # a = exp(-8 softplus(lam) r) ; init so a^(r=1) ~ 0.9..0.99
        lam=jnp.full((w,), -3.0, jnp.float32),
        w_out=common.dense_init(ks[5], (w, d)),
    )


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
        h=jnp.zeros((batch, w), jnp.float32),
    )


def causal_conv1d(u, conv_w, conv_b, prev):
    """u: (B, S, W); prev: (B, K-1, W) left context. Returns (y, new_prev)."""
    k = conv_w.shape[0]
    ext = jnp.concatenate([prev.astype(u.dtype), u], axis=1)   # (B, S+K-1, W)
    y = sum(ext[:, i:i + u.shape[1], :] * conv_w[i] for i in range(k))
    return y + conv_b, ext[:, -(k - 1):, :]


def _gates(p: RGLRUParams, u):
    r = jax.nn.sigmoid(u @ p.wa + p.ba)
    i = jax.nn.sigmoid(u @ p.wx + p.bx)
    log_a = -_C * r * jax.nn.softplus(p.lam)          # (B, S, W), <= 0
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    return a, gated_x


def rglru_scan(p: RGLRUParams, u, h0):
    """Associative-scan evaluation.  u: (B, S, W) fp32, h0: (B, W)."""
    a, b = _gates(p, u)
    # Fold h0 into the first step: h_1 = a_1 h0 + b_1.
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_step(p: RGLRUParams, u, h0):
    """Single decode step. u: (B, 1, W)."""
    a, b = _gates(p, u)
    h = a[:, 0] * h0 + b[:, 0]
    return h[:, None, :], h


def recurrent_block(cfg: ArchConfig, p: RGLRUParams, x,
                    state: RGLRUState | None):
    """Griffin recurrent block. x: (B, S, D). Returns (out, new_state)."""
    x32 = x.astype(jnp.float32)
    u = x32 @ p.w_in
    prev = (state.conv if state is not None
            else jnp.zeros((x.shape[0], cfg.conv1d_width - 1, u.shape[-1]),
                           u.dtype))
    u, new_conv = causal_conv1d(u, p.conv_w, p.conv_b, prev)
    h0 = (state.h if state is not None
          else jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32))
    if x.shape[1] == 1:
        y, h_fin = rglru_step(p, u, h0)
    else:
        y, h_fin = rglru_scan(p, u, h0)
    gate = jax.nn.gelu(x32 @ p.w_gate, approximate=True)
    out = (y * gate) @ p.w_out
    new_state = None
    if state is not None:
        new_state = RGLRUState(conv=new_conv, h=h_fin)
    return out.astype(x.dtype), new_state
