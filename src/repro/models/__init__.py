"""Pure-JAX model zoo for the assigned architectures."""
from repro.models import transformer
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, loss_fn, prefill)

__all__ = ["transformer", "decode_step", "forward", "init_cache",
           "init_params", "loss_fn", "prefill"]
