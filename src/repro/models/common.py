"""Shared model building blocks: norms, positional embeddings, init helpers.

Everything is functional: params are plain dict pytrees, and every function
works under ``jax.eval_shape`` so the dry-run can trace 480B-parameter
models without allocating them.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ----------------------------------------------------------------- init ----
def dense_init(key, shape, in_axis: int = -2):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)


def embed_init(key, shape):
    """GPT-style N(0, 0.02) — keeps tied-head logits O(0.1) at init (the
    archs that scale embeddings by sqrt(d) re-amplify on the way in)."""
    return 0.02 * jax.random.normal(key, shape, jnp.float32)


# ----------------------------------------------------------------- norms ---
def rms_norm(x, weight, eps: float = 1e-6, zero_centered: bool = True):
    """RMSNorm in fp32 with a (1+w) parameterization (gemma-style) when
    ``zero_centered``; plain ``w`` otherwise."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    x32 = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    scale = (1.0 + w) if zero_centered else w
    return (x32 * scale).astype(dt)


# ------------------------------------------------------------------ rope ---
def rope_freqs(head_dim: int, theta: float):
    exponent = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim // 2,)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                        # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, ...],
                theta: float = 10000.0):
    """Multimodal RoPE (qwen2-vl §3): the rotary dims are split into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, H, hd); positions3: (3, B, S); sections sum to hd // 2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = jnp.asarray(rope_freqs(hd, theta))           # (hd/2,)
    # Per rotary-dim section index 0/1/2 selecting t/h/w position streams.
    sec_ids = np.repeat(np.arange(len(sections)), sections)  # (hd/2,)
    pos = positions3[sec_ids]                            # (hd/2, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                       # (B, S, hd/2)
    angles = pos.astype(jnp.float32) * freqs             # (B, S, hd/2)
    angles = angles[..., None, :]                        # (B, S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions, dim: int):
    """Classic transformer sinusoidal embeddings (musicgen)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def softcap(x, cap: float):
    """gemma2 tanh soft-capping; identity when cap == 0."""
    if cap and cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


def cross_entropy_loss(logits, labels, ignore_index: int = -1):
    """Mean token CE in fp32 with ignore mask. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_index
    safe = jnp.where(mask, labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, safe[..., None], axis=-1).squeeze(-1)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
