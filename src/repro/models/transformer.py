"""Full model assembly for all assigned architecture families.

Layers are grouped into *superblocks* — the repeating layer pattern of the
architecture (1 layer for uniform stacks; [local, global] for gemma2;
[rg, rg, attn] for recurrentgemma) — whose parameters are stacked along a
leading axis and driven by ``lax.scan``.  This keeps the HLO size
O(superblock) at 60-layer scale, makes activation-checkpoint policies
uniform, and is what the dry-run compiles.

Caches: attention layers carry KV caches (rolling buffers sized to the
sliding window for local layers — the reason recurrentgemma's 500k-token
decode state stays small); rwkv/rg layers carry recurrent states.

Public surface (all pure functions of (cfg, params, ...)):
  init_params, loss_fn, prefill, decode_step, init_cache
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, common, mlp, rglru, rwkv6


# --------------------------------------------------------------------------
# Superblock structure
# --------------------------------------------------------------------------
def superblock_layout(cfg: ArchConfig) -> tuple[list[str], int, int]:
    """Returns (pattern, n_super, n_tail_layers).

    ``pattern`` is the per-superblock layer-kind list; the stack is
    ``pattern * n_super`` plus ``pattern[:n_tail]`` unscanned tail layers
    (recurrentgemma's 38 = 12*[rg, rg, attn] + [rg, rg]).
    """
    if cfg.family == "ssm":
        pattern = ["rwkv"]
    elif cfg.family == "hybrid":
        n = max(cfg.rg_pattern, 1)
        pattern = ["rg"] * (n - 1) + ["attn_local"]
    elif cfg.global_every and cfg.global_every > 1:
        pattern = ["attn_local"] * (cfg.global_every - 1) + ["attn_global"]
    elif cfg.global_every < 0:
        pattern = ["attn_local"]       # mistral-style: every layer windowed
    else:
        pattern = ["attn_global"]
    span = len(pattern)
    n_super, tail = divmod(cfg.n_layers, span)
    return pattern, n_super, tail


def layer_window(cfg: ArchConfig, kind: str) -> int:
    return cfg.sliding_window if kind in ("attn_local",) else 0


# --------------------------------------------------------------------------
# Per-layer params
# --------------------------------------------------------------------------
def _init_layer(cfg: ArchConfig, kind: str, key) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32),
                         "ln2": jnp.zeros((d,), jnp.float32)}
    if cfg.post_norms:
        p["post_ln1"] = jnp.zeros((d,), jnp.float32)
        p["post_ln2"] = jnp.zeros((d,), jnp.float32)
    if kind == "rwkv":
        p["tm"] = rwkv6.init_time_mix(cfg, ks[0])
        p["cm"] = rwkv6.init_channel_mix(cfg, ks[1])
        return p
    if kind == "rg":
        p["rg"] = rglru.init_rglru(cfg, ks[0])
    else:
        p["attn"] = attention.init_attn(cfg, ks[0])
    if cfg.n_experts:
        p["moe"] = mlp.init_moe(cfg, ks[1])
        if cfg.moe_dense_residual:
            p["mlp"] = mlp.init_mlp(cfg, ks[2])
    else:
        p["mlp"] = mlp.init_mlp(cfg, ks[1])
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    pattern, n_super, tail = superblock_layout(cfg)
    k_embed, k_blocks, k_tail, k_head, k_vis = jax.random.split(key, 5)

    def init_super(k):
        kk = jax.random.split(k, len(pattern))
        return {f"l{i}_{kind}": _init_layer(cfg, kind, kk[i])
                for i, kind in enumerate(pattern)}

    blocks = jax.vmap(init_super)(jax.random.split(k_blocks, n_super))

    params: dict[str, Any] = {"blocks": blocks}
    if tail:
        kk = jax.random.split(k_tail, tail)
        params["tail"] = {f"t{i}_{pattern[i]}": _init_layer(cfg, pattern[i], kk[i])
                          for i in range(tail)}

    if cfg.n_codebooks:
        params["embed"] = common.embed_init(
            k_embed, (cfg.n_codebooks, cfg.vocab, cfg.d_model))
        params["lm_head"] = common.dense_init(
            k_head, (cfg.n_codebooks, cfg.d_model, cfg.vocab), in_axis=1)
    else:
        params["embed"] = common.embed_init(k_embed, (cfg.vocab, cfg.d_model))
        if not cfg.tie_embeddings:
            params["lm_head"] = common.dense_init(
                k_head, (cfg.d_model, cfg.vocab), in_axis=0)
    if cfg.family == "vlm":
        params["vision_proj"] = common.dense_init(
            k_vis, (cfg.vision_dim, cfg.d_model), in_axis=0)
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# --------------------------------------------------------------------------
# Layer application
# --------------------------------------------------------------------------
def _apply_layer(cfg: ArchConfig, kind: str, p: dict, x, positions, *,
                 cache=None, cache_pos=None, mrope_positions=None):
    """One residual layer of the given kind. Returns (x, new_cache, aux)."""
    aux = {}
    h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "rwkv":
        out, new_tm = rwkv6.time_mix(cfg, p["tm"], h, cache)
        if cfg.post_norms:
            out = common.rms_norm(out, p["post_ln1"], cfg.norm_eps)
        x = x + out
        h2 = common.rms_norm(x, p["ln2"], cfg.norm_eps)
        out2, new_cm = rwkv6.channel_mix(cfg, p["cm"], h2, new_tm)
        x = x + out2
        return x, new_cm, aux
    if kind == "rg":
        out, new_cache = rglru.recurrent_block(cfg, p["rg"], h, cache)
    else:
        window = layer_window(cfg, kind)
        out, new_cache = attention.attend(
            cfg, p["attn"], h, positions, layer_window=window,
            cache_kv=cache, cache_pos=cache_pos,
            mrope_positions=mrope_positions)
    if cfg.post_norms:
        out = common.rms_norm(out, p["post_ln1"], cfg.norm_eps)
    x = x + out

    h2 = common.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        out2, moe_aux = mlp.moe(cfg, p["moe"], h2)
        aux["moe_aux_loss"] = moe_aux["aux_loss"]
        if cfg.moe_dense_residual:
            out2 = out2 + mlp.mlp(cfg, p["mlp"], h2)
    else:
        out2 = mlp.mlp(cfg, p["mlp"], h2)
    if cfg.post_norms:
        out2 = common.rms_norm(out2, p["post_ln2"], cfg.norm_eps)
    return x + out2, new_cache, aux


def _remat_wrap(cfg: ArchConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------
def embed_tokens(cfg: ArchConfig, params, batch) -> jax.Array:
    dt = common.dtype_of(cfg.compute_dtype)
    tokens = batch["tokens"]
    if cfg.n_codebooks:
        # (B, K, S) codebook ids -> summed per-codebook embeddings.
        h = sum(params["embed"][k][tokens[:, k]]
                for k in range(cfg.n_codebooks))
    else:
        h = params["embed"][tokens]
    h = h.astype(dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(dt) @ params["vision_proj"].astype(dt)
        h = jax.lax.dynamic_update_slice(h, vis, (0, 0, 0))
    if cfg.pos_emb == "sinusoidal":
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.arange(h.shape[1])[None, :]
        h = h + common.sinusoidal_pos_emb(pos, cfg.d_model).astype(dt)
    return h


def lm_logits(cfg: ArchConfig, params, h):
    dt = h.dtype
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,kdv->bksv", h, params["lm_head"].astype(dt))
    elif cfg.tie_embeddings:
        logits = h @ params["embed"].T.astype(dt)
    else:
        logits = h @ params["lm_head"].astype(dt)
    return common.softcap(logits.astype(jnp.float32), cfg.final_softcap)


# --------------------------------------------------------------------------
# Forward (training)
# --------------------------------------------------------------------------
def forward(cfg: ArchConfig, params, batch):
    """Training/prefill forward without caches. Returns (h_final, aux)."""
    pattern, n_super, tail = superblock_layout(cfg)
    h = embed_tokens(cfg, params, batch)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(h.shape[1])[None, :]
    mrope = batch.get("mrope_positions")

    def super_fn(x, block_params):
        aux_l = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            x, _, aux = _apply_layer(
                cfg, kind, block_params[f"l{i}_{kind}"], x, positions,
                mrope_positions=mrope)
            if "moe_aux_loss" in aux:
                aux_l = aux_l + aux["moe_aux_loss"]
        return x, aux_l

    super_fn = _remat_wrap(cfg, super_fn)

    def scan_body(x, block_params):
        x, aux_l = super_fn(x, block_params)
        return x, aux_l

    if cfg.scan_layers:
        h, aux_losses = jax.lax.scan(scan_body, h, params["blocks"])
        total_aux = jnp.sum(aux_losses)
    else:
        total_aux = jnp.zeros((), jnp.float32)
        for i in range(n_super):
            bp = jax.tree_util.tree_map(lambda l: l[i], params["blocks"])
            h, aux_l = super_fn(h, bp)
            total_aux = total_aux + aux_l

    for i in range(tail):
        kind = pattern[i]
        h, _, aux = _apply_layer(cfg, kind, params["tail"][f"t{i}_{kind}"],
                                 h, positions, mrope_positions=mrope)
        if "moe_aux_loss" in aux:
            total_aux = total_aux + aux["moe_aux_loss"]
    return h, {"moe_aux_loss": total_aux}


def loss_fn(cfg: ArchConfig, params, batch):
    h, aux = forward(cfg, params, batch)
    logits = lm_logits(cfg, params, h)
    labels = batch["labels"]
    ce = common.cross_entropy_loss(logits, labels)
    loss = ce
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * aux["moe_aux_loss"] / cfg.n_layers
    return loss, {"ce": ce, **aux}


# --------------------------------------------------------------------------
# KV / recurrent caches and decode
# --------------------------------------------------------------------------
class CacheSpec(NamedTuple):
    max_len: int


def _init_layer_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    dt = common.dtype_of(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    if kind == "rwkv":
        return rwkv6.init_state(cfg, batch, dt)
    if kind == "rg":
        return rglru.init_state(cfg, batch, dt)
    window = layer_window(cfg, kind)
    size = min(max_len, window) if window else max_len
    shape = (batch, size, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        def entry():
            return (jnp.zeros(shape, jnp.int8),
                    jnp.ones(shape[:-1] + (1,), jnp.float32))
        return (entry(), entry())
    return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    pattern, n_super, tail = superblock_layout(cfg)

    def one_super(_):
        return {f"l{i}_{kind}": _init_layer_cache(cfg, kind, batch, max_len)
                for i, kind in enumerate(pattern)}

    stacked = jax.vmap(one_super)(jnp.arange(n_super))
    cache = {"blocks": stacked}
    if tail:
        cache["tail"] = {
            f"t{i}_{pattern[i]}": _init_layer_cache(cfg, pattern[i], batch,
                                                    max_len)
            for i in range(tail)}
    return cache


def _decode_layer(cfg: ArchConfig, kind: str, p, x, cache, pos, positions,
                  mrope_positions=None):
    if kind in ("rwkv", "rg"):
        return _apply_layer(cfg, kind, p, x, positions, cache=cache)[:2]
    window = layer_window(cfg, kind)
    cache_size = (cache[0][0] if isinstance(cache[0], tuple)
                  else cache[0]).shape[1]
    h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
    if window:
        # Rolling buffer: write at pos % size; all populated slots are in
        # the past and within the window by construction.
        out, new_cache = attention.attend(
            cfg, p["attn"], h, positions, layer_window=0,
            cache_kv=cache, cache_pos=pos % cache_size,
            kv_valid_len=jnp.minimum(pos + 1, cache_size), rolling=True,
            mrope_positions=mrope_positions)
    else:
        out, new_cache = attention.attend(
            cfg, p["attn"], h, positions, layer_window=0,
            cache_kv=cache, cache_pos=pos,
            mrope_positions=mrope_positions)
    if cfg.post_norms:
        out = common.rms_norm(out, p["post_ln1"], cfg.norm_eps)
    x = x + out
    h2 = common.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        out2, _ = mlp.moe(cfg, p["moe"], h2)
        if cfg.moe_dense_residual:
            out2 = out2 + mlp.mlp(cfg, p["mlp"], h2)
    else:
        out2 = mlp.mlp(cfg, p["mlp"], h2)
    if cfg.post_norms:
        out2 = common.rms_norm(out2, p["post_ln2"], cfg.norm_eps)
    return x + out2, new_cache


def decode_step(cfg: ArchConfig, params, cache, batch, pos):
    """One-token decode. batch["tokens"]: (B, 1) (or (B, K, 1) audio).

    ``pos``: scalar int32 — absolute position of the new token.
    Returns (new_cache, logits).
    """
    pattern, n_super, tail = superblock_layout(cfg)
    if cfg.n_codebooks:
        tok = batch["tokens"]
        h = jnp.stack([
            params["embed"][k][tok[:, k]] for k in range(cfg.n_codebooks)
        ]).sum(0)
    else:
        h = params["embed"][batch["tokens"]]
    dt = common.dtype_of(cfg.compute_dtype)
    h = h.astype(dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    positions = jnp.full((h.shape[0], 1), pos)
    if cfg.pos_emb == "sinusoidal":
        h = h + common.sinusoidal_pos_emb(positions, cfg.d_model).astype(dt)
    mrope = batch.get("mrope_positions")

    def scan_body(x, scanned):
        block_params, block_cache = scanned
        new_caches = {}
        for i, kind in enumerate(pattern):
            key = f"l{i}_{kind}"
            x, nc = _decode_layer(cfg, kind, block_params[key], x,
                                  block_cache[key], pos, positions, mrope)
            new_caches[key] = nc
        return x, new_caches

    if cfg.scan_layers:
        h, new_block_cache = jax.lax.scan(
            scan_body, h, (params["blocks"], cache["blocks"]))
    else:
        outs = []
        for i in range(n_super):
            sl = jax.tree_util.tree_map(
                lambda l: l[i], (params["blocks"], cache["blocks"]))
            h, nc = scan_body(h, sl)
            outs.append(nc)
        new_block_cache = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *outs)
    new_cache = {"blocks": new_block_cache}

    if tail:
        new_tail = {}
        for i in range(tail):
            kind = pattern[i]
            key = f"t{i}_{kind}"
            h, nc = _decode_layer(cfg, kind, params["tail"][key], h,
                                  cache["tail"][key], pos, positions, mrope)
            new_tail[key] = nc
        new_cache["tail"] = new_tail

    logits = lm_logits(cfg, params, h)
    return new_cache, logits


def prefill(cfg: ArchConfig, params, batch, max_len: int | None = None):
    """Prefill: forward over the prompt, return (cache, last-token logits).

    ``max_len``: cache capacity (>= prompt length); defaults to the prompt
    length (decode_32k lowers with max_len = seq_len + decode budget).

    The cache is populated by replaying K/V projection per layer — shares
    the forward trace so XLA fuses it; recurrent layers return their final
    states directly.  For simplicity and dry-run fidelity we run forward
    and then rebuild caches from a decode-shaped pass; attention caches are
    filled inside the same scan.
    """
    pattern, n_super, tail = superblock_layout(cfg)
    S = batch["tokens"].shape[-1]
    B = batch["tokens"].shape[0]
    h = embed_tokens(cfg, params, batch)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(S)[None, :]
    mrope = batch.get("mrope_positions")
    dt = common.dtype_of(cfg.compute_dtype)

    def layer_with_cache(kind, p, x, cache):
        if kind in ("rwkv", "rg"):
            hsub = common.rms_norm(x, p["ln1"], cfg.norm_eps)
            if kind == "rwkv":
                out, st = rwkv6.time_mix(cfg, p["tm"], hsub, cache)
                if cfg.post_norms:
                    out = common.rms_norm(out, p["post_ln1"], cfg.norm_eps)
                x = x + out
                h2 = common.rms_norm(x, p["ln2"], cfg.norm_eps)
                out2, st = rwkv6.channel_mix(cfg, p["cm"], h2, st)
                return x + out2, st
            out, st = rglru.recurrent_block(cfg, p["rg"], hsub, cache)
            if cfg.post_norms:
                out = common.rms_norm(out, p["post_ln1"], cfg.norm_eps)
            x = x + out
            h2 = common.rms_norm(x, p["ln2"], cfg.norm_eps)
            out2 = mlp.mlp(cfg, p["mlp"], h2)
            if cfg.post_norms:
                out2 = common.rms_norm(out2, p["post_ln2"], cfg.norm_eps)
            return x + out2, st

        window = layer_window(cfg, kind)
        hsub = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        # Recompute K/V for cache while attending without cache.
        k = jnp.einsum("bsd,dhk->bshk", hsub.astype(dt),
                       p["attn"].wk.astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", hsub.astype(dt),
                       p["attn"].wv.astype(dt))
        if cfg.qk_norm:
            k = common.rms_norm(k, p["attn"].k_norm, cfg.norm_eps)
        if cfg.pos_emb == "rope":
            if cfg.mrope_sections and mrope is not None:
                k = common.apply_mrope(k, mrope, cfg.mrope_sections,
                                       cfg.rope_theta)
            else:
                k = common.apply_rope(k, positions, cfg.rope_theta)
        k_entry, v_entry = cache
        size = (k_entry[0] if isinstance(k_entry, tuple)
                else k_entry).shape[1]
        if cfg.kv_cache_dtype == "int8":
            kq, ks = attention.quantize_kv(k)
            vq, vs = attention.quantize_kv(v)
            if size >= S:
                kc = (jax.lax.dynamic_update_slice(k_entry[0], kq,
                                                   (0, 0, 0, 0)),
                      jax.lax.dynamic_update_slice(k_entry[1], ks,
                                                   (0, 0, 0, 0)))
                vc = (jax.lax.dynamic_update_slice(v_entry[0], vq,
                                                   (0, 0, 0, 0)),
                      jax.lax.dynamic_update_slice(v_entry[1], vs,
                                                   (0, 0, 0, 0)))
            else:
                idx = jnp.arange(S - size, S) % size
                kc = (k_entry[0].at[:, idx].set(kq[:, -size:]),
                      k_entry[1].at[:, idx].set(ks[:, -size:]))
                vc = (v_entry[0].at[:, idx].set(vq[:, -size:]),
                      v_entry[1].at[:, idx].set(vs[:, -size:]))
        elif size >= S:
            kc = jax.lax.dynamic_update_slice(
                k_entry, k.astype(k_entry.dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                v_entry, v.astype(v_entry.dtype), (0, 0, 0, 0))
        else:
            idx = jnp.arange(S - size, S) % size
            kc = k_entry.at[:, idx].set(k[:, -size:].astype(k_entry.dtype))
            vc = v_entry.at[:, idx].set(v[:, -size:].astype(v_entry.dtype))
        out, _ = attention.attend(cfg, p["attn"], hsub, positions,
                                  layer_window=window,
                                  mrope_positions=mrope)
        if cfg.post_norms:
            out = common.rms_norm(out, p["post_ln1"], cfg.norm_eps)
        x = x + out
        h2 = common.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            out2, _ = mlp.moe(cfg, p["moe"], h2)
            if cfg.moe_dense_residual:
                out2 = out2 + mlp.mlp(cfg, p["mlp"], h2)
        else:
            out2 = mlp.mlp(cfg, p["mlp"], h2)
        if cfg.post_norms:
            out2 = common.rms_norm(out2, p["post_ln2"], cfg.norm_eps)
        return x + out2, (kc, vc)

    cache0 = init_cache(cfg, B, max(max_len or S, S, 1))

    def scan_body(x, scanned):
        block_params, block_cache = scanned
        new_caches = {}
        for i, kind in enumerate(pattern):
            key = f"l{i}_{kind}"
            x, nc = layer_with_cache(kind, block_params[key], x,
                                     block_cache[key])
            new_caches[key] = nc
        return x, new_caches

    if cfg.scan_layers:
        h, new_block_cache = jax.lax.scan(
            scan_body, h, (params["blocks"], cache0["blocks"]))
    else:
        outs = []
        for i in range(n_super):
            sl = jax.tree_util.tree_map(
                lambda l: l[i], (params["blocks"], cache0["blocks"]))
            h, nc = scan_body(h, sl)
            outs.append(nc)
        new_block_cache = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *outs)
    new_cache = {"blocks": new_block_cache}
    if tail:
        new_tail = {}
        for i in range(tail):
            kind = pattern[i]
            key = f"t{i}_{kind}"
            h, nc = layer_with_cache(kind, params["tail"][key], h,
                                     cache0["tail"][key])
            new_tail[key] = nc
        new_cache["tail"] = new_tail

    logits = lm_logits(cfg, params, h[:, -1:, :])
    return new_cache, logits
