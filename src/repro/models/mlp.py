"""Feed-forward layers: gated MLP (SwiGLU/GeGLU) and Mixture-of-Experts.

The MoE uses a sort-based grouped dispatch (dropless up to a capacity
factor): tokens' (token, expert) assignments are sorted by expert, packed
into an (E, C, D) buffer, run through batched expert matmuls — the layout
Pallas's ``moe_gmm`` kernel and the expert-parallel sharding both exploit —
and combined back with router weights.  Overflowing assignments beyond
capacity are dropped (standard capacity semantics, counted in aux stats).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common


class MLPParams(NamedTuple):
    w_gate: jax.Array   # (D, F)
    w_up: jax.Array     # (D, F)
    w_down: jax.Array   # (F, D)


class MoEParams(NamedTuple):
    router: jax.Array   # (D, E)
    w_gate: jax.Array   # (E, D, F)
    w_up: jax.Array     # (E, D, F)
    w_down: jax.Array   # (E, F, D)


def init_mlp(cfg: ArchConfig, key, width: int | None = None) -> MLPParams:
    f = width or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return MLPParams(
        w_gate=common.dense_init(k1, (cfg.d_model, f), in_axis=0),
        w_up=common.dense_init(k2, (cfg.d_model, f), in_axis=0),
        w_down=common.dense_init(k3, (f, cfg.d_model), in_axis=0),
    )


def init_moe(cfg: ArchConfig, key) -> MoEParams:
    e, d, f = cfg.padded_experts, cfg.d_model, cfg.d_ff
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return MoEParams(
        router=common.dense_init(k0, (d, e), in_axis=0),
        w_gate=common.dense_init(k1, (e, d, f), in_axis=1),
        w_up=common.dense_init(k2, (e, d, f), in_axis=1),
        w_down=common.dense_init(k3, (e, f, d), in_axis=1),
    )


def mlp(cfg: ArchConfig, p: MLPParams, x):
    dt = common.dtype_of(cfg.compute_dtype)
    act = common.activation(cfg.act)
    x = x.astype(dt)
    h = act(x @ p.w_gate.astype(dt)) * (x @ p.w_up.astype(dt))
    return h @ p.w_down.astype(dt)


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25) -> int:
    c = int(math.ceil(n_tokens * top_k * capacity_factor / n_experts))
    return max(8, -(-c // 8) * 8)   # round up to 8 for TPU lane alignment


def moe(cfg: ArchConfig, p: MoEParams, x, capacity_factor: float | None = None):
    """Sort-based grouped MoE with PER-BATCH-ROW dispatch.

    x: (B, S, D) -> (B, S, D), aux dict.  Dispatch (router, sort, capacity
    packing) happens independently per batch row, so under batch-on-data
    sharding it is entirely local to each data shard; the only cross-device
    movement is the (B, E, Cr, D) grouped tensor resharding from
    batch-sharded to expert-sharded — the canonical MoE all-to-all.  (The
    earlier global-buffer formulation forced GSPMD to all-reduce an
    (E*C_global, D) buffer: terabytes/step on granite, see §Perf iter 2.)
    """
    dt = common.dtype_of(cfg.compute_dtype)
    act = common.activation(cfg.act)
    if capacity_factor is None:
        capacity_factor = cfg.capacity_factor
    b, s, d = x.shape
    e, k = cfg.padded_experts, cfg.top_k
    xf = x.astype(dt)

    # Router in fp32 for stable softmax; padded (dead) experts — added so
    # EP shards cleanly on the mesh — are masked out of the softmax.
    logits = jnp.einsum("bsd,de->bse", xf.astype(jnp.float32),
                        p.router.astype(jnp.float32))
    if e > cfg.n_experts:
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Per-row assignment sort (stable) — (B, S*k) everywhere below.
    flat_experts = expert_ids.reshape(b, s * k)
    order = jnp.argsort(flat_experts, axis=-1, stable=True)
    sorted_experts = jnp.take_along_axis(flat_experts, order, axis=-1)
    sorted_tokens = order // k                                   # row-local

    # Position within each expert group, per row.
    pos = jnp.cumsum(jnp.ones_like(sorted_experts), axis=-1) - 1
    group_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left")
    )(sorted_experts)                                            # (B, E)
    pos_in_expert = pos - jnp.take_along_axis(group_start,
                                              sorted_experts, axis=-1)

    cap = moe_capacity(s, e, k, capacity_factor)
    keep = pos_in_expert < cap
    slot = sorted_experts * cap + pos_in_expert
    slot = jnp.where(keep, slot, e * cap)                        # overflow

    # Row-local gather into the (B, E*Cr [+1 overflow], D) grouped buffer.
    src = jnp.take_along_axis(xf, sorted_tokens[..., None], axis=1)
    buf = jnp.zeros((b, e * cap + 1, d), dt)
    buf = jax.vmap(lambda bu, sl, v: bu.at[sl].set(v, mode="drop"))(
        buf, slot, src)
    grouped = buf[:, : e * cap].reshape(b, e, cap, d)

    # Expert matmuls (E sharded on "model" — the implicit all-to-all).
    h = act(jnp.einsum("becd,edf->becf", grouped, p.w_gate.astype(dt)))
    h = h * jnp.einsum("becd,edf->becf", grouped, p.w_up.astype(dt))
    out_g = jnp.einsum("becf,efd->becd", h, p.w_down.astype(dt))

    # Combine back per row, weighting by gate values.
    out_flat = out_g.reshape(b, e * cap, d)
    gathered = jnp.take_along_axis(
        out_flat, jnp.minimum(slot, e * cap - 1)[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    weights = jnp.take_along_axis(
        gate_vals.reshape(b, s * k), order, axis=-1)[..., None].astype(dt)
    contrib = gathered * weights                                  # (B,S*k,D)
    out = jnp.zeros((b, s, d), dt)
    out = jax.vmap(lambda o, t, c: o.at[t].add(c))(
        out, sorted_tokens, contrib)

    # Aux: load-balancing loss (Switch-style) + drop fraction.
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], e,
                                 dtype=jnp.float32), axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce)
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out, {"aux_loss": aux_loss, "drop_frac": drop_frac}
