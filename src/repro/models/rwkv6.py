"""RWKV-6 "Finch" blocks (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay, plus squared-ReLU channel mixing.

The time-mix recurrence per head (state S in R^{K x V}) is

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = S_{t-1}^T r_t + (r_t . (u . k_t)) v_t

with w_t = exp(-exp(ww_t)) a data-dependent decay.  Training uses a
chunk-parallel form whose factored terms stay bounded because every
exponent is a *pairwise difference* of decay cumsums within a chunk
(chunk 16, log-decay clamped at -4 per step — fidelity note in DESIGN.md).
The Pallas kernel (kernels/rwkv6_scan) implements the same algorithm; this
module is the XLA path and the oracle's building block.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common

CHUNK = 16
LOGW_MIN = -4.0
_LORA_RANK = 32
_MIX_STREAMS = 5   # r, k, v, w, g


class TimeMixParams(NamedTuple):
    mix_base: jax.Array    # (5, D) token-shift mixing coefficients
    mix_lora_a: jax.Array  # (5, D, R)
    mix_lora_b: jax.Array  # (5, R, D)
    wr: jax.Array          # (D, D)
    wk: jax.Array          # (D, D)
    wv: jax.Array          # (D, D)
    wg: jax.Array          # (D, D)
    w_base: jax.Array      # (D,) decay bias
    w_lora_a: jax.Array    # (D, R)
    w_lora_b: jax.Array    # (R, D)
    u: jax.Array           # (D,) per-channel bonus
    ln_w: jax.Array        # (D,) per-head group-norm scale
    wo: jax.Array          # (D, D)


class ChannelMixParams(NamedTuple):
    mix_k: jax.Array       # (D,)
    mix_r: jax.Array       # (D,)
    wk: jax.Array          # (D, F)
    wv: jax.Array          # (F, D)
    wr: jax.Array          # (D, D)


class RwkvState(NamedTuple):
    """Decode-time per-layer state."""

    tm_shift: jax.Array    # (B, D)  last input to time mix
    cm_shift: jax.Array    # (B, D)  last input to channel mix
    wkv: jax.Array         # (B, H, K, V) recurrence state


def init_time_mix(cfg: ArchConfig, key) -> TimeMixParams:
    d, r = cfg.d_model, _LORA_RANK
    ks = jax.random.split(key, 8)
    return TimeMixParams(
        mix_base=jax.random.uniform(ks[0], (_MIX_STREAMS, d), jnp.float32),
        mix_lora_a=0.01 * jax.random.normal(ks[1], (_MIX_STREAMS, d, r)),
        mix_lora_b=jnp.zeros((_MIX_STREAMS, r, d), jnp.float32),
        wr=common.dense_init(ks[2], (d, d)),
        wk=common.dense_init(ks[3], (d, d)),
        wv=common.dense_init(ks[4], (d, d)),
        wg=common.dense_init(ks[5], (d, d)),
        w_base=jnp.full((d,), -0.7, jnp.float32),   # exp(-exp(-0.7)) ~ 0.6
        w_lora_a=0.01 * jax.random.normal(ks[6], (d, r)),
        w_lora_b=jnp.zeros((r, d), jnp.float32),
        u=jnp.zeros((d,), jnp.float32),
        ln_w=jnp.zeros((d,), jnp.float32),
        wo=common.dense_init(ks[7], (d, d)),
    )


def init_channel_mix(cfg: ArchConfig, key) -> ChannelMixParams:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return ChannelMixParams(
        mix_k=0.5 * jnp.ones((d,), jnp.float32),
        mix_r=0.5 * jnp.ones((d,), jnp.float32),
        wk=common.dense_init(k1, (d, f)),
        wv=common.dense_init(k2, (f, d)),
        wr=common.dense_init(k3, (d, d)),
    )


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> RwkvState:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return RwkvState(
        tm_shift=jnp.zeros((batch, d), dtype),
        cm_shift=jnp.zeros((batch, d), dtype),
        wkv=jnp.zeros((batch, h, hd, hd), jnp.float32),
    )


def _token_shift(x, prev):
    """(B, S, D) -> previous-token stream, seeded by ``prev`` (B, D)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, x_prev, p: TimeMixParams):
    """Data-dependent token-shift mixing for the five streams."""
    delta = x_prev - x
    base = p.mix_base[:, None, None, :]                 # (5,1,1,D)
    lora = jnp.einsum("bsd,mdr->mbsr", jnp.tanh(x), p.mix_lora_a)
    lora = jnp.einsum("mbsr,mrd->mbsd", lora, p.mix_lora_b)
    return x[None] + delta[None] * (base + lora)        # (5, B, S, D)


def wkv_chunked(r, k, v, logw, u, s0, chunk: int = CHUNK):
    """Chunk-parallel RWKV-6 recurrence.

    r/k/v: (B, H, T, K); logw: (B, H, T, K) (log decay, <= 0);
    u: (H, K); s0: (B, H, K, V).  Returns (y (B,H,T,K), s_final).
    All math fp32; T must be a multiple of ``chunk``.
    """
    b, h, t, kk = r.shape
    n_chunks = t // chunk
    rs = r.reshape(b, h, n_chunks, chunk, kk)
    ks_ = k.reshape(b, h, n_chunks, chunk, kk)
    vs = v.reshape(b, h, n_chunks, chunk, kk)
    lw = logw.reshape(b, h, n_chunks, chunk, kk)
    cum = jnp.cumsum(lw, axis=-2)                       # inclusive
    cum_prev = cum - lw                                 # exclusive
    cum_end = cum[..., -1:, :]                          # (.., 1, K)

    q_t = rs * jnp.exp(cum_prev)                        # bounded <= |r|
    k_t = ks_ * jnp.exp(-cum)                           # <= |k| e^{chunk*|LOGW_MIN|}
    k_end = ks_ * jnp.exp(cum_end - cum)                # bounded <= |k|

    # Intra-chunk attention-style matrix, strictly causal + u-bonus diag.
    a = jnp.einsum("bhntk,bhnsk->bhnts", q_t, k_t)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    a = jnp.where(mask, a, 0.0)
    bonus = jnp.einsum("bhntk,bhntk->bhnt", rs, u[None, :, None, None, :] * ks_)
    y_intra = jnp.einsum("bhnts,bhnsv->bhntv", a, vs)
    y_intra = y_intra + bonus[..., None] * vs

    # Cross-chunk: scan the per-chunk state update.
    decay_end = jnp.exp(cum_end[..., 0, :])             # (B,H,N,K)
    s_delta = jnp.einsum("bhnsk,bhnsv->bhnkv", k_end, vs)

    def step(s, inp):
        dec, delta, q_c = inp
        y_c = jnp.einsum("bhtk,bhkv->bhtv", q_c, s)
        s = dec[..., :, None] * s + delta
        return s, y_c

    xs = (jnp.moveaxis(decay_end, 2, 0), jnp.moveaxis(s_delta, 2, 0),
          jnp.moveaxis(q_t, 2, 0))
    s_fin, y_inter = jax.lax.scan(step, s0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 2)               # (B,H,N,chunk,V)
    y = (y_intra + y_inter).reshape(b, h, t, kk)
    return y, s_fin


def wkv_sequential(r, k, v, logw, u, s0):
    """Step-by-step oracle of the recurrence (used by tests/decode)."""
    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s) + \
            jnp.einsum("bhk,bhk,bhv->bhv", r_t, u[None] * k_t, v_t)
        s = jnp.exp(lw_t)[..., None] * s + k_t[..., None] * v_t[..., None, :]
        return s, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r, k, v, logw))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2), s_fin


def time_mix(cfg: ArchConfig, p: TimeMixParams, x, state: RwkvState | None,
             use_chunked: bool = True):
    """RWKV-6 attention substitute. x: (B, S, D)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    x32 = x.astype(jnp.float32)
    prev = state.tm_shift if state is not None else jnp.zeros((b, d))
    xp = _token_shift(x32, prev.astype(jnp.float32))
    xr, xk, xv, xw, xg = _mix(x32, xp, p)

    r = (xr @ p.wr).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (xk @ p.wk).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (xv @ p.wv).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ p.wg)

    ww = p.w_base + jnp.tanh(xw @ p.w_lora_a) @ p.w_lora_b   # (B,S,D)
    logw = -jnp.exp(ww)
    logw = jnp.maximum(logw, LOGW_MIN)
    logw = logw.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    u = p.u.reshape(h, hd)

    s0 = (state.wkv if state is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))
    if use_chunked and s % CHUNK == 0 and s > 1:
        y, s_fin = wkv_chunked(r, k, v, logw, u, s0)
    else:
        y, s_fin = wkv_sequential(r, k, v, logw, u, s0)

    y = y.transpose(0, 2, 1, 3)                          # (B,S,H,hd)
    # Per-head group norm.
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(b, s, d) * (1.0 + p.ln_w)
    out = (y * g) @ p.wo
    new_state = None
    if state is not None:
        new_state = state._replace(tm_shift=x32[:, -1, :], wkv=s_fin)
    return out.astype(x.dtype), new_state


def channel_mix(cfg: ArchConfig, p: ChannelMixParams, x,
                state: RwkvState | None):
    b, s, d = x.shape
    x32 = x.astype(jnp.float32)
    prev = state.cm_shift if state is not None else jnp.zeros((b, d))
    xp = _token_shift(x32, prev.astype(jnp.float32))
    xk = x32 + (xp - x32) * p.mix_k
    xr = x32 + (xp - x32) * p.mix_r
    h = jnp.square(jax.nn.relu(xk @ p.wk)) @ p.wv
    out = jax.nn.sigmoid(xr @ p.wr) * h
    new_state = None
    if state is not None:
        new_state = state._replace(cm_shift=x32[:, -1, :])
    return out.astype(x.dtype), new_state
