"""Grouped-query attention with the assigned archs' feature set:

  causal masking, sliding-window (local) layers, gemma2 logit soft-capping,
  qwen3 per-head qk-RMSNorm, qwen2-vl M-RoPE, MQA (kv=1) for recurrentgemma,
  and a KV-cache decode path.

The XLA path below is the dry-run/roofline path; ``repro.kernels.
flash_attention`` provides the Pallas TPU kernel with the same semantics
(validated against this module's math via its ref oracle).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import common


class AttnParams(NamedTuple):
    wq: jax.Array        # (D, H, hd)
    wk: jax.Array        # (D, K, hd)
    wv: jax.Array        # (D, K, hd)
    wo: jax.Array        # (H, hd, D)
    q_norm: jax.Array    # (hd,) or ()   — qwen3 qk-norm
    k_norm: jax.Array    # (hd,) or ()


def init_attn(cfg: ArchConfig, key) -> AttnParams:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    qn = jnp.zeros((hd,), jnp.float32) if cfg.qk_norm else jnp.zeros((0,))
    return AttnParams(
        wq=common.dense_init(k1, (cfg.d_model, cfg.n_heads, hd), in_axis=0),
        wk=common.dense_init(k2, (cfg.d_model, cfg.n_kv_heads, hd), in_axis=0),
        wv=common.dense_init(k3, (cfg.d_model, cfg.n_kv_heads, hd), in_axis=0),
        wo=common.dense_init(k4, (cfg.n_heads, hd, cfg.d_model), in_axis=0),
        q_norm=qn, k_norm=qn,
    )


def quantize_kv(x):
    """int8-quantize (B, S, K, hd) with a per-(B, S, K) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def cache_write(entry, val, pos):
    """Write ``val`` at position ``pos`` into a cache entry.

    entry: either a plain array (bf16 cache) or an (int8, scale) pair."""
    if isinstance(entry, tuple):
        q, s = entry
        vq, vs = quantize_kv(val)
        q = jax.lax.dynamic_update_slice(q, vq, (0, pos, 0, 0))
        s = jax.lax.dynamic_update_slice(s, vs, (0, pos, 0, 0))
        return (q, s)
    return jax.lax.dynamic_update_slice(
        entry, val.astype(entry.dtype), (0, pos, 0, 0))


def cache_read(entry, dt):
    """Dequantize-on-read for int8 caches; plain cast otherwise."""
    if isinstance(entry, tuple):
        q, s = entry
        return (q.astype(jnp.float32) * s).astype(dt)
    return entry.astype(dt)


def _repeat_kv(k, n_rep: int):
    """(B, S, K, hd) -> (B, S, K*n_rep, hd) for grouped-query attention."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, kh, n_rep, hd)
    ).reshape(b, s, kh * n_rep, hd)


def attention_scores(q, k, v, *, causal_offset, window: int = 0,
                     cap: float = 0.0, kv_len_valid=None,
                     rolling: bool = False):
    """Core scaled-dot-product attention in fp32 softmax, GQA-grouped.

    q: (B, Sq, H, hd); k/v: (B, Skv, K, hd) with H = K * G.  The query
    heads are grouped per kv head so the K/V tensors are read ONCE —
    materializing the G-times-repeated cache costs G x the HBM traffic and
    was the dominant cost of the yi-34b decode cell (§Perf iteration).
    ``causal_offset`` = absolute position of q[0] minus position of k[0].
    ``window`` > 0 restricts attention to the last ``window`` keys.
    ``kv_len_valid``: number of valid cache entries (decode).
    ``rolling``: windowed rolling buffer — slot order is not positional,
    every written slot is in the past, so only validity masking applies.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = hd ** -0.5
    qg = q.reshape(b, sq, kh, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = common.softcap(logits, cap)

    skv = k.shape[1]
    k_pos = jnp.arange(skv)[None, :]                     # (1, Skv)
    if rolling:
        mask = jnp.broadcast_to(k_pos < kv_len_valid, (sq, skv))
    else:
        q_pos = jnp.arange(sq)[:, None] + causal_offset  # (Sq, 1)
        mask = k_pos <= q_pos
        if window and window > 0:
            mask = mask & (k_pos > q_pos - window)
        if kv_len_valid is not None:
            mask = mask & (k_pos < kv_len_valid)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, hd)


def attend(cfg: ArchConfig, p: AttnParams, x, positions, *,
           layer_window: int = 0,
           cache_kv: Optional[tuple] = None,
           cache_pos=None,
           kv_valid_len=None,
           rolling: bool = False,
           mrope_positions=None):
    """Full attention sub-layer. Returns (out, new_cache_kv).

    ``cache_kv``: (k_cache, v_cache) each (B, S_max, K, hd) for decode; the
    new token's k/v are written at ``cache_pos`` and attention runs over the
    whole cache with validity masking.  ``rolling``: windowed rolling
    buffer (``cache_pos`` already wrapped; ``kv_valid_len`` = number of
    populated slots).
    """
    dt = common.dtype_of(cfg.compute_dtype)
    x = x.astype(dt)
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq.astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk.astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv.astype(dt))
    # TP over (possibly pad-sharded) query heads: keeps the O(S^2) score
    # and value matmuls partitioned on the model axis even when n_heads
    # doesn't divide it (input shardings can't express that; activation
    # constraints can — see distributed.sharding).
    q = shd.constrain(q, batch_dim=0, head_dim=2)

    if cfg.qk_norm:
        q = common.rms_norm(q, p.q_norm, cfg.norm_eps)
        k = common.rms_norm(k, p.k_norm, cfg.norm_eps)

    if cfg.pos_emb == "rope":
        if cfg.mrope_sections and mrope_positions is not None:
            q = common.apply_mrope(q, mrope_positions, cfg.mrope_sections,
                                   cfg.rope_theta)
            k = common.apply_mrope(k, mrope_positions, cfg.mrope_sections,
                                   cfg.rope_theta)
        else:
            q = common.apply_rope(q, positions, cfg.rope_theta)
            k = common.apply_rope(k, positions, cfg.rope_theta)

    if cache_kv is None:
        out = attention_scores(q, k, v, causal_offset=0,
                               window=layer_window, cap=cfg.attn_softcap)
        new_cache = None
    else:
        k_cache, v_cache = cache_kv
        k_cache = cache_write(k_cache, k, cache_pos)
        v_cache = cache_write(v_cache, v, cache_pos)
        if kv_valid_len is None:
            kv_valid_len = cache_pos + x.shape[1]
        out = attention_scores(
            q, cache_read(k_cache, dt), cache_read(v_cache, dt),
            causal_offset=cache_pos,
            window=layer_window, cap=cfg.attn_softcap,
            kv_len_valid=kv_valid_len, rolling=rolling)
        new_cache = (k_cache, v_cache)

    out = jnp.einsum("bshk,hkd->bsd", out, p.wo.astype(dt))
    return out, new_cache
