"""Paper-reproduction walkthrough: watch Cohmeleon learn, then inspect the
policy it discovered.

Trains the Q-agent on a case-study SoC (SoC6, the computer-vision pipeline
night-vision -> autoencoder -> MLP), prints the per-iteration test curve
(paper Fig. 8), then decodes a few Q-table rows into human-readable rules
and compares them with the paper's manually-tuned Algorithm 1.

Run:  PYTHONPATH=src python examples/soc_rl_demo.py
"""
import numpy as np

from repro.core import qlearn
from repro.core.modes import MODE_NAMES
from repro.core.orchestrator import train_cohmeleon
from repro.core.state import ATTR_NAMES, decode_state
from repro.soc.config import SOCS
from repro.soc.des import SoCSimulator


def main():
    soc = SOCS["SoC6"]
    sim = SoCSimulator(soc, seed=1)
    print(f"training Cohmeleon on {soc.name} "
          f"({soc.n_accs} accelerators, {soc.n_mem_tiles} memory tiles)...")
    policy, hist = train_cohmeleon(sim, iterations=6, seed=0,
                                   eval_each_iteration=True, n_phases=4)
    print("\niteration curve (normalized to fixed non-coherent DMA):")
    for it, t, m in zip(hist.iteration, hist.exec_time, hist.offchip):
        bar = "#" * int(t * 30)
        print(f"  iter {it}: time={t:.2f} mem={m:.2f}  {bar}")

    print("\nlearned rules (most-visited states):")
    visits = np.asarray(policy.qs.visits.sum(axis=1))
    greedy = np.asarray(qlearn.greedy_policy(policy.qs))
    for s_idx in np.argsort(-visits)[:8]:
        if visits[s_idx] == 0:
            break
        attrs = decode_state(int(s_idx))
        desc = ", ".join(f"{n}={v}" for n, v in zip(ATTR_NAMES, attrs))
        print(f"  [{desc}] -> {MODE_NAMES[greedy[s_idx]]} "
              f"({int(visits[s_idx])} visits)")
    print("\n(compare with Algorithm 1: small footprints -> fully-coh, "
          "overflowing aggregate LLC -> non-coh-dma)")


if __name__ == "__main__":
    main()
