"""Paper-reproduction walkthrough: watch Cohmeleon learn, then inspect the
policy it discovered.

Trains the Q-agent on a case-study SoC (SoC6, the computer-vision pipeline
night-vision -> autoencoder -> MLP), prints the per-iteration test curve
(paper Fig. 8), then decodes a few Q-table rows into human-readable rules
and compares them with the paper's manually-tuned Algorithm 1.

Run:  PYTHONPATH=src python examples/soc_rl_demo.py
"""
import numpy as np

from repro.core import qlearn
from repro.core.modes import MODE_NAMES
from repro.core.orchestrator import train_cohmeleon
from repro.core.state import ATTR_NAMES, decode_state
from repro.soc.config import SOCS
from repro.soc.des import SoCSimulator


def main():
    soc = SOCS["SoC6"]
    sim = SoCSimulator(soc, seed=1)
    print(f"training Cohmeleon on {soc.name} "
          f"({soc.n_accs} accelerators, {soc.n_mem_tiles} memory tiles)...")
    policy, hist = train_cohmeleon(sim, iterations=6, seed=0,
                                   eval_each_iteration=True, n_phases=4)
    print("\niteration curve (normalized to fixed non-coherent DMA):")
    for it, t, m in zip(hist.iteration, hist.exec_time, hist.offchip):
        bar = "#" * int(t * 30)
        print(f"  iter {it}: time={t:.2f} mem={m:.2f}  {bar}")

    print("\nlearned rules (most-visited states):")
    visits = np.asarray(policy.qs.visits.sum(axis=1))
    greedy = np.asarray(qlearn.greedy_policy(policy.qs))
    for s_idx in np.argsort(-visits)[:8]:
        if visits[s_idx] == 0:
            break
        attrs = decode_state(int(s_idx))
        desc = ", ".join(f"{n}={v}" for n, v in zip(ATTR_NAMES, attrs))
        print(f"  [{desc}] -> {MODE_NAMES[greedy[s_idx]]} "
              f"({int(visits[s_idx])} visits)")
    print("\n(compare with Algorithm 1: small footprints -> fully-coh, "
          "overflowing aggregate LLC -> non-coh-dma)")

    # ---- one-line table -> MLP swap -------------------------------------
    # Every Policy lowers into the same unified episode; swapping the
    # tabular agent for the function-approximation one (repro.soc.nn) is
    # literally one line.  Distilling the trained table into the network
    # (one-hot embedding, weights = the table) must select the exact same
    # modes — then the MLP can keep training where the table cannot
    # generalize (see benchmarks/fig13_generalize.py).
    import jax

    from repro.core.policies import QPolicy
    from repro.soc import nn as socnn, vecenv as vec
    from repro.soc.apps import make_application

    env = vec.VecEnv(soc, seed=0)
    app = make_application(soc, seed=9, n_phases=2)
    compiled = vec.compile_app(app, soc, seed=11)
    qs = qlearn.freeze(policy.qs)
    tab = QPolicy(qlearn.QConfig())
    tab.qs = qs
    mlp = socnn.MLPQPolicy(socnn.freeze(socnn.mlp_from_qtable(qs.qtable)))
    key = jax.random.PRNGKey(0)
    _, res_t = env.episode_spec(compiled, tab.lower(env, compiled), key=key)
    (_, _), res_m = env.episode_spec(compiled, mlp.lower(env, compiled),
                                     key=key)
    same = bool(np.array_equal(np.asarray(res_t.mode),
                               np.asarray(res_m.mode)))
    print(f"\ndistilled MLP policy ({mlp.name}) selects the table's modes "
          f"on an unseen app: {same}")


if __name__ == "__main__":
    main()
