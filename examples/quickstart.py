"""Quickstart: the three layers of the system in ~60 seconds on CPU.

1. Paper reproduction — train Cohmeleon's Q-learning agent on a simulated
   ESP SoC and compare it with the paper's baseline policies.
2. Framework — train a reduced qwen3-family model for a few steps.
3. Kernels — run the Pallas flash-attention kernel against its oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# --- 1. Cohmeleon on the simulated SoC -----------------------------------
from repro.core.orchestrator import (compare_policies, train_cohmeleon)
from repro.core.policies import ManualPolicy
from repro.soc.apps import make_application
from repro.soc.config import SOC_MOTIV_PAR
from repro.soc.des import SoCSimulator

print("=== 1. Cohmeleon (paper) ===")
sim = SoCSimulator(SOC_MOTIV_PAR)
policy, _ = train_cohmeleon(sim, iterations=2, seed=0, n_phases=4)
app = make_application(sim.soc, seed=99, n_phases=4)
cmp = compare_policies(sim, app, [ManualPolicy(), policy], seed=1)
for name in cmp.policies:
    t, m = cmp.geomean(name)
    print(f"  {name:12s} norm_time={t:.2f} norm_offchip={m:.2f} "
          f"(vs fixed non-coherent DMA)")

# --- 1b. The scale path: many agents in one jitted batched call ----------
from repro.core.orchestrator import train_cohmeleon_batched

print("=== 1b. Cohmeleon, vectorized (soc.vecenv) ===")
res = train_cohmeleon_batched(
    SOC_MOTIV_PAR, iterations=2, seed=0, n_phases=4, n_seeds=2,
    weights=[(0.675, 0.075, 0.25), (0.125, 0.125, 0.75)])
nt, nm = res.evaluate(app, seed=1)
for w, t, m in zip(res.weights, res.per_weight(nt), res.per_weight(nm)):
    print(f"  weights {w.x}/{w.y}/{w.z}: norm_time={t:.2f} "
          f"norm_offchip={m:.2f} ({res.n_seeds} seeds, one vmap call)")

# --- 2. Train a reduced assigned architecture ----------------------------
from repro.configs import smoke_config
from repro.data.synthetic import DataConfig, host_batch
from repro.launch import steps as steps_lib

print("=== 2. LM training (qwen3-8b family, reduced) ===")
cfg = smoke_config("qwen3-8b")
state = steps_lib.make_train_state(cfg, jax.random.PRNGKey(0))
step = jax.jit(steps_lib.make_train_step(cfg), donate_argnums=(0,))
for i in range(5):
    batch = {k: jnp.asarray(v) for k, v in
             host_batch(cfg, DataConfig(64, 8, seed=i), i).items()}
    state, metrics = step(state, batch)
    print(f"  step {i} loss={float(metrics['loss']):.4f}")

# --- 3. Pallas kernel vs oracle ------------------------------------------
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

print("=== 3. Pallas flash attention (interpret mode) ===")
rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
out = flash_attention(q, q, q, causal=True, window=64, block_q=64,
                      block_kv=64)
ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(q, 1, 2),
                    jnp.swapaxes(q, 1, 2), causal=True, window=64)
err = float(jnp.max(jnp.abs(out - jnp.swapaxes(ref, 1, 2))))
print(f"  max |kernel - oracle| = {err:.2e}")
print("quickstart OK")
