"""Beyond-paper demo: Cohmeleon's Q-learning orchestrating TRAIN-STEP
memory modes (remat policy / microbatching) at runtime.

The orchestrator senses (batch, seq, live-memory headroom), picks one of
four precompiled step variants per invocation, and learns from measured
wall time + a traffic proxy with the paper's multi-objective reward.  On
CPU the fastest mode is remat_none (no recompute); the demo verifies the
agent converges to it while keeping decision overhead microscopic —
the paper's "negligible overhead / no prior knowledge" claims, transposed.

Run:  PYTHONPATH=src python examples/autotune_train.py
"""
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.configs.shapes import ShapeSpec
from repro.core.autotune import MODES, MemoryModeOrchestrator
from repro.data.synthetic import DataConfig, host_batch
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh


def main():
    cfg = smoke_config("qwen3-8b")
    spec = ShapeSpec("demo", "train", 128, 8)
    mesh = make_host_mesh(1, 1)
    orch = MemoryModeOrchestrator(cfg, spec, mesh, seed=0, total_steps=60)
    state = steps_lib.make_train_state(cfg, jax.random.PRNGKey(0))

    for step in range(60):
        batch = {k: jnp.asarray(v) for k, v in
                 host_batch(cfg, DataConfig(128, 8, seed=step), step).items()}
        state, metrics = orch.step(state, batch)
        if (step + 1) % 20 == 0:
            print(f"step {step + 1}: loss={float(metrics['loss']):.4f} "
                  f"decisions so far: {orch.decision_counts()}")

    counts = orch.decision_counts()
    best = max(counts, key=counts.get)
    print(f"\nconverged mode: {best} "
          f"({counts[best]}/{sum(counts.values())} invocations)")
    print(f"decision overhead: {orch.decide_overhead_s() * 1e6:.0f} us/step "
          f"(paper: 'negligible overhead')")
    assert best == "remat_none", counts   # fastest on CPU: no recompute
    print("autotune demo OK")


if __name__ == "__main__":
    main()
