"""Serving example: batched requests through prefill + decode.

Serves three reduced assigned architectures — a dense transformer, an
attention-free SSM, and the RG-LRU hybrid — with batched greedy decoding,
and prints latency/throughput per family (the state-size contrast is the
point: rwkv/recurrentgemma state is O(1) in sequence length).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.configs import smoke_config
from repro.launch.serve import serve
from repro.models import transformer


def main():
    for arch in ("qwen3-8b", "rwkv6-3b", "recurrentgemma-9b"):
        cfg = smoke_config(arch)
        out = serve(cfg, batch=4, prompt_len=64, gen=24)
        print(f"{arch:24s} prefill={out['prefill_s'] * 1e3:7.1f}ms "
              f"decode={out['decode_s'] * 1e3:7.1f}ms "
              f"({out['decode_tok_per_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
