"""End-to-end training example: ~100M-param model for a few hundred steps.

Builds a ~100M-parameter dense model (qwen3 family scaled down), trains it
on the synthetic pipeline with checkpointing and gradient compression, and
verifies the loss drops.  On CPU this takes a few minutes; pass --steps to
shorten.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.configs import get_arch
from repro.launch import train as train_cli


def config_100m():
    return get_arch("qwen3-8b").replace(
        name="qwen3-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab=8192, param_dtype="float32",
        compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = config_100m()
    n = cfg.param_count()
    print(f"training {cfg.name}: {n / 1e6:.0f}M params")
    # Reuse the production training driver with this config injected.
    import repro.configs as configs
    configs.ARCHS[cfg.name] = cfg
    losses = train_cli.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", "16", "--seq", "256",
        "--ckpt-dir", "/tmp/repro-train-lm-ckpt", "--ckpt-every", "100",
        "--log-every", "25",
    ])
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
